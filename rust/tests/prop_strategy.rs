//! Property tests: the mix-rule laws every zoo member must satisfy
//! (docs/algorithms.md). The load-bearing one is the consensus fixed
//! point — once the neighborhood agrees, no strategy's mix may move it
//! — because Alg. 2's convergence argument (and the `dasgd compare`
//! comparability claim) rests on projections contracting *toward*
//! consensus, never through it.

use dasgd::node_logic::{Strategy, StrategyKind};
use dasgd::util::proptest::{check, Gen};

fn close(a: f32, b: f32) -> bool {
    (a - b).abs() <= 1e-4 * b.abs().max(1.0)
}

fn encode_f32s(v: &[f32]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

fn decode_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

fn arb_uniform_neighborhood(g: &mut Gen) -> (usize, Vec<f32>, Vec<Vec<f32>>) {
    let dim = g.usize_in(1, 48);
    let m = g.usize_in(1, 8);
    let v = g.f32_vec(dim, -1e3, 1e3);
    (dim, v.clone(), vec![v; m])
}

#[test]
fn every_strategy_mix_preserves_the_uniform_fixed_point() {
    check("strategy-uniform-fixed-point", 300, 0x57AB, |g| {
        let (dim, v, rows_store) = arb_uniform_neighborhood(g);
        let rows: Vec<&[f32]> = rows_store.iter().map(|r| r.as_slice()).collect();
        // Uniform aux state too: either every member empty (a baseline
        // neighborhood) or every member carrying the same tracker blob.
        let tracker: Option<Vec<f32>> = if g.bool() {
            Some(g.f32_vec(dim, -10.0, 10.0))
        } else {
            None
        };
        let aux_store: Vec<Vec<u8>> = match &tracker {
            Some(t) => vec![encode_f32s(t); rows.len()],
            None => vec![Vec::new(); rows.len()],
        };
        let aux_rows: Vec<&[u8]> = aux_store.iter().map(|a| a.as_slice()).collect();
        for kind in StrategyKind::ALL {
            let mut strat = kind.build(0.1);
            let (w, aux) = strat.mix(&rows, &aux_rows);
            if w.len() != dim {
                return Err(format!("{kind}: mix changed the dimension to {}", w.len()));
            }
            for (j, (&a, &b)) in w.iter().zip(&v).enumerate() {
                if !close(a, b) {
                    return Err(format!(
                        "{kind}: mix moved uniform params at coord {j}: {a} vs {b}"
                    ));
                }
            }
            if tracker.is_none() && !aux.is_empty() {
                return Err(format!(
                    "{kind}: an all-empty aux neighborhood must mix to an empty blob, got {} bytes",
                    aux.len()
                ));
            }
            if let (Some(t), StrategyKind::Rfast) = (&tracker, kind) {
                // The gossiped tracker has the same fixed point.
                let y = decode_f32s(&aux);
                if y.len() != dim {
                    return Err(format!("rfast: tracker blob came back {} long", y.len()));
                }
                for (j, (&a, &b)) in y.iter().zip(t).enumerate() {
                    if !close(a, b) {
                        return Err(format!(
                            "rfast: mix moved a uniform tracker at coord {j}: {a} vs {b}"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn every_strategy_mix_stays_inside_the_neighborhood_hull() {
    // The contraction direction: each output coordinate lies within the
    // participants' min/max for that coordinate (the projection may
    // never extrapolate past the neighborhood).
    check("strategy-mix-hull", 300, 0x401D, |g| {
        let dim = g.usize_in(1, 32);
        let m = g.usize_in(1, 6);
        let rows_store: Vec<Vec<f32>> =
            (0..m).map(|_| g.f32_vec(dim, -1e3, 1e3)).collect();
        let rows: Vec<&[f32]> = rows_store.iter().map(|r| r.as_slice()).collect();
        let aux_store: Vec<Vec<u8>> = vec![Vec::new(); m];
        let aux_rows: Vec<&[u8]> = aux_store.iter().map(|a| a.as_slice()).collect();
        for kind in StrategyKind::ALL {
            let mut strat = kind.build(0.1);
            let (w, _) = strat.mix(&rows, &aux_rows);
            for j in 0..dim {
                let lo = rows_store.iter().map(|r| r[j]).fold(f32::INFINITY, f32::min);
                let hi = rows_store
                    .iter()
                    .map(|r| r[j])
                    .fold(f32::NEG_INFINITY, f32::max);
                let slack = 1e-4 * hi.abs().max(lo.abs()).max(1.0);
                if w[j] < lo - slack || w[j] > hi + slack {
                    return Err(format!(
                        "{kind}: coord {j} mixed to {} outside [{lo}, {hi}]",
                        w[j]
                    ));
                }
            }
        }
        Ok(())
    });
}
