//! Property tests for the observability layer: histogram snapshots
//! merge like a commutative monoid with nothing lost or invented, the
//! log2 bucketing is total and monotone, and the trace ring always
//! retains exactly the newest events in order.

use dasgd::obs::{bucket_index, HistSnapshot, MetricsSnapshot, TraceEvent, TraceRing, HIST_BUCKETS};
use dasgd::util::proptest::{check, Gen};

/// A histogram snapshot with a random (possibly empty) set of samples.
/// `sum`/`count`/`buckets` are kept mutually consistent the same way
/// `Histogram::record` keeps them, so conservation laws are checkable.
fn arb_hist(g: &mut Gen) -> HistSnapshot {
    let mut h = HistSnapshot::ZERO;
    for _ in 0..g.usize_in(0, 64) {
        let v = g.usize_in(0, 1 << 40) as u64;
        h.buckets[bucket_index(v)] += 1;
        h.count += 1;
        h.sum += v;
    }
    h
}

#[test]
fn hist_merge_is_commutative_and_associative_and_conserves_mass() {
    check("obs-hist-merge", 300, 0x0B51, |g| {
        let a = arb_hist(g);
        let b = arb_hist(g);
        let c = arb_hist(g);

        let mut ab = a;
        ab.merge(&b);
        let mut ba = b;
        ba.merge(&a);
        if ab != ba {
            return Err("merge is not commutative".into());
        }

        let mut ab_c = ab;
        ab_c.merge(&c);
        let mut bc = b;
        bc.merge(&c);
        let mut a_bc = a;
        a_bc.merge(&bc);
        if ab_c != a_bc {
            return Err("merge is not associative".into());
        }

        // Conservation: total count and sum add exactly, and the count
        // equals the bucket mass (no sample leaves its bucket).
        if ab_c.count != a.count + b.count + c.count {
            return Err(format!(
                "count not conserved: {} != {}",
                ab_c.count,
                a.count + b.count + c.count
            ));
        }
        if ab_c.sum != a.sum + b.sum + c.sum {
            return Err("sum not conserved".into());
        }
        let mass: u64 = ab_c.buckets.iter().sum();
        if mass != ab_c.count {
            return Err(format!("bucket mass {} != count {}", mass, ab_c.count));
        }
        // Merging the empty snapshot is the identity.
        let mut a_zero = a;
        a_zero.merge(&HistSnapshot::ZERO);
        if a_zero != a {
            return Err("ZERO is not a merge identity".into());
        }
        Ok(())
    });
}

#[test]
fn snapshot_merge_matches_componentwise_laws() {
    check("obs-snapshot-merge", 200, 0x0B52, |g| {
        let mut a = MetricsSnapshot::ZERO;
        let mut b = MetricsSnapshot::ZERO;
        for s in [&mut a, &mut b] {
            for ctr in s.counters.iter_mut() {
                *ctr = g.usize_in(0, 1 << 30) as u64;
            }
            for gv in s.gauges.iter_mut() {
                *gv = g.usize_in(0, 1 << 30) as u64;
            }
            for h in s.hists.iter_mut() {
                *h = arb_hist(g);
            }
        }
        let mut ab = a;
        ab.merge_from(&b);
        let mut ba = b;
        ba.merge_from(&a);
        if ab != ba {
            return Err("snapshot merge is not commutative".into());
        }
        for ((&m, &x), &y) in ab.counters.iter().zip(a.counters.iter()).zip(b.counters.iter()) {
            if m != x + y {
                return Err("counters must sum across processes".into());
            }
        }
        for ((&m, &x), &y) in ab.gauges.iter().zip(a.gauges.iter()).zip(b.gauges.iter()) {
            if m != x.max(y) {
                return Err("gauges must take the cluster max".into());
            }
        }
        Ok(())
    });
}

#[test]
fn bucket_index_is_total_and_monotone() {
    check("obs-bucket-index", 300, 0x0B53, |g| {
        let v = g.usize_in(0, usize::MAX / 2) as u64;
        let i = bucket_index(v);
        if i >= HIST_BUCKETS {
            return Err(format!("bucket_index({v}) = {i} out of range"));
        }
        // Monotone: a larger value never lands in a smaller bucket.
        let w = v.saturating_add(g.usize_in(0, 1 << 20) as u64);
        if bucket_index(w) < i {
            return Err(format!("bucket_index not monotone at {v} -> {w}"));
        }
        // The quantile of a single-sample histogram brackets the sample.
        let mut h = HistSnapshot::ZERO;
        h.buckets[i] += 1;
        h.count = 1;
        h.sum = v;
        let q = h.quantile(0.5);
        if q < v as f64 {
            return Err(format!("quantile {q} below its only sample {v}"));
        }
        Ok(())
    });
}

#[test]
fn trace_ring_wraparound_keeps_the_newest_events_in_order() {
    check("obs-trace-ring", 300, 0x0B54, |g| {
        let cap = g.usize_in(1, 64);
        let pushed = g.usize_in(0, 4 * cap);
        let mut ring = TraceRing::new(cap);
        for i in 0..pushed {
            ring.push(TraceEvent {
                seq: 0, // assigned by the ring
                t_us: i as u64,
                component: "test",
                event: "tick",
                node: (i % 7) as u64,
                detail: i as u64,
            });
        }
        let events = ring.events();
        let want = pushed.min(cap);
        if events.len() != want {
            return Err(format!("kept {} events, want {}", events.len(), want));
        }
        if ring.len() != want || ring.is_empty() != (want == 0) {
            return Err("len/is_empty disagree with events()".into());
        }
        // The retained window is exactly the newest `want` pushes, in
        // push order, with the sequence the ring assigned.
        for (j, e) in events.iter().enumerate() {
            let orig = pushed - want + j;
            if e.seq != orig as u64 || e.detail != orig as u64 {
                return Err(format!(
                    "slot {j}: seq {} detail {} — oldest events displaced the newest",
                    e.seq, e.detail
                ));
            }
        }
        Ok(())
    });
}
