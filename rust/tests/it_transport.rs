//! Integration: one `NodeLogic` over interchangeable transports.
//!
//! * SimNet determinism — same seed ⇒ identical `Recorder` trace, even
//!   with latency jitter and message drops in play.
//! * Cross-engine consensus — the wall-clock shared-memory runtime and
//!   the virtual-time SimNet driver reach consensus to the same
//!   tolerance on a fixed ring topology.
//! * Scale — thousands of nodes on a 3-regular graph with nonzero
//!   latency + 1% drop complete quickly and show the consensus residual
//!   falling from its peak (the 10k-node quickstart is
//!   `examples/simnet_scale.rs`).

use dasgd::coordinator::{consensus, AsyncCluster, AsyncConfig, StepSize};
use dasgd::experiments::synth_world;
use dasgd::graph::regular_circulant;
use dasgd::objective::Objective;
use dasgd::sim::{simnet_run, SimConfig, SpeedModel};
use dasgd::transport::{LatencyModel, SimNetConfig};

fn sim_cfg(horizon: f64, seed: u64, drop_prob: f64) -> SimConfig {
    SimConfig {
        p_grad: 0.5,
        stepsize: StepSize::Poly {
            a: 10.0,
            tau: 4000.0,
            pow: 0.75,
        },
        objective: Objective::LogReg,
        horizon,
        eval_every: horizon / 5.0,
        net: SimNetConfig {
            latency: LatencyModel {
                min_secs: 0.002,
                max_secs: 0.01,
                jitter_secs: 0.002,
            },
            drop_prob,
            partitions: vec![],
            seed,
        },
        seed,
    }
}

#[test]
fn simnet_trace_is_deterministic_given_seed() {
    let n = 8;
    let (shards, test) = synth_world(n, 40, 256, 51);
    let g = regular_circulant(n, 2); // fixed ring
    let speeds = SpeedModel::homogeneous(n, 1.0);
    let cfg = sim_cfg(120.0, 7, 0.02);
    let a = simnet_run(&g, &shards, &test, &speeds, &cfg);
    let b = simnet_run(&g, &shards, &test, &speeds, &cfg);
    assert_eq!(a.updates, b.updates);
    assert_eq!(a.messages, b.messages);
    assert_eq!(a.drops, b.drops);
    // The full Recorder trace is bit-identical, record by record.
    assert_eq!(a.recorder.records.len(), b.recorder.records.len());
    for (ra, rb) in a.recorder.records.iter().zip(&b.recorder.records) {
        assert_eq!(ra, rb);
    }
    assert_eq!(a.final_params, b.final_params);
}

#[test]
fn shared_mem_and_simnet_reach_consensus_to_same_tolerance() {
    // Fixed ring, same world: run the wall-clock shared-memory engine
    // and the virtual-time SimNet driver to comparable update budgets;
    // both must land inside the same consensus tolerance.
    const TOL: f64 = 5.0;
    let n = 8;
    let (shards, test) = synth_world(n, 60, 256, 77);
    let g = regular_circulant(n, 2);

    let cluster = AsyncCluster::new(g.clone(), shards.clone());
    let wall_cfg = AsyncConfig {
        duration_secs: 1.5,
        rate_hz: 400.0,
        ..AsyncConfig::quick(n)
    };
    let wall = cluster.run(&wall_cfg, &test).unwrap();
    let d_wall = consensus::consensus_distance(&wall.final_params);

    let speeds = SpeedModel::homogeneous(n, 1.0);
    let mut cfg = sim_cfg(400.0, 77, 0.0);
    cfg.stepsize = StepSize::paper_default(n);
    let sim = simnet_run(&g, &shards, &test, &speeds, &cfg);
    let d_sim = consensus::consensus_distance(&sim.final_params);

    assert!(wall.updates > 200, "wall updates={}", wall.updates);
    assert!(sim.updates > 200, "sim updates={}", sim.updates);
    assert!(d_wall < TOL, "shared-mem consensus {d_wall} ≥ {TOL}");
    assert!(d_sim < TOL, "simnet consensus {d_sim} ≥ {TOL}");
    // And both actually learned something on the shared test set.
    assert!(wall.recorder.last().unwrap().test_err < 0.7);
    assert!(sim.recorder.last().unwrap().test_err < 0.7);
}

#[test]
fn thousands_of_nodes_with_latency_and_drops_run_in_seconds() {
    // The scale path: 3-regular graph, nonzero per-edge latency, 1%
    // drop, incremental snapshots. (Debug-mode CI budget keeps this at
    // 2k nodes; the 10k quickstart example is the release-mode run.)
    let n = 2000;
    let per_node = 10;
    let (shards, test) = synth_world(n, per_node, 256, 3);
    let g = regular_circulant(n, 3);
    let speeds = SpeedModel::homogeneous(n, 1.0);
    let mut cfg = sim_cfg(6.0, 3, 0.01);
    cfg.stepsize = Objective::LogReg.default_stepsize(n);
    let wall = std::time::Instant::now();
    let rep = simnet_run(&g, &shards, &test, &speeds, &cfg);
    let elapsed = wall.elapsed().as_secs_f64();
    assert!(
        elapsed < 60.0,
        "2k-node sim took {elapsed:.1}s — the driver must stay event-cheap"
    );
    assert!(rep.updates > n as u64, "updates={}", rep.updates);
    assert!(rep.drops > 0, "expected dropped legs at 1%");
    // Consensus residual falls from its peak: gossip wins at scale.
    let peak = rep
        .recorder
        .records
        .iter()
        .map(|r| r.consensus)
        .fold(0.0f64, f64::max);
    let last = rep.recorder.last().unwrap().consensus;
    assert!(peak > 0.0);
    assert!(
        last < peak,
        "consensus residual should fall from its peak: peak={peak} last={last}"
    );
}

#[test]
fn killed_nodes_do_not_block_channel_projections() {
    // Channel transport under fault injection: the protocol's timeouts
    // must keep the survivors making progress.
    let n = 6;
    let (shards, test) = synth_world(n, 40, 256, 13);
    let cluster = AsyncCluster::new(regular_circulant(n, 2), shards);
    let cfg = AsyncConfig {
        duration_secs: 1.5,
        rate_hz: 300.0,
        kill_after_secs: Some(0.5),
        kill_nodes: 1,
        transport: dasgd::transport::TransportKind::Channel,
        ..AsyncConfig::quick(n)
    };
    let rep = cluster.run(&cfg, &test).unwrap();
    assert_eq!(rep.killed, 1);
    assert!(rep.updates > 20, "updates={}", rep.updates);
    assert!(rep
        .final_params
        .iter()
        .all(|w| w.iter().all(|v| v.is_finite())));
}
