//! Integration: the baseline algorithms and the virtual-time straggler
//! comparison — Alg. 2's positioning claims, measured.

use dasgd::baselines::{
    local_only_errors, server_worker, sync_dsgd, CentralizedSgd, ServerWorkerConfig,
    SyncDsgdConfig,
};
use dasgd::coordinator::{NativeBackend, StepSize, TrainConfig, Trainer};
use dasgd::data::Dataset;
use dasgd::experiments::{make_regular, synth_world};
use dasgd::sim::{virtual_async_run, SpeedModel, VirtualAsyncConfig};

fn world(n: usize, seed: u64) -> (Vec<Dataset>, Dataset) {
    synth_world(n, 150, 400, seed)
}

#[test]
fn alg2_approaches_centralized_accuracy() {
    // The §V-E claim on the synthetic corpus: decentralized ≈ centralized.
    let n = 10;
    let (shards, test) = world(n, 51);

    let mut pool = Dataset::new(50, 10);
    for s in &shards {
        pool.extend(s);
    }
    let mut central = CentralizedSgd::new(50, 10, StepSize::paper_default(1), 1);
    let crec = central.run(&pool, &test, 6000, 6000);

    let cfg = TrainConfig::paper_default(n).with_seed(51);
    let mut t = Trainer::new(
        cfg,
        make_regular(n, 4),
        shards,
        NativeBackend::new(50, 10),
    );
    let arec = t.run(6000, 6000, &test, "alg2").unwrap();

    let gap = arec.final_err() - crec.final_err();
    assert!(
        gap < 0.12,
        "alg2 err {} vs centralized {}",
        arec.final_err(),
        crec.final_err()
    );
}

#[test]
fn alg2_beats_local_only_under_skew() {
    let n = 10;
    let (shards, test) = world(n, 53);
    let (avg_err, per_node_err) =
        local_only_errors(&shards, &test, StepSize::paper_default(1), 600, 3);

    let cfg = TrainConfig::paper_default(n).with_seed(53);
    let mut t = Trainer::new(
        cfg,
        make_regular(n, 4),
        shards,
        NativeBackend::new(50, 10),
    );
    let rec = t.run(6000, 6000, &test, "alg2").unwrap();

    // Consensus training beats the mean isolated node on the mixture.
    assert!(
        rec.final_err() < per_node_err,
        "alg2 {} vs per-node {per_node_err} (avg-of-locals {avg_err})",
        rec.final_err()
    );
}

#[test]
fn sync_dsgd_and_server_worker_converge() {
    let n = 8;
    let (shards, test) = world(n, 57);
    let rep = sync_dsgd(
        &make_regular(n, 4),
        &shards,
        &test,
        &SyncDsgdConfig {
            stepsize: StepSize::Poly {
                a: 8.0,
                tau: 3000.0,
                pow: 0.75,
            },
            objective: dasgd::objective::Objective::LogReg,
            rounds: 500,
            eval_every: 250,
            seed: 5,
        },
    );
    assert!(rep.recorder.final_err() < 0.5);

    let rep = server_worker(
        &shards,
        &test,
        &ServerWorkerConfig {
            stepsize: StepSize::Poly {
                a: 1.0,
                tau: 2000.0,
                pow: 0.75,
            },
            objective: dasgd::objective::Objective::LogReg,
            rounds: 400,
            eval_every: 200,
            drop_frac: 0.25,
            worker_speed: vec![],
            seed: 5,
        },
    );
    assert!(rep.recorder.final_err() < 0.5);
}

#[test]
fn virtual_time_async_beats_sync_under_stragglers() {
    // The intro's claim, quantified: same virtual horizon, one 20x
    // straggler; async completes far more updates than sync rounds
    // would allow.
    let n = 8;
    let (shards, test) = world(n, 59);
    let g = make_regular(n, 4);
    let speeds = SpeedModel::with_stragglers(n, 1.0, 1, 20.0);
    let horizon = 150.0;

    let cfg = VirtualAsyncConfig {
        p_grad: 0.5,
        stepsize: StepSize::paper_default(n),
        objective: dasgd::objective::Objective::LogReg,
        horizon,
        eval_every: horizon,
        comm_latency: 0.05,
        seed: 7,
    };
    let async_rep = virtual_async_run(&g, &shards, &test, &speeds, &cfg);

    // Sync DSGD round = slowest node ≈ 20s ⇒ ~7 rounds in 150s, i.e.
    // ~7·n updates. Async should complete ≥ 3x more.
    let mut rng = dasgd::util::rng::Xoshiro256pp::seeded(9);
    let mut vt = 0.0;
    let mut rounds = 0u64;
    while vt < horizon {
        vt += dasgd::sim::sync_round_time(&speeds.sample_all(&mut rng), 0.05);
        rounds += 1;
    }
    let sync_updates = rounds * n as u64;
    assert!(
        async_rep.updates > sync_updates * 3,
        "async {} vs sync-equivalent {}",
        async_rep.updates,
        sync_updates
    );
}
