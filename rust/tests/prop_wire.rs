//! Property tests for the SocketNet wire codec: arbitrary messages
//! round-trip exactly (single-frame and chunked), arbitrary bytes —
//! garbage, bit flips, truncations — decode to a clean error or "need
//! more", never a panic and never a huge allocation, and the chunk
//! envelope rejects interleaved/short/corrupt streams totally.

use dasgd::net::wire::{
    self, decode, encode, encode_message, fnv1a64, read_frame, ChunkAssembler, WireError, WireMsg,
    MAX_FRAME_LEN,
};
use dasgd::net::{assignment_from_msg, plan_assign_msg};
use dasgd::objective::Objective;
use dasgd::util::proptest::{check, Gen};
use dasgd::workload::{PlanSpec, WorkloadPlan};

/// One arbitrary message (finite payloads so `PartialEq` is exact;
/// NaN bit-pattern survival is pinned by the unit tests in `wire.rs`).
fn arb_msg(g: &mut Gen) -> WireMsg {
    let w_len = g.usize_in(0, g.size * 64);
    match g.usize_in(0, 27) {
        0 => WireMsg::Hello {
            rank: g.usize_in(0, 1 << 20) as u32,
        },
        1 => WireMsg::Heartbeat {
            rank: g.usize_in(0, 64) as u32,
            seq: g.usize_in(0, usize::MAX / 2) as u64,
        },
        2 => WireMsg::CollectRequest {
            from: g.usize_in(0, 10_000) as u32,
            to: g.usize_in(0, 10_000) as u32,
            token: g.usize_in(0, usize::MAX / 2) as u64,
        },
        3 => WireMsg::CollectReply {
            from: g.usize_in(0, 10_000) as u32,
            to: g.usize_in(0, 10_000) as u32,
            token: g.usize_in(0, usize::MAX / 2) as u64,
            w: g.f32_vec(w_len, -1e6, 1e6),
            aux: (0..g.usize_in(0, 128))
                .map(|_| g.usize_in(0, 255) as u8)
                .collect(),
        },
        4 => WireMsg::Busy {
            from: g.usize_in(0, 10_000) as u32,
            to: g.usize_in(0, 10_000) as u32,
            token: g.usize_in(0, usize::MAX / 2) as u64,
        },
        5 => WireMsg::Abort {
            from: g.usize_in(0, 10_000) as u32,
            to: g.usize_in(0, 10_000) as u32,
            token: g.usize_in(0, usize::MAX / 2) as u64,
        },
        6 => WireMsg::ApplyAverage {
            from: g.usize_in(0, 10_000) as u32,
            to: g.usize_in(0, 10_000) as u32,
            token: g.usize_in(0, usize::MAX / 2) as u64,
            w: g.f32_vec(w_len, -1e6, 1e6),
            aux: (0..g.usize_in(0, 128))
                .map(|_| g.usize_in(0, 255) as u8)
                .collect(),
        },
        7 => WireMsg::SnapshotRequest,
        8 => {
            let shard = g.usize_in(0, 8);
            WireMsg::SnapshotReply {
                rank: g.usize_in(0, 64) as u32,
                counts: [
                    g.usize_in(0, 1 << 30) as u64,
                    g.usize_in(0, 1 << 30) as u64,
                    g.usize_in(0, 1 << 30) as u64,
                    g.usize_in(0, 1 << 30) as u64,
                ],
                params: (0..shard)
                    .map(|i| {
                        let len = g.usize_in(0, 64);
                        (i as u32, g.f32_vec(len, -100.0, 100.0))
                    })
                    .collect(),
                staging_bytes: g.usize_in(0, 1 << 30) as u64,
                stream_done: g.bool(),
                updates_at_stream_complete: if g.bool() {
                    u64::MAX
                } else {
                    g.usize_in(0, 1 << 30) as u64
                },
            }
        }
        9 => WireMsg::Shutdown,
        10 => {
            let dim = g.usize_in(1, 8);
            let rows = g.usize_in(0, g.size * 8);
            WireMsg::PlanAssign {
                node: g.usize_in(0, 10_000) as u32,
                obj_code: g.usize_in(0, 3) as u8,
                lam: g.f32_vec(1, 0.0, 1.0)[0],
                dim: dim as u32,
                classes: g.usize_in(1, 12) as u32,
                labels: (0..rows).map(|_| g.usize_in(0, 11) as u32).collect(),
                features: g.f32_vec(rows * dim, -100.0, 100.0),
                // Any byte round-trips; validation is the decoder
                // helper's job, not the codec's.
                strategy: g.usize_in(0, 255) as u8,
            }
        }
        11 => WireMsg::PlanStart {
            nodes: g.usize_in(0, 100_000) as u32,
            assigned: g.usize_in(0, 100_000) as u32,
            mixed: g.bool(),
            checksum: g.usize_in(0, usize::MAX / 2) as u64,
            streaming: g.bool(),
        },
        12 => WireMsg::ChunkBegin {
            total_bytes: g.usize_in(0, 1 << 28) as u64,
            chunk_count: g.usize_in(0, 1 << 10) as u32,
        },
        13 => WireMsg::ChunkData {
            bytes: (0..g.usize_in(0, 256)).map(|_| g.usize_in(0, 255) as u8).collect(),
        },
        14 => WireMsg::ChunkEnd {
            checksum: g.usize_in(0, usize::MAX / 2) as u64,
        },
        15 => {
            let dim = g.usize_in(1, 8);
            let rows = g.usize_in(0, g.size * 8);
            WireMsg::ShardBlock {
                node: g.usize_in(0, 10_000) as u32,
                seq: g.usize_in(0, 1 << 20) as u32,
                encoding: g.usize_in(0, 255) as u8,
                rows: rows as u32,
                dim: dim as u32,
                classes: g.usize_in(1, 12) as u32,
                labels: (0..rows).map(|_| g.usize_in(0, 11) as u32).collect(),
                features: g.f32_vec(rows * dim, -100.0, 100.0),
                checksum: g.usize_in(0, usize::MAX / 2) as u64,
            }
        }
        16 => WireMsg::ShardComplete {
            node: g.usize_in(0, 10_000) as u32,
            block_count: g.usize_in(0, 1 << 20) as u32,
            total_rows: g.usize_in(0, 1 << 30) as u64,
            checksum: g.usize_in(0, usize::MAX / 2) as u64,
        },
        17 => WireMsg::ShardCredit {
            bytes: g.usize_in(0, 1 << 30) as u64,
        },
        18 => WireMsg::MetricsRequest,
        19 => WireMsg::MetricsReply {
            rank: g.usize_in(0, 64) as u32,
            counters: (0..g.usize_in(0, 16))
                .map(|_| g.usize_in(0, 1 << 30) as u64)
                .collect(),
            hist_data: (0..g.usize_in(0, 5 * 66))
                .map(|_| g.usize_in(0, 1 << 30) as u64)
                .collect(),
        },
        20 => WireMsg::JoinRequest,
        21 => WireMsg::JoinGrant {
            rank: g.usize_in(0, 64) as u32,
            nodes: g.usize_in(1, 100_000) as u32,
            degree: g.usize_in(1, 32) as u32,
            param_len: g.usize_in(1, 1 << 20) as u32,
            seed: g.usize_in(0, usize::MAX / 2) as u64,
            secs: g.f64_in(0.0, 1e4),
            rate_hz: g.f64_in(0.0, 1e4),
            obj_code: g.usize_in(0, 3) as u8,
            lam: g.f32_vec(1, 0.0, 1.0)[0],
            staging_mb: g.usize_in(1, 4096) as u32,
            executors: g.usize_in(0, 64) as u32,
            flush_bytes: g.usize_in(0, 1 << 20) as u32,
            flush_micros: g.usize_in(0, 1 << 20) as u64,
            strategy: g.usize_in(0, 255) as u8,
            peers: (0..g.usize_in(0, 8))
                .map(|i| format!("127.0.0.1:{}", 1024 + i))
                .collect(),
        },
        22 => WireMsg::JoinReady {
            rank: g.usize_in(0, 64) as u32,
            addr: format!("127.0.0.1:{}", g.usize_in(1024, 65535)),
        },
        23 => WireMsg::PeerUpdate {
            rank: g.usize_in(0, 64) as u32,
            addr: format!("127.0.0.1:{}", g.usize_in(1024, 65535)),
        },
        24 => WireMsg::LeaveNotice {
            rank: g.usize_in(0, 64) as u32,
        },
        25 => WireMsg::TopologyPatch {
            version: g.usize_in(0, usize::MAX / 2) as u64,
            entries: (0..g.usize_in(0, 16))
                .map(|_| {
                    let node = g.usize_in(0, 10_000) as u32;
                    let hood = (0..g.usize_in(0, 8))
                        .map(|_| g.usize_in(0, 10_000) as u32)
                        .collect();
                    (node, hood)
                })
                .collect(),
        },
        26 => WireMsg::HandoffBegin {
            node: g.usize_in(0, 10_000) as u32,
            w: g.f32_vec(w_len, -1e6, 1e6),
        },
        _ => WireMsg::HandoffEnd {
            node: g.usize_in(0, 10_000) as u32,
            checksum: g.usize_in(0, usize::MAX / 2) as u64,
        },
    }
}

#[test]
fn arbitrary_messages_round_trip() {
    check("wire-roundtrip", 300, 0xC0DEC, |g| {
        let msg = arb_msg(g);
        let frame = encode(&msg).map_err(|e| format!("encode failed: {e}"))?;
        let (back, consumed) = decode(&frame)
            .map_err(|e| format!("decode of own encoding failed: {e}"))?
            .ok_or("own encoding reported incomplete")?;
        if consumed != frame.len() {
            return Err(format!("consumed {consumed} of {} bytes", frame.len()));
        }
        if back != msg {
            return Err(format!("round trip changed the message: {msg:?} → {back:?}"));
        }
        // The blocking stream reader agrees with the buffer decoder.
        let mut cursor = std::io::Cursor::new(&frame);
        match read_frame(&mut cursor) {
            Ok(m) if m == msg => Ok(()),
            Ok(m) => Err(format!("stream read disagreed: {m:?}")),
            Err(e) => Err(format!("stream read failed: {e}")),
        }
    });
}

#[test]
fn truncated_frames_ask_for_more_never_panic() {
    check("wire-truncation", 200, 0x7A11, |g| {
        let msg = arb_msg(g);
        let frame = encode(&msg).map_err(|e| format!("encode failed: {e}"))?;
        let cut = g.usize_in(0, frame.len().saturating_sub(1));
        match decode(&frame[..cut]) {
            Ok(None) => Ok(()),
            Ok(Some(_)) => Err(format!(
                "a {cut}-byte prefix of a {}-byte frame decoded as complete",
                frame.len()
            )),
            Err(e) => Err(format!("prefix decode must ask for more, got error: {e}")),
        }
    });
}

#[test]
fn garbage_and_bit_flips_error_never_panic() {
    check("wire-garbage", 500, 0xBAD, |g| {
        // Arbitrary bytes: any Result is fine, panics/aborts are not.
        let len = g.usize_in(0, 256);
        let garbage: Vec<u8> = (0..len).map(|_| g.usize_in(0, 255) as u8).collect();
        let _ = decode(&garbage);
        // A valid frame with one flipped byte must also decode totally
        // — and so must feeding the (possibly bent) result through a
        // chunk assembler.
        let frame = encode(&arb_msg(g)).map_err(|e| format!("encode failed: {e}"))?;
        let mut bent = frame.clone();
        let at = g.usize_in(0, bent.len() - 1);
        bent[at] ^= 1 << g.usize_in(0, 7);
        if let Ok(Some((msg, _))) = decode(&bent) {
            let mut asm = ChunkAssembler::new();
            let _ = asm.accept(msg);
        }
        // And the stream reader survives garbage too (EOF mid-frame is
        // an Io error, not a hang or panic).
        let mut cursor = std::io::Cursor::new(&garbage);
        let _ = read_frame(&mut cursor);
        Ok(())
    });
}

#[test]
fn oversized_aux_blobs_are_refused_before_allocation() {
    // A hostile peer can claim any aux length it likes; the decoder
    // must reject counts past the frame end *before* reserving memory
    // for them, not trust the field and allocate.
    check("wire-aux-oversize", 300, 0xA0B, |g| {
        let w_len = g.usize_in(0, 64);
        let aux_len = g.usize_in(0, 64);
        let msg = WireMsg::CollectReply {
            from: g.usize_in(0, 10_000) as u32,
            to: g.usize_in(0, 10_000) as u32,
            token: g.usize_in(0, usize::MAX / 2) as u64,
            w: g.f32_vec(w_len, -1e6, 1e6),
            aux: (0..aux_len).map(|_| g.usize_in(0, 255) as u8).collect(),
        };
        let frame = encode(&msg).map_err(|e| format!("encode failed: {e}"))?;
        // The aux count is the last u32 before the aux payload.
        let at = frame.len() - aux_len - 4;
        let mut bent = frame.clone();
        bent[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        match decode(&bent) {
            Err(WireError::Oversize { .. }) => Ok(()),
            other => Err(format!(
                "a {}-byte frame claiming 4 GiB of aux must refuse with Oversize, got {other:?}",
                bent.len()
            )),
        }
    });
}

#[test]
fn metrics_snapshot_wire_layout_is_roundtrip_and_length_tolerant() {
    use dasgd::obs::{Gauge, Hist, HistSnapshot, MetricsSnapshot};
    check("wire-metrics-snapshot", 150, 0x0B5E6, |g| {
        // A populated snapshot survives to_wire → MetricsReply frame →
        // decode → from_wire exactly.
        let mut snap = MetricsSnapshot::ZERO;
        for c in snap.counters.iter_mut() {
            *c = g.usize_in(0, 1 << 30) as u64;
        }
        snap.gauges[Gauge::StagingHighWater as usize] = g.usize_in(0, 1 << 30) as u64;
        let mut h = HistSnapshot::ZERO;
        for _ in 0..g.usize_in(1, 32) {
            let b = g.usize_in(0, 63);
            h.buckets[b] += 1;
            h.count += 1;
            h.sum += b as u64;
        }
        snap.hists[Hist::StalenessTicks as usize] = h;
        let (counters, hist_data) = snap.to_wire();
        let msg = WireMsg::MetricsReply {
            rank: 7,
            counters,
            hist_data,
        };
        let frame = encode(&msg).map_err(|e| format!("encode: {e}"))?;
        let (back, _) = decode(&frame)
            .map_err(|e| format!("decode: {e}"))?
            .ok_or("incomplete")?;
        let WireMsg::MetricsReply {
            counters, hist_data, ..
        } = back
        else {
            return Err("decoded as a different variant".into());
        };
        if MetricsSnapshot::from_wire(&counters, &hist_data) != snap {
            return Err("snapshot changed through the wire layout".into());
        }
        // Arbitrary-length vectors (a newer/older peer's layout) decode
        // without panicking: missing words read as zero, extras are
        // ignored.
        let short: Vec<u64> = counters.iter().copied().take(g.usize_in(0, 6)).collect();
        let bent: Vec<u64> = (0..g.usize_in(0, 500))
            .map(|_| g.usize_in(0, 1 << 30) as u64)
            .collect();
        let _ = MetricsSnapshot::from_wire(&short, &bent);
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Chunked logical messages
// ---------------------------------------------------------------------------

/// Push every frame of `frames` through a fresh assembler; exactly one
/// logical message must come out, with nothing left in flight.
fn reassemble(frames: &[Vec<u8>]) -> Result<WireMsg, String> {
    let mut asm = ChunkAssembler::new();
    let mut out = None;
    for f in frames {
        let (msg, used) = decode(f)
            .map_err(|e| format!("frame decode failed: {e}"))?
            .ok_or("frame incomplete")?;
        if used != f.len() {
            return Err(format!("frame used {used} of {} bytes", f.len()));
        }
        if let Some(m) = asm
            .accept(msg)
            .map_err(|e| format!("assembler rejected a valid stream: {e}"))?
        {
            if out.is_some() {
                return Err("two messages out of one stream".into());
            }
            out = Some(m);
        }
    }
    if asm.in_progress() {
        return Err("assembler still in progress after the full stream".into());
    }
    out.ok_or_else(|| "no message assembled".into())
}

/// Every assignment of `plan` must survive encode_message → reassemble
/// → assignment_from_msg with bit-identical labels and feature bits.
fn assert_plan_ships_bit_for_bit(plan: &WorkloadPlan) -> Result<(), String> {
    for id in 0..plan.len() {
        let msg = plan_assign_msg(id, plan.node(id));
        let frames = encode_message(&msg).map_err(|e| format!("encode_message: {e}"))?;
        let back = reassemble(&frames)?;
        if back != msg {
            return Err(format!("node {id}: reassembled message differs"));
        }
        let (rid, a) = assignment_from_msg(&back).map_err(|e| format!("decode: {e}"))?;
        if rid != id {
            return Err(format!("node id changed: {id} → {rid}"));
        }
        if a.shard.labels() != plan.shard(id).labels() {
            return Err(format!("node {id}: labels changed"));
        }
        let want: Vec<u32> = plan
            .shard(id)
            .features_flat()
            .iter()
            .map(|v| v.to_bits())
            .collect();
        let got: Vec<u32> = a.shard.features_flat().iter().map(|v| v.to_bits()).collect();
        if want != got {
            return Err(format!("node {id}: feature bits changed"));
        }
    }
    Ok(())
}

#[test]
fn workload_plans_round_trip_the_chunked_path_at_any_size() {
    check("wire-chunked-plan", 20, 0x51AB, |g| {
        let nodes = g.usize_in(2, 5);
        let spec = *g.choose(&[
            PlanSpec::Synth,
            PlanSpec::Dirichlet { alpha: 0.3 },
            PlanSpec::Quantity { alpha: 0.15 },
            PlanSpec::FeatureShift { sigma: 0.8 },
            PlanSpec::Mixed { alpha: 0.3 },
        ]);
        let samples = g.usize_in(2, 400);
        let seed = g.usize_in(0, 1 << 20) as u64;
        let (plan, _) = spec.build(Objective::LogReg, nodes, samples, 8, seed);
        assert_plan_ships_bit_for_bit(&plan)
    });
}

#[test]
fn shard_past_the_frame_cap_round_trips_bit_for_bit() {
    // 90k rows × 50 features ≈ 18.4 MB encoded — beyond MAX_FRAME_LEN,
    // so this is the chunk envelope's real regime. Features are a
    // deterministic (finite) bit pattern, compared by bits.
    let rows = 90_000usize;
    let dim = 50usize;
    let features: Vec<f32> = (0..rows * dim)
        .map(|i| (i as f32).mul_add(0.25, -1e6))
        .collect();
    let labels: Vec<u32> = (0..rows as u32).map(|i| i % 10).collect();
    let msg = WireMsg::PlanAssign {
        node: 3,
        obj_code: 0,
        lam: 0.0,
        dim: dim as u32,
        classes: 10,
        labels,
        features,
        strategy: 0,
    };
    // Single-frame encoding refuses (this is where the pre-chunking
    // launcher crashed)…
    assert!(matches!(encode(&msg), Err(WireError::Oversize { .. })));
    // …and the chunked path carries it exactly.
    let frames = encode_message(&msg).unwrap();
    assert!(frames.len() > 3, "expected an envelope, got {}", frames.len());
    for f in &frames {
        assert!(f.len() <= 4 + MAX_FRAME_LEN, "oversized frame in the envelope");
    }
    assert_eq!(reassemble(&frames).unwrap(), msg);
}

#[test]
fn chunk_streams_with_injected_faults_error_never_panic() {
    check("wire-chunk-faults", 100, 0xFA017, |g| {
        // A small hand-rolled envelope (the assembler accepts any
        // well-formed one; encode_message only *emits* them past the
        // frame cap).
        let inner = WireMsg::Heartbeat {
            rank: g.usize_in(0, 64) as u32,
            seq: g.usize_in(0, 1 << 30) as u64,
        };
        let inner_frame = encode(&inner).map_err(|e| format!("encode: {e}"))?;
        let body = inner_frame[4..].to_vec();
        let envelope = [
            WireMsg::ChunkBegin {
                total_bytes: body.len() as u64,
                chunk_count: 1,
            },
            WireMsg::ChunkData { bytes: body.clone() },
            WireMsg::ChunkEnd {
                checksum: fnv1a64(&body),
            },
        ];
        // The clean stream reassembles.
        let mut asm = ChunkAssembler::new();
        let mut got = None;
        for m in envelope.iter().cloned() {
            if let Some(m) = asm.accept(m).map_err(|e| format!("clean stream: {e}"))? {
                got = Some(m);
            }
        }
        if got != Some(inner.clone()) {
            return Err("clean envelope did not reassemble".into());
        }
        // Truncation: stop after a random proper prefix — no message,
        // and the assembler reports the message still in flight.
        let cut = g.usize_in(1, envelope.len() - 1);
        let mut asm = ChunkAssembler::new();
        for m in envelope.iter().take(cut).cloned() {
            if asm.accept(m).map_err(|e| format!("prefix: {e}"))?.is_some() {
                return Err("truncated stream produced a message".into());
            }
        }
        if !asm.in_progress() {
            return Err("truncated stream not reported in-progress".into());
        }
        // Interleaving: a random non-chunk frame injected mid-envelope
        // must error (and leave the assembler clean for reuse).
        let mut asm = ChunkAssembler::new();
        asm.accept(envelope[0].clone()).map_err(|e| format!("begin: {e}"))?;
        let intruder = match g.usize_in(0, 2) {
            0 => WireMsg::SnapshotRequest,
            1 => WireMsg::Shutdown,
            _ => WireMsg::Hello { rank: 1 },
        };
        if !matches!(asm.accept(intruder), Err(WireError::Chunk { .. })) {
            return Err("interleaved frame was not rejected".into());
        }
        // Corruption: flip one bit of the data payload — the checksum
        // must catch it at ChunkEnd.
        let mut bent = body.clone();
        let at = g.usize_in(0, bent.len() - 1);
        bent[at] ^= 1 << g.usize_in(0, 7);
        let mut asm = ChunkAssembler::new();
        asm.accept(envelope[0].clone()).map_err(|e| format!("begin: {e}"))?;
        asm.accept(WireMsg::ChunkData { bytes: bent })
            .map_err(|e| format!("data: {e}"))?;
        match asm.accept(envelope[2].clone()) {
            Err(WireError::Chunk { .. }) => Ok(()),
            other => Err(format!("corrupted payload not caught: {other:?}")),
        }
    });
}

// ---------------------------------------------------------------------------
// Batch envelopes (WIRE_VERSION 5 coalescing)
// ---------------------------------------------------------------------------

/// An arbitrary *batchable* message: anything but chunk frames and
/// nested batches (the envelope rejects those by contract).
fn arb_batchable(g: &mut Gen) -> WireMsg {
    loop {
        let m = arb_msg(g);
        if m.is_batchable() {
            return m;
        }
    }
}

#[test]
fn batches_of_arbitrary_interleavings_round_trip_bit_for_bit() {
    check("wire-batch-roundtrip", 200, 0xBA7C4, |g| {
        let msgs: Vec<WireMsg> = (0..g.usize_in(1, 8)).map(|_| arb_batchable(g)).collect();
        let batch = WireMsg::Batch { msgs: msgs.clone() };
        let frame = encode(&batch).map_err(|e| format!("batch encode: {e}"))?;
        let (back, used) = decode(&frame)
            .map_err(|e| format!("batch decode: {e}"))?
            .ok_or("own batch reported incomplete")?;
        if used != frame.len() {
            return Err(format!("consumed {used} of {} bytes", frame.len()));
        }
        let WireMsg::Batch { msgs: got } = back else {
            return Err("batch decoded as a non-batch".into());
        };
        if got.len() != msgs.len() {
            return Err(format!("{} entries in, {} out", msgs.len(), got.len()));
        }
        // Entry equality down to the encoded bits, not just PartialEq.
        for (i, (a, b)) in msgs.iter().zip(&got).enumerate() {
            let ea = encode(a).map_err(|e| format!("re-encode in: {e}"))?;
            let eb = encode(b).map_err(|e| format!("re-encode out: {e}"))?;
            if ea != eb {
                return Err(format!("entry {i} changed bits through the envelope"));
            }
        }
        // A batch passes a chunk assembler untouched (it is a plain
        // logical frame, not part of any envelope).
        let mut asm = ChunkAssembler::new();
        match asm.accept(WireMsg::Batch { msgs: got }) {
            Ok(Some(WireMsg::Batch { .. })) => Ok(()),
            other => Err(format!("assembler bent the batch: {other:?}")),
        }
    });
}

#[test]
fn batch_truncation_corruption_and_mixed_versions_error_never_panic() {
    check("wire-batch-faults", 200, 0xBADBA7, |g| {
        let msgs: Vec<WireMsg> = (0..g.usize_in(1, 5)).map(|_| arb_batchable(g)).collect();
        let frame = encode(&WireMsg::Batch { msgs }).map_err(|e| format!("encode: {e}"))?;
        // Truncation: any proper prefix asks for more or errors cleanly.
        let cut = g.usize_in(0, frame.len() - 1);
        match decode(&frame[..cut]) {
            Ok(Some(_)) => {
                return Err(format!(
                    "a {cut}-byte prefix of a {}-byte batch decoded as complete",
                    frame.len()
                ))
            }
            Ok(None) | Err(_) => {}
        }
        // Corruption: one flipped bit anywhere must never panic (any
        // Result is acceptable; most flips land in payload bytes).
        let mut bent = frame.clone();
        let at = g.usize_in(0, bent.len() - 1);
        bent[at] ^= 1 << g.usize_in(0, 7);
        let _ = decode(&bent);
        // Mixed versions: an entry stamped with an older wire version
        // must be refused — batches are a v5-only construct and every
        // entry body carries its own version byte. The first entry's
        // version byte sits right after [len][ver][tag][count][entry len].
        let mut mixed = frame.clone();
        mixed[14] = wire::WIRE_VERSION - 1;
        match decode(&mixed) {
            Err(WireError::Version { .. }) => Ok(()),
            other => Err(format!("pre-v5 entry not refused: {other:?}")),
        }
    });
}

#[test]
fn batched_streams_decode_to_the_unbatched_sequence() {
    // The coalescer's core contract: however frames get grouped into
    // flushes, the receiver sees exactly the sequence an unbatched
    // sender would have produced, bit for bit.
    check("wire-batch-stream", 150, 0x5EC0, |g| {
        let msgs: Vec<WireMsg> = (0..g.usize_in(1, 10)).map(|_| arb_batchable(g)).collect();
        // Random flush points via a reused BatchBuilder (singleton
        // flushes emit the plain frame — the wire shape of an
        // unbatched send).
        let mut builder = wire::BatchBuilder::new();
        let mut stream = Vec::new();
        let mut frame = Vec::new();
        for (i, m) in msgs.iter().enumerate() {
            builder.push(m).map_err(|e| format!("push: {e}"))?;
            if g.bool() || i + 1 == msgs.len() {
                builder
                    .frame_into(&mut frame)
                    .map_err(|e| format!("flush: {e}"))?;
                stream.extend_from_slice(&frame);
            }
        }
        // Decode the whole stream, flattening batches.
        let mut flat = Vec::new();
        let mut rest = stream.as_slice();
        while !rest.is_empty() {
            let (m, used) = decode(rest)
                .map_err(|e| format!("stream decode: {e}"))?
                .ok_or("stream ended mid-frame")?;
            rest = &rest[used..];
            match m {
                WireMsg::Batch { msgs } => flat.extend(msgs),
                other => flat.push(other),
            }
        }
        if flat.len() != msgs.len() {
            return Err(format!("{} messages in, {} out", msgs.len(), flat.len()));
        }
        for (i, (a, b)) in msgs.iter().zip(&flat).enumerate() {
            let ea = encode(a).map_err(|e| format!("re-encode in: {e}"))?;
            let eb = encode(b).map_err(|e| format!("re-encode out: {e}"))?;
            if ea != eb {
                return Err(format!("message {i} changed bits through batching"));
            }
        }
        Ok(())
    });
}

#[test]
fn write_message_over_a_stream_is_what_read_message_reads() {
    // The blocking-stream pair used by the control plane, across the
    // single-frame and chunked regimes in one stream.
    let small = WireMsg::Hello { rank: 1 };
    let big = WireMsg::PlanAssign {
        node: 0,
        obj_code: 1,
        lam: 0.01,
        dim: 50,
        classes: 10,
        labels: vec![1; 100_000],
        features: vec![1.5; 100_000 * 50],
        strategy: 2,
    };
    let mut buf = Vec::new();
    wire::write_message(&mut buf, &small).unwrap();
    wire::write_message(&mut buf, &big).unwrap();
    wire::write_message(&mut buf, &WireMsg::Shutdown).unwrap();
    let mut cursor = std::io::Cursor::new(&buf);
    let mut asm = ChunkAssembler::new();
    assert_eq!(wire::read_message(&mut cursor, &mut asm).unwrap(), small);
    assert_eq!(wire::read_message(&mut cursor, &mut asm).unwrap(), big);
    assert_eq!(
        wire::read_message(&mut cursor, &mut asm).unwrap(),
        WireMsg::Shutdown
    );
    assert_eq!(cursor.position() as usize, buf.len());
}
