//! Property tests for the SocketNet wire codec: arbitrary messages
//! round-trip exactly, and arbitrary bytes — garbage, bit flips,
//! truncations — decode to a clean error or "need more", never a panic
//! and never a huge allocation.

use dasgd::net::wire::{decode, encode, read_frame, WireMsg};
use dasgd::util::proptest::{check, Gen};

/// One arbitrary message (finite payloads so `PartialEq` is exact;
/// NaN bit-pattern survival is pinned by the unit tests in `wire.rs`).
fn arb_msg(g: &mut Gen) -> WireMsg {
    let w_len = g.usize_in(0, g.size * 64);
    match g.usize_in(0, 11) {
        0 => WireMsg::Hello {
            rank: g.usize_in(0, 1 << 20) as u32,
        },
        1 => WireMsg::Heartbeat {
            rank: g.usize_in(0, 64) as u32,
            seq: g.usize_in(0, usize::MAX / 2) as u64,
        },
        2 => WireMsg::CollectRequest {
            from: g.usize_in(0, 10_000) as u32,
            to: g.usize_in(0, 10_000) as u32,
            token: g.usize_in(0, usize::MAX / 2) as u64,
        },
        3 => WireMsg::CollectReply {
            from: g.usize_in(0, 10_000) as u32,
            to: g.usize_in(0, 10_000) as u32,
            token: g.usize_in(0, usize::MAX / 2) as u64,
            w: g.f32_vec(w_len, -1e6, 1e6),
        },
        4 => WireMsg::Busy {
            from: g.usize_in(0, 10_000) as u32,
            to: g.usize_in(0, 10_000) as u32,
            token: g.usize_in(0, usize::MAX / 2) as u64,
        },
        5 => WireMsg::Abort {
            from: g.usize_in(0, 10_000) as u32,
            to: g.usize_in(0, 10_000) as u32,
            token: g.usize_in(0, usize::MAX / 2) as u64,
        },
        6 => WireMsg::ApplyAverage {
            from: g.usize_in(0, 10_000) as u32,
            to: g.usize_in(0, 10_000) as u32,
            token: g.usize_in(0, usize::MAX / 2) as u64,
            w: g.f32_vec(w_len, -1e6, 1e6),
        },
        7 => WireMsg::SnapshotRequest,
        8 => {
            let shard = g.usize_in(0, 8);
            WireMsg::SnapshotReply {
                rank: g.usize_in(0, 64) as u32,
                counts: [
                    g.usize_in(0, 1 << 30) as u64,
                    g.usize_in(0, 1 << 30) as u64,
                    g.usize_in(0, 1 << 30) as u64,
                    g.usize_in(0, 1 << 30) as u64,
                ],
                params: (0..shard)
                    .map(|i| {
                        let len = g.usize_in(0, 64);
                        (i as u32, g.f32_vec(len, -100.0, 100.0))
                    })
                    .collect(),
            }
        }
        9 => WireMsg::Shutdown,
        10 => {
            let dim = g.usize_in(1, 8);
            let rows = g.usize_in(0, g.size * 8);
            WireMsg::PlanAssign {
                node: g.usize_in(0, 10_000) as u32,
                obj_code: g.usize_in(0, 3) as u8,
                lam: g.f32_vec(1, 0.0, 1.0)[0],
                dim: dim as u32,
                classes: g.usize_in(1, 12) as u32,
                labels: (0..rows).map(|_| g.usize_in(0, 11) as u32).collect(),
                features: g.f32_vec(rows * dim, -100.0, 100.0),
            }
        }
        _ => WireMsg::PlanStart {
            nodes: g.usize_in(0, 100_000) as u32,
            assigned: g.usize_in(0, 100_000) as u32,
            mixed: g.bool(),
        },
    }
}

#[test]
fn arbitrary_messages_round_trip() {
    check("wire-roundtrip", 300, 0xC0DEC, |g| {
        let msg = arb_msg(g);
        let frame = encode(&msg);
        let (back, consumed) = decode(&frame)
            .map_err(|e| format!("decode of own encoding failed: {e}"))?
            .ok_or("own encoding reported incomplete")?;
        if consumed != frame.len() {
            return Err(format!("consumed {consumed} of {} bytes", frame.len()));
        }
        if back != msg {
            return Err(format!("round trip changed the message: {msg:?} → {back:?}"));
        }
        // The blocking stream reader agrees with the buffer decoder.
        let mut cursor = std::io::Cursor::new(&frame);
        match read_frame(&mut cursor) {
            Ok(m) if m == msg => Ok(()),
            Ok(m) => Err(format!("stream read disagreed: {m:?}")),
            Err(e) => Err(format!("stream read failed: {e}")),
        }
    });
}

#[test]
fn truncated_frames_ask_for_more_never_panic() {
    check("wire-truncation", 200, 0x7A11, |g| {
        let msg = arb_msg(g);
        let frame = encode(&msg);
        let cut = g.usize_in(0, frame.len().saturating_sub(1));
        match decode(&frame[..cut]) {
            Ok(None) => Ok(()),
            Ok(Some(_)) => Err(format!(
                "a {cut}-byte prefix of a {}-byte frame decoded as complete",
                frame.len()
            )),
            Err(e) => Err(format!("prefix decode must ask for more, got error: {e}")),
        }
    });
}

#[test]
fn garbage_and_bit_flips_error_never_panic() {
    check("wire-garbage", 500, 0xBAD, |g| {
        // Arbitrary bytes: any Result is fine, panics/aborts are not.
        let len = g.usize_in(0, 256);
        let garbage: Vec<u8> = (0..len).map(|_| g.usize_in(0, 255) as u8).collect();
        let _ = decode(&garbage);
        // A valid frame with one flipped byte must also decode totally.
        let frame = encode(&arb_msg(g));
        let mut bent = frame.clone();
        let at = g.usize_in(0, bent.len() - 1);
        bent[at] ^= 1 << g.usize_in(0, 7);
        let _ = decode(&bent);
        // And the stream reader survives garbage too (EOF mid-frame is
        // an Io error, not a hang or panic).
        let mut cursor = std::io::Cursor::new(&garbage);
        let _ = read_frame(&mut cursor);
        Ok(())
    });
}
