//! Integration: the threaded asynchronous runtime, including the PJRT
//! executor-service path (node threads → channel → engine-owning
//! workers).

use dasgd::coordinator::{AsyncCluster, AsyncConfig, PjrtArtifacts};
use dasgd::experiments::{make_regular, synth_world};
use dasgd::runtime::{Engine, ExecutorService};

#[test]
fn async_cluster_native_learns_and_counts() {
    let n = 8;
    let (shards, test) = synth_world(n, 100, 256, 41);
    let cluster = AsyncCluster::new(make_regular(n, 4), shards);
    let cfg = AsyncConfig {
        duration_secs: 1.5,
        rate_hz: 500.0,
        ..AsyncConfig::quick(n)
    };
    let rep = cluster.run(&cfg, &test).unwrap();
    assert!(rep.updates > 300, "updates={}", rep.updates);
    assert_eq!(rep.updates, rep.grad_steps + rep.proj_steps);
    // Roughly half gradient steps (p_grad = 0.5) — allow wide slack for
    // lock-up backoffs.
    let frac = rep.grad_steps as f64 / rep.updates as f64;
    assert!((0.3..0.8).contains(&frac), "grad fraction {frac}");
    // Final parameters are finite and improved the model.
    assert!(rep
        .final_params
        .iter()
        .all(|w| w.iter().all(|v| v.is_finite())));
    let last = rep.recorder.last().unwrap();
    let first = rep.recorder.records.first().unwrap();
    assert!(last.test_err <= first.test_err, "{} -> {}", first.test_err, last.test_err);
}

#[test]
fn async_cluster_through_pjrt_executor_service() {
    if Engine::load("artifacts").is_err() {
        eprintln!("SKIP (run `make artifacts`)");
        return;
    }
    let n = 6;
    let (shards, test) = synth_world(n, 80, 256, 43);
    let service = ExecutorService::start("artifacts", 2).unwrap();
    let cluster = AsyncCluster::new(make_regular(n, 2), shards)
        .with_executor(service.handle(), PjrtArtifacts::synth());
    let cfg = AsyncConfig {
        duration_secs: 1.5,
        rate_hz: 150.0, // each op crosses the channel + PJRT
        ..AsyncConfig::quick(n)
    };
    let rep = cluster.run(&cfg, &test).unwrap();
    assert!(rep.updates > 50, "updates={}", rep.updates);
    assert!(rep
        .final_params
        .iter()
        .all(|w| w.iter().all(|v| v.is_finite())));
    // The model moved (weights no longer all-zero).
    assert!(rep
        .final_params
        .iter()
        .any(|w| w.iter().any(|&v| v != 0.0)));
}

#[test]
fn executor_service_survives_worker_churn() {
    if Engine::load("artifacts").is_err() {
        eprintln!("SKIP (run `make artifacts`)");
        return;
    }
    // Many short-lived client threads against a 2-worker service.
    let service = ExecutorService::start("artifacts", 2).unwrap();
    let mut joins = Vec::new();
    for round in 0..3 {
        for t in 0..4 {
            let h = service.handle();
            joins.push(std::thread::spawn(move || {
                let w = vec![0.1f32; 500];
                let x = vec![0.2f32; 50];
                let mut y = vec![0.0f32; 10];
                y[(round * 4 + t) % 10] = 1.0;
                let outs = h
                    .execute_f32(
                        "logreg_step_synth_b1",
                        &[&w, &x, &y, &[0.1f32], &[1.0f32]],
                    )
                    .unwrap();
                assert_eq!(outs[0].len(), 500);
            }));
        }
    }
    for j in joins {
        j.join().unwrap();
    }
}
