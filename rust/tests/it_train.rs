//! Integration: the full Alg. 2 training loop across modules — graph +
//! data + coordinator + metrics — and the native↔PJRT backend
//! equivalence on identical seeds.

use dasgd::coordinator::{NativeBackend, TrainConfig, Trainer};
use dasgd::experiments::{self, make_regular, synth_world};

#[test]
fn alg2_full_loop_consensus_and_accuracy() {
    let n = 10;
    let (shards, test) = synth_world(n, 150, 400, 17);
    let cfg = TrainConfig::paper_default(n).with_seed(17);
    let mut t = Trainer::new(cfg, make_regular(n, 4), shards, NativeBackend::new(50, 10));
    let rec = t.run(8000, 2000, &test, "it").unwrap();
    let last = rec.last().unwrap();
    // 10 classes → random = 0.9; the paper reaches < 0.4 at 40k on 30
    // nodes; at this scale demand clear learning.
    assert!(last.test_err < 0.45, "err={}", last.test_err);
    // Consensus must be tight at the end (diminishing steps).
    assert!(last.consensus < 5.0, "d^k={}", last.consensus);
    // Counter discipline.
    assert_eq!(t.counters.grad_steps + t.counters.proj_steps, t.k);
    assert_eq!(last.k, t.k);
}

#[test]
fn eval_cadence_and_monotone_k() {
    let n = 6;
    let (shards, test) = synth_world(n, 60, 128, 3);
    let cfg = TrainConfig::paper_default(n).with_seed(3);
    let mut t = Trainer::new(cfg, make_regular(n, 2), shards, NativeBackend::new(50, 10));
    let rec = t.run(1000, 100, &test, "cadence").unwrap();
    // Records at k=0, then ~every 100, then final: 11-13 records.
    assert!(rec.records.len() >= 10, "{}", rec.records.len());
    assert!(rec.records.windows(2).all(|w| w[0].k <= w[1].k));
    assert_eq!(rec.records.last().unwrap().k, 1000);
}

#[test]
fn pjrt_backend_matches_native_trajectory() {
    // Same seeds → identical node/data/selection randomness; the only
    // difference is where the math runs. Trajectories agree to float
    // accumulation tolerance.
    if dasgd::runtime::Engine::load("artifacts").is_err() {
        eprintln!("SKIP (run `make artifacts`)");
        return;
    }
    let (native, pjrt) = experiments::run_both_backends(8, 4, 600, 23).unwrap();
    let n_last = native.last().unwrap();
    let p_last = pjrt.last().unwrap();
    assert!(
        (n_last.test_err - p_last.test_err).abs() < 0.06,
        "err native={} pjrt={}",
        n_last.test_err,
        p_last.test_err
    );
    assert!(
        (n_last.consensus - p_last.consensus).abs()
            < 0.05 * n_last.consensus.abs().max(1.0),
        "consensus native={} pjrt={}",
        n_last.consensus,
        p_last.consensus
    );
    assert_eq!(n_last.grad_steps, p_last.grad_steps);
    assert_eq!(n_last.proj_steps, p_last.proj_steps);
}

#[test]
fn distributed_selection_end_to_end() {
    use dasgd::coordinator::SelectionMode;
    let n = 12;
    let (shards, test) = synth_world(n, 100, 256, 29);
    let cfg = TrainConfig {
        selection: SelectionMode::DistributedGeometric { p: 0.08 },
        ..TrainConfig::paper_default(n)
    }
    .with_seed(29);
    let mut t = Trainer::new(cfg, make_regular(n, 4), shards, NativeBackend::new(50, 10));
    let rec = t.run(5000, 2500, &test, "dist").unwrap();
    assert!(rec.final_err() < 0.5, "err={}", rec.final_err());
    // Fully distributed selection still covers all nodes.
    assert!(t.nodes.iter().all(|nd| nd.grad_steps + nd.proj_steps > 0));
}

#[test]
fn csv_export_from_training() {
    let n = 6;
    let (shards, test) = synth_world(n, 50, 128, 31);
    let cfg = TrainConfig::paper_default(n).with_seed(31);
    let mut t = Trainer::new(cfg, make_regular(n, 2), shards, NativeBackend::new(50, 10));
    let rec = t.run(300, 100, &test, "csv").unwrap();
    let path = std::env::temp_dir().join("dasgd_it_train.csv");
    rec.write_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.starts_with("k,time_secs,consensus"));
    assert!(text.lines().count() > 3);
    std::fs::remove_file(path).ok();
}
