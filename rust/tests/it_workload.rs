//! Integration: the workload-assignment subsystem.
//!
//! * Determinism — a plan built from `(spec, nodes, seed)` is
//!   bit-identical across builds *and* across a wire round trip of its
//!   assignments (what `dasgd launch` ships to workers).
//! * Coverage — property test that every partitioner assigns each base
//!   row to exactly one node and leaves no node empty, for synthetic
//!   and notMNIST-shaped data alike.
//! * Skew — small Dirichlet α produces measurably non-IID shards.
//! * End-to-end — a mixed hinge/lasso plan drives the event-driven
//!   engine to a finite, consensus-reaching state.

use dasgd::data::{Dataset, NotMnistGen};
use dasgd::experiments::make_regular;
use dasgd::net::{assignment_from_msg, plan_assign_msg};
use dasgd::net::wire;
use dasgd::objective::Objective;
use dasgd::sim::{simnet_run_plan, SimConfig, SpeedModel};
use dasgd::transport::SimNetConfig;
use dasgd::util::proptest::{check, Gen};
use dasgd::util::rng::Xoshiro256pp;
use dasgd::workload::{
    partition_iid, partition_label_skew, partition_quantity_skew, PlanSpec, WorkloadPlan,
};

fn assert_plans_equal(a: &WorkloadPlan, b: &WorkloadPlan) {
    assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        assert_eq!(a.objective(i).name(), b.objective(i).name(), "node {i}");
        assert_eq!(a.shard(i).labels(), b.shard(i).labels(), "node {i}");
        assert_eq!(
            a.shard(i).features_flat(),
            b.shard(i).features_flat(),
            "node {i}"
        );
    }
}

#[test]
fn plans_are_deterministic_in_spec_nodes_seed() {
    for spec in [
        PlanSpec::Synth,
        PlanSpec::Dirichlet { alpha: 0.1 },
        PlanSpec::Quantity { alpha: 0.4 },
        PlanSpec::FeatureShift { sigma: 0.7 },
        PlanSpec::Mixed { alpha: 0.1 },
    ] {
        let (p1, t1) = spec.build(Objective::LogReg, 8, 60, 128, 42);
        let (p2, t2) = spec.build(Objective::LogReg, 8, 60, 128, 42);
        assert_plans_equal(&p1, &p2);
        assert_eq!(t1.labels(), t2.labels(), "{spec:?} test set");
        // A different seed gives a different world.
        let (p3, _) = spec.build(Objective::LogReg, 8, 60, 128, 43);
        let same = (0..8).all(|i| p1.shard(i).labels() == p3.shard(i).labels());
        assert!(!same, "{spec:?}: seed 42 and 43 built identical plans");
    }
}

#[test]
fn plan_survives_a_wire_round_trip_bit_for_bit() {
    // The exact path `dasgd launch` uses: every assignment is encoded
    // as a PlanAssign frame, decoded on the far side, and reassembled
    // into the worker's partial plan. Data must survive by bits.
    let (plan, _) = PlanSpec::Mixed { alpha: 0.1 }.build(Objective::LogReg, 6, 50, 32, 7);
    let mut shipped = Vec::new();
    for id in 0..plan.len() {
        let frame = wire::encode(&plan_assign_msg(id, plan.node(id))).unwrap();
        let (msg, used) = wire::decode(&frame).unwrap().expect("complete frame");
        assert_eq!(used, frame.len());
        shipped.push(assignment_from_msg(&msg).unwrap());
    }
    let rebuilt = WorkloadPlan::from_partial(
        plan.len(),
        plan.dim(),
        plan.classes(),
        shipped,
        plan.is_mixed(),
    )
    .unwrap();
    assert_plans_equal(&plan, &rebuilt);
    assert_eq!(rebuilt.param_len(), plan.param_len());
    assert!(rebuilt.is_mixed());
}

/// Exactly-once coverage with no empty shard — the partitioner
/// contract.
fn assert_exact_cover(parts: &[Vec<usize>], rows: usize) -> Result<(), String> {
    let mut seen = vec![false; rows];
    for (node, part) in parts.iter().enumerate() {
        if part.is_empty() {
            return Err(format!("node {node} got no rows"));
        }
        for &i in part {
            if i >= rows {
                return Err(format!("row {i} out of range"));
            }
            if seen[i] {
                return Err(format!("row {i} assigned twice"));
            }
            seen[i] = true;
        }
    }
    match seen.iter().position(|&v| !v) {
        Some(i) => Err(format!("row {i} never assigned")),
        None => Ok(()),
    }
}

#[test]
fn prop_partitioners_cover_every_row_exactly_once() {
    check("partition-coverage", 120, 0x5EED, |g: &mut Gen| {
        let nodes = g.usize_in(1, 12);
        let rows = g.usize_in(nodes.max(2), nodes * 40);
        let classes = g.usize_in(2, 10);
        let alpha = g.f64_in(0.02, 5.0);
        let labels: Vec<usize> = (0..rows).map(|_| g.rng.index(classes)).collect();
        let mut rng = Xoshiro256pp::seeded(g.rng.next_u64());
        assert_exact_cover(&partition_iid(rows, nodes, &mut rng), rows)
            .map_err(|e| format!("iid: {e}"))?;
        assert_exact_cover(
            &partition_label_skew(&labels, classes, nodes, alpha, &mut rng),
            rows,
        )
        .map_err(|e| format!("label-skew α={alpha}: {e}"))?;
        assert_exact_cover(&partition_quantity_skew(rows, nodes, alpha, &mut rng), rows)
            .map_err(|e| format!("quantity α={alpha}: {e}"))?;
        Ok(())
    });
}

#[test]
fn partitioners_work_over_notmnist_data() {
    // The partitioners are generic over the base dataset: the same
    // recipes split the 256-feature glyph corpus.
    let gen = NotMnistGen::new(4, 11);
    let mut rng = Xoshiro256pp::seeded(11);
    let base = gen.global_test_set(120, &mut rng);
    let plan = PlanSpec::Dirichlet { alpha: 0.2 }.build_over(&base, Objective::LogReg, 5, 11);
    assert_eq!(plan.len(), 5);
    assert_eq!(plan.dim(), base.dim());
    let total: usize = (0..5).map(|i| plan.shard(i).len()).sum();
    assert_eq!(total, base.len(), "every glyph row lands on exactly one node");
    assert!((0..5).all(|i| !plan.shard(i).is_empty()));
    assert_eq!(plan.param_len(), base.dim() * base.classes());
}

#[test]
fn small_alpha_is_measurably_non_iid() {
    let max_class_frac = |plan: &WorkloadPlan| {
        (0..plan.len())
            .map(|i| {
                let counts = plan.shard(i).class_counts();
                let total: usize = counts.iter().sum();
                *counts.iter().max().unwrap() as f64 / total.max(1) as f64
            })
            .fold(0.0f64, f64::max)
    };
    let (skewed, _) = PlanSpec::Dirichlet { alpha: 0.05 }.build(Objective::LogReg, 12, 60, 16, 5);
    let (iid, _) = PlanSpec::Dirichlet { alpha: 200.0 }.build(Objective::LogReg, 12, 60, 16, 5);
    let s = max_class_frac(&skewed);
    let f = max_class_frac(&iid);
    assert!(
        s > f + 0.15,
        "α=0.05 should concentrate labels well beyond α=200: {s:.3} vs {f:.3}"
    );
}

#[test]
fn quantity_skew_spreads_shard_sizes() {
    let (plan, _) = PlanSpec::Quantity { alpha: 0.1 }.build(Objective::LogReg, 10, 50, 16, 9);
    let sizes: Vec<usize> = (0..10).map(|i| plan.shard(i).len()).collect();
    let max = *sizes.iter().max().unwrap();
    let min = *sizes.iter().min().unwrap();
    assert!(min >= 1, "no node may be starved: {sizes:?}");
    assert!(
        max >= min * 3,
        "α=0.1 should spread sizes at least 3x: {sizes:?}"
    );
    assert_eq!(sizes.iter().sum::<usize>(), 500);
}

#[test]
fn mixed_plan_drives_the_event_engine_to_consensus() {
    let n = 8;
    let (plan, test) = PlanSpec::Mixed { alpha: 0.5 }.build(Objective::LogReg, n, 60, 256, 21);
    let g = make_regular(n, 4);
    let speeds = SpeedModel::homogeneous(n, 1.0);
    let cfg = SimConfig {
        p_grad: 0.5,
        stepsize: Objective::lasso().default_stepsize(n), // superseded per node
        objective: Objective::LogReg,
        horizon: 200.0,
        eval_every: 50.0,
        net: SimNetConfig::ideal(0.002),
        seed: 21,
    };
    let rep = simnet_run_plan(&g, &plan, &test, &speeds, &cfg);
    assert!(rep.updates > 800, "updates={}", rep.updates);
    assert!(rep.proj_steps > 0, "no projections between mixed families");
    let last = rep.recorder.last().unwrap();
    assert!(last.test_loss.is_finite() && last.test_err.is_finite());
    // Gossip keeps the mixed cohort bounded: d^k stays within the same
    // order as one stepsize-scale deviation per node, not diverging.
    assert!(
        last.consensus.is_finite() && last.consensus < 100.0,
        "mixed-cohort consensus diverged: {}",
        last.consensus
    );
    assert!(rep
        .final_params
        .iter()
        .all(|w| w.len() == 50 && w.iter().all(|v| v.is_finite())));
}

#[test]
fn homogeneous_wrapper_matches_plan_path_exactly() {
    // simnet_run(shards) and simnet_run_plan(homogeneous plan) are the
    // same computation — seeded runs must agree bit-for-bit.
    let n = 6;
    let (shards, test) = dasgd::experiments::synth_world(n, 40, 128, 13);
    let g = make_regular(n, 2);
    let speeds = SpeedModel::homogeneous(n, 1.0);
    let cfg = SimConfig {
        p_grad: 0.5,
        stepsize: Objective::LogReg.default_stepsize(n),
        objective: Objective::LogReg,
        horizon: 60.0,
        eval_every: 20.0,
        net: SimNetConfig::ideal(0.001),
        seed: 13,
    };
    let a = dasgd::sim::simnet_run(&g, &shards, &test, &speeds, &cfg);
    let plan = WorkloadPlan::homogeneous(Objective::LogReg, shards);
    let b = simnet_run_plan(&g, &plan, &test, &speeds, &cfg);
    assert_eq!(a.updates, b.updates);
    assert_eq!(a.final_params, b.final_params);
    assert_eq!(
        a.recorder.last().unwrap().test_err,
        b.recorder.last().unwrap().test_err
    );
}

#[test]
fn feature_shift_plan_keeps_label_marginals() {
    let (shifted, _) =
        PlanSpec::FeatureShift { sigma: 1.0 }.build(Objective::LogReg, 6, 40, 16, 31);
    let (plain, _) = PlanSpec::Dirichlet { alpha: 1e6 }.build(Objective::LogReg, 6, 40, 16, 31);
    // Covariate shift: features move, the overall label pool does not.
    let pool = |p: &WorkloadPlan| {
        let mut all: Vec<usize> = (0..p.len()).flat_map(|i| p.shard(i).labels().to_vec()).collect();
        all.sort_unstable();
        all
    };
    assert_eq!(pool(&shifted), pool(&plain));
    // And per-node feature means genuinely differ under the shift.
    let mean0: f32 = shifted.shard(0).features_flat().iter().sum::<f32>()
        / shifted.shard(0).features_flat().len() as f32;
    let mean1: f32 = shifted.shard(1).features_flat().iter().sum::<f32>()
        / shifted.shard(1).features_flat().len() as f32;
    assert!((mean0 - mean1).abs() > 1e-3, "shift did nothing: {mean0} vs {mean1}");
}

#[test]
fn empty_dataset_helpers_reject_bad_shapes() {
    // WorkloadPlan::homogeneous refuses an all-empty world.
    let result = std::panic::catch_unwind(|| {
        WorkloadPlan::homogeneous(Objective::LogReg, vec![Dataset::new(3, 2)])
    });
    assert!(result.is_err(), "all-empty plan must be rejected");
}
