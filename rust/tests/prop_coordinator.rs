//! Property tests on coordinator invariants (in-repo harness — the
//! `proptest` crate does not resolve offline; see `util::proptest`).
//!
//! Invariants checked across randomized topologies, parameters, and
//! schedules:
//!  * projection preserves the closed-neighborhood mean and never
//!    increases the consensus distance;
//!  * trainer counter discipline (k = grads + projections; message
//!    accounting matches Σ 2·deg over projections in central mode);
//!  * selection statistics (all indices valid, distributed rates
//!    proportional);
//!  * generated regular graphs are simple, regular, connected;
//!  * spectral bound stays in (0, 1] and orders with degree.

use dasgd::coordinator::{
    consensus, NativeBackend, TrainConfig, Trainer,
};
use dasgd::data::{Dataset, SyntheticGen};
use dasgd::experiments::make_regular;
use dasgd::graph::{random_regular, spectral, Graph};
use dasgd::util::proptest::{check, Gen};
use dasgd::util::rng::Xoshiro256pp;

fn random_params(g: &mut Gen, n: usize, len: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| g.f32_vec(len, -5.0, 5.0)).collect()
}

fn random_connected_graph(g: &mut Gen, n: usize) -> Graph {
    // Random spanning tree + extra random edges: always connected.
    let mut graph = Graph::empty(n);
    for v in 1..n {
        let u = g.usize_in(0, v - 1);
        graph.add_edge(u, v);
    }
    let extra = g.usize_in(0, n);
    for _ in 0..extra {
        let u = g.usize_in(0, n - 1);
        let v = g.usize_in(0, n - 1);
        if u != v {
            graph.add_edge(u, v);
        }
    }
    graph
}

#[test]
fn projection_preserves_neighborhood_mean_and_contracts() {
    check("projection-invariants", 60, 0xA11CE, |g| {
        let n = g.usize_in(3, 12);
        let len = g.usize_in(1, 20);
        let graph = random_connected_graph(g, n);
        let params = random_params(g, n, len);
        let m = g.usize_in(0, n - 1);

        let hood = graph.closed_neighborhood(m);
        let rows: Vec<&[f32]> = hood.iter().map(|&i| params[i].as_slice()).collect();
        let avg = dasgd::linalg::mean_of(&rows);

        // Mean preservation: sum over the neighborhood is unchanged.
        for j in 0..len {
            let before: f32 = hood.iter().map(|&i| params[i][j]).sum();
            let after = avg[j] * hood.len() as f32;
            if (before - after).abs() > 1e-3 * before.abs().max(1.0) {
                return Err(format!("mass not conserved at coord {j}: {before} vs {after}"));
            }
        }

        // Consensus distance never increases under a projection.
        let d_before = consensus::consensus_distance(&params);
        let mut after_params = params.clone();
        for &i in &hood {
            after_params[i] = avg.clone();
        }
        let d_after = consensus::consensus_distance(&after_params);
        if d_after > d_before + 1e-6 {
            return Err(format!("projection increased d: {d_before} -> {d_after}"));
        }

        // DF also never increases.
        let df_before = consensus::feasibility(&params, &graph).df_sq;
        let df_after = consensus::feasibility(&after_params, &graph).df_sq;
        if df_after > df_before + 1e-6 {
            return Err(format!("projection increased DF: {df_before} -> {df_after}"));
        }
        Ok(())
    });
}

#[test]
fn trainer_counter_discipline() {
    check("trainer-counters", 12, 0xBEEF, |g| {
        let n = g.usize_in(4, 10);
        let degree = *g.choose(&[2usize, 4]);
        let iters = g.usize_in(50, 400) as u64;
        let p_grad = g.f64_in(0.0, 1.0);
        let seed = g.rng.next_u64();

        let gen = SyntheticGen::new(n, 10, 3, 2.0, 0.4, 0.3, seed);
        let mut rng = Xoshiro256pp::seeded(seed ^ 1);
        let shards: Vec<Dataset> =
            (0..n).map(|i| gen.node_dataset(i, 20, &mut rng)).collect();
        let test = gen.global_test_set(60, &mut rng);

        let cfg = TrainConfig::paper_default(n)
            .with_p_grad(p_grad)
            .with_seed(seed);
        let mut t = Trainer::new(
            cfg,
            make_regular(n, degree),
            shards,
            NativeBackend::new(10, 3),
        );
        t.run(iters, iters, &test, "prop").map_err(|e| e.to_string())?;

        if t.k != iters {
            return Err(format!("k={} != iters={iters}", t.k));
        }
        if t.counters.grad_steps + t.counters.proj_steps != t.k {
            return Err("grad+proj != k".into());
        }
        // Central mode: every projection on node m sends 2·deg(m)
        // messages; degree is uniform so messages = 2·deg·projs.
        let expect = 2 * t.graph.degree(0) as u64 * t.counters.proj_steps;
        if t.counters.messages != expect {
            return Err(format!(
                "messages {} != {}",
                t.counters.messages, expect
            ));
        }
        // Per-node counts sum to totals.
        let node_sum: u64 = t.nodes.iter().map(|nd| nd.grad_steps + nd.proj_steps).sum();
        if node_sum != t.k {
            return Err("per-node counts don't sum to k".into());
        }
        // All parameters finite.
        if !t.params().iter().all(|w| w.iter().all(|v| v.is_finite())) {
            return Err("non-finite parameter".into());
        }
        Ok(())
    });
}

#[test]
fn distributed_selection_stats_and_conflicts() {
    check("selection-stats", 10, 0xCAFE, |g| {
        use dasgd::coordinator::GeometricSelector;
        let n = g.usize_in(3, 16);
        let p = g.f64_in(0.01, 0.4);
        let seed = g.rng.next_u64();
        let mut sel = GeometricSelector::uniform(n, p, seed);
        let mut counts = vec![0u64; n];
        let draws = 4000;
        for _ in 0..draws {
            let slot = sel.next();
            if slot.fired.is_empty() {
                return Err("empty firing set".into());
            }
            for i in slot.fired {
                if i >= n {
                    return Err(format!("fired index {i} out of range"));
                }
                counts[i] += 1;
            }
        }
        let total: u64 = counts.iter().sum();
        let expect = total as f64 / n as f64;
        for (i, &c) in counts.iter().enumerate() {
            if (c as f64 - expect).abs() > expect * 0.5 {
                return Err(format!("node {i} count {c} vs expected {expect:.0}"));
            }
        }
        Ok(())
    });
}

#[test]
fn random_regular_graphs_always_valid() {
    check("random-regular", 25, 0xD00D, |g| {
        let n = g.usize_in(6, 24);
        let mut k = g.usize_in(2, (n - 1).min(8));
        if (n * k) % 2 == 1 {
            k -= 1;
        }
        let k = k.max(2);
        let graph = random_regular(n, k, &mut g.rng);
        if graph.is_regular() != Some(k) {
            return Err(format!("not {k}-regular"));
        }
        if !graph.is_connected() {
            return Err("disconnected".into());
        }
        // Simple: no self-loops (enforced) and degree == neighbor count.
        for u in 0..n {
            let nb = graph.neighbors(u);
            if nb.windows(2).any(|w| w[0] == w[1]) {
                return Err("duplicate neighbor".into());
            }
            if nb.contains(&u) {
                return Err("self-loop".into());
            }
        }
        Ok(())
    });
}

#[test]
fn spectral_bound_ranges_and_ordering() {
    check("spectral-bound", 10, 0xE77A, |g| {
        let n = 2 * g.usize_in(4, 14); // even, 8..28
        let k1 = 2;
        let k2 = (n / 2).min(10);
        let g1 = make_regular(n, k1);
        let g2 = make_regular(n, k2);
        let e1 = spectral::lemma1_eta_lower_bound(&g1);
        let e2 = spectral::lemma1_eta_lower_bound(&g2);
        if !(0.0 < e1 && e1 <= 1.0 + 1e-9) {
            return Err(format!("eta1 out of range: {e1}"));
        }
        if !(0.0 < e2 && e2 <= 1.0 + 1e-9) {
            return Err(format!("eta2 out of range: {e2}"));
        }
        if e2 < e1 - 1e-6 {
            return Err(format!("denser graph got smaller bound: {e2} < {e1}"));
        }
        Ok(())
    });
}

#[test]
fn gossip_idempotent_at_consensus() {
    check("gossip-idempotent", 30, 0xF00D, |g| {
        let n = g.usize_in(3, 10);
        let len = g.usize_in(1, 16);
        let graph = random_connected_graph(g, n);
        let shared = g.f32_vec(len, -3.0, 3.0);
        let params: Vec<Vec<f32>> = (0..n).map(|_| shared.clone()).collect();
        let m = g.usize_in(0, n - 1);
        let hood = graph.closed_neighborhood(m);
        let rows: Vec<&[f32]> = hood.iter().map(|&i| params[i].as_slice()).collect();
        let avg = dasgd::linalg::mean_of(&rows);
        dasgd::util::proptest::assert_allclose(&avg, &shared, 1e-5, 1e-6)
    });
}

#[test]
fn sorted_lockup_order_never_deadlocks() {
    // The §IV-C lock-up acquires the closed neighborhood's locks in
    // sorted node order. The runtime uses try-lock (abort on busy), but
    // the sorted order makes even *blocking* acquisition deadlock-free:
    // every initiator acquires along a single global total order, so the
    // wait-for graph cannot contain a cycle. Simulate any set of
    // simultaneous initiators with blocking semantics and assert the
    // system always drains.
    check("sorted-lockup-deadlock-free", 50, 0x10CC, |g| {
        let n = g.usize_in(4, 24);
        let graph = random_connected_graph(g, n);
        let mut initiators: Vec<usize> = (0..n).filter(|_| g.bool()).collect();
        if initiators.is_empty() {
            initiators.push(g.usize_in(0, n - 1));
        }
        let hoods: Vec<Vec<usize>> = initiators
            .iter()
            .map(|&m| graph.closed_neighborhood(m))
            .collect();
        // owner[lock] = which initiator currently holds it.
        let mut owner: Vec<Option<usize>> = vec![None; n];
        // next[i] = how far initiator i has acquired along its sorted hood.
        let mut next = vec![0usize; initiators.len()];
        let mut done = vec![false; initiators.len()];
        let mut remaining = initiators.len();
        while remaining > 0 {
            let mut progressed = false;
            for i in 0..initiators.len() {
                if done[i] {
                    continue;
                }
                while next[i] < hoods[i].len() {
                    let lock = hoods[i][next[i]];
                    match owner[lock] {
                        None => {
                            owner[lock] = Some(i);
                            next[i] += 1;
                            progressed = true;
                        }
                        Some(o) if o == i => next[i] += 1,
                        Some(_) => break, // blocked: wait for the holder
                    }
                }
                if next[i] == hoods[i].len() {
                    // Full neighborhood held: project, then release all.
                    for &l in &hoods[i] {
                        if owner[l] == Some(i) {
                            owner[l] = None;
                        }
                    }
                    done[i] = true;
                    remaining -= 1;
                    progressed = true;
                }
            }
            if !progressed {
                return Err(format!(
                    "deadlock: {remaining} initiators stuck in a wait-for cycle \
                     (initiators {initiators:?})"
                ));
            }
        }
        // Every lock was released.
        if owner.iter().any(Option::is_some) {
            return Err("locks leaked after all initiators finished".into());
        }
        Ok(())
    });
}

#[test]
fn distributed_matches_central_throughput_share() {
    // With non-uniform rates, per-node selection shares follow rates —
    // the §IV-A "preferred probability" design, as a property.
    check("weighted-rates", 6, 0xFEED, |g| {
        use dasgd::coordinator::GeometricSelector;
        let n = g.usize_in(2, 6);
        let rates: Vec<f64> = (0..n).map(|_| g.f64_in(0.02, 0.2)).collect();
        let mut sel = GeometricSelector::with_rates(rates.clone(), g.rng.next_u64());
        let mut counts = vec![0f64; n];
        for _ in 0..30_000 {
            for i in sel.next().fired {
                counts[i] += 1.0;
            }
        }
        let total: f64 = counts.iter().sum();
        let rate_total: f64 = rates.iter().sum();
        for i in 0..n {
            let got = counts[i] / total;
            let want = rates[i] / rate_total;
            if (got - want).abs() > want * 0.25 {
                return Err(format!("node {i}: share {got:.3} vs rate share {want:.3}"));
            }
        }
        Ok(())
    });
}
