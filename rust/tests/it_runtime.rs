//! Integration: PJRT engine loads the AOT artifacts and agrees with the
//! rust-native math — the cross-layer correctness signal.
//!
//! Requires `make artifacts` to have run (skips with a message if not).

use dasgd::model::LogReg;
use dasgd::runtime::Engine;
use dasgd::util::proptest::assert_allclose;
use dasgd::util::rng::Xoshiro256pp;

fn engine_or_skip() -> Option<Engine> {
    match Engine::load("artifacts") {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn onehot(label: usize, c: usize) -> Vec<f32> {
    let mut v = vec![0.0; c];
    v[label] = 1.0;
    v
}

#[test]
fn manifest_lists_all_expected_artifacts() {
    let Some(engine) = engine_or_skip() else { return };
    for name in [
        "logreg_step_synth_b1",
        "logreg_step_synth_b8",
        "logreg_step_notmnist_b1",
        "logreg_step_notmnist_b8",
        "logreg_eval_synth",
        "logreg_eval_notmnist",
        "gossip_avg_synth",
        "gossip_avg_notmnist",
        "gossip_avg_dim50",
        "hinge_step_b1",
        "hinge_eval",
        "lasso_step_b1",
        "lasso_eval",
    ] {
        assert!(engine.has(name), "missing artifact {name}");
    }
}

#[test]
fn logreg_step_artifact_matches_native() {
    let Some(mut engine) = engine_or_skip() else { return };
    let (d, c) = (50usize, 10usize);
    let mut rng = Xoshiro256pp::seeded(42);
    let w: Vec<f32> = (0..d * c).map(|_| rng.gauss_f32(0.0, 0.2)).collect();
    let x: Vec<f32> = (0..d).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    let label = 3usize;
    let y = onehot(label, c);
    let lr = [0.1f32];
    let scale = [1.0f32 / 30.0];

    let outs = engine
        .execute_f32(
            "logreg_step_synth_b1",
            &[&w, &x, &y, &lr, &scale],
        )
        .unwrap();
    assert_eq!(outs.len(), 2);
    let (w_hlo, loss_hlo) = (&outs[0], outs[1][0]);

    let mut native = LogReg::from_weights(d, c, w.clone());
    let loss_native = native.sgd_step(&[&x], &[label], 0.1, 1.0 / 30.0);

    assert_allclose(w_hlo, &native.w, 1e-4, 1e-6).unwrap();
    assert!(
        (loss_hlo - loss_native).abs() < 1e-4,
        "loss hlo={loss_hlo} native={loss_native}"
    );
}

#[test]
fn logreg_eval_artifact_matches_native() {
    let Some(mut engine) = engine_or_skip() else { return };
    let (d, c, n) = (50usize, 10usize, 256usize);
    let mut rng = Xoshiro256pp::seeded(7);
    let w: Vec<f32> = (0..d * c).map(|_| rng.gauss_f32(0.0, 0.3)).collect();
    let mut xs = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n * c);
    for _ in 0..n {
        for _ in 0..d {
            xs.push(rng.gauss_f32(0.0, 1.0));
        }
        let l = rng.index(c);
        labels.push(l);
        y.extend(onehot(l, c));
    }
    let outs = engine
        .execute_f32("logreg_eval_synth", &[&w, &xs, &y])
        .unwrap();
    let (loss_hlo, err_hlo) = (outs[0][0], outs[1][0]);

    let native = LogReg::from_weights(d, c, w);
    let eval = native.evaluate(&xs, &labels);
    assert!(
        (loss_hlo - eval.loss_sum).abs() / eval.loss_sum.abs() < 1e-3,
        "loss hlo={loss_hlo} native={}",
        eval.loss_sum
    );
    assert_eq!(err_hlo as usize, eval.err_count);
}

#[test]
fn gossip_artifact_matches_mean() {
    let Some(mut engine) = engine_or_skip() else { return };
    let k = 500usize; // synth: 50*10
    let m = 16usize;
    let live = 5usize;
    let mut rng = Xoshiro256pp::seeded(3);
    let mut p = vec![0.0f32; m * k];
    for row in 0..live {
        for j in 0..k {
            p[row * k + j] = rng.gauss_f32(0.0, 1.0);
        }
    }
    let mut wts = vec![0.0f32; m];
    for w in wts.iter_mut().take(live) {
        *w = 1.0 / live as f32;
    }
    let outs = engine.execute_f32("gossip_avg_synth", &[&p, &wts]).unwrap();
    let avg = &outs[0];
    // Native mean of the live rows.
    let rows: Vec<&[f32]> = (0..live).map(|r| &p[r * k..(r + 1) * k]).collect();
    let expect = dasgd::linalg::mean_of(&rows);
    assert_allclose(avg, &expect, 1e-5, 1e-6).unwrap();
}

#[test]
fn hinge_and_lasso_artifacts_match_native() {
    let Some(mut engine) = engine_or_skip() else { return };
    let d = 50usize;
    let mut rng = Xoshiro256pp::seeded(11);
    let w: Vec<f32> = (0..d).map(|_| rng.gauss_f32(0.0, 0.5)).collect();
    let x: Vec<f32> = (0..d).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    let lr = [0.05f32];
    let scale = [1.0f32];
    let lam = [0.01f32];

    // Hinge, y = -1.
    let y = [-1.0f32];
    let outs = engine
        .execute_f32("hinge_step_b1", &[&w, &x, &y, &lr, &scale, &lam])
        .unwrap();
    let mut wn = w.clone();
    let loss_native =
        dasgd::model::hinge_step_native(&mut wn, &[&x], &[-1.0], 0.05, 1.0, 0.01);
    assert_allclose(&outs[0], &wn, 1e-4, 1e-6).unwrap();
    assert!((outs[1][0] - loss_native).abs() < 1e-4);

    // Lasso, y = 0.7.
    let y = [0.7f32];
    let outs = engine
        .execute_f32("lasso_step_b1", &[&w, &x, &y, &lr, &scale, &lam])
        .unwrap();
    let mut wn = w.clone();
    let loss_native =
        dasgd::model::lasso_step_native(&mut wn, &[&x], &[0.7], 0.05, 1.0, 0.01);
    assert_allclose(&outs[0], &wn, 1e-4, 1e-6).unwrap();
    assert!((outs[1][0] - loss_native).abs() < 1e-4);
}

#[test]
fn hinge_lasso_eval_artifacts_match_native() {
    let Some(mut engine) = engine_or_skip() else { return };
    let (d, n) = (50usize, 256usize);
    let mut rng = Xoshiro256pp::seeded(23);
    let w: Vec<f32> = (0..d).map(|_| rng.gauss_f32(0.0, 0.5)).collect();
    let xs: Vec<f32> = (0..n * d).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    let labels: Vec<usize> = (0..n).map(|_| rng.index(10)).collect();
    let lam = 0.01f32;

    for obj in [
        dasgd::objective::Objective::Hinge { lam },
        dasgd::objective::Objective::Lasso { lam },
    ] {
        let targets = obj.encode_targets(&labels, 10);
        let name = obj.pjrt_eval_artifact("synth").unwrap();
        let outs = engine
            .execute_f32(&name, &[&w, &xs, &targets, &[lam]])
            .unwrap();
        let (loss, err) = obj.pjrt_eval_outputs(outs[0][0], outs[1][0], n);
        let (nl, ne) = obj.native_eval(&w, d, 10, &xs, &labels, &targets);
        assert!(
            (loss - nl).abs() < 1e-3 * nl.abs().max(1.0),
            "{obj}: loss hlo={loss} native={nl}"
        );
        assert!(
            (err - ne).abs() < 1e-4,
            "{obj}: err hlo={err} native={ne}"
        );
    }
}

#[test]
fn gossip_dim50_artifact_matches_mean() {
    let Some(mut engine) = engine_or_skip() else { return };
    let (k, m, live) = (50usize, 16usize, 4usize);
    let mut rng = Xoshiro256pp::seeded(31);
    let mut p = vec![0.0f32; m * k];
    for row in 0..live {
        for j in 0..k {
            p[row * k + j] = rng.gauss_f32(0.0, 1.0);
        }
    }
    let mut wts = vec![0.0f32; m];
    for w in wts.iter_mut().take(live) {
        *w = 1.0 / live as f32;
    }
    let outs = engine.execute_f32("gossip_avg_dim50", &[&p, &wts]).unwrap();
    let rows: Vec<&[f32]> = (0..live).map(|r| &p[r * k..(r + 1) * k]).collect();
    let expect = dasgd::node_logic::neighborhood_average(&rows);
    assert_allclose(&outs[0], &expect, 1e-5, 1e-6).unwrap();
}

#[test]
fn engine_rejects_bad_shapes_and_names() {
    let Some(mut engine) = engine_or_skip() else { return };
    assert!(engine.execute_f32("no_such_artifact", &[]).is_err());
    let short = vec![0.0f32; 3];
    assert!(engine
        .execute_f32("logreg_step_synth_b1", &[&short])
        .is_err());
}

#[test]
fn executor_service_roundtrip_from_threads() {
    use dasgd::runtime::ExecutorService;
    if Engine::load("artifacts").is_err() {
        eprintln!("SKIP (run `make artifacts`)");
        return;
    }
    let service = ExecutorService::start("artifacts", 2).unwrap();
    let mut joins = Vec::new();
    for t in 0..4 {
        let h = service.handle();
        joins.push(std::thread::spawn(move || {
            let mut rng = Xoshiro256pp::seeded(100 + t);
            let (d, c) = (50usize, 10usize);
            let w: Vec<f32> = (0..d * c).map(|_| rng.gauss_f32(0.0, 0.1)).collect();
            let x: Vec<f32> = (0..d).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let mut y = vec![0.0f32; c];
            y[(t as usize) % c] = 1.0;
            let outs = h
                .execute_f32(
                    "logreg_step_synth_b1",
                    &[&w, &x, &y, &[0.1f32], &[1.0f32]],
                )
                .unwrap();
            assert_eq!(outs[0].len(), d * c);
            assert!(outs[1][0].is_finite());
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
}
