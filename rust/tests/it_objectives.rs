//! Integration: objective parity and generality.
//!
//! * Golden-vector tests pin `hinge_step_native` / `lasso_step_native`
//!   to the Pallas reference kernels' semantics: the constants below
//!   were produced by running `python/compile/kernels/{hinge,lasso}.py`
//!   (`hinge_step` / `lasso_step`, interpret mode) on these exact
//!   inputs. If either side drifts, this suite fails.
//! * Backend-parity tests assert the `Objective`-dispatched
//!   `StepBackend::grad_step` equals the raw kernels under the label
//!   encoding.
//! * Trainer smoke tests prove each objective runs through the *same*
//!   `Trainer`/`StepBackend` path with a decreasing consensus residual.

use dasgd::coordinator::{NativeBackend, StepBackend, TrainConfig, Trainer};
use dasgd::experiments::{make_regular, synth_world};
use dasgd::model::{hinge_step_native, lasso_step_native};
use dasgd::objective::Objective;
use dasgd::util::proptest::assert_allclose;

#[test]
fn golden_hinge_step_matches_pallas_kernel() {
    // B = 2, D = 4, both margins active; lr 0.2, scale 0.5, λ 0.01.
    let mut w = vec![0.5f32, -0.25, 0.1, 0.0];
    let x1 = [1.0f32, 2.0, -1.0, 0.5];
    let x2 = [0.2f32, -0.3, 0.4, 1.0];
    let loss = hinge_step_native(&mut w, &[&x1, &x2], &[1.0, -1.0], 0.2, 0.5, 0.01);
    // Golden outputs from the Pallas hinge_step kernel.
    assert_allclose(&w, &[0.539, -0.1345, 0.0298, -0.025], 1e-6, 1e-6).unwrap();
    assert!((loss - 1.160725).abs() < 1e-5, "loss {loss}");
}

#[test]
fn golden_hinge_inactive_margin_matches_pallas_kernel() {
    // Margin ≫ 1: the data term vanishes; only 2λw shrinkage remains.
    let mut w = vec![0.5f32; 4];
    let x = [10.0f32; 4];
    let loss = hinge_step_native(&mut w, &[&x], &[1.0], 0.1, 1.0, 0.05);
    assert_allclose(&w, &[0.495; 4], 1e-6, 1e-6).unwrap();
    assert!((loss - 0.05).abs() < 1e-6, "loss {loss}"); // λ‖w‖² only
}

#[test]
fn golden_lasso_step_matches_pallas_kernel() {
    // B = 2, D = 4; note w[3] = 0 exercises sign(0) = 0; lr 0.1, λ 0.05.
    let mut w = vec![1.0f32, -2.0, 0.5, 0.0];
    let x1 = [3.0f32, 1.0, 0.0, 2.0];
    let x2 = [0.5f32, 0.5, 0.5, 0.5];
    let loss = lasso_step_native(&mut w, &[&x1, &x2], &[2.0, 0.0], 0.1, 1.0, 0.05);
    // Golden outputs from the Pallas lasso_step kernel.
    assert_allclose(&w, &[1.15125, -1.93875, 0.50125, 0.10625], 1e-6, 1e-6).unwrap();
    assert!((loss - 0.440625).abs() < 1e-5, "loss {loss}");
}

#[test]
fn backend_grad_step_equals_raw_kernels_under_encoding() {
    let (dim, classes) = (6usize, 4usize);
    let xs: Vec<f32> = (0..dim).map(|i| ((i * 7 + 3) as f32 * 0.21).cos()).collect();
    for obj in [Objective::hinge(), Objective::lasso()] {
        for label in 0..classes {
            let mut backend = NativeBackend::for_objective(obj, dim, classes);
            let mut w_b = vec![0.2f32; dim];
            let mut w_raw = w_b.clone();
            let loss_b = backend.grad_step(&mut w_b, &xs, &[label], 0.15, 0.25).unwrap();
            let y = obj.encode_label(label, classes);
            let loss_raw = match obj {
                Objective::Hinge { lam } => {
                    hinge_step_native(&mut w_raw, &[&xs], &[y], 0.15, 0.25, lam)
                }
                Objective::Lasso { lam } => {
                    lasso_step_native(&mut w_raw, &[&xs], &[y], 0.15, 0.25, lam)
                }
                Objective::LogReg => unreachable!(),
            };
            assert_eq!(w_b, w_raw, "{obj} label {label}");
            assert_eq!(loss_b, loss_raw, "{obj} label {label}");
        }
    }
}

/// One Alg. 2 run per objective through the identical trainer path.
fn smoke(obj: Objective, seed: u64) -> (f64, f64, f64, f64) {
    let n = 8;
    let (shards, test) = synth_world(n, 100, 256, seed);
    let cfg = TrainConfig::objective_default(obj, n)
        .with_init_scale(1.0)
        .with_seed(seed);
    let mut t = Trainer::new(
        cfg,
        make_regular(n, 4),
        shards,
        NativeBackend::for_objective(obj, 50, 10),
    );
    let rec = t.run(2000, 2000, &test, obj.name()).unwrap();
    // Parameter shape follows the objective.
    for w in t.params() {
        assert_eq!(w.len(), obj.param_len(50, 10), "{obj}");
        assert!(w.iter().all(|v| v.is_finite()), "{obj}");
    }
    // Both step kinds ran, through the one shared code path.
    assert!(t.counters.grad_steps > 0 && t.counters.proj_steps > 0);
    let first = rec.records.first().unwrap();
    let last = rec.last().unwrap();
    (first.consensus, last.consensus, first.test_err, last.test_err)
}

#[test]
fn trainer_smoke_logreg_consensus_decreases() {
    let (d0, d1, e0, e1) = smoke(Objective::LogReg, 11);
    assert!(d1 < d0 * 0.5, "consensus {d0} -> {d1}");
    assert!(e1 <= e0, "err {e0} -> {e1}");
}

#[test]
fn trainer_smoke_hinge_consensus_decreases() {
    let (d0, d1, e0, e1) = smoke(Objective::hinge(), 13);
    assert!(d1 < d0 * 0.5, "consensus {d0} -> {d1}");
    assert!(e1 <= e0 + 0.05, "binary err {e0} -> {e1}");
}

#[test]
fn trainer_smoke_lasso_consensus_decreases() {
    let (d0, d1, e0, e1) = smoke(Objective::lasso(), 17);
    assert!(d1 < d0 * 0.5, "consensus {d0} -> {d1}");
    assert!(e1 < e0, "rmse {e0} -> {e1}");
}
