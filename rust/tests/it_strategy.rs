//! Integration: the strategy layer's equivalence pins.
//!
//! The refactor contract for the algorithm zoo (docs/algorithms.md) is
//! that routing the paper baseline through the [`Strategy`] trait is a
//! pure factoring: in deterministic mode the engines reproduce the
//! pre-refactor trace *bit for bit* — same RNG streams, same event
//! schedule, same counters, same parameter bytes. These tests pin that
//! from outside the crate, against hand-written Eq. (6)/(7) loops that
//! never touch the trait:
//!
//! * the event-driven SimNet driver vs. an inline reimplementation of
//!   its pre-refactor loop (`NodeLogic::draw_action` +
//!   `native_grad_step` + `neighborhood_average`, no strategies);
//! * both deterministic wall-clock engines (single-executor virtual
//!   time and the sequenced thread-per-node baseline) against each
//!   other on an explicit dasgd plan;
//! * every zoo member on the same deterministic schedule: identical
//!   action/sample draws mean identical Counts, and only the update
//!   math may differ.

use std::sync::Arc;
use std::time::Duration;

use dasgd::coordinator::{spawn_shard, AsyncConfig, EngineKind, ShardRun, StepSize};
use dasgd::data::{Dataset, SyntheticGen};
use dasgd::graph::{regular_circulant, Graph};
use dasgd::metrics::{Record, Recorder};
use dasgd::node_logic::{
    neighborhood_average, Action, Counts, NodeLogic, Probe, StrategyKind,
};
use dasgd::objective::Objective;
use dasgd::sim::{simnet_run_plan, ShardedEventQueue, SimConfig, SpeedModel};
use dasgd::transport::{
    LatencyModel, ProjectionOutcome, SharedMem, SimNet, SimNetConfig, Transport,
};
use dasgd::util::rng::Xoshiro256pp;
use dasgd::workload::WorkloadPlan;

const SEED: u64 = 42;
const NODES: usize = 8;

fn world() -> (Graph, Vec<Dataset>, Dataset) {
    let gen = SyntheticGen::new(NODES, 10, 4, 2.0, 0.5, 0.3, SEED);
    let mut rng = Xoshiro256pp::seeded(SEED);
    let shards = (0..NODES)
        .map(|i| gen.node_dataset(i, 40, &mut rng))
        .collect();
    let test = gen.global_test_set(200, &mut rng);
    (regular_circulant(NODES, 2), shards, test)
}

fn sim_cfg(net: SimNetConfig) -> SimConfig {
    SimConfig {
        p_grad: 0.5,
        stepsize: StepSize::paper_default(NODES),
        objective: Objective::LogReg,
        horizon: 30.0,
        eval_every: 7.5,
        net,
        seed: SEED,
    }
}

fn bits(params: &[Vec<f32>]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|w| w.iter().map(|v| v.to_bits()).collect())
        .collect()
}

/// The pre-refactor SimNet driver loop, reimplemented inline with the
/// raw Eq. (6)/(7) helpers and **no strategy objects**: one
/// `NodeLogic` per node, `draw_action` → `native_grad_step` or a plain
/// `neighborhood_average` mix, with the exact RNG call order the
/// driver has always used (compute draw before the action draw).
fn pre_refactor_simnet(
    g: &Graph,
    shards: &[Dataset],
    test: &Dataset,
    speeds: &SpeedModel,
    cfg: &SimConfig,
) -> (Recorder, u64, Counts, Vec<Vec<f32>>) {
    let n = g.len();
    let param_len = cfg
        .objective
        .param_len(shards[0].dim(), shards[0].classes());
    let mut root = Xoshiro256pp::seeded(cfg.seed);
    let mut logics: Vec<NodeLogic> = (0..n)
        .map(|i| {
            NodeLogic::new(
                i,
                cfg.objective,
                cfg.p_grad,
                shards[i].clone(),
                n,
                root.split(i as u64),
            )
        })
        .collect();
    let hoods: Vec<Vec<usize>> = (0..n).map(|i| g.closed_neighborhood(i)).collect();
    let net = SimNet::new(n, param_len, cfg.net.clone());
    let probe = Probe::new(cfg.objective, test);

    let mut queue = ShardedEventQueue::for_nodes(n);
    for (i, logic) in logics.iter_mut().enumerate() {
        let dt = speeds.sample(i, &mut logic.rng);
        queue.push(dt, i);
    }

    let mut rec = Recorder::new("simnet");
    let mut k = 0u64;
    let mut counts = Counts::default();
    let mut next_eval = 0.0f64;
    let snap = |t: f64, k: u64, counts: &Counts, net: &SimNet, rec: &mut Recorder| {
        let mut c = *counts;
        c.messages = net.net_stats().0;
        rec.push(probe.snapshot(k, t, &net.snapshot(), &c));
    };

    while let Some((t, i)) = queue.pop() {
        if t > cfg.horizon {
            break;
        }
        while t >= next_eval {
            snap(next_eval, k, &counts, &net, &mut rec);
            next_eval += cfg.eval_every;
        }
        net.set_now(t);
        let lr = cfg.stepsize.at(k);
        let logic = &mut logics[i];
        let mut op_time = speeds.sample(i, &mut logic.rng);
        match logic.draw_action() {
            Action::Grad => {
                net.update_own_with_aux(i, &mut |w, _aux| {
                    logic.native_grad_step(w, lr);
                });
                counts.grad_steps += 1;
                k += 1;
            }
            Action::Project => {
                match net.try_project(i, &hoods[i], Duration::ZERO, &mut |rows, _aux| {
                    (neighborhood_average(rows), Vec::new())
                }) {
                    ProjectionOutcome::Applied { .. } => {
                        op_time += net.take_last_comm();
                        counts.proj_steps += 1;
                        k += 1;
                    }
                    ProjectionOutcome::Isolated => {}
                    ProjectionOutcome::Conflict => unreachable!("SimNet is conflict-free"),
                }
            }
        }
        queue.push(t + op_time, i);
    }
    snap(cfg.horizon, k, &counts, &net, &mut rec);
    (rec, k, counts, net.snapshot())
}

fn assert_records_identical(ours: &[Record], theirs: &[Record], tag: &str) {
    assert_eq!(ours.len(), theirs.len(), "{tag}: snapshot count diverged");
    for (i, (a, b)) in ours.iter().zip(theirs).enumerate() {
        assert_eq!(a, b, "{tag}: record {i} diverged");
    }
}

#[test]
fn dasgd_reproduces_the_pre_refactor_simnet_trace() {
    let (g, shards, test) = world();
    let speeds = SpeedModel::homogeneous(NODES, 1.0);
    let lossy = SimNetConfig {
        latency: LatencyModel {
            min_secs: 0.005,
            max_secs: 0.02,
            jitter_secs: 0.005,
        },
        drop_prob: 0.05,
        partitions: vec![],
        seed: SEED,
    };
    for (tag, net) in [
        ("ideal", SimNetConfig::ideal(0.002)),
        ("lossy", lossy),
    ] {
        let cfg = sim_cfg(net);
        let (rec, k, counts, params) =
            pre_refactor_simnet(&g, &shards, &test, &speeds, &cfg);
        // The refactored path: the same plan routed through the
        // baseline strategy (the plan default).
        let plan = WorkloadPlan::homogeneous(cfg.objective, shards.clone());
        let rep = simnet_run_plan(&g, &plan, &test, &speeds, &cfg);
        assert_eq!(rep.updates, k, "{tag}: update counter diverged");
        assert_eq!(rep.grad_steps, counts.grad_steps, "{tag}");
        assert_eq!(rep.proj_steps, counts.proj_steps, "{tag}");
        assert!(rep.updates > 100, "{tag}: trace too short to mean much");
        assert_records_identical(&rec.records, &rep.recorder.records, tag);
        assert_eq!(
            bits(&params),
            bits(&rep.final_params),
            "{tag}: parameter bytes diverged through the strategy layer"
        );
    }
}

/// Run a fixed dasgd ring deterministically on the given engine and
/// return (params, counts) after exactly `budget` firings.
fn deterministic_trace(
    kind: StrategyKind,
    engine: EngineKind,
    budget: u64,
) -> (Vec<Vec<f32>>, Counts) {
    let gen = SyntheticGen::new(NODES, 10, 4, 2.0, 0.5, 0.3, SEED);
    let mut rng = Xoshiro256pp::seeded(SEED);
    let shards: Vec<Dataset> = (0..NODES)
        .map(|i| gen.node_dataset(i, 40, &mut rng))
        .collect();
    let plan =
        WorkloadPlan::homogeneous(Objective::LogReg, shards).with_uniform_strategy(kind);
    let graph = regular_circulant(NODES, 2);
    let cfg = AsyncConfig {
        engine,
        deterministic_events: Some(budget),
        seed: SEED,
        ..AsyncConfig::quick(NODES)
    };
    let transport: Arc<dyn Transport> = Arc::new(SharedMem::new(NODES, plan.param_len()));
    let run = spawn_shard(&graph, &plan, &cfg, Arc::clone(&transport), 0..NODES, None);
    let counts = wait_for_budget(run, budget);
    (transport.snapshot(), counts)
}

/// The deterministic engines stop themselves once `budget` firings have
/// executed, and on an all-alive SharedMem ring every firing lands in
/// exactly one counter — so the counter sum reaching the budget means
/// the engine is done. (Stopping earlier would truncate the trace and
/// break the bit-for-bit comparison, hence the wait.)
fn wait_for_budget(run: ShardRun, budget: u64) -> Counts {
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    loop {
        let c = run.counts();
        if c.grad_steps + c.proj_steps + c.conflicts >= budget {
            return run.stop_and_join();
        }
        assert!(
            std::time::Instant::now() < deadline,
            "deterministic engine stalled at {c:?} of {budget} events"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn the_dasgd_pin_holds_in_both_deterministic_engines() {
    // An explicit dasgd plan through the executor pool and the
    // sequenced thread-per-node engine: identical counters and
    // parameter bits at every probed budget.
    for budget in [120u64, 350] {
        let (p_pool, c_pool) =
            deterministic_trace(StrategyKind::Dasgd, EngineKind::Executors(1), budget);
        let (p_tpn, c_tpn) =
            deterministic_trace(StrategyKind::Dasgd, EngineKind::ThreadPerNode, budget);
        assert_eq!(c_pool, c_tpn, "counters diverged at budget {budget}");
        assert!(c_pool.updates() > 0, "no updates at budget {budget}");
        assert_eq!(
            bits(&p_pool),
            bits(&p_tpn),
            "params diverged across engines at budget {budget}"
        );
    }
}

#[test]
fn every_strategy_keeps_the_deterministic_event_schedule() {
    // The comparability contract behind `dasgd compare`: strategies
    // consume identical RNG draws, so on a fixed seed every zoo member
    // fires the same events with the same grad/project split — only
    // the update math may differ.
    let budget = 250u64;
    let (p_base, c_base) =
        deterministic_trace(StrategyKind::Dasgd, EngineKind::Executors(1), budget);
    for kind in StrategyKind::ALL {
        let (p, c) = deterministic_trace(kind, EngineKind::Executors(1), budget);
        assert_eq!(c, c_base, "{kind}: event schedule diverged");
        for (id, w) in p.iter().enumerate() {
            assert!(
                w.iter().all(|v| v.is_finite()),
                "{kind}: node {id} diverged to non-finite params"
            );
        }
        if kind == StrategyKind::Rfast {
            // Gradient tracking genuinely changes the trajectory.
            assert_ne!(bits(&p), bits(&p_base), "rfast must not be a no-op");
        }
    }
}

#[test]
fn strategies_share_one_simnet_schedule() {
    // Same contract under the virtual-time driver: one world, four
    // strategies, identical event/update counts.
    let (g, shards, test) = world();
    let speeds = SpeedModel::homogeneous(NODES, 1.0);
    let cfg = sim_cfg(SimNetConfig::ideal(0.002));
    let base = simnet_run_plan(
        &g,
        &WorkloadPlan::homogeneous(cfg.objective, shards.clone()),
        &test,
        &speeds,
        &cfg,
    );
    for kind in StrategyKind::ALL {
        let plan = WorkloadPlan::homogeneous(cfg.objective, shards.clone())
            .with_uniform_strategy(kind);
        let rep = simnet_run_plan(&g, &plan, &test, &speeds, &cfg);
        assert_eq!(rep.updates, base.updates, "{kind}");
        assert_eq!(rep.grad_steps, base.grad_steps, "{kind}");
        assert_eq!(rep.proj_steps, base.proj_steps, "{kind}");
        let last = rep.recorder.last().expect("snapshots recorded");
        assert!(
            last.consensus.is_finite() && last.test_err.is_finite(),
            "{kind}: non-finite outcome"
        );
    }
}
