//! Property tests for membership repair and the worker-side topology
//! view: any removal/add sequence on an arbitrary regular launch graph
//! keeps the active subgraph connected with degrees within ±1 of the
//! launch degree and never orphans a node, patches describe exactly
//! the graph the monitor holds, and a worker replaying the patch
//! stream converges to that same graph.

use dasgd::experiments::make_regular;
use dasgd::membership::{Membership, TopologyView};
use dasgd::util::proptest::{check, Gen};

/// One churn op against a [`Membership`], driven by the generator:
/// deactivate a random batch of active nodes (never below the
/// `degree + 2` floor the guarantees are stated for) or re-admit a
/// random batch of inactive ones.
fn churn_step(
    g: &mut Gen,
    m: &mut Membership,
    d0: usize,
) -> (Vec<usize>, bool, Vec<(u32, Vec<u32>)>) {
    let n = m.graph().len();
    let inactive: Vec<usize> = (0..n).filter(|&u| !m.is_active(u)).collect();
    let add = !inactive.is_empty() && g.bool();
    if add {
        let count = g.usize_in(1, inactive.len());
        let mut nodes = Vec::new();
        for _ in 0..count {
            let pick = *g.choose(&inactive);
            if !nodes.contains(&pick) {
                nodes.push(pick);
            }
        }
        let patch = m.activate(&nodes);
        (nodes, true, patch)
    } else {
        let active: Vec<usize> = (0..n).filter(|&u| m.is_active(u)).collect();
        // Keep at least d0 + 2 nodes active — the floor the repair
        // guarantees are stated for (see membership::repair).
        let room = active.len().saturating_sub(d0 + 2);
        let mut nodes = Vec::new();
        if room > 0 {
            for _ in 0..g.usize_in(1, room.min(4)) {
                let pick = *g.choose(&active);
                if !nodes.contains(&pick) {
                    nodes.push(pick);
                }
            }
        }
        // room == 0: too small to remove anyone — the empty deactivate
        // still exercises the patch path (and must be a graph no-op).
        let patch = m.deactivate(&nodes);
        (nodes, false, patch)
    }
}

/// The repair guarantees, checked wholesale.
fn check_invariants(m: &Membership, d0: usize) -> Result<(), String> {
    if !m.is_active_connected() {
        return Err("active subgraph disconnected".into());
    }
    let g = m.graph();
    for u in 0..g.len() {
        let d = g.degree(u);
        if m.is_active(u) {
            if m.active_count() > 1 && d == 0 {
                return Err(format!("active node {u} orphaned"));
            }
            if d + 1 < d0 || d > d0 + 1 {
                return Err(format!("node {u}: degree {d} outside {d0}±1"));
            }
        } else if d != 0 {
            return Err(format!("inactive node {u} still holds {d} edges"));
        }
        for &v in g.neighbors(u) {
            if v == u {
                return Err(format!("self-loop at {u}"));
            }
            if !g.has_edge(v, u) {
                return Err(format!("asymmetric edge {u}-{v}"));
            }
        }
    }
    Ok(())
}

#[test]
fn arbitrary_churn_preserves_connectivity_and_degree() {
    check("membership-churn", 60, 0x3E7A, |g| {
        let degree = *g.choose(&[2usize, 3, 4, 6]);
        let n = g.usize_in(degree + 6, 40);
        let mut m = Membership::new(make_regular(n, degree), degree);
        check_invariants(&m, degree)?;
        for _ in 0..g.usize_in(1, 6) {
            let (_, _, patch) = churn_step(g, &mut m, degree);
            check_invariants(&m, degree)?;
            // The patch is exactly the monitor's graph at the touched
            // nodes: full sorted neighbor lists, empty for vacated
            // nodes.
            for (node, hood) in &patch {
                let now: Vec<u32> = m
                    .graph()
                    .neighbors(*node as usize)
                    .iter()
                    .map(|&v| v as u32)
                    .collect();
                if hood != &now {
                    return Err(format!(
                        "patch for node {node} says {hood:?}, graph has {now:?}"
                    ));
                }
                if !m.is_active(*node as usize) && !hood.is_empty() {
                    return Err(format!("vacated node {node} shipped edges {hood:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn patch_stream_replays_to_the_monitor_graph() {
    check("membership-view-convergence", 40, 0x3E7B, |g| {
        let degree = *g.choose(&[2usize, 4]);
        let n = g.usize_in(degree + 6, 32);
        let launch = make_regular(n, degree);
        let mut m = Membership::new(launch.clone(), degree);
        let view = TopologyView::new(launch);
        let mut history: Vec<(u64, Vec<(u32, Vec<u32>)>)> = Vec::new();
        for _ in 0..g.usize_in(1, 6) {
            let (_, _, patch) = churn_step(g, &mut m, degree);
            let version = m.version();
            if !view.apply(version, &patch) {
                return Err(format!("view rejected fresh patch v{version}"));
            }
            // A replayed (stale) patch must be ignored without
            // touching the view.
            if let Some((v0, p0)) = history.last() {
                if view.apply(*v0, p0) {
                    return Err(format!("view accepted stale patch v{v0}"));
                }
            }
            history.push((version, patch));
        }
        // The worker's replayed view is the monitor's graph, edge for
        // edge — on every node, touched or not.
        let got = view.snapshot();
        for u in 0..n {
            if got.neighbors(u) != m.graph().neighbors(u) {
                return Err(format!(
                    "node {u}: view has {:?}, monitor has {:?}",
                    got.neighbors(u),
                    m.graph().neighbors(u)
                ));
            }
        }
        if view.version() != m.version() {
            return Err("view version diverged from the monitor".into());
        }
        Ok(())
    });
}
