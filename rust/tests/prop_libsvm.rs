//! Property tests for the streaming data plane's ingest side: the
//! libsvm parser is *total* (malformed, truncated, NaN-laden, or
//! duplicate-index text errors with a line number, never a panic),
//! well-formed text round-trips bit-for-bit through the dense
//! [`Dataset`], every partitioner covers a parsed corpus exactly once,
//! and a `ShardBlock` stream survives fault injection — interleaving
//! across nodes is legal, while drops, duplicates, reorders, and
//! corruption are refused totally.

use dasgd::data::parse_libsvm;
use dasgd::data::stream::{
    fold_payloads, payload_checksum, shard_checksum, RowBlock, StreamProgress,
};
use dasgd::data::Dataset;
use dasgd::objective::Objective;
use dasgd::util::proptest::{check, Gen};
use dasgd::workload::PlanSpec;

/// One well-formed libsvm line: an integral label plus strictly
/// ascending sparse pairs. Values go through `{}` formatting, which for
/// f32 is shortest-round-trip — the parse must recover the exact bits.
fn arb_line(g: &mut Gen, dim: usize, out_rows: &mut Vec<(i64, Vec<(usize, f32)>)>) -> String {
    let label = g.usize_in(0, 6) as i64 - 3;
    let mut pairs: Vec<(usize, f32)> = Vec::new();
    let mut idx = 0usize;
    loop {
        idx += g.usize_in(1, 3);
        if idx > dim || g.usize_in(0, 3) == 0 {
            break;
        }
        pairs.push((idx, g.f32_vec(1, -100.0, 100.0)[0]));
    }
    let mut line = format!("{label}");
    for (i, v) in &pairs {
        line.push_str(&format!(" {i}:{v}"));
    }
    out_rows.push((label, pairs));
    line
}

#[test]
fn well_formed_text_round_trips_exactly() {
    check("libsvm-roundtrip", 150, 0x11B5, |g| {
        let dim = g.usize_in(2, 12);
        let n = g.usize_in(1, g.size * 8 + 1);
        let mut rows = Vec::new();
        let mut text = String::from("# generated corpus\n");
        for _ in 0..n {
            text.push_str(&arb_line(g, dim, &mut rows));
            text.push('\n');
            if g.usize_in(0, 4) == 0 {
                text.push('\n'); // blank lines are skipped
            }
        }
        let d = parse_libsvm(&text, Some(dim)).map_err(|e| format!("valid text refused: {e}"))?;
        if d.len() != rows.len() {
            return Err(format!("{} rows in, {} out", rows.len(), d.len()));
        }
        if d.dim() != dim {
            return Err(format!("dim {} ≠ expected {dim}", d.dim()));
        }
        // Labels remap by sorted distinct value.
        let mut distinct: Vec<i64> = rows.iter().map(|(l, _)| *l).collect();
        distinct.sort_unstable();
        distinct.dedup();
        for (i, (raw, pairs)) in rows.iter().enumerate() {
            let want = distinct.binary_search(raw).unwrap();
            if d.labels()[i] != want {
                return Err(format!("row {i}: label {} ≠ {want}", d.labels()[i]));
            }
            let mut dense = vec![0.0f32; dim];
            for &(idx, v) in pairs {
                dense[idx - 1] = v;
            }
            let got = d.sample(i).features;
            let want_bits: Vec<u32> = dense.iter().map(|v| v.to_bits()).collect();
            let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            if want_bits != got_bits {
                return Err(format!("row {i}: feature bits changed crossing the text"));
            }
        }
        Ok(())
    });
}

#[test]
fn mutated_text_errors_never_panics() {
    check("libsvm-total", 300, 0xDEAF, |g| {
        // Start from valid text, then bend it: truncate at an arbitrary
        // byte, flip a byte, or splice in a hostile token. Any Result
        // is acceptable; a panic is not.
        let dim = g.usize_in(2, 8);
        let mut rows = Vec::new();
        let mut text = String::new();
        for _ in 0..g.usize_in(1, 10) {
            text.push_str(&arb_line(g, dim, &mut rows));
            text.push('\n');
        }
        match g.usize_in(0, 3) {
            0 => {
                let mut cut = g.usize_in(0, text.len());
                while !text.is_char_boundary(cut) {
                    cut -= 1;
                }
                let _ = parse_libsvm(&text[..cut], None);
            }
            1 => {
                let mut bytes = text.into_bytes();
                let at = g.usize_in(0, bytes.len() - 1);
                bytes[at] = g.usize_in(0, 255) as u8;
                let bent = String::from_utf8_lossy(&bytes).into_owned();
                let _ = parse_libsvm(&bent, None);
            }
            _ => {
                let intruder = *g.choose(&[
                    "nan 1:1",
                    "1 1:nan",
                    "1 1:inf",
                    "1 0:3",
                    "1 2:1 2:1",
                    "1 5:1 3:1",
                    "1 :",
                    "1 a:b",
                    "1e99 1:1",
                    "1 1:1e999",
                    "\u{0}",
                ]);
                text.push_str(intruder);
                text.push('\n');
                if parse_libsvm(&text, None).is_ok()
                    && matches!(intruder, "nan 1:1" | "1 1:nan" | "1 0:3" | "1 2:1 2:1")
                {
                    return Err(format!("hostile line {intruder:?} accepted"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn partitioners_cover_a_parsed_corpus_exactly_once() {
    check("libsvm-partition-cover", 60, 0xC0FE, |g| {
        // Row i carries the unique marker i+1 at feature 1, so shard
        // membership is readable off the partitioned rows. Every
        // marker must appear exactly once across all node shards.
        let nodes = g.usize_in(2, 6);
        let n = g.usize_in(nodes.max(4), 60);
        let mut text = String::new();
        for i in 0..n {
            text.push_str(&format!("{} 1:{}\n", i % 3, i + 1));
        }
        let base = parse_libsvm(&text, Some(2)).map_err(|e| e.to_string())?;
        let spec = *g.choose(&[
            PlanSpec::Synth,
            PlanSpec::Dirichlet { alpha: 0.3 },
            PlanSpec::Quantity { alpha: 0.4 },
            PlanSpec::Mixed { alpha: 0.5 },
        ]);
        let seed = g.usize_in(0, 1 << 20) as u64;
        let plan = spec.build_over(&base, Objective::LogReg, nodes, seed);
        let mut seen: Vec<usize> = (0..nodes)
            .flat_map(|i| {
                let s = plan.shard(i);
                (0..s.len())
                    .map(|r| s.sample(r).features[0] as usize)
                    .collect::<Vec<_>>()
            })
            .collect();
        seen.sort_unstable();
        let want: Vec<usize> = (1..=n).collect();
        if seen != want {
            return Err(format!(
                "{spec:?} over {n} rows / {nodes} nodes lost or duplicated rows \
                 ({} recovered)",
                seen.len()
            ));
        }
        Ok(())
    });
}

/// A small random dense dataset to carve into blocks.
fn arb_dataset(g: &mut Gen) -> Dataset {
    let dim = g.usize_in(1, 6);
    let classes = g.usize_in(2, 5);
    let rows = g.usize_in(1, g.size * 10 + 2);
    let mut d = Dataset::with_capacity(dim, classes, rows);
    for _ in 0..rows {
        let row = g.f32_vec(dim, -10.0, 10.0);
        let label = g.usize_in(0, classes - 1);
        d.push(&row, label);
    }
    d
}

#[test]
fn clean_block_streams_reassemble_and_certify() {
    check("stream-clean", 120, 0xB10C, |g| {
        let data = arb_dataset(g);
        let block_rows = g.usize_in(1, data.len() + 2);
        let blocks = RowBlock::carve(7, &data, block_rows);
        // Per-block self-checks pass, and the whole-shard fold equals
        // the shard's own checksum — the bit-identity certificate.
        let mut progress = StreamProgress::default();
        for b in &blocks {
            b.validate(data.dim(), data.classes())
                .map_err(|e| format!("carved block refused: {e}"))?;
            progress.fold(b).map_err(|e| format!("in-order fold refused: {e}"))?;
        }
        progress
            .verify_complete(blocks.len() as u32, data.len() as u64, fold_payloads(&blocks))
            .map_err(|e| format!("clean completion refused: {e}"))?;
        if progress.checksum() != shard_checksum(&data) {
            return Err("stream fold ≠ shard checksum".into());
        }
        // Reassembly appends back to an identical dataset.
        let mut rebuilt = Dataset::with_capacity(data.dim(), data.classes(), data.len());
        for b in &blocks {
            b.append_to(&mut rebuilt);
        }
        if rebuilt.labels() != data.labels() {
            return Err("labels changed crossing the block carve".into());
        }
        let want: Vec<u32> = data.features_flat().iter().map(|v| v.to_bits()).collect();
        let got: Vec<u32> = rebuilt.features_flat().iter().map(|v| v.to_bits()).collect();
        if want != got {
            return Err("feature bits changed crossing the block carve".into());
        }
        Ok(())
    });
}

#[test]
fn interleaved_node_streams_are_legal_but_faults_are_refused() {
    check("stream-faults", 150, 0xFA57, |g| {
        let data_a = arb_dataset(g);
        let mut data_b = Dataset::with_capacity(data_a.dim(), data_a.classes(), 3);
        for _ in 0..g.usize_in(1, 5) {
            let row = g.f32_vec(data_a.dim(), -1.0, 1.0);
            data_b.push(&row, g.usize_in(0, data_a.classes() - 1));
        }
        let rows_per = g.usize_in(1, 4);
        let a = RowBlock::carve(0, &data_a, rows_per);
        let b = RowBlock::carve(1, &data_b, rows_per);
        // Interleave the two nodes' streams arbitrarily — per-node
        // trackers must both complete (this is the wire's real shape:
        // the launcher round-robins blocks across a rank's nodes).
        let mut track = [StreamProgress::default(), StreamProgress::default()];
        let (mut ia, mut ib) = (0, 0);
        while ia < a.len() || ib < b.len() {
            let take_a = ib >= b.len() || (ia < a.len() && g.bool());
            let blk = if take_a { &a[ia] } else { &b[ib] };
            track[blk.node].fold(blk).map_err(|e| format!("interleave refused: {e}"))?;
            if take_a {
                ia += 1;
            } else {
                ib += 1;
            }
        }
        track[0]
            .verify_complete(a.len() as u32, data_a.len() as u64, fold_payloads(&a))
            .map_err(|e| format!("node 0 completion: {e}"))?;
        track[1]
            .verify_complete(b.len() as u32, data_b.len() as u64, fold_payloads(&b))
            .map_err(|e| format!("node 1 completion: {e}"))?;

        // Faults on node 0's stream: each must error, never panic.
        if a.len() >= 2 {
            // Dropped block → the gap is caught at the next fold.
            let mut t = StreamProgress::default();
            t.fold(&a[0]).map_err(|e| e.to_string())?;
            if a.len() > 2 {
                if t.fold(&a[2]).is_ok() {
                    return Err("dropped block not caught".into());
                }
            } else if t
                .verify_complete(a.len() as u32, data_a.len() as u64, fold_payloads(&a))
                .is_ok()
            {
                return Err("short stream completion not caught".into());
            }
            // Duplicate → seq repeats.
            let mut t = StreamProgress::default();
            t.fold(&a[0]).map_err(|e| e.to_string())?;
            if t.fold(&a[0]).is_ok() {
                return Err("duplicate block not caught".into());
            }
            // Reorder → later seq first.
            let mut t = StreamProgress::default();
            if t.fold(&a[1]).is_ok() {
                return Err("reordered block not caught".into());
            }
        }
        // Corruption: flip one feature bit (or a label) — the per-block
        // checksum catches it before any fold.
        let mut bent = a[g.usize_in(0, a.len() - 1)].clone();
        if bent.labels.is_empty() {
            return Err("carve produced an empty block".into());
        }
        if g.bool() && !bent.features.is_empty() {
            let at = g.usize_in(0, bent.features.len() - 1);
            bent.features[at] = f32::from_bits(bent.features[at].to_bits() ^ 1);
        } else {
            let at = g.usize_in(0, bent.labels.len() - 1);
            bent.labels[at] ^= 1;
        }
        if bent.validate(data_a.dim(), data_a.classes()).is_ok() {
            return Err("corrupted block passed validation".into());
        }
        // Tampered totals: a wrong announced checksum is refused.
        let mut t = StreamProgress::default();
        for blk in &a {
            t.fold(blk).map_err(|e| e.to_string())?;
        }
        if t.verify_complete(a.len() as u32, data_a.len() as u64, fold_payloads(&a) ^ 1)
            .is_ok()
        {
            return Err("tampered shard checksum not caught".into());
        }
        // And the per-block checksum really is position-sensitive: two
        // different payloads hash differently here (FNV-1a collision on
        // these tiny inputs would be astonishing).
        if a.len() >= 2 && payload_checksum(&a[0].labels, &a[0].features)
            == payload_checksum(&a[1].labels, &a[1].features)
            && (a[0].labels != a[1].labels || a[0].features != a[1].features)
        {
            return Err("distinct payloads collided".into());
        }
        Ok(())
    });
}
