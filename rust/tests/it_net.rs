//! Integration: the multi-process SocketNet deployment.
//!
//! * In-process pair — two `SocketNet` shards over loopback TCP drive
//!   the same `spawn_shard` engine the workers use and reach the
//!   consensus tolerance of the in-process channel transport.
//! * Real processes — `dasgd launch --workers 2` (spawned from the
//!   built binary) reaches the same tolerance with matching seeds, and
//!   killing one worker mid-run leaves the survivor making progress
//!   (projections degrade to Conflict/Isolated, no hang).

use std::net::{TcpListener, TcpStream};
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use dasgd::coordinator::{consensus, spawn_shard, AsyncCluster, AsyncConfig};
use dasgd::data::stream::{fold_payloads, RowBlock, DEFAULT_BLOCK_ROWS};
use dasgd::experiments::{make_regular, synth_world};
use dasgd::net::wire::{self, WireMsg, MONITOR_RANK};
use dasgd::net::{
    assignment_from_msg, plan_assign_msg, LaunchConfig, ShardMap, SocketConfig, SocketNet,
};
use dasgd::node_logic::neighborhood_average;
use dasgd::objective::Objective;
use dasgd::transport::{ProjectionOutcome, Transport, TransportKind};
use dasgd::workload::{PlanSpec, WorkloadPlan};

/// Consensus tolerance shared by every engine comparison on the fixed
/// ring world below (`it_transport.rs` uses 5.0 for shared-vs-simnet;
/// the message-passing substrates complete fewer projection rounds per
/// second — protocol waits + poll cadence — so they get a more generous
/// common bound).
const TOL: f64 = 10.0;
const SEED: u64 = 42;
const NODES: usize = 8;

fn dasgd_bin() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_BIN_EXE_dasgd"))
}

/// The in-process reference: the channel transport on the same world.
fn channel_consensus() -> (f64, u64) {
    let (shards, test) = synth_world(NODES, 300, 512, SEED);
    let cluster = AsyncCluster::new(make_regular(NODES, 2), shards);
    let cfg = AsyncConfig {
        duration_secs: 2.0,
        rate_hz: 300.0,
        transport: TransportKind::Channel,
        seed: SEED,
        ..AsyncConfig::quick(NODES)
    };
    let rep = cluster.run(&cfg, &test).unwrap();
    (consensus::consensus_distance(&rep.final_params), rep.updates)
}

#[test]
fn socket_pair_matches_channel_consensus_tolerance_in_process() {
    // Two SocketNet shards over real loopback TCP, one spawn_shard
    // engine each — the worker path without process boundaries.
    let (shards, _test) = synth_world(NODES, 300, 512, SEED);
    let graph = make_regular(NODES, 2); // fixed ring
    let param_len = Objective::LogReg.param_len(shards[0].dim(), shards[0].classes());
    let map = ShardMap::new(NODES, 2);
    let cfg_net = SocketConfig::default();
    let a = SocketNet::bind(0, map, param_len, "127.0.0.1:0", cfg_net).unwrap();
    let b = SocketNet::bind(1, map, param_len, "127.0.0.1:0", cfg_net).unwrap();
    let peers = vec![a.local_addr().to_string(), b.local_addr().to_string()];
    a.connect_peers(&peers);
    b.connect_peers(&peers);
    assert!(a.wait_connected(Duration::from_secs(5)));
    assert!(b.wait_connected(Duration::from_secs(5)));

    let cfg = AsyncConfig {
        rate_hz: 300.0,
        seed: SEED,
        transport: TransportKind::Socket,
        ..AsyncConfig::quick(NODES)
    };
    let plan = WorkloadPlan::homogeneous(Objective::LogReg, shards);
    let run_a = spawn_shard(
        &graph,
        &plan,
        &cfg,
        Arc::new(a.clone()) as Arc<dyn Transport>,
        a.local_nodes(),
        None,
    );
    let run_b = spawn_shard(
        &graph,
        &plan,
        &cfg,
        Arc::new(b.clone()) as Arc<dyn Transport>,
        b.local_nodes(),
        None,
    );
    std::thread::sleep(Duration::from_secs(2));
    let ca = run_a.stop_and_join();
    let cb = run_b.stop_and_join();

    let mut params: Vec<(usize, Vec<f32>)> = a.local_params();
    params.extend(b.local_params());
    params.sort_by_key(|(id, _)| *id);
    let cohort: Vec<Vec<f32>> = params.into_iter().map(|(_, w)| w).collect();
    assert_eq!(cohort.len(), NODES);
    let d_socket = consensus::consensus_distance(&cohort);
    a.shutdown();
    b.shutdown();

    let (d_channel, channel_updates) = channel_consensus();
    assert!(ca.updates() + cb.updates() > 100, "socket updates too few");
    assert!(channel_updates > 100, "channel updates too few");
    assert!(
        ca.proj_steps + cb.proj_steps > 0,
        "no projection completed across the wire"
    );
    assert!(
        d_socket < TOL,
        "socket consensus {d_socket} ≥ {TOL} (channel reached {d_channel})"
    );
    assert!(d_channel < TOL, "channel consensus {d_channel} ≥ {TOL}");
    assert!(cohort.iter().all(|w| w.iter().all(|v| v.is_finite())));
}

#[test]
fn batched_and_unbatched_socket_runs_apply_identical_updates() {
    // The coalescing acceptance check: the same scripted horizon —
    // deterministic local steps plus sequential cross-shard projection
    // rounds on the fixed ring — must apply exactly the same updates
    // whether frames leave one per message (`flush_bytes: 0`) or
    // coalesced into WIRE_VERSION 5 `Batch` envelopes (the default
    // policy). Applied-update counts AND final parameter bits have to
    // agree; the test mirrors the whole trajectory in-process so every
    // remote apply is also checked bit-for-bit as it lands.
    const PARAM_LEN: usize = 6;
    const GRAD_PASSES: u32 = 3;
    const PROJ_ROUNDS: usize = 2;

    let run = |cfg: SocketConfig| -> (u64, Vec<Vec<u32>>) {
        let map = ShardMap::new(NODES, 2);
        let a = SocketNet::bind(0, map, PARAM_LEN, "127.0.0.1:0", cfg).unwrap();
        let b = SocketNet::bind(1, map, PARAM_LEN, "127.0.0.1:0", cfg).unwrap();
        let peers = vec![a.local_addr().to_string(), b.local_addr().to_string()];
        a.connect_peers(&peers);
        b.connect_peers(&peers);
        assert!(a.wait_connected(Duration::from_secs(5)));
        assert!(b.wait_connected(Duration::from_secs(5)));
        let owner = |i: usize| if i < NODES / 2 { &a } else { &b };

        // In-process mirror of every node's parameters — the oracle the
        // live deployment must track bit-for-bit.
        let mut world: Vec<Vec<f32>> = vec![vec![0.0; PARAM_LEN]; NODES];
        let mut applied = 0u64;

        // Deterministic "grad" phase: local steps only, no wire.
        for pass in 0..GRAD_PASSES {
            for i in 0..NODES {
                let bump = |w: &mut [f32]| {
                    for (j, v) in w.iter_mut().enumerate() {
                        *v += (i as f32 + 1.0) * 0.25
                            + pass as f32 * 0.125
                            + j as f32 * 0.0625;
                    }
                };
                owner(i).update_own(i, &mut |w| bump(w));
                bump(&mut world[i]);
                applied += 1;
            }
        }

        // Serve cross-shard rounds: pump poll() for every node except
        // the one currently initiating (in the real engine a node never
        // polls concurrently with its own round).
        let stop = Arc::new(AtomicBool::new(false));
        let cur = Arc::new(AtomicUsize::new(usize::MAX));
        let pumps: Vec<_> = [
            (a.clone(), 0..NODES / 2),
            (b.clone(), NODES / 2..NODES),
        ]
        .into_iter()
        .map(|(net, ids)| {
            let stop = stop.clone();
            let cur = cur.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    for j in ids.clone() {
                        if j != cur.load(Ordering::Relaxed) {
                            net.poll(j);
                        }
                    }
                    std::thread::sleep(Duration::from_micros(100));
                }
            })
        })
        .collect();

        // Block until a node's live params match the mirror exactly.
        let wait_bits = |i: usize, want: &[f32]| {
            let want: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let deadline = Instant::now() + Duration::from_secs(5);
            loop {
                let got = owner(i)
                    .local_params()
                    .into_iter()
                    .find(|(id, _)| *id == i)
                    .expect("own node listed")
                    .1;
                if got.iter().map(|v| v.to_bits()).collect::<Vec<u32>>() == want {
                    return;
                }
                assert!(
                    Instant::now() < deadline,
                    "node {i} never reached the mirrored value (want {want:?}, got {got:?})"
                );
                std::thread::sleep(Duration::from_millis(2));
            }
        };

        // Sequential ring projections — every round must apply, and the
        // averaged value must land bit-identically on every hood member
        // before the next round reads it.
        for _ in 0..PROJ_ROUNDS {
            for i in 0..NODES {
                let mut hood = [(i + NODES - 1) % NODES, i, (i + 1) % NODES];
                hood.sort_unstable(); // try_project takes the sorted closed neighborhood
                cur.store(i, Ordering::Relaxed);
                let out = owner(i).try_project(i, &hood, Duration::ZERO, &mut |rows, _aux| {
                    (neighborhood_average(rows), Vec::new())
                });
                cur.store(usize::MAX, Ordering::Relaxed);
                assert_eq!(out, ProjectionOutcome::Applied { participants: 3 });
                applied += 1;
                let rows: Vec<&[f32]> = hood.iter().map(|&j| world[j].as_slice()).collect();
                let avg = neighborhood_average(&rows);
                for &j in &hood {
                    world[j] = avg.clone();
                }
                for &j in &hood {
                    wait_bits(j, &world[j]);
                }
            }
        }

        stop.store(true, Ordering::Relaxed);
        for p in pumps {
            p.join().unwrap();
        }
        // Return the LIVE parameters (already proven equal to the
        // mirror above) so the cross-policy comparison below is over
        // what the deployment actually holds.
        let mut live: Vec<(usize, Vec<f32>)> = a.local_params();
        live.extend(b.local_params());
        live.sort_by_key(|(id, _)| *id);
        assert_eq!(live.len(), NODES);
        let bits = live
            .into_iter()
            .map(|(_, w)| w.iter().map(|v| v.to_bits()).collect())
            .collect();
        a.shutdown();
        b.shutdown();
        (applied, bits)
    };

    let unbatched = run(SocketConfig {
        flush_bytes: 0,
        ..SocketConfig::default()
    });
    let batched = run(SocketConfig::default());
    let expected = (GRAD_PASSES as u64) * NODES as u64 + (PROJ_ROUNDS * NODES) as u64;
    assert_eq!(unbatched.0, expected, "unbatched run dropped updates");
    assert_eq!(
        unbatched.0, batched.0,
        "applied-update counts diverged across flush policies"
    );
    assert_eq!(
        unbatched.1, batched.1,
        "final parameter bits diverged across flush policies"
    );
}

#[test]
fn launch_two_workers_reaches_channel_tolerance() {
    // The full CLI path: `dasgd launch` semantics driven through
    // run_launch with the built binary as the worker image.
    let cfg = LaunchConfig {
        binary: Some(dasgd_bin()),
        horizon_updates: 1500,
        secs_cap: 25.0,
        seed: SEED,
        ..LaunchConfig::quick(2, NODES)
    };
    let rep = dasgd::net::run_launch(&cfg).expect("launch failed");
    assert_eq!(rep.live_workers, 2, "both workers must stay live");
    assert!(rep.reached_horizon, "run must end at the horizon, not the cap");
    assert!(
        rep.counts.updates() >= 1500,
        "stopped before the horizon: {} updates",
        rep.counts.updates()
    );
    assert!(rep.counts.proj_steps > 0, "no cross-process projections");
    let last = rep.recorder.last().expect("monitor recorded snapshots");
    let (d_channel, _) = channel_consensus();
    assert!(
        last.consensus < TOL,
        "launch consensus {} ≥ {TOL} (channel reached {d_channel})",
        last.consensus
    );
    assert!(d_channel < TOL);
    assert!(last.test_err.is_finite() && last.test_err < 0.9);
}

#[test]
fn launch_mixed_plan_ships_non_iid_shards_over_the_wire() {
    // The heterogeneity acceptance path: a 2-worker deployment with a
    // label-skew Dirichlet split (α = 0.1) and a hinge/lasso objective
    // mix. Workers are spawned with `--plan wire`, so every shard they
    // train on crossed the control connection — nothing is regenerated
    // from the seed — and the run must still reach its horizon.
    let cfg = LaunchConfig {
        binary: Some(dasgd_bin()),
        plan: PlanSpec::Mixed { alpha: 0.1 },
        horizon_updates: 800,
        secs_cap: 25.0,
        seed: SEED,
        ..LaunchConfig::quick(2, NODES)
    };
    let rep = dasgd::net::run_launch(&cfg).expect("heterogeneous launch failed");
    assert_eq!(rep.live_workers, 2, "both workers must stay live");
    assert!(rep.reached_horizon, "heterogeneous run stalled before the horizon");
    assert!(rep.counts.updates() >= 800);
    assert!(rep.counts.proj_steps > 0, "no cross-process projections");
    let last = rep.recorder.last().expect("monitor recorded snapshots");
    assert!(last.consensus.is_finite());
    assert!(last.test_loss.is_finite() && last.test_err.is_finite());
    // The shipped shards really are non-IID: with α = 0.1 the plan's
    // label distribution differs sharply across nodes.
    let (plan, _) = PlanSpec::Mixed { alpha: 0.1 }.build(
        Objective::LogReg,
        NODES,
        300,
        16,
        SEED,
    );
    let max_frac = |counts: Vec<usize>| {
        let total: usize = counts.iter().sum();
        *counts.iter().max().unwrap() as f64 / total.max(1) as f64
    };
    let most_skewed = (0..NODES)
        .map(|i| max_frac(plan.shard(i).class_counts()))
        .fold(0.0f64, f64::max);
    assert!(
        most_skewed > 0.5,
        "α=0.1 should concentrate labels, max fraction {most_skewed}"
    );
}

#[test]
fn launch_ships_quantity_skewed_shards_past_the_frame_cap() {
    // The 16 MiB wire-cap regression: a quantity-skew plan (α = 0.05)
    // over a pool large enough that the biggest shard is *guaranteed*
    // past the frame cap (the max share of a Dirichlet split is ≥ 1/k,
    // so ≥ 85k of the 340k pooled rows — ≈ 17.3 MB encoded at 50
    // features). Pre-chunking, `dasgd launch` hard-errored here before
    // any worker started.
    const SAMPLES: usize = 85_000;
    const SKEW_NODES: usize = 4;
    let spec = PlanSpec::Quantity { alpha: 0.05 };
    let (plan, _) = spec.build(Objective::LogReg, SKEW_NODES, SAMPLES, 16, SEED);
    let big = (0..SKEW_NODES)
        .max_by_key(|&i| plan.shard(i).len())
        .unwrap();
    let msg = plan_assign_msg(big, plan.node(big));
    assert!(
        matches!(wire::encode(&msg), Err(wire::WireError::Oversize { .. })),
        "the largest shard must exceed one frame for this test to bite"
    );
    // The chunk envelope round-trips that shard bit-for-bit in-process.
    let frames = wire::encode_message(&msg).unwrap();
    assert!(frames.len() > 3, "expected a chunk envelope");
    let bytes = frames.concat();
    let mut asm = wire::ChunkAssembler::new();
    let mut cursor = std::io::Cursor::new(&bytes);
    let back = wire::read_message(&mut cursor, &mut asm).expect("reassembly failed");
    assert_eq!(cursor.position() as usize, bytes.len());
    let (rid, a) = assignment_from_msg(&back).unwrap();
    assert_eq!(rid, big);
    assert_eq!(a.shard.labels(), plan.shard(big).labels());
    let want: Vec<u32> = plan
        .shard(big)
        .features_flat()
        .iter()
        .map(|v| v.to_bits())
        .collect();
    let got: Vec<u32> = a.shard.features_flat().iter().map(|v| v.to_bits()).collect();
    assert_eq!(want, got, "feature bits changed crossing the chunked codec");

    // End-to-end: the same plan ships to two real worker processes and
    // the run reaches its horizon. PlanStart carries a checksum folded
    // over every shipped assignment and a worker refuses to start on a
    // mismatch — so reaching the horizon certifies the workers trained
    // on bit-identical copies of the in-process plan above (the
    // builders are deterministic in (spec, nodes, samples, seed)).
    let cfg = LaunchConfig {
        binary: Some(dasgd_bin()),
        plan: spec,
        samples_per_node: SAMPLES,
        horizon_updates: 300,
        secs_cap: 90.0,
        seed: SEED,
        ..LaunchConfig::quick(2, SKEW_NODES)
    };
    let rep = dasgd::net::run_launch(&cfg).expect("giant-shard launch failed");
    assert_eq!(rep.live_workers, 2, "both workers must stay live");
    assert!(rep.reached_horizon, "giant-shard deployment stalled");
    assert!(rep.counts.updates() >= 300);
    let last = rep.recorder.last().expect("monitor recorded snapshots");
    assert!(last.consensus.is_finite());
    assert!(last.test_err.is_finite());
}

#[test]
fn streaming_keeps_staging_bounded_and_steps_before_the_shard_completes() {
    // The streaming data-plane acceptance run: a worker whose total
    // shard bytes provably exceed its --staging-mb budget must still
    // reach the horizon, with its BlockBuffer high-water mark bounded
    // by the budget and its first update applied before the last
    // ShardComplete landed. Reaching the horizon also certifies
    // bit-identity: every block is checksummed, every stream's fold is
    // checked against the plan-side ShardComplete digest, and a worker
    // that sees any mismatch refuses the stream and dies.
    const SAMPLES: usize = 8_000;
    const STAGING_MB: usize = 4;
    let budget = (STAGING_MB as u64) << 20;
    let spec = PlanSpec::Synth;
    let (plan, _) = spec.build(Objective::LogReg, NODES, SAMPLES, 16, SEED);
    // Worker 0 owns nodes 0..NODES/2; sum its streamed payload exactly
    // the way the launcher carves it.
    let owned = 0..NODES / 2;
    let worker_bytes: u64 = owned
        .clone()
        .map(|i| {
            RowBlock::carve(i, plan.shard(i), DEFAULT_BLOCK_ROWS)
                .iter()
                .map(|b| b.payload_bytes())
                .sum::<u64>()
        })
        .sum();
    assert!(
        worker_bytes > budget,
        "worker 0's shard ({worker_bytes} B) must exceed the {budget} B \
         staging budget for this test to bite"
    );
    // Every individual block still fits the budget, so the pump can
    // always make progress.
    for i in owned {
        for b in RowBlock::carve(i, plan.shard(i), DEFAULT_BLOCK_ROWS) {
            assert!(b.payload_bytes() <= budget, "block larger than the budget");
        }
    }

    let cfg = LaunchConfig {
        binary: Some(dasgd_bin()),
        plan: spec,
        samples_per_node: SAMPLES,
        staging_mb: STAGING_MB,
        horizon_updates: 400,
        secs_cap: 60.0,
        seed: SEED,
        ..LaunchConfig::quick(2, NODES)
    };
    let rep = dasgd::net::run_launch(&cfg).expect("streaming launch failed");
    assert_eq!(rep.live_workers, 2, "both workers must stay live");
    assert!(rep.reached_horizon, "streaming deployment stalled");
    assert!(rep.counts.updates() >= 400);
    assert!(
        rep.max_staging_bytes > 0,
        "monitor never observed a staging high-water mark"
    );
    assert!(
        rep.max_staging_bytes <= budget,
        "staging peaked at {} B — past the {budget} B budget",
        rep.max_staging_bytes
    );
    assert!(
        rep.stepped_before_stream_complete,
        "no worker applied an update before its shard streams completed — \
         the data plane is not actually incremental"
    );
    let last = rep.recorder.last().expect("monitor recorded snapshots");
    assert!(last.consensus.is_finite());
    assert!(last.test_err.is_finite());
}

#[test]
fn launch_with_metrics_jsonl_exports_cluster_staleness() {
    // The observability acceptance path: a 2-worker launch with
    // --metrics-jsonl must leave behind schema-valid JSONL whose final
    // line aggregates nonzero staleness samples pulled from the worker
    // processes over MetricsRequest/MetricsReply control frames.
    let path = std::env::temp_dir().join(format!("dasgd_it_metrics_{}.jsonl", std::process::id()));
    let trace = std::env::temp_dir().join(format!("dasgd_it_trace_{}.jsonl", std::process::id()));
    let rank_traces: Vec<std::path::PathBuf> = (0..2)
        .map(|r| {
            std::env::temp_dir()
                .join(format!("dasgd_it_trace_{}.rank{r}.jsonl", std::process::id()))
        })
        .collect();
    let _ = std::fs::remove_file(&path);
    for p in &rank_traces {
        let _ = std::fs::remove_file(p);
    }
    let cfg = LaunchConfig {
        binary: Some(dasgd_bin()),
        horizon_updates: 1500,
        secs_cap: 25.0,
        seed: SEED,
        metrics_jsonl: Some(path.clone()),
        trace_jsonl: Some(trace.clone()),
        log_level: Some("warn".into()),
        ..LaunchConfig::quick(2, NODES)
    };
    let rep = dasgd::net::run_launch(&cfg).expect("instrumented launch failed");
    assert_eq!(rep.live_workers, 2, "both workers must stay live");
    assert!(rep.reached_horizon, "instrumented run stalled before the horizon");

    // --trace-jsonl is forwarded per rank: each worker process dumps
    // its own armed ring on exit. (This test process never armed a
    // tracer, so only the forwarded files exist — arming the global
    // tracer here would leak into sibling tests.)
    for (r, p) in rank_traces.iter().enumerate() {
        let text = std::fs::read_to_string(p)
            .unwrap_or_else(|e| panic!("rank {r} trace file {}: {e}", p.display()));
        let _ = std::fs::remove_file(p);
        let first = text
            .lines()
            .find(|l| !l.trim().is_empty())
            .unwrap_or_else(|| panic!("rank {r} trace dump is empty — no events fired"));
        let j = dasgd::util::json::parse(first).expect("trace line must parse as JSON");
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("trace"));
    }

    let text = std::fs::read_to_string(&path).expect("metrics JSONL written");
    let _ = std::fs::remove_file(&path);
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    assert!(!lines.is_empty(), "monitor appended no metrics lines");
    let mut last_k = 0u64;
    let mut last = None;
    for line in &lines {
        let j = dasgd::util::json::parse(line).expect("metrics line must parse as JSON");
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("metrics"));
        assert_eq!(j.get("scope").and_then(|v| v.as_str()), Some("cluster"));
        assert!(j.get("t_secs").and_then(|v| v.as_f64()).is_some());
        let k = j.get("k").and_then(|v| v.as_f64()).expect("k present") as u64;
        assert!(k >= last_k, "applied-update count went backwards in the export");
        last_k = k;
        for section in ["counters", "gauges", "hists"] {
            assert!(j.get(section).is_some(), "line missing {section:?}");
        }
        last = Some(j);
    }
    let last = last.unwrap();
    let staleness = last
        .get("hists")
        .and_then(|h| h.get("staleness_ticks"))
        .expect("staleness_ticks histogram exported");
    let count = staleness
        .get("count")
        .and_then(|v| v.as_f64())
        .expect("histogram count");
    assert!(
        count > 0.0,
        "cluster-wide staleness histogram is empty — worker metrics never \
         crossed the control plane"
    );
    assert!(staleness.get("p50").and_then(|v| v.as_f64()).is_some());
    assert!(staleness.get("p99").and_then(|v| v.as_f64()).is_some());
    // The aggregated staleness also landed in the monitor's CSV record.
    let rec = rep.recorder.last().expect("monitor recorded snapshots");
    assert!(
        rec.staleness_p99 >= rec.staleness_p50 && rec.staleness_p50 >= 0.0,
        "record quantiles inconsistent: p50 {} p99 {}",
        rec.staleness_p50,
        rec.staleness_p99
    );
}

#[test]
fn churn_2_1_2_hands_off_every_shard_exactly_once() {
    // The membership acceptance run: a 2-worker deployment loses rank 1
    // to a SIGKILL 10% into the horizon and admits a `--join`
    // replacement once the rank is vacated. The run must still reach
    // its horizon with two live workers, and every node of the killed
    // rank must have been handed off exactly once, checksum-certified:
    // the monitor records the fold-of-checksums it streamed per node,
    // the joiner verifies the same fold block-by-block and dies on any
    // mismatch, and the carve is deterministic in (plan, block rows) —
    // so equality against a local re-carve proves the replacement holds
    // a bit-identical copy of the shard.
    const HORIZON: u64 = 25_000;
    let cfg = LaunchConfig {
        binary: Some(dasgd_bin()),
        horizon_updates: HORIZON,
        secs_cap: 90.0,
        seed: SEED,
        chaos_kill: Some((1, 0.1)),
        chaos_join: Some(0.2),
        log_level: Some("warn".into()),
        ..LaunchConfig::quick(2, NODES)
    };
    let rep = dasgd::net::run_launch(&cfg).expect("churn launch failed");
    assert!(rep.reached_horizon, "churned deployment stalled before the horizon");
    assert_eq!(
        rep.live_workers, 2,
        "the replacement must be live at shutdown (joins={}, evictions={})",
        rep.joins, rep.evictions
    );
    assert!(rep.evictions >= 1, "the killed rank was never evicted");
    assert!(rep.joins >= 1, "the replacement was never admitted");
    assert!(rep.repairs >= 1, "no topology repair was shipped");

    // Rank 1 of a 2-worker, 8-node map owns nodes 4..8; each must have
    // been handed off exactly once, none of rank 0's ever.
    let (plan, _) = PlanSpec::Synth.build(Objective::LogReg, NODES, 300, 512, SEED);
    for node in 0..NODES as u32 {
        let times = rep.handoffs.iter().filter(|(n, _)| *n == node).count();
        if node < NODES as u32 / 2 {
            assert_eq!(times, 0, "rank 0's node {node} was handed off");
        } else {
            assert_eq!(times, 1, "node {node} handed off {times} times, want exactly 1");
            let (_, fold) = rep.handoffs.iter().find(|(n, _)| *n == node).unwrap();
            let want = fold_payloads(&RowBlock::carve(
                node as usize,
                plan.shard(node as usize),
                DEFAULT_BLOCK_ROWS,
            ));
            assert_eq!(
                *fold, want,
                "node {node}: handed-off shard checksum fold diverged from the plan"
            );
        }
    }
    let last = rep.recorder.last().expect("monitor recorded snapshots");
    assert!(last.consensus.is_finite());
    assert!(rep.counts.updates() >= HORIZON);
}

/// Snapshot one worker over a monitor control connection.
fn snapshot(conn: &mut TcpStream) -> Option<(u64, Vec<(u32, Vec<f32>)>)> {
    wire::write_frame(conn, &WireMsg::SnapshotRequest).ok()?;
    match wire::read_frame(conn).ok()? {
        WireMsg::SnapshotReply { counts, params, .. } => Some((counts[0] + counts[1], params)),
        _ => None,
    }
}

#[test]
fn killing_one_worker_leaves_the_survivor_live() {
    // Two REAL worker processes; rank 1 is killed without ceremony.
    // The survivor must keep applying updates (its cross-shard
    // projections degrade to conflicts) and still answer snapshots.
    let peers: Vec<String> = (0..2)
        .map(|_| {
            let port = TcpListener::bind("127.0.0.1:0")
                .unwrap()
                .local_addr()
                .unwrap()
                .port();
            format!("127.0.0.1:{port}")
        })
        .collect();
    let bin = dasgd_bin();
    let mut children: Vec<_> = (0..2)
        .map(|rank| {
            Command::new(&bin)
                .args([
                    "worker",
                    "--rank",
                    &rank.to_string(),
                    "--peers",
                    &peers.join(","),
                    "--nodes",
                    &NODES.to_string(),
                    "--degree",
                    "2",
                    "--secs",
                    "20",
                    "--rate",
                    "300",
                    "--seed",
                    "7",
                ])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawn worker")
        })
        .collect();

    // Monitor-connect to the survivor (rank 0).
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut conn = loop {
        if let Ok(mut s) = TcpStream::connect(&peers[0]) {
            s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
            if wire::write_frame(&mut s, &WireMsg::Hello { rank: MONITOR_RANK }).is_ok() {
                break s;
            }
        }
        assert!(Instant::now() < deadline, "worker 0 never accepted");
        std::thread::sleep(Duration::from_millis(50));
    };

    // Wait until the deployment is actually making progress.
    let deadline = Instant::now() + Duration::from_secs(10);
    let before_kill = loop {
        if let Some((k, params)) = snapshot(&mut conn) {
            // The worker reports exactly its own shard (nodes 0..4).
            assert!(params.iter().all(|(id, _)| *id < 4));
            if k > 50 {
                break k;
            }
        }
        assert!(Instant::now() < deadline, "worker 0 never made progress");
        std::thread::sleep(Duration::from_millis(100));
    };

    children[1].kill().expect("kill worker 1");
    let _ = children[1].wait();

    // The survivor keeps updating after the peer is gone — and answers
    // within a bounded time (no wedged projection rounds).
    std::thread::sleep(Duration::from_secs(1));
    let k1 = snapshot(&mut conn).expect("survivor must answer").0;
    std::thread::sleep(Duration::from_secs(1));
    let k2 = snapshot(&mut conn).expect("survivor must answer").0;
    assert!(
        k2 > k1 && k1 >= before_kill,
        "survivor stalled after peer death: {before_kill} → {k1} → {k2}"
    );

    // Graceful shutdown still works on the survivor.
    wire::write_frame(&mut conn, &WireMsg::Shutdown).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match children[0].try_wait().unwrap() {
            Some(status) => {
                assert!(status.success(), "survivor exited with {status}");
                break;
            }
            None => {
                if Instant::now() >= deadline {
                    let _ = children[0].kill();
                    panic!("survivor never exited after Shutdown");
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}
