//! Degree-preserving, spectrally-steered topology repair.
//!
//! The paper's convergence constant for a k-regular graph is
//! `η ≥ (1 − σ₂²)(k+1)/N` (Lemma 1): connectivity makes consensus
//! *possible*, degree sets the `(k+1)` factor, and a small σ₂ makes
//! the contraction *fast*. The repair policy honors them in that
//! order — every membership change yields a connected active graph
//! with degrees within ±1 of the launch degree, and wherever several
//! rewirings satisfy those constraints the policy greedily steers
//! toward spectral gap: on small graphs it evaluates
//! [`sigma2`](crate::graph::spectral::sigma2) for each candidate and
//! keeps the minimum; on large graphs (where the O(n²)-per-iteration
//! power method is too slow for a repair that blocks patch shipment)
//! it uses an expansion proxy — connect the farthest-apart endpoints,
//! which is what shrinking σ₂ asks for in a regular graph.

use std::collections::{BTreeSet, HashMap, VecDeque};

use crate::graph::{spectral, Graph};

/// Above this many active nodes, candidate steering switches from
/// exact σ₂ evaluation to the BFS-distance expansion proxy.
const SPECTRAL_N_MAX: usize = 96;

/// Power-iteration depth for candidate σ₂ scoring — enough to rank
/// candidates, far less than a publication-grade estimate.
const SPECTRAL_ITERS: usize = 40;

/// The monitor-side membership controller: which nodes are active,
/// the current communication graph over them, and the repair policy
/// that rewires it on every change.
///
/// [`Membership::deactivate`] and [`Membership::activate`] return the
/// patch to ship — the *complete* new neighbor list of every node the
/// repair touched (and nothing else, so unaffected workers receive
/// nothing). Guarantees, for any removal/add sequence that keeps at
/// least `degree + 2` nodes active:
///
/// - the active subgraph stays connected (no node is ever orphaned),
/// - every active degree stays within ±1 of the launch degree,
/// - inactive nodes hold no edges.
#[derive(Clone, Debug)]
pub struct Membership {
    graph: Graph,
    active: Vec<bool>,
    /// The launch-time regular degree — the repair target.
    degree: usize,
    version: u64,
    touched: BTreeSet<usize>,
}

impl Membership {
    /// Wrap the launch topology (all nodes active, patch version 0 —
    /// matching a fresh [`TopologyView`](super::TopologyView)).
    pub fn new(graph: Graph, degree: usize) -> Self {
        let n = graph.len();
        Self {
            graph,
            active: vec![true; n],
            degree,
            version: 0,
            touched: BTreeSet::new(),
        }
    }

    /// Version of the last emitted patch (0 = launch topology).
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn is_active(&self, u: usize) -> bool {
        self.active[u]
    }

    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|a| **a).count()
    }

    /// Is the active subgraph connected? (Trivially true with ≤ 1
    /// active node.)
    pub fn is_active_connected(&self) -> bool {
        self.components().len() <= 1
    }

    /// Remove `nodes` from the deployment and repair around the hole.
    /// Returns the topology patch (full new neighbor lists of every
    /// touched node; the removed nodes appear with empty lists).
    pub fn deactivate(&mut self, nodes: &[usize]) -> Vec<(u32, Vec<u32>)> {
        self.touched.clear();
        for &v in nodes {
            if v >= self.active.len() || !self.active[v] {
                continue;
            }
            self.active[v] = false;
            self.touched.insert(v);
            let ex = self.graph.neighbors(v).to_vec();
            for &nb in &ex {
                self.graph.remove_edge(v, nb);
                self.touched.insert(nb);
            }
            // Local repair first: pair up the ex-neighbors that each
            // lost an edge, restoring their degree in place.
            self.pair_up(&ex);
        }
        self.bridge();
        self.top_up();
        self.finish()
    }

    /// Re-admit `nodes` and weave them into the topology at the launch
    /// degree. Returns the topology patch.
    pub fn activate(&mut self, nodes: &[usize]) -> Vec<(u32, Vec<u32>)> {
        self.touched.clear();
        for &v in nodes {
            if v >= self.active.len() || self.active[v] {
                continue;
            }
            self.active[v] = true;
            self.touched.insert(v);
            // Defensive: an inactive node must hold no edges, but a
            // stale one would poison the weave below.
            for nb in self.graph.neighbors(v).to_vec() {
                self.graph.remove_edge(v, nb);
                self.touched.insert(nb);
            }
            self.weave_in(v);
        }
        self.bridge();
        self.top_up();
        self.finish()
    }

    fn finish(&mut self) -> Vec<(u32, Vec<u32>)> {
        self.version += 1;
        let touched = std::mem::take(&mut self.touched);
        touched
            .into_iter()
            .map(|u| {
                let hood = self.graph.neighbors(u).iter().map(|&v| v as u32).collect();
                (u as u32, hood)
            })
            .collect()
    }

    /// Greedily add edges between ex-neighbors of a removed node:
    /// every pair that is active, non-adjacent, and below the target
    /// degree heals two deficits with one edge — the removal's local,
    /// degree-preserving repair.
    fn pair_up(&mut self, ex: &[usize]) {
        loop {
            let mut cands = Vec::new();
            for i in 0..ex.len() {
                for j in i + 1..ex.len() {
                    let (u, w) = (ex[i], ex[j]);
                    if u != w
                        && self.active[u]
                        && self.active[w]
                        && self.graph.degree(u) < self.degree
                        && self.graph.degree(w) < self.degree
                        && !self.graph.has_edge(u, w)
                    {
                        cands.push((u, w));
                    }
                }
            }
            let Some((u, w)) = self.pick_pair(&cands) else {
                break;
            };
            self.graph.add_edge(u, w);
            self.touched.insert(u);
            self.touched.insert(w);
        }
    }

    /// Insert `v` (currently edgeless) at the launch degree without
    /// disturbing anyone else's: each *edge subdivision* removes an
    /// active edge (a, b) disjoint from v's neighborhood and adds
    /// (a, v), (b, v) — a and b keep their degree, v gains two, and
    /// the replaced path a–v–b preserves connectivity. ⌊degree/2⌋
    /// subdivisions reach the target (odd remainders and thin graphs
    /// are topped up afterwards).
    fn weave_in(&mut self, v: usize) {
        for _ in 0..self.degree / 2 {
            let cands = self.subdividable_edges(v);
            let Some((a, b)) = self.pick_split(v, &cands) else {
                break;
            };
            self.graph.remove_edge(a, b);
            self.graph.add_edge(a, v);
            self.graph.add_edge(b, v);
            self.touched.insert(a);
            self.touched.insert(b);
        }
    }

    /// Active edges (a, b) whose endpoints are both outside
    /// {v} ∪ N(v) — eligible for subdivision toward v.
    fn subdividable_edges(&self, v: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for a in 0..self.graph.len() {
            if !self.active[a] || a == v || self.graph.has_edge(a, v) {
                continue;
            }
            for &b in self.graph.neighbors(a) {
                if b > a && self.active[b] && b != v && !self.graph.has_edge(b, v) {
                    out.push((a, b));
                }
            }
        }
        out
    }

    /// Merge the active components until one remains. Two components
    /// that both hold an internal edge merge by a degree-preserving
    /// 2-swap (remove (a,a′) and (b,b′), add the cross edges (a,b)
    /// and (a′,b′) — cross-component, so never already present); an
    /// edgeless component (an orphan) gets a direct edge to the other
    /// side's minimum-degree node.
    fn bridge(&mut self) {
        loop {
            let comps = self.components();
            if comps.len() <= 1 {
                return;
            }
            let (ca, cb) = (&comps[0], &comps[1]);
            match (self.internal_edge(ca), self.internal_edge(cb)) {
                (Some((a, a2)), Some((b, b2))) => {
                    self.graph.remove_edge(a, a2);
                    self.graph.remove_edge(b, b2);
                    self.graph.add_edge(a, b);
                    self.graph.add_edge(a2, b2);
                    for u in [a, a2, b, b2] {
                        self.touched.insert(u);
                    }
                }
                _ => {
                    let u = *ca.iter().min_by_key(|&&x| self.graph.degree(x)).unwrap();
                    let w = *cb.iter().min_by_key(|&&x| self.graph.degree(x)).unwrap();
                    self.graph.add_edge(u, w);
                    self.touched.insert(u);
                    self.touched.insert(w);
                }
            }
        }
    }

    /// Raise every active node still two or more below the target:
    /// prefer a direct edge to a below-target partner (both ends stay
    /// ≤ degree); when the neighborhood is saturated, subdivide a
    /// disjoint edge instead (+2 toward the target, nobody else
    /// moves). Total deficit strictly decreases per round, so the
    /// loop terminates; nodes at exactly degree−1 are left alone —
    /// within the ±1 guarantee by definition.
    fn top_up(&mut self) {
        loop {
            let Some(u) = (0..self.graph.len())
                .filter(|&u| self.active[u] && self.graph.degree(u) + 2 <= self.degree)
                .min_by_key(|&u| self.graph.degree(u))
            else {
                return;
            };
            let cands: Vec<(usize, usize)> = (0..self.graph.len())
                .filter(|&w| {
                    w != u
                        && self.active[w]
                        && self.graph.degree(w) < self.degree
                        && !self.graph.has_edge(u, w)
                })
                .map(|w| (u, w))
                .collect();
            if let Some((u, w)) = self.pick_pair(&cands) {
                self.graph.add_edge(u, w);
                self.touched.insert(u);
                self.touched.insert(w);
                continue;
            }
            let splits = self.subdividable_edges(u);
            if let Some((a, b)) = self.pick_split(u, &splits) {
                self.graph.remove_edge(a, b);
                self.graph.add_edge(a, u);
                self.graph.add_edge(b, u);
                self.touched.insert(a);
                self.touched.insert(b);
                self.touched.insert(u);
                continue;
            }
            // Too small or too saturated to do better — every larger
            // deployment the guarantees are stated for never lands
            // here.
            return;
        }
    }

    /// Choose the edge to add among `cands`, steering toward spectral
    /// gap: exact σ₂ scoring on small graphs, farthest-endpoints
    /// expansion proxy on large ones (one BFS per distinct source,
    /// unreachable = infinitely far — bridging beats everything).
    fn pick_pair(&self, cands: &[(usize, usize)]) -> Option<(usize, usize)> {
        match cands.len() {
            0 => return None,
            1 => return Some(cands[0]),
            _ => {}
        }
        if self.active_count() <= SPECTRAL_N_MAX {
            let scored: Vec<(f64, (usize, usize))> = cands
                .iter()
                .map(|&(u, w)| (self.sigma2_after(|g| g.add_edge(u, w)), (u, w)))
                .collect();
            return scored
                .into_iter()
                .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(_, c)| c);
        }
        let mut dist: HashMap<usize, Vec<Option<usize>>> = HashMap::new();
        cands.iter().copied().max_by_key(|&(u, w)| {
            let d = dist
                .entry(u)
                .or_insert_with(|| self.graph.bfs_distances(u));
            d[w].unwrap_or(usize::MAX)
        })
    }

    /// Choose the edge to subdivide toward `v`: σ₂ scoring on small
    /// graphs, farthest-from-`v` endpoints on large ones (spreading
    /// v's links apart is the expander move).
    fn pick_split(&self, v: usize, cands: &[(usize, usize)]) -> Option<(usize, usize)> {
        match cands.len() {
            0 => return None,
            1 => return Some(cands[0]),
            _ => {}
        }
        if self.active_count() <= SPECTRAL_N_MAX {
            let scored: Vec<(f64, (usize, usize))> = cands
                .iter()
                .map(|&(a, b)| {
                    let s = self.sigma2_after(|g| {
                        g.remove_edge(a, b);
                        g.add_edge(a, v);
                        g.add_edge(b, v);
                    });
                    (s, (a, b))
                })
                .collect();
            return scored
                .into_iter()
                .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(_, c)| c);
        }
        let dist = self.graph.bfs_distances(v);
        cands
            .iter()
            .copied()
            .max_by_key(|&(a, b)| dist[a].unwrap_or(usize::MAX).min(dist[b].unwrap_or(usize::MAX)))
    }

    /// σ₂ of the active subgraph after applying `change` to a scratch
    /// copy (inactive isolates would pin σ₂ at 1 and drown the
    /// signal, so the scratch graph is compacted to active nodes).
    fn sigma2_after(&self, change: impl Fn(&mut Graph)) -> f64 {
        let mut g = self.graph.clone();
        change(&mut g);
        let mut pos = vec![usize::MAX; g.len()];
        let mut m = 0;
        for u in 0..g.len() {
            if self.active[u] {
                pos[u] = m;
                m += 1;
            }
        }
        let mut compact = Graph::empty(m);
        for u in 0..g.len() {
            if !self.active[u] {
                continue;
            }
            for &w in g.neighbors(u) {
                if w > u && self.active[w] {
                    compact.add_edge(pos[u], pos[w]);
                }
            }
        }
        spectral::sigma2(&compact, SPECTRAL_ITERS)
    }

    /// Connected components of the active subgraph (inactive nodes
    /// hold no edges, so plain BFS over the graph restricted to
    /// active sources is exact). [`Graph::is_connected`] is not
    /// usable here — it counts *all* n nodes, vacated ones included.
    fn components(&self) -> Vec<Vec<usize>> {
        let mut seen = vec![false; self.graph.len()];
        let mut comps = Vec::new();
        for s in 0..self.graph.len() {
            if !self.active[s] || seen[s] {
                continue;
            }
            seen[s] = true;
            let mut comp = vec![s];
            let mut queue = VecDeque::from([s]);
            while let Some(u) = queue.pop_front() {
                for &w in self.graph.neighbors(u) {
                    if !seen[w] {
                        seen[w] = true;
                        comp.push(w);
                        queue.push_back(w);
                    }
                }
            }
            comps.push(comp);
        }
        comps
    }

    /// Any edge with both endpoints inside `comp` (every neighbor of a
    /// component member is in the component by definition).
    fn internal_edge(&self, comp: &[usize]) -> Option<(usize, usize)> {
        comp.iter()
            .find(|&&u| self.graph.degree(u) > 0)
            .map(|&u| (u, self.graph.neighbors(u)[0]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{regular_circulant, ring};

    fn assert_repaired(m: &Membership, d0: usize) {
        assert!(m.is_active_connected(), "active subgraph disconnected");
        for u in 0..m.graph().len() {
            if m.is_active(u) {
                let d = m.graph().degree(u);
                assert!(
                    d + 1 >= d0 && d <= d0 + 1,
                    "node {u}: degree {d} outside {d0}±1"
                );
                assert!(d > 0, "node {u} orphaned");
            } else {
                assert_eq!(m.graph().degree(u), 0, "inactive node {u} holds edges");
            }
        }
        // Symmetric, loop-free adjacency.
        for u in 0..m.graph().len() {
            for &v in m.graph().neighbors(u) {
                assert_ne!(u, v);
                assert!(m.graph().has_edge(v, u));
            }
        }
    }

    #[test]
    fn ring_survives_removal_and_readmission() {
        let mut m = Membership::new(ring(8), 2);
        let patch = m.deactivate(&[3]);
        assert_eq!(m.version(), 1);
        assert_eq!(m.active_count(), 7);
        assert_repaired(&m, 2);
        // The removed node appears in the patch with an empty list,
        // and its ex-neighbors were rewired to each other.
        assert!(patch.iter().any(|(n, h)| *n == 3 && h.is_empty()));
        assert!(m.graph().has_edge(2, 4));

        let patch = m.activate(&[3]);
        assert_eq!(m.version(), 2);
        assert_eq!(m.active_count(), 8);
        assert_repaired(&m, 2);
        assert!(patch.iter().any(|(n, h)| *n == 3 && h.len() == 2));
    }

    #[test]
    fn circulant_survives_a_block_removal() {
        // A whole worker block leaving at once (the eviction path).
        let mut m = Membership::new(regular_circulant(16, 4), 4);
        m.deactivate(&[4, 5, 6, 7]);
        assert_eq!(m.active_count(), 12);
        assert_repaired(&m, 4);
        m.activate(&[4, 5, 6, 7]);
        assert_eq!(m.active_count(), 16);
        assert_repaired(&m, 4);
    }

    #[test]
    fn patch_covers_exactly_the_touched_nodes() {
        let mut m = Membership::new(regular_circulant(16, 4), 4);
        let before = m.graph().clone();
        let patch = m.deactivate(&[0]);
        let patched: BTreeSet<usize> = patch.iter().map(|(n, _)| *n as usize).collect();
        for u in 0..16 {
            let now: Vec<usize> = m.graph().neighbors(u).to_vec();
            if patched.contains(&u) {
                let shipped: Vec<usize> = patch
                    .iter()
                    .find(|(n, _)| *n as usize == u)
                    .unwrap()
                    .1
                    .iter()
                    .map(|&v| v as usize)
                    .collect();
                assert_eq!(shipped, now, "patch for {u} disagrees with the graph");
            } else {
                assert_eq!(before.neighbors(u), &now[..], "untouched node {u} changed");
            }
        }
    }

    #[test]
    fn repair_is_idempotent_on_noops() {
        let mut m = Membership::new(ring(6), 2);
        m.deactivate(&[2]);
        let v = m.version();
        // Deactivating an already-inactive node and activating an
        // already-active one still bump the version (an empty patch
        // ships fine) but change no edges.
        let before = m.graph().clone();
        let patch = m.deactivate(&[2]);
        assert!(patch.is_empty());
        assert_eq!(m.version(), v + 1);
        for u in 0..6 {
            assert_eq!(before.neighbors(u), m.graph().neighbors(u));
        }
    }

    #[test]
    fn losing_every_neighbor_never_orphans_a_node() {
        // Remove both ring neighbors of node 0 in one call: the local
        // pair-up plus bridging must leave node 0 attached.
        let mut m = Membership::new(ring(8), 2);
        m.deactivate(&[1, 7]);
        assert_repaired(&m, 2);
        assert!(m.graph().degree(0) >= 1, "node 0 left orphaned");
    }

    #[test]
    fn churn_sequence_holds_the_guarantees() {
        let mut m = Membership::new(regular_circulant(24, 4), 4);
        let seq: &[(&[usize], bool)] = &[
            (&[0, 1], false),
            (&[10], false),
            (&[0], true),
            (&[17, 18, 19], false),
            (&[1, 10, 17], true),
            (&[5], false),
        ];
        for &(nodes, add) in seq {
            if add {
                m.activate(nodes);
            } else {
                m.deactivate(nodes);
            }
            assert_repaired(&m, 4);
        }
    }
}
