//! Elastic membership: workers join and leave a running deployment.
//!
//! Three pieces make churn survivable (docs/membership.md):
//!
//! 1. **Protocol** — the WIRE_VERSION 7 frames
//!    ([`JoinRequest`](crate::net::WireMsg::JoinRequest) →
//!    [`JoinGrant`](crate::net::WireMsg::JoinGrant) →
//!    [`JoinReady`](crate::net::WireMsg::JoinReady), plus
//!    [`LeaveNotice`](crate::net::WireMsg::LeaveNotice) /
//!    [`PeerUpdate`](crate::net::WireMsg::PeerUpdate)) drive admission
//!    and departure on the existing control plane
//!    (`net::cluster::run_launch` is the controller, `dasgd worker
//!    --join ADDR` the joiner).
//! 2. **Topology repair** — [`Membership`] recomputes the affected
//!    neighborhoods on every change, preserving connectivity and
//!    degree and greedily steering toward spectral gap
//!    ([`crate::graph::spectral::sigma2`]; the paper's regular-graph
//!    bound `η ≥ (1 − σ₂²)(k+1)/N` is the objective). The result
//!    ships as a [`TopologyPatch`](crate::net::WireMsg::TopologyPatch)
//!    to affected workers only.
//! 3. **Atomic view swap** — workers hold their topology behind a
//!    [`TopologyView`]: a patch replaces whole neighbor lists under a
//!    write lock, while each collect round samples its neighborhood
//!    once under a read lock — an in-flight `CollectRequest` never
//!    sees a torn view.
//!
//! State handoff (a departing worker's shards re-streaming to the
//! replacement, parameters carried in
//! [`HandoffBegin`](crate::net::WireMsg::HandoffBegin)) lives in
//! `net::cluster` — it is a data-plane concern, not a graph one.

mod repair;

pub use repair::Membership;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use crate::graph::Graph;

/// A shared, versioned view of the communication topology.
///
/// Node threads read neighborhoods from it on every firing; the serve
/// loop applies [`TopologyPatch`](crate::net::WireMsg::TopologyPatch)
/// frames to it between collect rounds. Versions are monotonic: a
/// stale or replayed patch is ignored, so out-of-order delivery cannot
/// regress the view.
#[derive(Debug)]
pub struct TopologyView {
    graph: RwLock<Graph>,
    version: AtomicU64,
}

impl TopologyView {
    /// Wrap the launch-time graph as patch version 0.
    pub fn new(graph: Graph) -> Self {
        Self {
            graph: RwLock::new(graph),
            version: AtomicU64::new(0),
        }
    }

    /// The version of the last applied patch (0 = launch topology).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Number of nodes (fixed for the run — membership vacates nodes,
    /// it never renumbers them).
    pub fn len(&self) -> usize {
        self.graph.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The closed neighborhood {u} ∪ N(u) under the current view —
    /// one consistent sample per call (the collect round that uses it
    /// keeps it even if a patch lands mid-round).
    pub fn closed_neighborhood(&self, u: usize) -> Vec<usize> {
        self.graph.read().unwrap().closed_neighborhood(u)
    }

    /// A full snapshot of the current graph (clone; test/diagnostic
    /// use — the hot path wants [`Self::closed_neighborhood`]).
    pub fn snapshot(&self) -> Graph {
        self.graph.read().unwrap().clone()
    }

    /// Apply one topology patch: each entry replaces that node's
    /// *complete* neighbor list (an empty list detaches the node).
    /// Returns `false` without touching the view when the patch is
    /// stale (`version` not newer than the current one) or malformed
    /// (out-of-range ids, self-loops) — a worker never lets a bad
    /// frame corrupt its topology.
    pub fn apply(&self, version: u64, entries: &[(u32, Vec<u32>)]) -> bool {
        let mut g = self.graph.write().unwrap();
        if version <= self.version.load(Ordering::Acquire) {
            return false;
        }
        let n = g.len();
        let ok = entries.iter().all(|(node, hood)| {
            (*node as usize) < n
                && hood
                    .iter()
                    .all(|&nb| (nb as usize) < n && nb != *node)
        });
        if !ok {
            return false;
        }
        // Two passes keep edge symmetry intact: first detach every
        // patched node, then re-add each one's full new list (add_edge
        // is idempotent, so the shared edges of two patched nodes are
        // inserted once).
        for (node, _) in entries {
            let node = *node as usize;
            for nb in g.neighbors(node).to_vec() {
                g.remove_edge(node, nb);
            }
        }
        for (node, hood) in entries {
            for &nb in hood {
                g.add_edge(*node as usize, nb as usize);
            }
        }
        self.version.store(version, Ordering::Release);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ring;

    #[test]
    fn view_applies_patches_in_version_order() {
        let view = TopologyView::new(ring(6));
        assert_eq!(view.version(), 0);
        assert_eq!(view.closed_neighborhood(0), vec![0, 1, 5]);

        // Detach node 0, rewire 1–5 directly.
        let patch = vec![(0u32, vec![]), (1u32, vec![2, 5]), (5u32, vec![1, 4])];
        assert!(view.apply(1, &patch));
        assert_eq!(view.version(), 1);
        assert_eq!(view.closed_neighborhood(0), vec![0]);
        assert_eq!(view.closed_neighborhood(1), vec![1, 2, 5]);

        // A stale replay is ignored.
        assert!(!view.apply(1, &[(0u32, vec![1])]));
        assert_eq!(view.closed_neighborhood(0), vec![0]);

        // A malformed patch is rejected without touching the view.
        assert!(!view.apply(2, &[(0u32, vec![99])]));
        assert!(!view.apply(2, &[(3u32, vec![3])]));
        assert_eq!(view.version(), 1);

        // A newer well-formed patch lands.
        assert!(view.apply(2, &[(0u32, vec![1]), (1u32, vec![0, 2, 5])]));
        assert_eq!(view.closed_neighborhood(0), vec![0, 1]);
        assert_eq!(view.version(), 2);
    }

    #[test]
    fn patched_edges_stay_symmetric() {
        let view = TopologyView::new(ring(5));
        // Patch two adjacent nodes whose lists mention each other:
        // the shared edge must appear exactly once in each list.
        assert!(view.apply(1, &[(0u32, vec![2]), (2u32, vec![0, 1, 3])]));
        let g = view.snapshot();
        for u in 0..g.len() {
            for &v in g.neighbors(u) {
                assert!(g.has_edge(v, u), "asymmetric edge {u}-{v}");
            }
        }
        assert!(g.has_edge(0, 2));
        assert!(!g.has_edge(0, 1));
    }
}
