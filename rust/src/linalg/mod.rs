//! Dense f32 linear algebra substrate.
//!
//! Powers the rust-native model math (`crate::model`), the spectral
//! analysis of averaging matrices (`crate::graph::spectral`), and the
//! baselines. Row-major, allocation-explicit, no BLAS: shapes in this
//! system are tiny (≤ 256×16), so simple triple loops with row slicing
//! are at memory-bandwidth roofline.

mod matrix;

pub use matrix::Matrix;

/// y += alpha * x (vectors).
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f32 {
    x.iter().map(|v| v * v).sum::<f32>().sqrt()
}

/// Squared Euclidean distance between two vectors.
pub fn dist2_sq(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Dot product.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Scale a vector in place.
pub fn scale(x: &mut [f32], alpha: f32) {
    for v in x {
        *v *= alpha;
    }
}

/// Element-wise mean of several equal-length vectors.
pub fn mean_of(vectors: &[&[f32]]) -> Vec<f32> {
    assert!(!vectors.is_empty());
    let len = vectors[0].len();
    let mut out = vec![0.0f32; len];
    for v in vectors {
        assert_eq!(v.len(), len);
        axpy(1.0, v, &mut out);
    }
    scale(&mut out, 1.0 / vectors.len() as f32);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_ops() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(2.0, &[1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert!((dot(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-6);
        assert!((dist2_sq(&[0.0, 0.0], &[3.0, 4.0]) - 25.0).abs() < 1e-6);
    }

    #[test]
    fn mean_of_vectors() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let m = mean_of(&[&a, &b]);
        assert_eq!(m, vec![2.0, 4.0]);
    }
}
