//! Row-major dense f32 matrix.

/// Row-major dense matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "matrix data length mismatch");
        Self { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// C = A @ B.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        // ikj loop order: streams B rows, writes C rows sequentially.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let crow = out.row_mut(i);
                for (c, b) in crow.iter_mut().zip(brow) {
                    *c += a * b;
                }
            }
        }
        out
    }

    /// y = A @ x (matrix-vector).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| crate::linalg::dot(self.row(i), x))
            .collect()
    }

    /// y = A^T @ x.
    pub fn matvec_t(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.rows, x.len(), "matvec_t shape mismatch");
        let mut out = vec![0.0f32; self.cols];
        for (i, &xi) in x.iter().enumerate() {
            if xi != 0.0 {
                crate::linalg::axpy(xi, self.row(i), &mut out);
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        crate::linalg::norm2(&self.data)
    }

    /// Element-wise A - B.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;

    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn identity_is_neutral() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let i2 = Matrix::eye(2);
        assert_eq!(i2.matmul(&a), a);
        let i3 = Matrix::eye(3);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matvec_agrees_with_matmul() {
        let a = Matrix::from_vec(3, 2, vec![1.0, 2.0, 0.0, -1.0, 4.0, 0.5]);
        let x = vec![2.0, 3.0];
        let direct = a.matvec(&x);
        let via = a.matmul(&Matrix::from_vec(2, 1, x));
        assert_eq!(direct, via.data());
    }

    #[test]
    fn matvec_t_is_transpose_matvec() {
        let a = Matrix::from_vec(3, 2, vec![1.0, 2.0, 0.0, -1.0, 4.0, 0.5]);
        let x = vec![1.0, -2.0, 0.5];
        assert_eq!(a.matvec_t(&x), a.transpose().matvec(&x));
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn fro_norm_and_sub() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
        let b = a.sub(&a);
        assert_eq!(b.fro_norm(), 0.0);
    }
}
