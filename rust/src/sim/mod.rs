//! Discrete-event simulator: convergence in *virtual time* under
//! heterogeneous node speeds — the substrate for the intro's claim that
//! synchronized schemes lose to asynchronous ones when stragglers exist.
//!
//! The simulator charges every operation a virtual cost drawn from a
//! per-node speed model and advances an event queue; no wall-clock
//! sleeping is involved, so large straggler ratios are cheap to study.
//!
//! The engine is the event-driven [`simnet_run`] driver over a
//! [`SimNet`](crate::transport::SimNet) substrate (per-edge latency,
//! drops, partitions, 10k-node scale); [`virtual_async_run`] is its
//! ideal-network preset.

mod driver;
mod event_queue;
mod speed;
mod virtual_async;

pub use driver::{simnet_run, simnet_run_plan, SimConfig, SimReport, EXACT_SCAN_MAX};
pub use event_queue::{EventQueue, ShardedEventQueue};
pub use speed::SpeedModel;
pub use virtual_async::{virtual_async_run, VirtualAsyncConfig, VirtualAsyncReport};

/// Virtual time accounting for one synchronous round of a barrier-based
/// scheme: the barrier waits for the slowest participant.
pub fn sync_round_time(compute_times: &[f64], comm_latency: f64) -> f64 {
    compute_times
        .iter()
        .copied()
        .fold(0.0f64, f64::max)
        + comm_latency
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_round_is_max_plus_comm() {
        assert_eq!(sync_round_time(&[1.0, 3.0, 2.0], 0.5), 3.5);
        assert_eq!(sync_round_time(&[], 0.5), 0.5);
    }
}
