//! Per-node speed models for heterogeneous clusters.

use crate::util::rng::Xoshiro256pp;

/// Mean compute time per operation for every node, with per-operation
/// jitter. Models the paper's "heterogeneous system including
/// high-performance computing clusters and low-performance mobile
/// devices" (§VI future work — we simulate it).
#[derive(Clone, Debug)]
pub struct SpeedModel {
    /// Mean seconds per gradient step, per node.
    means: Vec<f64>,
}

impl SpeedModel {
    /// Homogeneous cluster: everyone at `mean` s/op.
    pub fn homogeneous(n: usize, mean: f64) -> Self {
        Self {
            means: vec![mean; n],
        }
    }

    /// Log-normal heterogeneity: node means `mean · exp(N(0, spread))`.
    pub fn lognormal(n: usize, mean: f64, spread: f64, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::seeded(seed);
        Self {
            means: (0..n)
                .map(|_| mean * (rng.next_gauss() * spread).exp())
                .collect(),
        }
    }

    /// A homogeneous cluster with `stragglers` nodes slowed by `factor`.
    pub fn with_stragglers(n: usize, mean: f64, stragglers: usize, factor: f64) -> Self {
        assert!(stragglers <= n);
        let mut means = vec![mean; n];
        for m in means.iter_mut().take(stragglers) {
            *m *= factor;
        }
        Self { means }
    }

    pub fn len(&self) -> usize {
        self.means.len()
    }

    pub fn is_empty(&self) -> bool {
        self.means.is_empty()
    }

    pub fn mean(&self, node: usize) -> f64 {
        self.means[node]
    }

    /// Sample one operation's duration: Exp(1/mean_i) jitter.
    pub fn sample(&self, node: usize, rng: &mut Xoshiro256pp) -> f64 {
        rng.exponential(1.0 / self.means[node])
    }

    /// One synchronized-round compute draw for every node.
    pub fn sample_all(&self, rng: &mut Xoshiro256pp) -> Vec<f64> {
        (0..self.means.len()).map(|i| self.sample(i, rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straggler_means() {
        let m = SpeedModel::with_stragglers(5, 1.0, 2, 10.0);
        assert_eq!(m.mean(0), 10.0);
        assert_eq!(m.mean(1), 10.0);
        assert_eq!(m.mean(4), 1.0);
    }

    #[test]
    fn samples_average_to_mean() {
        let m = SpeedModel::homogeneous(1, 2.0);
        let mut rng = Xoshiro256pp::seeded(1);
        let n = 20_000;
        let total: f64 = (0..n).map(|_| m.sample(0, &mut rng)).sum();
        let mean = total / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn lognormal_spread_creates_heterogeneity() {
        let m = SpeedModel::lognormal(50, 1.0, 1.0, 3);
        let max = m.means.iter().cloned().fold(0.0f64, f64::max);
        let min = m.means.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 3.0, "max/min = {}", max / min);
    }
}
