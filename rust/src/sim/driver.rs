//! The discrete-event driver: one `NodeLogic` per node firing as an
//! independent renewal process over a [`SimNet`] substrate, with a
//! sharded event queue and incremental snapshots so 10,000+ node
//! systems simulate in seconds.
//!
//! The driver owns virtual time: it pops the next firing, advances the
//! substrate clock, lets the node's logic decide grad-vs-projection,
//! and charges the event its compute draw plus whatever communication
//! delay the substrate accrued (latency legs of the projection round).
//! Message drops and partitions shrink a projection's participant set —
//! the initiator averages whoever answered, exactly the "average over
//! whoever is reachable" semantics of the wall-clock engine under
//! failures.
//!
//! # Snapshot cost
//!
//! Up to [`EXACT_SCAN_MAX`] nodes the driver scans all parameters per
//! evaluation and records the paper's exact d^k (so small simulations
//! are directly comparable to the other engines). Beyond that it reads
//! the substrate's O(dim) incremental aggregates and records the L2
//! consensus residual `sqrt(Σ‖β_i − β̄‖²)` — a lower bound on d^k that
//! is zero exactly at consensus (see
//! [`ConsensusTracker`](crate::node_logic::ConsensusTracker)).

use std::time::Duration;

use crate::coordinator::StepSize;
use crate::data::Dataset;
use crate::graph::Graph;
use crate::metrics::Recorder;
use crate::node_logic::{Action, Counts, NodeLogic, Probe, Strategy};
use crate::objective::Objective;
use crate::transport::{ProjectionOutcome, SimNet, SimNetConfig, Transport};
use crate::util::rng::Xoshiro256pp;
use crate::workload::WorkloadPlan;

use super::{ShardedEventQueue, SpeedModel};

/// Largest node count for which snapshots do a full parameter scan
/// (exact d^k); larger systems use the incremental aggregates.
pub const EXACT_SCAN_MAX: usize = 256;

/// Configuration of one event-driven simulation.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub p_grad: f64,
    pub stepsize: StepSize,
    /// The §II loss family every node optimizes.
    pub objective: Objective,
    /// Virtual seconds to simulate.
    pub horizon: f64,
    /// Evaluation cadence in virtual seconds.
    pub eval_every: f64,
    /// The network model (latency / drops / partitions).
    pub net: SimNetConfig,
    pub seed: u64,
}

/// Outcome of one event-driven simulation.
#[derive(Debug)]
pub struct SimReport {
    pub recorder: Recorder,
    pub updates: u64,
    pub grad_steps: u64,
    pub proj_steps: u64,
    pub messages: u64,
    /// Projection legs lost to the drop probability.
    pub drops: u64,
    /// Projection attempts with nobody reachable (drops/partitions).
    pub isolated: u64,
    /// Final per-node parameters (one full materialization).
    pub final_params: Vec<Vec<f32>>,
}

/// Run Alg. 2 under the event-driven driver on a [`SimNet`] substrate
/// with one objective and one shard per node (the homogeneous preset —
/// a thin wrapper over [`simnet_run_plan`]).
pub fn simnet_run(
    g: &Graph,
    shards: &[Dataset],
    test: &Dataset,
    speeds: &SpeedModel,
    cfg: &SimConfig,
) -> SimReport {
    let plan = WorkloadPlan::homogeneous(cfg.objective, shards.to_vec());
    simnet_run_plan(g, &plan, test, speeds, cfg)
}

/// Run Alg. 2 under the event-driven driver, constructing every node
/// from its [`WorkloadPlan`] assignment (per-node objective + shard).
/// `cfg.objective` is superseded by the plan; homogeneous plans use
/// `cfg.stepsize`, mixed plans give each node its own family's default
/// schedule (see docs/heterogeneity.md).
pub fn simnet_run_plan(
    g: &Graph,
    plan: &WorkloadPlan,
    test: &Dataset,
    speeds: &SpeedModel,
    cfg: &SimConfig,
) -> SimReport {
    let n = g.len();
    assert_eq!(plan.len(), n);
    assert_eq!(speeds.len(), n);
    // A non-positive cadence would pin `next_eval` and snapshot forever.
    assert!(
        cfg.eval_every > 0.0 && cfg.horizon.is_finite(),
        "eval_every must be > 0 and horizon finite"
    );
    let param_len = plan.param_len();
    let mixed = plan.is_mixed();

    let mut root = Xoshiro256pp::seeded(cfg.seed);
    let mut logics: Vec<NodeLogic> = (0..n)
        .map(|i| {
            let a = plan.node(i);
            NodeLogic::new(i, a.objective, cfg.p_grad, a.shard.clone(), n, root.split(i as u64))
        })
        .collect();
    let steps: Vec<StepSize> = (0..n)
        .map(|i| {
            if mixed {
                plan.objective(i).default_stepsize(n)
            } else {
                cfg.stepsize
            }
        })
        .collect();
    // Per-node update strategies from the plan (delay-aware ones read
    // the same staleness-in-ticks signal the wall-clock engines feed).
    let mut strategies: Vec<Box<dyn Strategy>> = (0..n)
        .map(|i| plan.strategy(i).build(steps[i].at(0)))
        .collect();
    let mut last_k: Vec<u64> = vec![0; n];
    let hoods: Vec<Vec<usize>> = (0..n).map(|i| g.closed_neighborhood(i)).collect();
    let net = SimNet::new(n, param_len, cfg.net.clone());
    let probe = Probe::mixed(&plan.objectives(), test);

    let mut queue = ShardedEventQueue::for_nodes(n);
    for (i, logic) in logics.iter_mut().enumerate() {
        let dt = speeds.sample(i, &mut logic.rng);
        queue.push(dt, i);
    }

    let mut rec = Recorder::new("simnet");
    let mut k = 0u64;
    let mut counts = Counts::default();
    let mut isolated = 0u64;
    let mut next_eval = 0.0f64;
    let exact = n <= EXACT_SCAN_MAX;

    let snap = |t: f64, k: u64, counts: &Counts, net: &SimNet, rec: &mut Recorder| {
        let mut c = *counts;
        c.messages = net.net_stats().0;
        if exact {
            rec.push(probe.snapshot(k, t, &net.snapshot(), &c));
        } else {
            let (mean, residual) = net.mean_and_residual();
            rec.push(probe.snapshot_at(k, t, &mean, residual, &c));
        }
    };

    while let Some((t, i)) = queue.pop() {
        if t > cfg.horizon {
            break;
        }
        while t >= next_eval {
            snap(next_eval, k, &counts, &net, &mut rec);
            next_eval += cfg.eval_every;
        }
        net.set_now(t);
        let lr = steps[i].at(k);
        let logic = &mut logics[i];
        let strategy = &mut strategies[i];
        let staleness = k.saturating_sub(last_k[i]);
        let mut op_time = speeds.sample(i, &mut logic.rng);
        match strategy.draw_action(logic) {
            Action::Grad => {
                net.update_own_with_aux(i, &mut |w, aux| {
                    strategy.local_step(logic, w, aux, lr, staleness);
                });
                counts.grad_steps += 1;
                last_k[i] = k;
                k += 1;
            }
            Action::Project => {
                match net.try_project(i, &hoods[i], Duration::ZERO, &mut |rows, aux_rows| {
                    strategy.mix(rows, aux_rows)
                }) {
                    ProjectionOutcome::Applied { .. } => {
                        op_time += net.take_last_comm();
                        counts.proj_steps += 1;
                        last_k[i] = k;
                        k += 1;
                    }
                    ProjectionOutcome::Isolated => {
                        isolated += 1;
                    }
                    // The virtual substrate never contends.
                    ProjectionOutcome::Conflict => unreachable!("SimNet is conflict-free"),
                }
            }
        }
        queue.push(t + op_time, i);
    }
    snap(cfg.horizon, k, &counts, &net, &mut rec);

    let (messages, drops) = net.net_stats();
    SimReport {
        recorder: rec,
        updates: k,
        grad_steps: counts.grad_steps,
        proj_steps: counts.proj_steps,
        messages,
        drops,
        isolated,
        final_params: net.snapshot(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGen;
    use crate::graph::regular_circulant;
    use crate::transport::{LatencyModel, PartitionWindow};

    fn world(n: usize, per_node: usize, seed: u64) -> (Graph, Vec<Dataset>, Dataset) {
        let gen = SyntheticGen::new(n, 10, 4, 2.5, 0.4, 0.3, seed);
        let mut rng = Xoshiro256pp::seeded(seed ^ 7);
        let shards = (0..n)
            .map(|i| gen.node_dataset(i, per_node, &mut rng))
            .collect();
        let test = gen.global_test_set(200, &mut rng);
        (regular_circulant(n, 4), shards, test)
    }

    fn cfg(horizon: f64, net: SimNetConfig) -> SimConfig {
        SimConfig {
            p_grad: 0.5,
            stepsize: StepSize::Poly {
                a: 10.0,
                tau: 4000.0,
                pow: 0.75,
            },
            objective: Objective::LogReg,
            horizon,
            eval_every: horizon / 4.0,
            net,
            seed: 5,
        }
    }

    #[test]
    fn lossy_network_still_converges() {
        let (g, shards, test) = world(8, 60, 3);
        let speeds = SpeedModel::homogeneous(8, 1.0);
        let net = SimNetConfig {
            latency: LatencyModel {
                min_secs: 0.01,
                max_secs: 0.05,
                jitter_secs: 0.01,
            },
            drop_prob: 0.05,
            partitions: vec![],
            seed: 5,
        };
        let rep = simnet_run(&g, &shards, &test, &speeds, &cfg(250.0, net));
        assert!(rep.updates > 500, "updates={}", rep.updates);
        assert!(rep.drops > 0, "expected dropped legs at 5%");
        let first = rep.recorder.records.first().unwrap();
        let last = rep.recorder.last().unwrap();
        assert!(last.test_err < 0.5, "err={}", last.test_err);
        assert!(last.test_err <= first.test_err);
    }

    #[test]
    fn partition_halves_then_heals() {
        // Split an 8-ring down the middle for the first half of the
        // run; consensus must still be reached after it heals.
        let (g, shards, test) = world(8, 60, 9);
        let speeds = SpeedModel::homogeneous(8, 1.0);
        let net = SimNetConfig {
            partitions: vec![PartitionWindow {
                start_secs: 0.0,
                end_secs: 100.0,
                boundary: 4,
            }],
            ..SimNetConfig::ideal(0.0)
        };
        let rep = simnet_run(&g, &shards, &test, &speeds, &cfg(300.0, net));
        let last = rep.recorder.last().unwrap();
        assert!(last.consensus < 10.0, "post-heal consensus {}", last.consensus);
        assert!(rep.updates > 500);
    }

    #[test]
    fn large_system_uses_incremental_snapshots() {
        // Above EXACT_SCAN_MAX the driver must stay fast and still show
        // a decreasing consensus residual.
        let n = 300;
        let (g, shards, test) = world(n, 10, 17);
        let speeds = SpeedModel::homogeneous(n, 1.0);
        let rep = simnet_run(
            &g,
            &shards,
            &test,
            &speeds,
            &cfg(20.0, SimNetConfig::ideal(0.001)),
        );
        assert!(rep.updates > n as u64);
        let records = &rep.recorder.records;
        assert!(records.last().unwrap().consensus.is_finite());
        assert_eq!(rep.final_params.len(), n);
    }
}
