//! Alg. 2 under a virtual clock: every node is an independent renewal
//! process whose firing interval is its (heterogeneous) compute time.
//! No barriers means a slow node only slows *its own* updates — the
//! claim this simulator quantifies against the synchronous baselines.
//!
//! Since the transport refactor this is a thin preset over the
//! event-driven [`simnet_run`](super::simnet_run) driver: an ideal
//! [`SimNet`](crate::transport::SimNet) (fixed one-way latency, no
//! drops, no partitions) consuming the same
//! [`NodeLogic`](crate::node_logic::NodeLogic) as every other engine.
//! Use [`SimConfig`](super::SimConfig) directly for lossy/partitioned
//! networks and 10k-node scale.

use crate::coordinator::StepSize;
use crate::data::Dataset;
use crate::graph::Graph;
use crate::metrics::Recorder;
use crate::objective::Objective;
use crate::transport::SimNetConfig;

use super::{simnet_run, SimConfig, SpeedModel};

#[derive(Clone, Debug)]
pub struct VirtualAsyncConfig {
    pub p_grad: f64,
    pub stepsize: StepSize,
    /// The §II loss family every node optimizes.
    pub objective: Objective,
    /// Virtual seconds to simulate.
    pub horizon: f64,
    /// Evaluation cadence in virtual seconds.
    pub eval_every: f64,
    /// One-way message latency charged to each projection (collect +
    /// broadcast = 2 latencies on top of compute).
    pub comm_latency: f64,
    pub seed: u64,
}

#[derive(Debug)]
pub struct VirtualAsyncReport {
    pub recorder: Recorder,
    pub updates: u64,
    pub grad_steps: u64,
    pub proj_steps: u64,
    pub messages: u64,
}

/// Simulate Alg. 2 in virtual time over `speeds` on an ideal network.
pub fn virtual_async_run(
    g: &Graph,
    shards: &[Dataset],
    test: &Dataset,
    speeds: &SpeedModel,
    cfg: &VirtualAsyncConfig,
) -> VirtualAsyncReport {
    let sim = SimConfig {
        p_grad: cfg.p_grad,
        stepsize: cfg.stepsize,
        objective: cfg.objective,
        horizon: cfg.horizon,
        eval_every: cfg.eval_every,
        net: SimNetConfig::ideal(cfg.comm_latency),
        seed: cfg.seed,
    };
    let rep = simnet_run(g, shards, test, speeds, &sim);
    VirtualAsyncReport {
        recorder: rep.recorder,
        updates: rep.updates,
        grad_steps: rep.grad_steps,
        proj_steps: rep.proj_steps,
        messages: rep.messages,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGen;
    use crate::graph::regular_circulant;
    use crate::util::rng::Xoshiro256pp;

    fn setup(n: usize) -> (Graph, Vec<Dataset>, Dataset) {
        let gen = SyntheticGen::new(n, 10, 4, 2.5, 0.4, 0.3, 31);
        let mut rng = Xoshiro256pp::seeded(8);
        let shards = (0..n).map(|i| gen.node_dataset(i, 80, &mut rng)).collect();
        let test = gen.global_test_set(300, &mut rng);
        (regular_circulant(n, 4), shards, test)
    }

    fn quick_cfg() -> VirtualAsyncConfig {
        VirtualAsyncConfig {
            p_grad: 0.5,
            stepsize: StepSize::Poly {
                a: 10.0,
                tau: 4000.0,
                pow: 0.75,
            },
            objective: Objective::LogReg,
            horizon: 300.0,
            eval_every: 100.0,
            comm_latency: 0.05,
            seed: 5,
        }
    }

    #[test]
    fn virtual_async_learns_in_virtual_time() {
        let (g, shards, test) = setup(8);
        let speeds = SpeedModel::homogeneous(8, 1.0);
        let rep = virtual_async_run(&g, &shards, &test, &speeds, &quick_cfg());
        assert!(rep.updates > 1000, "updates={}", rep.updates);
        assert!(rep.recorder.last().unwrap().test_err < 0.5);
    }

    #[test]
    fn deterministic_given_seed() {
        let (g, shards, test) = setup(6);
        let speeds = SpeedModel::homogeneous(6, 1.0);
        let a = virtual_async_run(&g, &shards, &test, &speeds, &quick_cfg());
        let b = virtual_async_run(&g, &shards, &test, &speeds, &quick_cfg());
        assert_eq!(a.updates, b.updates);
        assert_eq!(
            a.recorder.last().unwrap().test_err,
            b.recorder.last().unwrap().test_err
        );
    }

    #[test]
    fn virtual_async_runs_lasso_objective() {
        let (g, shards, test) = setup(6);
        let speeds = SpeedModel::homogeneous(6, 1.0);
        let cfg = VirtualAsyncConfig {
            objective: Objective::lasso(),
            stepsize: Objective::lasso().default_stepsize(6),
            ..quick_cfg()
        };
        let rep = virtual_async_run(&g, &shards, &test, &speeds, &cfg);
        assert!(rep.updates > 500);
        let first = rep.recorder.records.first().unwrap();
        let last = rep.recorder.last().unwrap();
        // RMSE column must improve from the w = 0 baseline.
        assert!(
            last.test_err < first.test_err,
            "rmse {} -> {}",
            first.test_err,
            last.test_err
        );
    }

    #[test]
    fn stragglers_only_slow_themselves() {
        // One node 50x slower: total update count drops by ≈ its share
        // (1/8), not by 50x — the asynchronous advantage.
        let (g, shards, test) = setup(8);
        let fast = SpeedModel::homogeneous(8, 1.0);
        let slow = SpeedModel::with_stragglers(8, 1.0, 1, 50.0);
        let a = virtual_async_run(&g, &shards, &test, &fast, &quick_cfg());
        let b = virtual_async_run(&g, &shards, &test, &slow, &quick_cfg());
        let ratio = b.updates as f64 / a.updates as f64;
        assert!(
            ratio > 0.75,
            "async throughput should lose ≲ one node's share, got ratio {ratio}"
        );
    }
}
