//! Alg. 2 under a virtual clock: every node is an independent renewal
//! process whose firing interval is its (heterogeneous) compute time.
//! No barriers means a slow node only slows *its own* updates — the
//! claim this simulator quantifies against the synchronous baselines.

use crate::coordinator::{consensus, EvalBatch, StepSize};
use crate::data::Dataset;
use crate::graph::Graph;
use crate::metrics::{Record, Recorder};
use crate::objective::Objective;
use crate::util::rng::Xoshiro256pp;

use super::{EventQueue, SpeedModel};

#[derive(Clone, Debug)]
pub struct VirtualAsyncConfig {
    pub p_grad: f64,
    pub stepsize: StepSize,
    /// The §II loss family every node optimizes.
    pub objective: Objective,
    /// Virtual seconds to simulate.
    pub horizon: f64,
    /// Evaluation cadence in virtual seconds.
    pub eval_every: f64,
    /// One-way message latency charged to each projection (collect +
    /// broadcast = 2 latencies on top of compute).
    pub comm_latency: f64,
    pub seed: u64,
}

#[derive(Debug)]
pub struct VirtualAsyncReport {
    pub recorder: Recorder,
    pub updates: u64,
    pub grad_steps: u64,
    pub proj_steps: u64,
    pub messages: u64,
}

/// Simulate Alg. 2 in virtual time over `speeds`.
pub fn virtual_async_run(
    g: &Graph,
    shards: &[Dataset],
    test: &Dataset,
    speeds: &SpeedModel,
    cfg: &VirtualAsyncConfig,
) -> VirtualAsyncReport {
    let n = g.len();
    assert_eq!(shards.len(), n);
    assert_eq!(speeds.len(), n);
    let dim = shards[0].dim();
    let classes = shards[0].classes();
    let obj = cfg.objective;
    let mut root = Xoshiro256pp::seeded(cfg.seed);
    let mut rngs: Vec<Xoshiro256pp> = (0..n).map(|i| root.split(i as u64)).collect();
    let mut params: Vec<Vec<f32>> = vec![vec![0.0; obj.param_len(dim, classes)]; n];

    let mut queue = EventQueue::new();
    for i in 0..n {
        let dt = speeds.sample(i, &mut rngs[i]);
        queue.push(dt, i);
    }

    let test_batch = EvalBatch::for_objective(obj, test, None);
    let mut rec = Recorder::new("virtual_async");
    let mut k = 0u64;
    let mut grad_steps = 0u64;
    let mut proj_steps = 0u64;
    let mut messages = 0u64;
    let mut next_eval = 0.0f64;

    let snap = |t: f64,
                k: u64,
                params: &[Vec<f32>],
                grad_steps: u64,
                proj_steps: u64,
                messages: u64,
                rec: &mut Recorder| {
        let mean = consensus::mean_param(params);
        let (loss, err) = test_batch.eval(obj, &mean);
        rec.push(Record {
            k,
            time_secs: t,
            consensus: consensus::consensus_distance(params),
            test_loss: loss as f64,
            test_err: err as f64,
            grad_steps,
            proj_steps,
            messages,
            ..Default::default()
        });
    };

    while let Some((t, i)) = queue.pop() {
        if t > cfg.horizon {
            break;
        }
        while t >= next_eval {
            snap(next_eval, k, &params, grad_steps, proj_steps, messages, &mut rec);
            next_eval += cfg.eval_every;
        }
        let lr = cfg.stepsize.at(k);
        let mut op_time = speeds.sample(i, &mut rngs[i]);
        if rngs[i].next_f64() < cfg.p_grad {
            // Local gradient step.
            let idx = rngs[i].index(shards[i].len());
            let s = shards[i].sample(idx);
            let mut w = std::mem::take(&mut params[i]);
            obj.native_step(&mut w, s.features, &[s.label], dim, classes, lr, 1.0 / n as f32);
            params[i] = w;
            grad_steps += 1;
        } else {
            // Projection: collect + average + broadcast.
            let hood = g.closed_neighborhood(i);
            let rows: Vec<&[f32]> = hood.iter().map(|&j| params[j].as_slice()).collect();
            let avg = crate::linalg::mean_of(&rows);
            for &j in &hood {
                params[j].copy_from_slice(&avg);
            }
            messages += 2 * (hood.len() as u64 - 1);
            op_time += 2.0 * cfg.comm_latency;
            proj_steps += 1;
        }
        k += 1;
        queue.push(t + op_time, i);
    }
    snap(
        cfg.horizon,
        k,
        &params,
        grad_steps,
        proj_steps,
        messages,
        &mut rec,
    );
    VirtualAsyncReport {
        recorder: rec,
        updates: k,
        grad_steps,
        proj_steps,
        messages,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGen;
    use crate::graph::regular_circulant;

    fn setup(n: usize) -> (Graph, Vec<Dataset>, Dataset) {
        let gen = SyntheticGen::new(n, 10, 4, 2.5, 0.4, 0.3, 31);
        let mut rng = Xoshiro256pp::seeded(8);
        let shards = (0..n).map(|i| gen.node_dataset(i, 80, &mut rng)).collect();
        let test = gen.global_test_set(300, &mut rng);
        (regular_circulant(n, 4), shards, test)
    }

    fn quick_cfg() -> VirtualAsyncConfig {
        VirtualAsyncConfig {
            p_grad: 0.5,
            stepsize: StepSize::Poly {
                a: 10.0,
                tau: 4000.0,
                pow: 0.75,
            },
            objective: Objective::LogReg,
            horizon: 300.0,
            eval_every: 100.0,
            comm_latency: 0.05,
            seed: 5,
        }
    }

    #[test]
    fn virtual_async_learns_in_virtual_time() {
        let (g, shards, test) = setup(8);
        let speeds = SpeedModel::homogeneous(8, 1.0);
        let rep = virtual_async_run(&g, &shards, &test, &speeds, &quick_cfg());
        assert!(rep.updates > 1000, "updates={}", rep.updates);
        assert!(rep.recorder.last().unwrap().test_err < 0.5);
    }

    #[test]
    fn deterministic_given_seed() {
        let (g, shards, test) = setup(6);
        let speeds = SpeedModel::homogeneous(6, 1.0);
        let a = virtual_async_run(&g, &shards, &test, &speeds, &quick_cfg());
        let b = virtual_async_run(&g, &shards, &test, &speeds, &quick_cfg());
        assert_eq!(a.updates, b.updates);
        assert_eq!(
            a.recorder.last().unwrap().test_err,
            b.recorder.last().unwrap().test_err
        );
    }

    #[test]
    fn virtual_async_runs_lasso_objective() {
        let (g, shards, test) = setup(6);
        let speeds = SpeedModel::homogeneous(6, 1.0);
        let cfg = VirtualAsyncConfig {
            objective: Objective::lasso(),
            stepsize: Objective::lasso().default_stepsize(6),
            ..quick_cfg()
        };
        let rep = virtual_async_run(&g, &shards, &test, &speeds, &cfg);
        assert!(rep.updates > 500);
        let first = rep.recorder.records.first().unwrap();
        let last = rep.recorder.last().unwrap();
        // RMSE column must improve from the w = 0 baseline.
        assert!(
            last.test_err < first.test_err,
            "rmse {} -> {}",
            first.test_err,
            last.test_err
        );
    }

    #[test]
    fn stragglers_only_slow_themselves() {
        // One node 50x slower: total update count drops by ≈ its share
        // (1/8), not by 50x — the asynchronous advantage.
        let (g, shards, test) = setup(8);
        let fast = SpeedModel::homogeneous(8, 1.0);
        let slow = SpeedModel::with_stragglers(8, 1.0, 1, 50.0);
        let a = virtual_async_run(&g, &shards, &test, &fast, &quick_cfg());
        let b = virtual_async_run(&g, &shards, &test, &slow, &quick_cfg());
        let ratio = b.updates as f64 / a.updates as f64;
        assert!(
            ratio > 0.75,
            "async throughput should lose ≲ one node's share, got ratio {ratio}"
        );
    }
}
