//! Min-heap event queue keyed by virtual time (f64).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event at a virtual timestamp.
#[derive(Clone, Copy, Debug)]
struct Entry<T> {
    time: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap; tie-break on insertion order so the
        // schedule is deterministic.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-time event queue.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn push(&mut self, time: f64, payload: T) {
        assert!(time.is_finite(), "event time must be finite");
        self.heap.push(Entry {
            time,
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    /// Pop the earliest event: (time, payload).
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.time, e.payload))
    }

    /// The earliest event's time and payload, without removing it.
    pub fn peek(&self) -> Option<(f64, &T)> {
        self.heap.peek().map(|e| (e.time, &e.payload))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Event queue sharded by node id: one small heap per shard instead of
/// a single N-node heap. Pushes touch a heap of size ~N/S (better cache
/// behavior and shallower sift-ups at 10k+ nodes); pops scan the S
/// shard heads, which is cheap for the small S used.
///
/// Deterministic: ties across shards break toward the lowest shard
/// index, ties within a shard by insertion order.
#[derive(Debug)]
pub struct ShardedEventQueue {
    shards: Vec<EventQueue<usize>>,
    mask: usize,
}

impl ShardedEventQueue {
    /// Queue with `shards` shards (rounded up to a power of two).
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1).next_power_of_two();
        Self {
            shards: (0..shards).map(|_| EventQueue::new()).collect(),
            mask: shards - 1,
        }
    }

    /// Shard count appropriate for an `n`-node simulation.
    pub fn for_nodes(n: usize) -> Self {
        Self::new((n / 1024).clamp(1, 32))
    }

    pub fn push(&mut self, time: f64, node: usize) {
        self.shards[node & self.mask].push(time, node);
    }

    /// Pop the globally earliest event.
    pub fn pop(&mut self) -> Option<(f64, usize)> {
        let mut best: Option<(f64, usize)> = None;
        for (s, q) in self.shards.iter().enumerate() {
            if let Some((t, _)) = q.peek() {
                if best.map(|(bt, _)| t < bt).unwrap_or(true) {
                    best = Some((t, s));
                }
            }
        }
        best.and_then(|(_, s)| self.shards[s].pop())
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(EventQueue::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(EventQueue::is_empty)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    fn peek_does_not_consume() {
        let mut q = EventQueue::new();
        q.push(2.0, "b");
        q.push(1.0, "a");
        assert_eq!(q.peek(), Some((1.0, &"a")));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some((1.0, "a")));
    }

    #[test]
    fn sharded_queue_is_globally_time_ordered() {
        let mut q = ShardedEventQueue::new(4);
        let mut rng = crate::util::rng::Xoshiro256pp::seeded(9);
        for node in 0..200 {
            q.push(rng.next_f64() * 100.0, node);
        }
        assert_eq!(q.len(), 200);
        let mut last = f64::NEG_INFINITY;
        let mut popped = 0;
        while let Some((t, node)) = q.pop() {
            assert!(t >= last, "out of order: {t} after {last}");
            assert!(node < 200);
            last = t;
            popped += 1;
        }
        assert_eq!(popped, 200);
        assert!(q.is_empty());
    }

    #[test]
    fn sharded_queue_matches_single_queue_schedule() {
        // Same pushes → same (time-sorted) pop sequence of times.
        let mut sharded = ShardedEventQueue::new(8);
        let mut single = EventQueue::new();
        let mut rng = crate::util::rng::Xoshiro256pp::seeded(4);
        for node in 0..64 {
            let t = (rng.next_f64() * 10.0).round(); // force some ties
            sharded.push(t, node);
            single.push(t, node);
        }
        let mut a: Vec<f64> = Vec::new();
        while let Some((t, _)) = sharded.pop() {
            a.push(t);
        }
        let mut b: Vec<f64> = Vec::new();
        while let Some((t, _)) = single.pop() {
            b.push(t);
        }
        assert_eq!(a, b);
    }
}
