//! Min-heap event queue keyed by virtual time (f64).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event at a virtual timestamp.
#[derive(Clone, Copy, Debug)]
struct Entry<T> {
    time: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap; tie-break on insertion order so the
        // schedule is deterministic.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-time event queue.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn push(&mut self, time: f64, payload: T) {
        assert!(time.is_finite(), "event time must be finite");
        self.heap.push(Entry {
            time,
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    /// Pop the earliest event: (time, payload).
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.time, e.payload))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }
}
