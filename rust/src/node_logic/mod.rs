//! Engine-agnostic Alg. 2 per-node logic — the single place the paper's
//! update math lives.
//!
//! Every execution engine (the thread-per-node wall-clock runtime, the
//! virtual-time [`crate::sim`] driver, and the baselines' gossip paths)
//! used to carry its own copy of the gradient/projection step. That
//! forked the algorithm's semantics across engines; this module is the
//! one canonical implementation they all consume:
//!
//! * [`NodeLogic`] — the per-node state machine: exponential firing
//!   clock, the grad-vs-projection draw, sample selection, and the
//!   Eq. (6) gradient step, all on the node's private RNG stream.
//! * [`strategy`] — the pluggable update-policy trait and the
//!   algorithm zoo (`dasgd`/`dcasgd`/`delay-agnostic`/`rfast`).
//!   Engines and baselines reach the update math exclusively through
//!   a [`strategy::Strategy`]; the raw helpers below are the
//!   strategies' (and tests') building blocks.
//! * [`sgd_step`] / [`neighborhood_average`] — the raw Eq. (6)/(7)
//!   update math the baseline strategy is built from.
//! * [`Probe`] / [`Counts`] — the shared evaluate-and-snapshot path
//!   every engine records through.
//! * [`ConsensusTracker`] — incremental O(dim) mean + consensus
//!   residual for simulations too large to scan per snapshot.
//!
//! # Message accounting (the canonical convention)
//!
//! Engines historically disagreed: the wall-clock runtime charged one
//! message per lock *acquisition attempt* (so an aborted lock-up still
//! counted traffic), while the virtual-time simulator charged
//! collect + broadcast per applied projection. The convention every
//! engine now reports, via [`projection_messages`]:
//!
//! * an **applied projection** over a closed neighborhood with `h`
//!   participating members costs `2·(h − 1)` point-to-point messages —
//!   the initiator collects one parameter vector from each of its
//!   `h − 1` participating neighbors and broadcasts the average back;
//! * an **aborted lock-up** contributes **zero** to `messages` — it is
//!   reported separately as a `conflict` (control-plane lock traffic is
//!   not data-plane vector transfer);
//! * **gradient steps** are purely local and cost nothing.

use crate::coordinator::backend::EvalBatch;
use crate::data::stream::ShardReceiver;
use crate::data::Dataset;
use crate::metrics::Record;
use crate::objective::Objective;
use crate::util::rng::Xoshiro256pp;

pub mod strategy;

pub use strategy::{Strategy, StrategyKind};

/// Point-to-point messages charged for one applied Eq. (7) projection
/// over `participants` closed-neighborhood members (collect +
/// broadcast; see the module docs for the full convention).
#[inline]
pub fn projection_messages(participants: usize) -> u64 {
    debug_assert!(participants >= 1);
    2 * (participants as u64 - 1)
}

/// One Eq. (6) local gradient step: draw a uniform sample from `data`
/// on `rng`, then apply `objective`'s subgradient update
/// `w ← w − lr·scale·∇f` in place. Returns the sample loss.
///
/// This is the only gradient-step call site the engines and baselines
/// use; the RNG call order (one `index` draw, then the step) is part of
/// the contract so seeded runs stay reproducible across refactors.
#[allow(clippy::too_many_arguments)]
pub fn sgd_step(
    objective: Objective,
    w: &mut Vec<f32>,
    data: &Dataset,
    rng: &mut Xoshiro256pp,
    dim: usize,
    classes: usize,
    lr: f32,
    scale: f32,
) -> f32 {
    let idx = rng.index(data.len());
    let s = data.sample(idx);
    objective.native_step(w, s.features, &[s.label], dim, classes, lr, scale)
}

/// The Eq. (7) projection onto B_m: the closed neighborhood moves to
/// its unweighted average. The single place the projection math lives.
pub fn neighborhood_average(rows: &[&[f32]]) -> Vec<f32> {
    crate::linalg::mean_of(rows)
}

/// What a firing node decided to do this event (Alg. 2 line 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Gradient step on the node's own variable (w.p. `p_grad`).
    Grad,
    /// Eq. (7) projection over the closed neighborhood.
    Project,
}

/// The per-node Alg. 2 state machine: everything a node decides locally
/// — when it fires, what it does, which sample it draws, how its
/// variable moves — with *communication* left to a
/// [`Transport`](crate::transport::Transport) or driver.
///
/// Owns the node's data shard and private RNG stream, so engines stay
/// bit-for-bit reproducible: all randomness a node consumes flows
/// through this struct in a fixed call order.
#[derive(Clone, Debug)]
pub struct NodeLogic {
    pub id: usize,
    objective: Objective,
    p_grad: f64,
    data: Dataset,
    dim: usize,
    classes: usize,
    /// Eq. (6) gradient scale (1/N).
    scale: f32,
    /// The node's private randomness (firing clock, action draw,
    /// sample selection).
    pub rng: Xoshiro256pp,
    /// Streaming-plan feed: rows drain from here into `data` as their
    /// blocks land ([`NodeLogic::has_data`]). `None` for fully-shipped
    /// shards — the historical path, bit-for-bit unchanged.
    feed: Option<ShardReceiver>,
}

impl NodeLogic {
    pub fn new(
        id: usize,
        objective: Objective,
        p_grad: f64,
        data: Dataset,
        n_nodes: usize,
        rng: Xoshiro256pp,
    ) -> Self {
        assert!(!data.is_empty(), "node {id} has no local data");
        assert!((0.0..=1.0).contains(&p_grad));
        let dim = data.dim();
        let classes = data.classes();
        Self {
            id,
            objective,
            p_grad,
            data,
            dim,
            classes,
            scale: 1.0 / n_nodes as f32,
            rng,
            feed: None,
        }
    }

    /// A node whose shard arrives incrementally as a block stream: it
    /// starts with no local rows and steps as soon as the first block
    /// lands (see [`NodeLogic::has_data`]). `dim`/`classes` come from
    /// the plan metadata so the parameter vector binds before any data
    /// exists.
    #[allow(clippy::too_many_arguments)]
    pub fn streaming(
        id: usize,
        objective: Objective,
        p_grad: f64,
        feed: ShardReceiver,
        dim: usize,
        classes: usize,
        n_nodes: usize,
        rng: Xoshiro256pp,
    ) -> Self {
        assert!((0.0..=1.0).contains(&p_grad));
        assert!(dim > 0 && classes > 0, "node {id} has a degenerate shape");
        Self {
            id,
            objective,
            p_grad,
            data: Dataset::new(dim, classes),
            dim,
            classes,
            scale: 1.0 / n_nodes as f32,
            rng,
            feed: Some(feed),
        }
    }

    /// Ensure local rows exist to sample from, draining any staged
    /// stream blocks first (bounded wait while the first block is still
    /// in flight). Consumes no RNG, so fixed-plan runs are bit-for-bit
    /// unaffected. A `false` return means the node cannot take a
    /// gradient step *yet* — callers skip the step and redraw, exactly
    /// like a busy neighborhood.
    pub fn has_data(&mut self) -> bool {
        let mut retire = false;
        if let Some(feed) = &self.feed {
            feed.drain_into(&mut self.data);
            if self.data.is_empty() {
                feed.wait_for_block(std::time::Duration::from_millis(50));
                feed.drain_into(&mut self.data);
            }
            if feed.is_complete() {
                // Final drain below the completion mark is exhaustive:
                // every block was pushed before the stream completed.
                feed.drain_into(&mut self.data);
                retire = true;
            }
        }
        if retire {
            // Steady-state sampling pays no lock after the stream ends.
            self.feed = None;
        }
        !self.data.is_empty()
    }

    pub fn objective(&self) -> Objective {
        self.objective
    }

    pub fn data(&self) -> &Dataset {
        &self.data
    }

    /// Length of this node's flat parameter vector β_i.
    pub fn param_len(&self) -> usize {
        self.objective.param_len(self.dim, self.classes)
    }

    /// Continuous-time §IV-A clock: seconds until this node's next
    /// firing at `rate_hz` events/second.
    pub fn wait_secs(&mut self, rate_hz: f64) -> f64 {
        self.rng.exponential(rate_hz.max(1e-9))
    }

    /// Alg. 2 line 3: gradient step w.p. `p_grad`, else projection.
    pub fn draw_action(&mut self) -> Action {
        if self.rng.next_f64() < self.p_grad {
            Action::Grad
        } else {
            Action::Project
        }
    }

    /// Draw the index of this event's training sample (the PJRT path
    /// stages inputs itself and needs the draw separated from the step).
    pub fn draw_index(&mut self) -> usize {
        self.rng.index(self.data.len())
    }

    /// One native Eq. (6) gradient step on `w` (draws the sample
    /// internally — same RNG order as [`sgd_step`]).
    pub fn native_grad_step(&mut self, w: &mut Vec<f32>, lr: f32) -> f32 {
        sgd_step(
            self.objective,
            w,
            &self.data,
            &mut self.rng,
            self.dim,
            self.classes,
            lr,
            self.scale,
        )
    }

    /// The Eq. (6) scale factor (1/N) this node applies.
    pub fn grad_scale(&self) -> f32 {
        self.scale
    }
}

/// Cumulative per-engine counters in the canonical accounting
/// convention (see the module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counts {
    pub grad_steps: u64,
    pub proj_steps: u64,
    /// Data-plane messages: `2·(h−1)` per applied projection.
    pub messages: u64,
    /// Aborted lock-ups / simultaneous-firing collisions.
    pub conflicts: u64,
}

impl Counts {
    /// Applied updates (the paper's iteration counter k).
    pub fn updates(&self) -> u64 {
        self.grad_steps + self.proj_steps
    }
}

/// The shared evaluate-and-snapshot path: owns the held-out
/// [`EvalBatch`] in each objective's encoding and turns engine state
/// into [`Record`]s, so no engine carries its own eval/snapshot code.
///
/// A probe is usually homogeneous ([`Probe::new`]); heterogeneous
/// workloads where nodes disagree on loss family use [`Probe::mixed`],
/// which evaluates the mean parameter under every family present and
/// reports the node-count-weighted average of the per-family metrics
/// (the convention documented in docs/heterogeneity.md — consensus
/// needs no rule, it lives in the shared parameter space).
#[derive(Clone, Debug)]
pub struct Probe {
    /// One entry per distinct loss family: `(family, weight, batch)`,
    /// weights summing to 1.
    parts: Vec<(Objective, f32, EvalBatch)>,
}

impl Probe {
    pub fn new(objective: Objective, test: &Dataset) -> Self {
        Self::mixed(&[objective], test)
    }

    /// Probe for a (possibly mixed) cohort: `objectives` lists every
    /// node's family in node order; duplicates weight their family.
    /// Grouping is by exact objective (λ included) — two Lasso cohorts
    /// with different regularization evaluate under their own losses.
    pub fn mixed(objectives: &[Objective], test: &Dataset) -> Self {
        assert!(!objectives.is_empty(), "a probe needs at least one objective");
        let mut parts: Vec<(Objective, f32, EvalBatch)> = Vec::new();
        for &o in objectives {
            match parts.iter_mut().find(|(e, _, _)| *e == o) {
                Some((_, w, _)) => *w += 1.0,
                None => parts.push((o, 1.0, EvalBatch::for_objective(o, test, None))),
            }
        }
        let total: f32 = parts.iter().map(|(_, w, _)| w).sum();
        for (_, w, _) in &mut parts {
            *w /= total;
        }
        Self { parts }
    }

    /// `(loss, err)` of `w` on the held-out batch (native math) — the
    /// weighted per-family average for mixed cohorts.
    pub fn eval(&self, w: &[f32]) -> (f32, f32) {
        let (mut loss, mut err) = (0.0f32, 0.0f32);
        for (obj, weight, batch) in &self.parts {
            let (l, e) = batch.eval(*obj, w);
            loss += weight * l;
            err += weight * e;
        }
        (loss, err)
    }

    /// Full-scan snapshot: exact d^k consensus + metrics at β̄.
    pub fn snapshot(&self, k: u64, time_secs: f64, params: &[Vec<f32>], c: &Counts) -> Record {
        let mean = crate::coordinator::consensus::mean_param(params);
        let consensus = crate::coordinator::consensus::consensus_distance(params);
        self.snapshot_at(k, time_secs, &mean, consensus, c)
    }

    /// Snapshot at a precomputed mean / consensus value (the
    /// incremental path for simulations too large to scan).
    pub fn snapshot_at(
        &self,
        k: u64,
        time_secs: f64,
        mean: &[f32],
        consensus: f64,
        c: &Counts,
    ) -> Record {
        let (loss, err) = self.eval(mean);
        Record {
            k,
            time_secs,
            consensus,
            test_loss: loss as f64,
            test_err: err as f64,
            grad_steps: c.grad_steps,
            proj_steps: c.proj_steps,
            messages: c.messages,
            conflicts: c.conflicts,
            staleness_p50: 0.0,
            staleness_p99: 0.0,
            staging_bytes: 0,
        }
    }
}

/// Incremental consensus aggregates: maintains S = Σ_i β_i and
/// Q = Σ_i ‖β_i‖² under point updates, so a snapshot costs O(dim)
/// instead of O(n·dim).
///
/// The residual reported is the L2 (Frobenius) consensus residual
/// `sqrt(Σ_i ‖β_i − β̄‖²) = sqrt(Q − ‖S‖²/n)` — a lower bound on the
/// paper's d^k = Σ_i ‖β_i − β̄‖ (they agree at 0, i.e. at consensus).
/// Engines that can afford a full scan report exact d^k; the 10k-node
/// simulator reports this residual and documents it.
#[derive(Clone, Debug)]
pub struct ConsensusTracker {
    n: usize,
    sum: Vec<f64>,
    sumsq: f64,
}

impl ConsensusTracker {
    /// Tracker for `n` nodes all starting at the zero vector.
    pub fn new(n: usize, param_len: usize) -> Self {
        assert!(n > 0);
        Self {
            n,
            sum: vec![0.0; param_len],
            sumsq: 0.0,
        }
    }

    /// Add one node's contribution (call after its variable changes).
    pub fn add(&mut self, w: &[f32]) {
        debug_assert_eq!(w.len(), self.sum.len());
        let mut q = 0.0f64;
        for (s, &v) in self.sum.iter_mut().zip(w) {
            let v = v as f64;
            *s += v;
            q += v * v;
        }
        self.sumsq += q;
    }

    /// Remove one node's contribution (call before its variable
    /// changes). Exact inverse of [`ConsensusTracker::add`] in f64.
    pub fn sub(&mut self, w: &[f32]) {
        debug_assert_eq!(w.len(), self.sum.len());
        let mut q = 0.0f64;
        for (s, &v) in self.sum.iter_mut().zip(w) {
            let v = v as f64;
            *s -= v;
            q += v * v;
        }
        self.sumsq -= q;
    }

    /// β̄ = S/n.
    pub fn mean(&self) -> Vec<f32> {
        let n = self.n as f64;
        self.sum.iter().map(|&s| (s / n) as f32).collect()
    }

    /// The L2 consensus residual `sqrt(max(0, Q − ‖S‖²/n))`.
    pub fn residual(&self) -> f64 {
        let norm_sq: f64 = self.sum.iter().map(|&s| s * s).sum();
        (self.sumsq - norm_sq / self.n as f64).max(0.0).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGen;

    fn shard(seed: u64) -> Dataset {
        let gen = SyntheticGen::new(4, 10, 4, 2.0, 0.5, 0.3, seed);
        let mut rng = Xoshiro256pp::seeded(seed);
        gen.node_dataset(0, 40, &mut rng)
    }

    #[test]
    fn accounting_convention() {
        // Closed neighborhood of 5 (self + 4): collect 4 + broadcast 4.
        assert_eq!(projection_messages(5), 8);
        assert_eq!(projection_messages(1), 0);
    }

    #[test]
    fn sgd_step_matches_manual_rng_order() {
        // The contract: exactly one index draw, then the objective step.
        let data = shard(3);
        let obj = Objective::LogReg;
        let (dim, classes) = (data.dim(), data.classes());
        let mut w1 = vec![0.0f32; obj.param_len(dim, classes)];
        let mut w2 = w1.clone();
        let mut r1 = Xoshiro256pp::seeded(7);
        let mut r2 = Xoshiro256pp::seeded(7);
        let l1 = sgd_step(obj, &mut w1, &data, &mut r1, dim, classes, 0.3, 0.5);
        let idx = r2.index(data.len());
        let s = data.sample(idx);
        let l2 = obj.native_step(&mut w2, s.features, &[s.label], dim, classes, 0.3, 0.5);
        assert_eq!(w1, w2);
        assert_eq!(l1, l2);
        // Both RNGs advanced identically.
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn node_logic_draws_follow_p_grad() {
        let mut logic = NodeLogic::new(
            0,
            Objective::LogReg,
            0.7,
            shard(5),
            8,
            Xoshiro256pp::seeded(11),
        );
        let grads = (0..4000)
            .filter(|_| logic.draw_action() == Action::Grad)
            .count();
        let frac = grads as f64 / 4000.0;
        assert!((frac - 0.7).abs() < 0.05, "grad fraction {frac}");
        assert!((logic.grad_scale() - 1.0 / 8.0).abs() < 1e-7);
        assert_eq!(logic.param_len(), 10 * 4);
    }

    #[test]
    fn native_grad_step_moves_weights() {
        let mut logic = NodeLogic::new(
            0,
            Objective::LogReg,
            0.5,
            shard(9),
            4,
            Xoshiro256pp::seeded(2),
        );
        let mut w = vec![0.0f32; logic.param_len()];
        let loss = logic.native_grad_step(&mut w, 1.0);
        assert!(loss > 0.0);
        assert!(w.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn streaming_node_steps_as_blocks_land() {
        use crate::data::stream::{BlockBuffer, RowBlock};
        let data = shard(5);
        let blocks = RowBlock::carve(0, &data, 16);
        let buf = BlockBuffer::new(1, u64::MAX);
        let mut logic = NodeLogic::streaming(
            0,
            Objective::LogReg,
            0.5,
            buf.receiver(0),
            data.dim(),
            data.classes(),
            4,
            Xoshiro256pp::seeded(2),
        );
        assert!(!logic.has_data(), "no block has landed yet");
        // The first block lands → the node can step immediately, long
        // before the stream completes.
        buf.push(blocks[0].clone()).unwrap();
        assert!(logic.has_data());
        let mut w = vec![0.0f32; logic.param_len()];
        let loss = logic.native_grad_step(&mut w, 1.0);
        assert!(loss > 0.0);
        assert!(w.iter().any(|&v| v != 0.0));
        // The rest of the stream drains into the same shard.
        for b in &blocks[1..] {
            buf.push(b.clone()).unwrap();
        }
        buf.mark_complete(0);
        assert!(logic.has_data());
        assert_eq!(logic.data().len(), data.len());
        assert_eq!(logic.data().labels(), data.labels());
        assert_eq!(logic.data().features_flat(), data.features_flat());
    }

    #[test]
    fn tracker_matches_full_scan() {
        let params: Vec<Vec<f32>> = vec![
            vec![1.0, 2.0],
            vec![-1.0, 0.5],
            vec![3.0, -2.0],
        ];
        let mut t = ConsensusTracker::new(3, 2);
        for p in &params {
            t.add(p);
        }
        // Mean matches.
        let mean = crate::coordinator::consensus::mean_param(&params);
        for (a, b) in t.mean().iter().zip(&mean) {
            assert!((a - b).abs() < 1e-6);
        }
        // Residual = sqrt(Σ‖β_i − β̄‖²), computed by hand.
        let expect: f64 = params
            .iter()
            .map(|p| {
                p.iter()
                    .zip(&mean)
                    .map(|(&v, &m)| (v as f64 - m as f64).powi(2))
                    .sum::<f64>()
            })
            .sum::<f64>()
            .sqrt();
        assert!((t.residual() - expect).abs() < 1e-9);
        // sub is the exact inverse of add.
        let mut t2 = t.clone();
        t2.sub(&params[1]);
        t2.add(&params[1]);
        assert!((t2.residual() - t.residual()).abs() < 1e-12);
    }

    #[test]
    fn tracker_zero_at_consensus() {
        let mut t = ConsensusTracker::new(4, 3);
        for _ in 0..4 {
            t.add(&[2.0, -1.0, 0.5]);
        }
        assert!(t.residual() < 1e-9);
    }

    #[test]
    fn mixed_probe_is_weighted_family_average() {
        let gen = SyntheticGen::new(2, 6, 4, 2.0, 0.5, 0.3, 3);
        let mut rng = Xoshiro256pp::seeded(4);
        let test = gen.global_test_set(80, &mut rng);
        let w = vec![0.05f32; 6];
        let hinge = Probe::new(Objective::hinge(), &test);
        let lasso = Probe::new(Objective::lasso(), &test);
        let (hl, he) = hinge.eval(&w);
        let (ll, le) = lasso.eval(&w);
        // 3 hinge nodes + 1 lasso node → 0.75/0.25 weights.
        let mixed = Probe::mixed(
            &[
                Objective::hinge(),
                Objective::hinge(),
                Objective::lasso(),
                Objective::hinge(),
            ],
            &test,
        );
        let (ml, me) = mixed.eval(&w);
        assert!((ml - (0.75 * hl + 0.25 * ll)).abs() < 1e-5);
        assert!((me - (0.75 * he + 0.25 * le)).abs() < 1e-5);
        // The homogeneous case is unchanged by the generalization.
        let (l1, e1) = Probe::mixed(&[Objective::hinge()], &test).eval(&w);
        assert_eq!((l1, e1), (hl, he));
    }

    #[test]
    fn probe_snapshot_fields() {
        let gen = SyntheticGen::new(2, 10, 4, 2.0, 0.5, 0.3, 1);
        let mut rng = Xoshiro256pp::seeded(1);
        let test = gen.global_test_set(50, &mut rng);
        let probe = Probe::new(Objective::LogReg, &test);
        let params = vec![vec![0.0f32; 40]; 3];
        let c = Counts {
            grad_steps: 5,
            proj_steps: 2,
            messages: 8,
            conflicts: 1,
        };
        let r = probe.snapshot(7, 1.5, &params, &c);
        assert_eq!(r.k, 7);
        assert_eq!(r.grad_steps, 5);
        assert_eq!(r.messages, 8);
        assert!(r.consensus < 1e-9); // all-equal params
        assert!(r.test_err > 0.0 && r.test_err <= 1.0);
        assert_eq!(c.updates(), 7);
    }
}
