//! DCASGD — asynchronous SGD with Taylor-expansion delay compensation
//! (Zheng et al., ICML 2017; the SNIPPETS.md reference implementation).
//!
//! The compensated update approximates the gradient at the *current*
//! parameters from a gradient computed at stale ones via a first-order
//! Taylor term with a diagonal Hessian surrogate `g ⊙ g`:
//!
//! ```text
//! mse ← β·mse + (1−β)·g²            (bias-corrected, β = 0.95)
//! λ_t  = λ₀ / √(mse/(1−β^t) + ε)
//! w   ← w − lr·(g + λ_t·g⊙g⊙(w − w_bak))
//! ```
//!
//! **Adaptation to this runtime:** the parameter-server formulation
//! compensates `w_now − w_at_gradient_time`. Our nodes step in place,
//! so the gradient is never stale against the node's *own* writes —
//! the staleness comes from neighbors' Eq. (7) mixes landing between
//! this node's events. `w_bak` is therefore the node's parameters
//! right after its previous local step: the drift `w − w_bak` is
//! exactly what the neighborhood moved under this node's feet, which
//! is the delay DCASGD's correction targets. No aux bytes are
//! published — the compensation state is node-private.

use super::{Strategy, StrategyKind};
use crate::node_logic::{neighborhood_average, NodeLogic};

const BETA: f32 = 0.95;
const LAM0: f32 = 2.0;
const EPS: f32 = 1e-7;

#[derive(Clone, Debug, Default)]
pub struct Dcasgd {
    /// EMA of g² (the diagonal Hessian surrogate), lazily sized.
    mse: Vec<f32>,
    /// This node's parameters right after its previous local step.
    w_bak: Vec<f32>,
    /// Step counter for the EMA bias correction.
    t: u32,
}

impl Dcasgd {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Strategy for Dcasgd {
    fn kind(&self) -> StrategyKind {
        StrategyKind::Dcasgd
    }

    fn local_step(
        &mut self,
        logic: &mut NodeLogic,
        w: &mut Vec<f32>,
        _aux: &mut Vec<u8>,
        lr: f32,
        _staleness: u64,
    ) -> f32 {
        // Recover the scaled subgradient by probing the canonical step:
        // probe = w − lr·g, so g = (w − probe)/lr. One sample-index
        // draw, same as the baseline — the RNG contract holds.
        let mut probe = w.clone();
        let loss = logic.native_grad_step(&mut probe, lr);
        if lr == 0.0 {
            return loss;
        }
        if self.mse.len() != w.len() {
            self.mse = vec![0.0; w.len()];
            self.w_bak = w.clone();
        }
        self.t = self.t.saturating_add(1);
        let bias = 1.0 - BETA.powi(self.t as i32);
        for j in 0..w.len() {
            let g = (w[j] - probe[j]) / lr;
            self.mse[j] = BETA * self.mse[j] + (1.0 - BETA) * g * g;
            let lam = LAM0 / (self.mse[j] / bias + EPS).sqrt();
            let drift = w[j] - self.w_bak[j];
            w[j] -= lr * (g + lam * g * g * drift);
        }
        self.w_bak.clone_from(w);
        loss
    }

    fn mix(&mut self, rows: &[&[f32]], _aux_rows: &[&[u8]]) -> (Vec<f32>, Vec<u8>) {
        // Delay compensation changes the local rule only; consensus
        // still moves by the Eq. (7) average.
        (neighborhood_average(rows), Vec::new())
    }
}
