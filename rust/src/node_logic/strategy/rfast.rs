//! R-FAST — robust asynchronous gradient tracking (arXiv 2307.11617).
//!
//! Gradient tracking replaces the raw local gradient with a tracker
//! `y` that asymptotically follows the *global* average gradient:
//!
//! ```text
//! y ← y + g_new − g_prev         (local update, one fresh sample)
//! w ← w − lr·y
//! mix: (w, y) ← neighborhood averages of (w, y)
//! ```
//!
//! **Adaptation to this runtime:** R-FAST's spanning-tree weight
//! matrices reduce to the uniform closed-neighborhood average our
//! Eq. (7) projection already implements, applied to both the
//! parameters and the tracker. The tracker is the strategy's aux blob
//! — `param_len` little-endian f32s riding the collect/apply wire
//! frames (v8) — so it gossips wherever `w` does, across every
//! transport, with the robustness to drops/partitions coming from the
//! same capture/abort machinery the parameters use. `g_prev` stays
//! node-private.

use super::{aux_f32s, encode_aux_f32s, Strategy, StrategyKind};
use crate::node_logic::{neighborhood_average, NodeLogic};

#[derive(Clone, Debug, Default)]
pub struct Rfast {
    /// The gradient at this node's previous local step.
    g_prev: Vec<f32>,
}

impl Rfast {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Strategy for Rfast {
    fn kind(&self) -> StrategyKind {
        StrategyKind::Rfast
    }

    fn local_step(
        &mut self,
        logic: &mut NodeLogic,
        w: &mut Vec<f32>,
        aux: &mut Vec<u8>,
        lr: f32,
        _staleness: u64,
    ) -> f32 {
        // Fresh scaled subgradient at the current parameters, recovered
        // by probing the canonical step (one sample draw — the RNG
        // contract the comparability tests pin).
        let mut probe = w.clone();
        let loss = logic.native_grad_step(&mut probe, lr);
        if lr == 0.0 {
            return loss;
        }
        let g: Vec<f32> = w
            .iter()
            .zip(&probe)
            .map(|(&wj, &pj)| (wj - pj) / lr)
            .collect();
        // The tracker lives in the aux blob so it travels with w; a
        // missing/foreign blob (first event, or a mix with baseline
        // peers) re-initializes it from the fresh gradient.
        let mut y = aux_f32s(aux, w.len()).unwrap_or_else(|| g.clone());
        if self.g_prev.len() == w.len() {
            for j in 0..w.len() {
                y[j] += g[j] - self.g_prev[j];
            }
        }
        for j in 0..w.len() {
            w[j] -= lr * y[j];
        }
        self.g_prev = g;
        encode_aux_f32s(&y, aux);
        loss
    }

    fn mix(&mut self, rows: &[&[f32]], aux_rows: &[&[u8]]) -> (Vec<f32>, Vec<u8>) {
        let mean_w = neighborhood_average(rows);
        // Average the trackers alongside the parameters. Blobs from
        // baseline-strategy peers (or nodes yet to take a step) are
        // absent; they contribute the zero tracker. All-absent in ⇒
        // empty blob out, so pure-baseline neighborhoods stay
        // byte-identical.
        let len = mean_w.len();
        let decoded: Vec<Option<Vec<f32>>> =
            aux_rows.iter().map(|a| aux_f32s(a, len)).collect();
        if decoded.iter().all(|d| d.is_none()) {
            return (mean_w, Vec::new());
        }
        let mut mean_y = vec![0.0f32; len];
        let scale = 1.0 / aux_rows.len() as f32;
        for d in decoded.iter().flatten() {
            for j in 0..len {
                mean_y[j] += scale * d[j];
            }
        }
        let mut aux = Vec::new();
        encode_aux_f32s(&mean_y, &mut aux);
        (mean_w, aux)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_averages_trackers_and_preserves_absent_as_zero() {
        let rows: Vec<Vec<f32>> = vec![vec![1.0, 3.0], vec![3.0, 1.0]];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut t1 = Vec::new();
        encode_aux_f32s(&[2.0, -4.0], &mut t1);
        let mut s = Rfast::new();
        // One tracker present, one absent (counts as zeros).
        let (w, aux) = s.mix(&refs, &[&t1, &[]]);
        assert_eq!(w, vec![2.0, 2.0]);
        assert_eq!(aux_f32s(&aux, 2).unwrap(), vec![1.0, -2.0]);
        // All absent stays empty — baseline neighborhoods unchanged.
        let (_, aux) = s.mix(&refs, &[&[], &[]]);
        assert!(aux.is_empty());
    }
}
