//! Delay-agnostic stepsizes (arXiv 2303.18034).
//!
//! The paper's insight: asynchronous gradient methods converge with a
//! *fixed* stepsize chosen against the delays actually experienced —
//! no global delay bound, no per-iteration decay. We realize that as:
//!
//! ```text
//! s̄  ← (1−ρ)·s̄ + ρ·staleness        (ρ = 0.1)
//! w  ← w − base_lr/(1 + s̄) · scale·∇f
//! ```
//!
//! **Adaptation to this runtime:** the engine's decaying schedule is
//! ignored entirely — the stepsize is the fixed `base_lr` (the
//! schedule's k=0 value) discounted by a running estimate of this
//! node's observed staleness-in-ticks, the same signal the obs layer
//! histograms. Fast nodes in a slow neighborhood self-throttle; a
//! delay-free run converges at the full fixed step. No aux bytes are
//! published.

use super::{Strategy, StrategyKind};
use crate::node_logic::{neighborhood_average, NodeLogic};

/// EMA weight on the newest staleness observation.
const RHO: f64 = 0.1;

#[derive(Clone, Debug)]
pub struct DelayAgnostic {
    base_lr: f32,
    /// Running mean of observed staleness ticks.
    s_bar: f64,
}

impl DelayAgnostic {
    pub fn new(base_lr: f32) -> Self {
        Self {
            base_lr,
            s_bar: 0.0,
        }
    }

    /// The staleness-discounted fixed stepsize this node runs at.
    pub fn effective_lr(&self) -> f32 {
        (self.base_lr as f64 / (1.0 + self.s_bar)) as f32
    }
}

impl Strategy for DelayAgnostic {
    fn kind(&self) -> StrategyKind {
        StrategyKind::DelayAgnostic
    }

    fn local_step(
        &mut self,
        logic: &mut NodeLogic,
        w: &mut Vec<f32>,
        _aux: &mut Vec<u8>,
        _schedule_lr: f32,
        staleness: u64,
    ) -> f32 {
        self.s_bar = (1.0 - RHO) * self.s_bar + RHO * staleness as f64;
        logic.native_grad_step(w, self.effective_lr())
    }

    fn mix(&mut self, rows: &[&[f32]], _aux_rows: &[&[u8]]) -> (Vec<f32>, Vec<u8>) {
        (neighborhood_average(rows), Vec::new())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stale_nodes_self_throttle() {
        let mut a = DelayAgnostic::new(0.5);
        let mut b = DelayAgnostic::new(0.5);
        assert_eq!(a.effective_lr(), 0.5);
        for _ in 0..100 {
            a.s_bar = (1.0 - RHO) * a.s_bar + RHO * 0.0;
            b.s_bar = (1.0 - RHO) * b.s_bar + RHO * 9.0;
        }
        assert!(a.effective_lr() > 0.49, "delay-free keeps the full step");
        assert!(
            b.effective_lr() < 0.06,
            "staleness 9 discounts toward base/(1+9)"
        );
    }
}
