//! The paper baseline: Alg. 2's Eq. (6)/(7) update rule, verbatim.

use super::{Strategy, StrategyKind};
use crate::node_logic::{neighborhood_average, NodeLogic};

/// Eq. (6) local gradient steps and Eq. (7) closed-neighborhood
/// averaging — exactly the math the engines ran before the strategy
/// trait existed. Stateless, publishes no aux bytes, and consumes the
/// node RNG in the identical call order, so deterministic runs are
/// bit-for-bit the pre-refactor trace (pinned by
/// `tests/it_strategy.rs`).
#[derive(Clone, Copy, Debug, Default)]
pub struct Dasgd;

impl Strategy for Dasgd {
    fn kind(&self) -> StrategyKind {
        StrategyKind::Dasgd
    }

    fn local_step(
        &mut self,
        logic: &mut NodeLogic,
        w: &mut Vec<f32>,
        _aux: &mut Vec<u8>,
        lr: f32,
        _staleness: u64,
    ) -> f32 {
        logic.native_grad_step(w, lr)
    }

    fn mix(&mut self, rows: &[&[f32]], _aux_rows: &[&[u8]]) -> (Vec<f32>, Vec<u8>) {
        (neighborhood_average(rows), Vec::new())
    }

    fn pjrt_compatible(&self) -> bool {
        // The compiled step/gossip artifacts *are* this strategy's
        // math — the engines may collapse events into them freely.
        true
    }
}
