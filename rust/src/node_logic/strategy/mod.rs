//! Pluggable per-node update policies — the algorithm zoo.
//!
//! Alg. 2's update rule used to be welded into [`NodeLogic`] and its
//! engines: every firing event was "draw grad-vs-project, take the
//! Eq. (6) step or the Eq. (7) neighborhood average". That answers
//! "how fast does *this* algorithm converge" but never "is it the
//! right algorithm for this topology/delay regime". This module
//! factors the policy out into a [`Strategy`] trait so the same
//! engines, transports, heterogeneity plans, and fault schedules run
//! head-to-head comparisons (`dasgd compare`) between:
//!
//! * [`dasgd`] — the paper baseline, bit-for-bit identical to the
//!   pre-trait engines in deterministic mode;
//! * [`dcasgd`] — Taylor-expansion delay compensation
//!   (Zheng et al., "Asynchronous SGD with delay compensation");
//! * [`delay_agnostic`] — staleness-keyed fixed stepsizes
//!   (arXiv 2303.18034);
//! * [`rfast`] — gradient tracking with the tracker gossiped as an
//!   auxiliary blob (R-FAST, arXiv 2307.11617).
//!
//! A strategy owns four decisions:
//!
//! 1. the **action draw** (grad vs. mix) — one RNG draw on the node's
//!    private stream, in the same call order for every strategy so
//!    deterministic schedules stay comparable across strategies;
//! 2. the **local step rule** — what happens to the node's own
//!    variable on a gradient event, fed the engine's stepsize and the
//!    staleness-in-ticks signal the obs layer already computes;
//! 3. the **mix rule** over neighborhood captures — what replaces the
//!    closed neighborhood's variables on a projection event;
//! 4. an opaque per-node **aux blob** that rides the collect/apply
//!    wire messages (wire v8) next to the parameter vector. The
//!    baseline publishes an empty blob, so its byte stream carries no
//!    extra payload.
//!
//! The trait-default [`Strategy::step_sample`] wraps the raw
//! [`sgd_step`](super::sgd_step) math, and the dasgd mix rule is the
//! only caller of [`neighborhood_average`](super::neighborhood_average)
//! — engines and baselines reach both exclusively through a strategy,
//! so no update math leaks outside this module.
//!
//! # Adding a strategy
//!
//! See docs/algorithms.md for the full contract; in short: add a
//! [`StrategyKind`] variant (name + wire code), implement [`Strategy`]
//! in a sibling file, and the CLI, wire plumbing, per-node plans, and
//! `dasgd compare` pick it up through [`StrategyKind::build`].

use crate::data::Dataset;
use crate::node_logic::{Action, NodeLogic};
use crate::objective::Objective;
use crate::util::rng::Xoshiro256pp;

mod dasgd;
mod dcasgd;
mod delay_agnostic;
mod rfast;

pub use dasgd::Dasgd;
pub use dcasgd::Dcasgd;
pub use delay_agnostic::DelayAgnostic;
pub use rfast::Rfast;

/// The per-node update policy: everything a node's firing event does
/// to its own variable (and its neighborhood's) beyond deciding *when*
/// to fire. One instance per node — strategies carry mutable per-node
/// state (moment estimates, trackers) across events.
///
/// Implementations must preserve the engines' RNG call-order contract:
/// [`Strategy::draw_action`] consumes exactly one draw and
/// [`Strategy::local_step`] exactly one sample-index draw on the
/// node's stream, so seeded runs stay reproducible and different
/// strategies see the same event schedule.
pub trait Strategy: Send {
    /// Which zoo member this is (name, wire code).
    fn kind(&self) -> StrategyKind;

    /// Alg. 2 line 3: gradient step w.p. `p_grad`, else mix. One RNG
    /// draw; the default is the draw every current strategy uses.
    fn draw_action(&mut self, logic: &mut NodeLogic) -> Action {
        logic.draw_action()
    }

    /// The local step rule: advance the node's own variable `w` (and
    /// its published aux blob) by one gradient event. `lr` is the
    /// engine's schedule at the shared iteration counter; `staleness`
    /// is the applied-update ticks since this node's last applied
    /// update (the signal the obs histograms record). Returns the
    /// sample loss.
    fn local_step(
        &mut self,
        logic: &mut NodeLogic,
        w: &mut Vec<f32>,
        aux: &mut Vec<u8>,
        lr: f32,
        staleness: u64,
    ) -> f32;

    /// Raw Eq. (6) entry point for callers that manage their own
    /// per-node RNGs and have no [`NodeLogic`] (the synchronous
    /// baselines). The default is the canonical sample-then-step
    /// math; delay-aware strategies have nothing to compensate in a
    /// synchronous round, so they inherit it.
    #[allow(clippy::too_many_arguments)]
    fn step_sample(
        &mut self,
        objective: Objective,
        w: &mut Vec<f32>,
        data: &Dataset,
        rng: &mut Xoshiro256pp,
        dim: usize,
        classes: usize,
        lr: f32,
        scale: f32,
    ) -> f32 {
        super::sgd_step(objective, w, data, rng, dim, classes, lr, scale)
    }

    /// The mix rule: fold the closed neighborhood's captured parameter
    /// rows (and their aux blobs, same order) into the `(w, aux)` that
    /// replaces every participant. Must preserve the consensus fixed
    /// point: uniform rows in ⇒ that same row out (pinned by
    /// `prop_strategy.rs`).
    fn mix(&mut self, rows: &[&[f32]], aux_rows: &[&[u8]]) -> (Vec<f32>, Vec<u8>);

    /// Whether the compiled PJRT step/gossip artifacts compute this
    /// strategy's math. Only the paper baseline qualifies; everything
    /// else runs the native path even when an accelerator is attached.
    fn pjrt_compatible(&self) -> bool {
        false
    }
}

/// The strategy registry: CLI names, wire codes, and construction.
/// `Copy` + a stable `u8` code so per-node strategies ride
/// `PlanAssign`/`JoinGrant` frames exactly like objectives do.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StrategyKind {
    /// The paper's Alg. 2 baseline (Eq. (6)/(7)).
    #[default]
    Dasgd,
    /// Taylor delay compensation (SNIPPETS: DCASGD).
    Dcasgd,
    /// Staleness-keyed fixed stepsize (arXiv 2303.18034).
    DelayAgnostic,
    /// Gradient tracking with a gossiped tracker (arXiv 2307.11617).
    Rfast,
}

impl StrategyKind {
    /// Every CLI-accepted name, for `--strategy` did-you-mean hints.
    pub const NAMES: [&'static str; 4] = ["dasgd", "dcasgd", "delay-agnostic", "rfast"];

    /// All kinds, in wire-code order (the `compare` default lineup).
    pub const ALL: [StrategyKind; 4] = [
        StrategyKind::Dasgd,
        StrategyKind::Dcasgd,
        StrategyKind::DelayAgnostic,
        StrategyKind::Rfast,
    ];

    /// Parse a CLI name (`dasgd`, `dcasgd`, `delay-agnostic`, `rfast`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "dasgd" => Some(StrategyKind::Dasgd),
            "dcasgd" => Some(StrategyKind::Dcasgd),
            "delay-agnostic" | "delay_agnostic" => Some(StrategyKind::DelayAgnostic),
            "rfast" | "r-fast" => Some(StrategyKind::Rfast),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Dasgd => "dasgd",
            StrategyKind::Dcasgd => "dcasgd",
            StrategyKind::DelayAgnostic => "delay-agnostic",
            StrategyKind::Rfast => "rfast",
        }
    }

    /// Stable wire code (PlanAssign/JoinGrant, v8).
    pub fn code(&self) -> u8 {
        match self {
            StrategyKind::Dasgd => 0,
            StrategyKind::Dcasgd => 1,
            StrategyKind::DelayAgnostic => 2,
            StrategyKind::Rfast => 3,
        }
    }

    /// Inverse of [`StrategyKind::code`]; `None` for codes from a
    /// newer peer's zoo.
    pub fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(StrategyKind::Dasgd),
            1 => Some(StrategyKind::Dcasgd),
            2 => Some(StrategyKind::DelayAgnostic),
            3 => Some(StrategyKind::Rfast),
            _ => None,
        }
    }

    /// Construct one node's strategy instance. `base_lr` seeds the
    /// strategies that replace the engine schedule with their own
    /// (delay-agnostic); the others ignore it.
    pub fn build(&self, base_lr: f32) -> Box<dyn Strategy> {
        match self {
            StrategyKind::Dasgd => Box::new(Dasgd),
            StrategyKind::Dcasgd => Box::new(Dcasgd::new()),
            StrategyKind::DelayAgnostic => Box::new(DelayAgnostic::new(base_lr)),
            StrategyKind::Rfast => Box::new(Rfast::new()),
        }
    }
}

impl std::fmt::Display for StrategyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Decode an aux blob as little-endian f32s of the expected length;
/// anything else (empty baseline blobs, a foreign strategy's layout,
/// truncation) reads as "absent". Shared by the strategies that gossip
/// a vector in the blob.
pub(crate) fn aux_f32s(aux: &[u8], len: usize) -> Option<Vec<f32>> {
    if aux.len() != len * 4 {
        return None;
    }
    Some(
        aux.chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect(),
    )
}

/// Encode a vector into the aux blob layout [`aux_f32s`] reads.
pub(crate) fn encode_aux_f32s(v: &[f32], aux: &mut Vec<u8>) {
    aux.clear();
    aux.reserve(v.len() * 4);
    for x in v {
        aux.extend_from_slice(&x.to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGen;

    fn shard(seed: u64) -> Dataset {
        let gen = SyntheticGen::new(4, 10, 4, 2.0, 0.5, 0.3, seed);
        let mut rng = Xoshiro256pp::seeded(seed);
        gen.node_dataset(0, 40, &mut rng)
    }

    fn logic(seed: u64) -> NodeLogic {
        NodeLogic::new(
            0,
            Objective::LogReg,
            0.5,
            shard(seed),
            8,
            Xoshiro256pp::seeded(seed),
        )
    }

    #[test]
    fn registry_round_trips_names_and_codes() {
        for kind in StrategyKind::ALL {
            assert_eq!(StrategyKind::parse(kind.name()), Some(kind));
            assert_eq!(StrategyKind::from_code(kind.code()), Some(kind));
            assert_eq!(kind.build(0.1).kind(), kind);
        }
        assert_eq!(StrategyKind::parse("adamw"), None);
        assert_eq!(StrategyKind::from_code(200), None);
        assert_eq!(StrategyKind::default(), StrategyKind::Dasgd);
        // Aliases.
        assert_eq!(StrategyKind::parse("r-fast"), Some(StrategyKind::Rfast));
        assert_eq!(
            StrategyKind::parse("delay_agnostic"),
            Some(StrategyKind::DelayAgnostic)
        );
    }

    #[test]
    fn dasgd_local_step_is_the_native_grad_step_bit_for_bit() {
        // The equivalence contract underneath the engine-level pin in
        // tests/it_strategy.rs: same RNG stream, same parameter bits,
        // and no aux bytes published.
        let mut a = logic(7);
        let mut b = logic(7);
        let mut strat = StrategyKind::Dasgd.build(0.0);
        let mut w1 = vec![0.0f32; a.param_len()];
        let mut w2 = w1.clone();
        let mut aux = Vec::new();
        for _ in 0..50 {
            let l1 = strat.local_step(&mut a, &mut w1, &mut aux, 0.3, 2);
            let l2 = b.native_grad_step(&mut w2, 0.3);
            assert_eq!(l1.to_bits(), l2.to_bits());
        }
        let bits = |w: &[f32]| w.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&w1), bits(&w2));
        assert!(aux.is_empty(), "the baseline publishes no aux bytes");
        assert_eq!(a.rng.next_u64(), b.rng.next_u64());
    }

    #[test]
    fn every_strategy_consumes_one_draw_per_local_step() {
        // The comparability contract: identical RNG consumption means
        // every strategy sees the same action/sample schedule.
        for kind in StrategyKind::ALL {
            let mut l = logic(13);
            let mut reference = logic(13);
            let mut strat = kind.build(0.2);
            let mut w = vec![0.0f32; l.param_len()];
            let mut wr = w.clone();
            let mut aux = Vec::new();
            for s in 0..20 {
                assert_eq!(strat.draw_action(&mut l), reference.draw_action());
                strat.local_step(&mut l, &mut w, &mut aux, 0.2, s);
                reference.native_grad_step(&mut wr, 0.2);
            }
            assert_eq!(
                l.rng.next_u64(),
                reference.rng.next_u64(),
                "{kind} bent the RNG stream"
            );
        }
    }

    #[test]
    fn every_strategy_moves_weights_and_stays_finite() {
        for kind in StrategyKind::ALL {
            let mut l = logic(21);
            let mut strat = kind.build(0.2);
            let mut w = vec![0.0f32; l.param_len()];
            let mut aux = Vec::new();
            for s in 0..200 {
                strat.local_step(&mut l, &mut w, &mut aux, 0.2, s % 7);
            }
            assert!(w.iter().any(|&v| v != 0.0), "{kind} never moved");
            assert!(w.iter().all(|v| v.is_finite()), "{kind} diverged");
        }
    }

    #[test]
    fn mix_averages_params_for_every_strategy() {
        let rows: Vec<Vec<f32>> = vec![vec![1.0, -2.0], vec![3.0, 0.0], vec![-1.0, 5.0]];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let aux_rows: Vec<&[u8]> = vec![&[], &[], &[]];
        let want = crate::node_logic::neighborhood_average(&refs);
        for kind in StrategyKind::ALL {
            let mut strat = kind.build(0.1);
            let (got, _) = strat.mix(&refs, &aux_rows);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-6, "{kind} mix is not the average");
            }
        }
    }

    #[test]
    fn aux_codec_round_trips_and_rejects_wrong_lengths() {
        let v = vec![1.5f32, -0.25, f32::MIN_POSITIVE];
        let mut aux = Vec::new();
        encode_aux_f32s(&v, &mut aux);
        assert_eq!(aux.len(), 12);
        assert_eq!(aux_f32s(&aux, 3).as_deref(), Some(v.as_slice()));
        assert_eq!(aux_f32s(&aux, 2), None);
        assert_eq!(aux_f32s(&[], 3), None);
        assert_eq!(aux_f32s(&aux[..11], 3), None);
        // Empty-for-empty is the baseline's fixed point.
        assert_eq!(aux_f32s(&[], 0).as_deref(), Some(&[][..]));
    }

    #[test]
    fn rfast_tracker_rides_the_aux_blob() {
        let mut l = logic(31);
        let mut strat = StrategyKind::Rfast.build(0.2);
        let mut w = vec![0.0f32; l.param_len()];
        let mut aux = Vec::new();
        strat.local_step(&mut l, &mut w, &mut aux, 0.2, 0);
        assert_eq!(aux.len(), w.len() * 4, "tracker published as f32 bytes");
        let y = aux_f32s(&aux, w.len()).unwrap();
        assert!(y.iter().any(|&v| v != 0.0), "tracker initialized from g");
    }

    #[test]
    fn only_the_baseline_claims_pjrt_artifacts() {
        for kind in StrategyKind::ALL {
            let compat = kind.build(0.1).pjrt_compatible();
            assert_eq!(compat, kind == StrategyKind::Dasgd, "{kind}");
        }
    }
}
