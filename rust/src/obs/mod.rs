//! Process-wide observability: lock-free metrics, a bounded structured
//! tracer, and leveled logging.
//!
//! Three cooperating pieces, all allocation-free on the hot path:
//!
//! - A static [`MetricsRegistry`] of atomic counters, gauges, and
//!   log2-bucketed [`Histogram`]s. Recording is a handful of relaxed
//!   `fetch_add`s; snapshots are mergeable across processes so the
//!   `dasgd launch` monitor can aggregate a cluster-wide view from
//!   per-worker `MetricsReply` frames.
//! - A bounded ring-buffer tracer ([`trace`]) for structured
//!   fire/collect/apply/flush/reconnect events, dumped as JSONL on
//!   exit, on panic, or on demand. A single relaxed atomic load when
//!   disabled.
//! - A leveled, component-tagged [`log!`]/[`log_rl!`] macro pair
//!   replacing ad-hoc `eprintln!` diagnostics (`--log-level`).
//!
//! None of this consumes node RNG or alters scheduling decisions: the
//! deterministic-engine bit-identity tests stay valid with
//! instrumentation compiled in.

use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, Once, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Metric identifiers
// ---------------------------------------------------------------------------

/// Monotonic event counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Node tasks stolen by an idle executor from a peer's run queue.
    Steals = 0,
    /// Backlogged firings collapsed into one compiled `b8` step.
    B8Collapses = 1,
    /// Streaming sends parked because the peer's credit window was empty.
    CreditStalls = 2,
    /// Projection attempts that lost the lock race (§IV-C lock-up).
    Conflicts = 3,
    /// Socket dial-loop reconnect attempts after a dropped peer link.
    Reconnects = 4,
    /// Workers admitted into a running deployment (`--join`).
    Joins = 5,
    /// Workers removed from a running deployment (heartbeat strikes or
    /// a graceful `LeaveNotice`).
    Evictions = 6,
    /// Topology repair patches computed and shipped after a membership
    /// change.
    Repairs = 7,
}

/// High-water marks (merged by `max`, not sum).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gauge {
    /// Peak bytes staged in the streaming data plane's block buffer.
    StagingHighWater = 0,
    /// Peak bytes staged in the wire chunk reassembler.
    ChunkHighWater = 1,
}

/// Log2-bucketed histograms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hist {
    /// Microseconds from a node's firing-clock tick to its update applying.
    FireToApplyUs = 0,
    /// Microseconds a projection round spent waiting on peer replies.
    MessageDelayUs = 1,
    /// Gradient staleness: applied-update ticks since this node last fired.
    StalenessTicks = 2,
    /// Microseconds an executor timer-heap entry popped past its deadline.
    TimerLagUs = 3,
    /// Bytes per coalesced socket flush.
    FlushBytes = 4,
}

pub const N_COUNTERS: usize = 8;
pub const N_GAUGES: usize = 2;
pub const N_HISTS: usize = 5;
/// u64 words per histogram on the wire: count, sum, then 64 buckets.
pub const HIST_BUCKETS: usize = 64;
pub const HIST_WIRE_LEN: usize = 2 + HIST_BUCKETS;

pub const COUNTER_NAMES: [&str; N_COUNTERS] = [
    "steals",
    "b8_collapses",
    "credit_stalls",
    "conflicts",
    "reconnects",
    "joins",
    "evictions",
    "repairs",
];
pub const GAUGE_NAMES: [&str; N_GAUGES] = ["staging_high_water_bytes", "chunk_high_water_bytes"];
pub const HIST_NAMES: [&str; N_HISTS] =
    ["fire_to_apply_us", "message_delay_us", "staleness_ticks", "timer_lag_us", "flush_bytes"];

// ---------------------------------------------------------------------------
// Histogram
// ---------------------------------------------------------------------------

/// Bucket index for a value: bucket 0 holds exactly 0, bucket `i >= 1`
/// covers `[2^(i-1), 2^i - 1]`. 64 buckets span the full u64 range.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

/// Upper edge of a bucket, used as the quantile estimate and the
/// Prometheus `le` label: `2^i - 1` for bucket `i >= 1`, 0 for bucket 0.
#[inline]
pub fn bucket_upper(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        (((1u128 << i) - 1) as u64).min(u64::MAX)
    }
}

/// A lock-free log2 histogram: recording is three relaxed `fetch_add`s.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

// Interior-mutable const is exactly what we want here: it is only used
// to initialise distinct array elements, never shared.
#[allow(clippy::declare_interior_mutable_const)]
const ATOMIC_ZERO: AtomicU64 = AtomicU64::new(0);

impl Histogram {
    pub const fn new() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: [ATOMIC_ZERO; HIST_BUCKETS],
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for (dst, src) in buckets.iter_mut().zip(self.buckets.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            buckets,
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// An owned, mergeable histogram snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub buckets: [u64; HIST_BUCKETS],
}

impl HistSnapshot {
    pub const ZERO: HistSnapshot = HistSnapshot { count: 0, sum: 0, buckets: [0; HIST_BUCKETS] };

    /// Pointwise sum; saturating so corrupt peers cannot panic the monitor.
    pub fn merge(&mut self, other: &HistSnapshot) {
        self.count = self.count.saturating_add(other.count);
        self.sum = self.sum.saturating_add(other.sum);
        for (dst, src) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *dst = dst.saturating_add(*src);
        }
    }

    /// Quantile estimate: upper edge of the bucket holding the q-th
    /// sample (`q` in [0, 1]). Returns 0.0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen = seen.saturating_add(c);
            if seen >= target {
                return bucket_upper(i) as f64;
            }
        }
        bucket_upper(HIST_BUCKETS - 1) as f64
    }
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot::ZERO
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// The process-wide registry. All recording goes through the module
/// functions ([`add`], [`observe`], [`gauge_max`]); snapshots through
/// [`snapshot`].
pub struct MetricsRegistry {
    counters: [AtomicU64; N_COUNTERS],
    gauges: [AtomicU64; N_GAUGES],
    hists: [Histogram; N_HISTS],
}

// Same element-initialisation idiom as the histogram buckets.
#[allow(clippy::declare_interior_mutable_const)]
const HIST_ZERO: Histogram = Histogram::new();

static METRICS: MetricsRegistry = MetricsRegistry {
    counters: [ATOMIC_ZERO; N_COUNTERS],
    gauges: [ATOMIC_ZERO; N_GAUGES],
    hists: [HIST_ZERO; N_HISTS],
};

/// Increment a counter.
#[inline]
pub fn add(c: Counter, n: u64) {
    METRICS.counters[c as usize].fetch_add(n, Ordering::Relaxed);
}

/// Record a histogram sample.
#[inline]
pub fn observe(h: Hist, v: u64) {
    METRICS.hists[h as usize].record(v);
}

/// Raise a high-water gauge to at least `v`.
#[inline]
pub fn gauge_max(g: Gauge, v: u64) {
    METRICS.gauges[g as usize].fetch_max(v, Ordering::Relaxed);
}

/// Snapshot the whole registry.
pub fn snapshot() -> MetricsSnapshot {
    let mut s = MetricsSnapshot::ZERO;
    for (dst, src) in s.counters.iter_mut().zip(METRICS.counters.iter()) {
        *dst = src.load(Ordering::Relaxed);
    }
    for (dst, src) in s.gauges.iter_mut().zip(METRICS.gauges.iter()) {
        *dst = src.load(Ordering::Relaxed);
    }
    for (dst, src) in s.hists.iter_mut().zip(METRICS.hists.iter()) {
        *dst = src.snapshot();
    }
    s
}

/// An owned snapshot of every metric, mergeable across processes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub counters: [u64; N_COUNTERS],
    pub gauges: [u64; N_GAUGES],
    pub hists: [HistSnapshot; N_HISTS],
}

impl MetricsSnapshot {
    pub const ZERO: MetricsSnapshot = MetricsSnapshot {
        counters: [0; N_COUNTERS],
        gauges: [0; N_GAUGES],
        hists: [HistSnapshot::ZERO; N_HISTS],
    };

    /// Fold another process's snapshot into this one: counters and
    /// histograms sum, gauges take the max.
    pub fn merge_from(&mut self, other: &MetricsSnapshot) {
        for (dst, src) in self.counters.iter_mut().zip(other.counters.iter()) {
            *dst = dst.saturating_add(*src);
        }
        for (dst, src) in self.gauges.iter_mut().zip(other.gauges.iter()) {
            *dst = (*dst).max(*src);
        }
        for (dst, src) in self.hists.iter_mut().zip(other.hists.iter()) {
            dst.merge(src);
        }
    }

    /// Flatten for the `MetricsReply` wire frame: counters-then-gauges
    /// in one vec, histograms as `N_HISTS x HIST_WIRE_LEN` u64 words.
    pub fn to_wire(&self) -> (Vec<u64>, Vec<u64>) {
        let mut counters = Vec::with_capacity(N_COUNTERS + N_GAUGES);
        counters.extend_from_slice(&self.counters);
        counters.extend_from_slice(&self.gauges);
        let mut hist_data = Vec::with_capacity(N_HISTS * HIST_WIRE_LEN);
        for h in &self.hists {
            hist_data.push(h.count);
            hist_data.push(h.sum);
            hist_data.extend_from_slice(&h.buckets);
        }
        (counters, hist_data)
    }

    /// Inverse of [`to_wire`](Self::to_wire), tolerant of peers built
    /// with fewer (missing => 0) or more (extra ignored) metrics.
    pub fn from_wire(counters: &[u64], hist_data: &[u64]) -> Self {
        let mut s = MetricsSnapshot::ZERO;
        for (dst, src) in s.counters.iter_mut().zip(counters.iter()) {
            *dst = *src;
        }
        for (dst, src) in s.gauges.iter_mut().zip(counters.iter().skip(N_COUNTERS)) {
            *dst = *src;
        }
        let n = (hist_data.len() / HIST_WIRE_LEN).min(N_HISTS);
        for (i, h) in s.hists.iter_mut().enumerate().take(n) {
            let base = i * HIST_WIRE_LEN;
            h.count = hist_data[base];
            h.sum = hist_data[base + 1];
            for (dst, src) in h.buckets.iter_mut().zip(&hist_data[base + 2..base + HIST_WIRE_LEN]) {
                *dst = *src;
            }
        }
        s
    }

    /// Prometheus text exposition (format 0.0.4): counters as
    /// `dasgd_<name>_total`, gauges as `dasgd_<name>`, histograms as
    /// cumulative `dasgd_<name>_bucket{le="..."}` series.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::with_capacity(4096);
        for (name, v) in COUNTER_NAMES.iter().zip(self.counters.iter()) {
            out.push_str(&format!("# TYPE dasgd_{name}_total counter\n"));
            out.push_str(&format!("dasgd_{name}_total {v}\n"));
        }
        for (name, v) in GAUGE_NAMES.iter().zip(self.gauges.iter()) {
            out.push_str(&format!("# TYPE dasgd_{name} gauge\n"));
            out.push_str(&format!("dasgd_{name} {v}\n"));
        }
        for (name, h) in HIST_NAMES.iter().zip(self.hists.iter()) {
            out.push_str(&format!("# TYPE dasgd_{name} histogram\n"));
            let top = h
                .buckets
                .iter()
                .rposition(|&c| c > 0)
                .unwrap_or(0)
                .max(1);
            let mut cum = 0u64;
            for i in 0..=top {
                cum = cum.saturating_add(h.buckets[i]);
                out.push_str(&format!(
                    "dasgd_{name}_bucket{{le=\"{}\"}} {cum}\n",
                    bucket_upper(i)
                ));
            }
            out.push_str(&format!("dasgd_{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            out.push_str(&format!("dasgd_{name}_sum {}\n", h.sum));
            out.push_str(&format!("dasgd_{name}_count {}\n", h.count));
        }
        out
    }

    /// One metrics JSONL line (hand-built; the repo has no JSON dep).
    /// Buckets are emitted sparse as `[index, count]` pairs.
    pub fn jsonl(&self, scope: &str, t_secs: f64, k: u64) -> String {
        let mut out = String::with_capacity(1024);
        out.push_str(&format!(
            "{{\"kind\":\"metrics\",\"scope\":\"{scope}\",\"t_secs\":{t_secs:.3},\"k\":{k}"
        ));
        out.push_str(",\"counters\":{");
        for (i, (name, v)) in COUNTER_NAMES.iter().zip(self.counters.iter()).enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{name}\":{v}"));
        }
        out.push_str("},\"gauges\":{");
        for (i, (name, v)) in GAUGE_NAMES.iter().zip(self.gauges.iter()).enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{name}\":{v}"));
        }
        out.push_str("},\"hists\":{");
        for (i, (name, h)) in HIST_NAMES.iter().zip(self.hists.iter()).enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\"{name}\":{{\"count\":{},\"sum\":{},\"p50\":{:.1},\"p99\":{:.1},\"buckets\":[",
                h.count,
                h.sum,
                h.quantile(0.50),
                h.quantile(0.99)
            ));
            let mut first = true;
            for (bi, &c) in h.buckets.iter().enumerate() {
                if c > 0 {
                    if !first {
                        out.push(',');
                    }
                    first = false;
                    out.push_str(&format!("[{bi},{c}]"));
                }
            }
            out.push_str("]}");
        }
        out.push_str("}}");
        out
    }
}

impl Default for MetricsSnapshot {
    fn default() -> Self {
        MetricsSnapshot::ZERO
    }
}

// ---------------------------------------------------------------------------
// Structured tracer
// ---------------------------------------------------------------------------

/// One structured trace event. Components and event names are static
/// so pushing an event never allocates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub seq: u64,
    pub t_us: u64,
    pub component: &'static str,
    pub event: &'static str,
    pub node: u64,
    pub detail: u64,
}

/// Fixed-capacity ring: once full, the oldest event is overwritten so
/// the newest `cap` events are always retained.
pub struct TraceRing {
    cap: usize,
    buf: Vec<TraceEvent>,
    next: usize,
    seq: u64,
}

impl TraceRing {
    pub const fn new(cap: usize) -> Self {
        TraceRing { cap, buf: Vec::new(), next: 0, seq: 0 }
    }

    pub fn push(&mut self, mut e: TraceEvent) {
        e.seq = self.seq;
        self.seq += 1;
        if self.buf.len() < self.cap {
            self.buf.push(e);
            self.next = self.buf.len() % self.cap;
        } else {
            self.buf[self.next] = e;
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Events oldest-to-newest.
    pub fn events(&self) -> Vec<TraceEvent> {
        if self.buf.len() < self.cap {
            self.buf.clone()
        } else {
            let mut out = Vec::with_capacity(self.buf.len());
            out.extend_from_slice(&self.buf[self.next..]);
            out.extend_from_slice(&self.buf[..self.next]);
            out
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

const TRACE_CAP: usize = 1 << 16;

static TRACE_ON: AtomicBool = AtomicBool::new(false);
static TRACE_RING: Mutex<TraceRing> = Mutex::new(TraceRing::new(TRACE_CAP));
static TRACE_PATH: Mutex<Option<std::path::PathBuf>> = Mutex::new(None);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static PANIC_HOOK: Once = Once::new();

/// Microseconds since tracing was enabled.
fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Record a structured event. A single relaxed load when disabled.
#[inline]
pub fn trace(component: &'static str, event: &'static str, node: u64, detail: u64) {
    if !TRACE_ON.load(Ordering::Relaxed) {
        return;
    }
    let e = TraceEvent { seq: 0, t_us: now_us(), component, event, node, detail };
    if let Ok(mut ring) = TRACE_RING.lock() {
        ring.push(e);
    }
}

/// Enable tracing and arrange for a JSONL dump to `path` on exit or
/// panic. The panic hook chains to the previous one.
pub fn trace_to(path: &std::path::Path) {
    *TRACE_PATH.lock().unwrap_or_else(|e| e.into_inner()) = Some(path.to_path_buf());
    EPOCH.get_or_init(Instant::now);
    TRACE_ON.store(true, Ordering::Relaxed);
    PANIC_HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            trace_dump();
            prev(info);
        }));
    });
}

/// Whether tracing is currently enabled.
pub fn trace_enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// Dump the ring as JSONL to the path configured by [`trace_to`].
/// Poison-safe: a panic mid-push must not lose the dump.
pub fn trace_dump() {
    let path = match TRACE_PATH.lock().unwrap_or_else(|e| e.into_inner()).clone() {
        Some(p) => p,
        None => return,
    };
    if let Ok(mut f) = std::fs::File::create(&path) {
        let ring = TRACE_RING.lock().unwrap_or_else(|e| e.into_inner());
        let _ = trace_write(&ring, &mut f);
    }
}

/// Write a ring's events as JSONL.
pub fn trace_write(ring: &TraceRing, w: &mut dyn Write) -> std::io::Result<()> {
    for e in ring.events() {
        writeln!(
            w,
            "{{\"kind\":\"trace\",\"seq\":{},\"t_us\":{},\"component\":\"{}\",\"event\":\"{}\",\"node\":{},\"detail\":{}}}",
            e.seq, e.t_us, e.component, e.event, e.node, e.detail
        )?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Leveled logging
// ---------------------------------------------------------------------------

/// Diagnostic verbosity, ordered: a message logs when its level is at
/// or below the configured one.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub const NAMES: [&'static str; 4] = ["error", "warn", "info", "debug"];

    pub fn name(self) -> &'static str {
        Level::NAMES[self as usize]
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

static LOG_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_log_level(l: Level) {
    LOG_LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn log_level() -> Level {
    match LOG_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

#[inline]
pub fn log_enabled(l: Level) -> bool {
    (l as u8) <= LOG_LEVEL.load(Ordering::Relaxed)
}

/// Component-tagged leveled log line to stderr:
/// `obs::log!(Warn, "socket", "peer {} dropped", rank)`.
#[macro_export]
macro_rules! log {
    ($lvl:ident, $comp:expr, $($arg:tt)*) => {{
        let __lvl = $crate::obs::Level::$lvl;
        if $crate::obs::log_enabled(__lvl) {
            eprintln!("dasgd[{}] {}: {}", $comp, __lvl.name(), format_args!($($arg)*));
        }
    }};
}

/// Rate-limited variant for per-message paths: logs the 1st, 2nd, 4th,
/// 8th, ... occurrence at this callsite, tagging the repeat count.
#[macro_export]
macro_rules! log_rl {
    ($lvl:ident, $comp:expr, $($arg:tt)*) => {{
        let __lvl = $crate::obs::Level::$lvl;
        if $crate::obs::log_enabled(__lvl) {
            static __HITS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
            let __n = __HITS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            if __n == 0 || __n.is_power_of_two() {
                eprintln!(
                    "dasgd[{}] {}: {} (seen {}x)",
                    $comp,
                    __lvl.name(),
                    format_args!($($arg)*),
                    __n + 1
                );
            }
        }
    }};
}

// Allow `obs::log!` / `obs::log_rl!` paths in addition to the crate root.
pub use crate::{log, log_rl};

// ---------------------------------------------------------------------------
// Stdlib HTTP metrics endpoint + JSONL appender
// ---------------------------------------------------------------------------

/// Serve `body()` as a Prometheus text page on `addr` from a detached
/// thread. Minimal stdlib HTTP/1.0 responder — enough for a scraper or
/// `curl`, deliberately not a web server. Returns the bound address
/// (useful with port 0).
pub fn serve_metrics<F>(addr: &str, body: F) -> std::io::Result<std::net::SocketAddr>
where
    F: Fn() -> String + Send + 'static,
{
    let listener = std::net::TcpListener::bind(addr)?;
    let bound = listener.local_addr()?;
    std::thread::Builder::new().name("dasgd-metrics".into()).spawn(move || {
        for conn in listener.incoming() {
            let mut stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            // Read and discard the request head; we answer every path.
            let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(500)));
            let mut buf = [0u8; 1024];
            let _ = std::io::Read::read(&mut stream, &mut buf);
            let page = body();
            let head = format!(
                "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
                page.len()
            );
            let _ = stream.write_all(head.as_bytes());
            let _ = stream.write_all(page.as_bytes());
        }
    })?;
    Ok(bound)
}

/// Append one line to a JSONL file, creating it if needed.
pub fn append_jsonl(path: &std::path::Path, line: &str) -> std::io::Result<()> {
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "{line}")
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_edges() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), 63);
        // Every bucket's upper edge lands in its own bucket.
        for i in 1..HIST_BUCKETS - 1 {
            assert_eq!(bucket_index(bucket_upper(i)), i, "upper edge of bucket {i}");
            assert_eq!(bucket_index(bucket_upper(i) + 1), i + 1);
        }
    }

    #[test]
    fn histogram_records_and_snapshots() {
        let h = Histogram::new();
        for v in [0u64, 1, 5, 5, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 1011);
        assert_eq!(s.buckets.iter().sum::<u64>(), 5);
        assert_eq!(s.buckets[bucket_index(5)], 2);
    }

    #[test]
    fn quantiles_walk_buckets() {
        let mut s = HistSnapshot::ZERO;
        // 90 samples in bucket 3 ([4,7]), 10 in bucket 10 ([512,1023]).
        s.buckets[3] = 90;
        s.buckets[10] = 10;
        s.count = 100;
        assert_eq!(s.quantile(0.5), bucket_upper(3) as f64);
        assert_eq!(s.quantile(0.99), bucket_upper(10) as f64);
        assert_eq!(HistSnapshot::ZERO.quantile(0.5), 0.0);
    }

    #[test]
    fn snapshot_wire_roundtrip() {
        let mut s = MetricsSnapshot::ZERO;
        s.counters[Counter::Steals as usize] = 7;
        s.gauges[Gauge::StagingHighWater as usize] = 1 << 20;
        s.hists[Hist::StalenessTicks as usize].count = 3;
        s.hists[Hist::StalenessTicks as usize].sum = 12;
        s.hists[Hist::StalenessTicks as usize].buckets[2] = 3;
        let (counters, hist_data) = s.to_wire();
        assert_eq!(counters.len(), N_COUNTERS + N_GAUGES);
        assert_eq!(hist_data.len(), N_HISTS * HIST_WIRE_LEN);
        assert_eq!(MetricsSnapshot::from_wire(&counters, &hist_data), s);
        // Tolerant decode: short inputs zero-fill, long inputs ignore extra.
        assert_eq!(MetricsSnapshot::from_wire(&[], &[]), MetricsSnapshot::ZERO);
        let mut long = counters.clone();
        long.push(999);
        assert_eq!(MetricsSnapshot::from_wire(&long, &hist_data), s);
    }

    #[test]
    fn merge_sums_counters_and_maxes_gauges() {
        let mut a = MetricsSnapshot::ZERO;
        let mut b = MetricsSnapshot::ZERO;
        a.counters[0] = 2;
        b.counters[0] = 3;
        a.gauges[0] = 10;
        b.gauges[0] = 7;
        a.hists[0].count = 1;
        a.hists[0].buckets[1] = 1;
        b.hists[0].count = 2;
        b.hists[0].buckets[4] = 2;
        a.merge_from(&b);
        assert_eq!(a.counters[0], 5);
        assert_eq!(a.gauges[0], 10);
        assert_eq!(a.hists[0].count, 3);
        assert_eq!(a.hists[0].buckets.iter().sum::<u64>(), 3);
    }

    #[test]
    fn prometheus_text_shape() {
        let mut s = MetricsSnapshot::ZERO;
        s.hists[Hist::StalenessTicks as usize].count = 4;
        s.hists[Hist::StalenessTicks as usize].sum = 20;
        s.hists[Hist::StalenessTicks as usize].buckets[3] = 4;
        let text = s.prometheus_text();
        assert!(text.contains("dasgd_steals_total 0"));
        assert!(text.contains("# TYPE dasgd_staleness_ticks histogram"));
        assert!(text.contains("dasgd_staleness_ticks_bucket{le=\"+Inf\"} 4"));
        assert!(text.contains("dasgd_staleness_ticks_count 4"));
        // Cumulative buckets: the le="7" bucket holds all 4 samples.
        assert!(text.contains("dasgd_staleness_ticks_bucket{le=\"7\"} 4"));
    }

    #[test]
    fn jsonl_line_parses_with_repo_json() {
        let mut s = MetricsSnapshot::ZERO;
        s.counters[Counter::Conflicts as usize] = 9;
        s.hists[Hist::FlushBytes as usize].count = 1;
        s.hists[Hist::FlushBytes as usize].sum = 128;
        s.hists[Hist::FlushBytes as usize].buckets[bucket_index(128)] = 1;
        let line = s.jsonl("worker:0", 1.5, 42);
        let j = crate::util::json::parse(&line).expect("jsonl line must parse");
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("metrics"));
        assert_eq!(j.get("k").and_then(|v| v.as_f64()), Some(42.0));
        let hists = j.get("hists").expect("hists object");
        let fb = hists.get("flush_bytes").expect("flush_bytes hist");
        assert_eq!(fb.get("count").and_then(|v| v.as_f64()), Some(1.0));
    }

    #[test]
    fn trace_ring_wraps_keeping_newest() {
        let mut ring = TraceRing::new(4);
        for i in 0..10u64 {
            ring.push(TraceEvent {
                seq: 0,
                t_us: i,
                component: "t",
                event: "e",
                node: i,
                detail: 0,
            });
        }
        let evs = ring.events();
        assert_eq!(evs.len(), 4);
        assert_eq!(evs.iter().map(|e| e.node).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        // Sequence numbers stay monotonic oldest-to-newest.
        assert!(evs.windows(2).all(|w| w[0].seq + 1 == w[1].seq));
    }

    #[test]
    fn trace_write_emits_parseable_jsonl() {
        let mut ring = TraceRing::new(8);
        ring.push(TraceEvent {
            seq: 0,
            t_us: 5,
            component: "socket",
            event: "flush",
            node: 2,
            detail: 512,
        });
        let mut buf = Vec::new();
        trace_write(&ring, &mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let j = crate::util::json::parse(text.trim()).expect("trace line must parse");
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("trace"));
        assert_eq!(j.get("component").and_then(|v| v.as_str()), Some("socket"));
        assert_eq!(j.get("detail").and_then(|v| v.as_f64()), Some(512.0));
    }

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("verbose"), None);
        assert!(Level::Error < Level::Debug);
    }
}
