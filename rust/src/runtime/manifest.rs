//! `artifacts/manifest.json` loader: describes every AOT artifact's
//! inputs/outputs so call sites are validated at startup, not at
//! execute time.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// One tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn tensor_list(v: &Json, key: &str) -> Result<Vec<TensorSpec>> {
    let arr = v
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest entry missing {key:?}"))?;
    arr.iter()
        .map(|t| {
            let name = t
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tensor missing name"))?
                .to_string();
            let shape = t
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensor {name} missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim in {name}")))
                .collect::<Result<Vec<_>>>()?;
            let dtype = t.get("dtype").and_then(Json::as_str).unwrap_or("f32");
            if dtype != "f32" {
                bail!("tensor {name}: unsupported dtype {dtype}");
            }
            Ok(TensorSpec { name, shape })
        })
        .collect()
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let doc = json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let format = doc.get("format").and_then(Json::as_str).unwrap_or("");
        if format != "hlo-text" {
            bail!("unsupported manifest format {format:?} (want \"hlo-text\")");
        }
        let mut artifacts = BTreeMap::new();
        for entry in doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let name = entry
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = dir.join(
                entry
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact {name} missing file"))?,
            );
            if !file.exists() {
                bail!("artifact file {file:?} missing — run `make artifacts`");
            }
            let spec = ArtifactSpec {
                inputs: tensor_list(entry, "inputs")?,
                outputs: tensor_list(entry, "outputs")?,
                name: name.clone(),
                file,
            };
            artifacts.insert(name, spec);
        }
        if artifacts.is_empty() {
            bail!("manifest lists no artifacts");
        }
        Ok(Self { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    #[test]
    fn parses_valid_manifest() {
        let dir = std::env::temp_dir().join("dasgd_manifest_ok");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.hlo.txt"), "HloModule m").unwrap();
        write_manifest(
            &dir,
            r#"{"format":"hlo-text","artifacts":[
                {"name":"a","file":"a.hlo.txt",
                 "inputs":[{"name":"w","shape":[50,10],"dtype":"f32"}],
                 "outputs":[{"name":"o","shape":[1,1],"dtype":"f32"}]}]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("a").unwrap();
        assert_eq!(a.inputs[0].shape, vec![50, 10]);
        assert_eq!(a.inputs[0].element_count(), 500);
        assert!(m.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_missing_file_and_bad_format() {
        let dir = std::env::temp_dir().join("dasgd_manifest_bad");
        write_manifest(
            &dir,
            r#"{"format":"hlo-text","artifacts":[
                {"name":"a","file":"missing.hlo.txt","inputs":[],"outputs":[]}]}"#,
        );
        assert!(Manifest::load(&dir).is_err());
        write_manifest(&dir, r#"{"format":"protobuf","artifacts":[]}"#);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
