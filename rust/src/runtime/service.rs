//! Executor service: makes the (thread-bound) [`Engine`](super::Engine)
//! usable from the multi-threaded actor runtime.
//!
//! The `xla` crate's PJRT handles are `Rc`-based and cannot cross
//! threads, so the service spawns one or more worker threads, each
//! owning a private `Engine` (its own PJRT client + compiled artifacts),
//! all draining a shared request queue. Node actors submit flat-f32
//! requests and block on a per-request reply channel — the same design
//! a real deployment uses for a device executor.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::Engine;

struct Request {
    artifact: String,
    inputs: Vec<Vec<f32>>,
    reply: Sender<Result<Vec<Vec<f32>>>>,
}

/// Cloneable handle for submitting execute requests.
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: Sender<Request>,
}

// `Sender` is Send but not Sync; handles are cloned per thread.
impl ExecutorHandle {
    /// Execute `artifact` with flat f32 inputs; blocks for the reply.
    pub fn execute_f32(&self, artifact: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request {
                artifact: artifact.to_string(),
                inputs: inputs.iter().map(|b| b.to_vec()).collect(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("executor service is down"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("executor worker dropped the reply"))?
    }
}

/// The executor service: owns the worker threads.
pub struct ExecutorService {
    tx: Option<Sender<Request>>,
    workers: Vec<JoinHandle<()>>,
}

impl ExecutorService {
    /// Spawn `workers` engine-owning threads loading artifacts from `dir`.
    ///
    /// Each worker compiles its own copy of the artifact set (PJRT
    /// handles cannot be shared); compilation happens on the worker
    /// thread before it starts serving. Errors during load surface on
    /// the first request.
    pub fn start(dir: impl Into<PathBuf>, workers: usize) -> Result<Self> {
        assert!(workers >= 1);
        let dir = dir.into();
        let (tx, rx) = channel::<Request>();
        let shared_rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = Arc::clone(&shared_rx);
            let dir = dir.clone();
            handles.push(std::thread::spawn(move || {
                let mut engine = match Engine::load(&dir) {
                    Ok(e) => e,
                    Err(e) => {
                        // Fail every request we manage to grab.
                        loop {
                            let req = { rx.lock().unwrap().recv() };
                            match req {
                                Ok(r) => {
                                    let _ = r
                                        .reply
                                        .send(Err(anyhow!("engine load failed: {e:#}")));
                                }
                                Err(_) => return,
                            }
                        }
                    }
                };
                loop {
                    // Hold the lock only while dequeuing.
                    let req = { rx.lock().unwrap().recv() };
                    match req {
                        Ok(r) => {
                            let ins: Vec<&[f32]> =
                                r.inputs.iter().map(|v| v.as_slice()).collect();
                            let out = engine.execute_f32(&r.artifact, &ins);
                            let _ = r.reply.send(out);
                        }
                        Err(_) => return, // all senders dropped: shut down
                    }
                }
            }));
        }
        Ok(Self {
            tx: Some(tx),
            workers: handles,
        })
    }

    pub fn handle(&self) -> ExecutorHandle {
        ExecutorHandle {
            tx: self.tx.as_ref().expect("service running").clone(),
        }
    }
}

impl Drop for ExecutorService {
    fn drop(&mut self) {
        // Close the queue, then join workers.
        self.tx.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}
