//! Runtime layer: PJRT loading + execution of the AOT artifacts.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`, wrapped as:
//!
//! * [`Manifest`] — validated description of `artifacts/`.
//! * [`Engine`] — single-thread owner of compiled executables.
//! * [`ExecutorService`] / [`ExecutorHandle`] — channel-based executor
//!   threads for use from the multi-threaded actor runtime.

mod engine;
mod manifest;
mod service;

pub use engine::Engine;
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
pub use service::{ExecutorHandle, ExecutorService};
