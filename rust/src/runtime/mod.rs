//! Runtime layer: PJRT loading + execution of the AOT artifacts.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`, wrapped as:
//!
//! * [`Manifest`] — validated description of `artifacts/`.
//! * [`Engine`] — single-thread owner of compiled executables.
//! * [`ExecutorService`] / [`ExecutorHandle`] — channel-based executor
//!   threads for use from the multi-threaded actor runtime.

mod engine;
mod manifest;
mod service;

pub use engine::Engine;
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
pub use service::{ExecutorHandle, ExecutorService};

/// Default artifact directory: `$DASGD_ARTIFACTS` or `artifacts/`
/// relative to the workspace root. Single source of truth for
/// [`Engine::load_default`] and availability probes.
pub fn default_artifact_dir() -> String {
    std::env::var("DASGD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}
