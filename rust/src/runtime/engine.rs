//! PJRT execution engine: loads HLO-text artifacts, compiles them once,
//! and executes them from the coordinator hot path.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so an
//! `Engine` lives on one thread; the threaded actor runtime either uses
//! native math per node or funnels execute requests to an engine-owning
//! service thread via channels (see `runtime::service`).
//!
//! The `xla` crate is an optional dependency (feature `pjrt`): images
//! without it still build, and `Engine::load` fails cleanly so every
//! caller takes its native fallback path.

#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use super::manifest::{ArtifactSpec, Manifest};

/// A loaded, compiled artifact set bound to one PJRT (CPU) client.
#[cfg(feature = "pjrt")]
pub struct Engine {
    manifest: Manifest,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Pre-shaped input literals, reused across calls (§Perf: building a
    /// Literal via `vec1` + `reshape` allocated + copied twice per input;
    /// `copy_raw_from` into a cached literal does one memcpy, no alloc).
    input_cache: BTreeMap<String, Vec<xla::Literal>>,
    /// Cumulative number of `execute` calls (perf accounting).
    pub exec_count: u64,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Load every artifact in `dir` and compile it on a fresh CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        let mut executables = BTreeMap::new();
        for (name, spec) in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                spec.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path {:?}", spec.file))?,
            )
            .map_err(|e| anyhow!("parsing HLO text {:?}: {e:?}", spec.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            executables.insert(name.clone(), exe);
        }
        let mut input_cache = BTreeMap::new();
        for (name, spec) in &manifest.artifacts {
            let lits: Vec<xla::Literal> = spec
                .inputs
                .iter()
                .map(|t| {
                    xla::Literal::create_from_shape(xla::PrimitiveType::F32, &t.shape)
                })
                .collect();
            input_cache.insert(name.clone(), lits);
        }
        Ok(Self {
            manifest,
            executables,
            input_cache,
            exec_count: 0,
        })
    }

    /// Load from [`super::default_artifact_dir`].
    pub fn load_default() -> Result<Self> {
        Self::load(super::default_artifact_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute artifact `name` with flat f32 input buffers (shape-checked
    /// against the manifest); returns flat f32 outputs.
    pub fn execute_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.get(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: got {} inputs, want {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        let cached = self
            .input_cache
            .get_mut(name)
            .ok_or_else(|| anyhow!("artifact {name} has no input cache"))?;
        for ((buf, tspec), lit) in inputs.iter().zip(&spec.inputs).zip(cached.iter_mut()) {
            if buf.len() != tspec.element_count() {
                bail!(
                    "{name}: input {} has {} elements, want {} (shape {:?})",
                    tspec.name,
                    buf.len(),
                    tspec.element_count(),
                    tspec.shape
                );
            }
            lit.copy_raw_from(buf)
                .map_err(|e| anyhow!("{name}: staging input {}: {e:?}", tspec.name))?;
        }
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not compiled"))?;
        let refs: Vec<&xla::Literal> = cached.iter().collect();
        let result = exe
            .execute::<&xla::Literal>(&refs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        self.exec_count += 1;
        let root = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("{name}: empty result"))?
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: fetching result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root
            .to_tuple()
            .map_err(|e| anyhow!("{name}: decomposing tuple: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name}: got {} outputs, want {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, tspec)| {
                let v = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("{name}: output {}: {e:?}", tspec.name))?;
                if v.len() != tspec.element_count() {
                    bail!(
                        "{name}: output {} has {} elements, want {}",
                        tspec.name,
                        v.len(),
                        tspec.element_count()
                    );
                }
                Ok(v)
            })
            .collect()
    }

    /// Convenience: execute and return the single scalar output of a
    /// `(1,1)`-shaped result tensor at position `idx`.
    pub fn execute_scalar_out(
        &mut self,
        name: &str,
        inputs: &[&[f32]],
        idx: usize,
    ) -> Result<f32> {
        let outs = self.execute_f32(name, inputs)?;
        outs.get(idx)
            .and_then(|v| v.first())
            .copied()
            .ok_or_else(|| anyhow!("{name}: no output {idx}"))
    }
}

#[cfg(feature = "pjrt")]
impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("artifacts", &self.executables.keys().collect::<Vec<_>>())
            .field("exec_count", &self.exec_count)
            .finish()
    }
}

// ---------------------------------------------------------------------------
// Stub engine (feature `pjrt` disabled): same API, loading always fails.
// ---------------------------------------------------------------------------

/// Stub engine compiled when the `xla` dependency is unavailable.
///
/// [`Engine::load`] validates the manifest (so configuration errors still
/// surface) and then refuses to run, which routes every caller onto its
/// rust-native fallback path.
#[cfg(not(feature = "pjrt"))]
#[derive(Debug)]
pub struct Engine {
    manifest: Manifest,
    /// Cumulative number of `execute` calls (always 0 on the stub).
    pub exec_count: u64,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Validate the manifest, then report that PJRT execution is
    /// unavailable in this build.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let _ = Manifest::load(dir)?;
        bail!("dasgd was built without the `pjrt` feature — PJRT execution unavailable (rebuild with `--features pjrt`)")
    }

    /// Load from [`super::default_artifact_dir`].
    pub fn load_default() -> Result<Self> {
        Self::load(super::default_artifact_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    pub fn has(&self, _name: &str) -> bool {
        false
    }

    /// Unreachable in practice (`load` never returns a stub instance),
    /// but kept signature-compatible with the real engine.
    pub fn execute_f32(&mut self, name: &str, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        bail!("{name}: PJRT execution unavailable (built without the `pjrt` feature)")
    }

    /// Convenience: execute and return the single scalar output of a
    /// `(1,1)`-shaped result tensor at position `idx`.
    pub fn execute_scalar_out(
        &mut self,
        name: &str,
        inputs: &[&[f32]],
        idx: usize,
    ) -> Result<f32> {
        let outs = self.execute_f32(name, inputs)?;
        outs.get(idx)
            .and_then(|v| v.first())
            .copied()
            .ok_or_else(|| anyhow!("{name}: no output {idx}"))
    }
}
