//! Small statistics helpers shared by metrics, benches, and experiments.

/// Running summary of a sample (Welford's online algorithm).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Ordinary least-squares slope of y against x (convergence-rate fits).
pub fn ols_slope(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut den = 0.0;
    for (xi, yi) in x.iter().zip(y) {
        num += (xi - mx) * (yi - my);
        den += (xi - mx) * (xi - mx);
    }
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_direct_computation() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
        assert!((percentile(&xs, 99.0) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn ols_slope_exact_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v + 1.0).collect();
        assert!((ols_slope(&x, &y) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_single_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        let s = Summary::new();
        assert_eq!(s.variance(), 0.0);
    }
}
