//! Shared substrates: RNG, statistics, JSON, CSV, property testing, timing.
//!
//! Everything here is hand-built: the offline image resolves no external
//! crates beyond `xla`/`anyhow`/`thiserror` (see DESIGN.md §3).

pub mod csv;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;

use std::time::Instant;

/// Wall-clock stopwatch with split support.
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_secs();
        self.start = Instant::now();
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let a = sw.elapsed_secs();
        assert!(a >= 0.004);
        let split = sw.restart();
        assert!(split >= a);
        assert!(sw.elapsed_secs() < split);
    }
}
