//! In-repo property-testing harness (the `proptest` crate is unavailable
//! offline). Provides seeded generators and a `check` runner with
//! linear input shrinking on failure — enough for the coordinator
//! invariants exercised in `rust/tests/`.

use crate::util::rng::Xoshiro256pp;

/// A generation context handed to properties: a seeded RNG plus helpers.
pub struct Gen {
    pub rng: Xoshiro256pp,
    /// Current size budget; generators scale ranges by it so early cases
    /// are small (easier to debug) and later cases grow.
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.index(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn f32_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len)
            .map(|_| lo + self.rng.next_f32() * (hi - lo))
            .collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub struct PropError {
    pub case: usize,
    pub seed: u64,
    pub message: String,
}

impl std::fmt::Display for PropError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed on case {} (seed {}): {}",
            self.case, self.seed, self.message
        )
    }
}

/// Run `cases` random cases of `prop`. The property returns
/// `Err(message)` to signal failure; panics are NOT caught (the test
/// harness reports them with the case seed via the panic message hook).
pub fn check<F>(name: &str, cases: usize, base_seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut g = Gen {
            rng: Xoshiro256pp::seeded(seed),
            size: 4 + case * 4 / cases.max(1),
        };
        if let Err(message) = prop(&mut g) {
            panic!(
                "{}",
                PropError {
                    case,
                    seed,
                    message: format!("[{name}] {message}"),
                }
            );
        }
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_good_property() {
        check("add-commutes", 50, 1, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            if (a + b - (b + a)).abs() < 1e-12 {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failure() {
        check("always-fails", 5, 2, |_| Err("nope".into()));
    }

    #[test]
    fn allclose_tolerances() {
        assert!(assert_allclose(&[1.0], &[1.0 + 1e-7], 1e-5, 0.0).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-5, 0.0).is_err());
        assert!(assert_allclose(&[0.0], &[1e-9], 0.0, 1e-8).is_ok());
        assert!(assert_allclose(&[1.0, 2.0], &[1.0], 0.1, 0.1).is_err());
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen {
            rng: Xoshiro256pp::seeded(9),
            size: 8,
        };
        for _ in 0..1000 {
            let x = g.usize_in(3, 7);
            assert!((3..=7).contains(&x));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        let v = g.f32_vec(16, 0.0, 1.0);
        assert_eq!(v.len(), 16);
        assert!(v.iter().all(|x| (0.0..1.0).contains(x)));
    }
}
