//! Tiny CSV writer for experiment time-series dumps.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(Self {
            out,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, values: &[f64]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "csv row arity mismatch");
        let line = values
            .iter()
            .map(|v| format!("{v}"))
            .collect::<Vec<_>>()
            .join(",");
        writeln!(self.out, "{line}")
    }

    pub fn row_str(&mut self, values: &[String]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "csv row arity mismatch");
        writeln!(self.out, "{}", values.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("dasgd_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["k", "d"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.row(&[2.0, 1.25]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "k,d\n1,2.5\n2,1.25\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let dir = std::env::temp_dir().join("dasgd_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        let _ = w.row(&[1.0]);
    }
}
