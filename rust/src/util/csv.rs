//! Tiny CSV writer for experiment time-series dumps.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(Self {
            out,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, values: &[f64]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "csv row arity mismatch");
        let line = values
            .iter()
            .map(|v| format!("{v}"))
            .collect::<Vec<_>>()
            .join(",");
        writeln!(self.out, "{line}")
    }

    pub fn row_str(&mut self, values: &[String]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "csv row arity mismatch");
        writeln!(self.out, "{}", values.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Append-only CSV schema: a fixed base column order plus extensions
/// that may only be appended at the end, never inserted or reordered —
/// so every writer that shares a base (the run time-series, the
/// strategy-comparison dump, the heterogeneity sweep) agrees on every
/// shared column's position and new columns can't silently shift old
/// ones. Duplicate names panic at construction: a repeated column
/// means two writers disagree about what it holds.
#[derive(Clone, Debug)]
pub struct Schema {
    cols: Vec<&'static str>,
}

impl Schema {
    pub fn new(base: &[&'static str]) -> Self {
        let s = Self {
            cols: base.to_vec(),
        };
        s.assert_unique();
        s
    }

    /// Append one column at the end (the only legal extension).
    #[must_use]
    pub fn with(mut self, col: &'static str) -> Self {
        self.cols.push(col);
        self.assert_unique();
        self
    }

    pub fn columns(&self) -> &[&'static str] {
        &self.cols
    }

    /// Open a [`CsvWriter`] with this schema's header.
    pub fn create<P: AsRef<Path>>(&self, path: P) -> std::io::Result<CsvWriter> {
        CsvWriter::create(path, &self.cols)
    }

    fn assert_unique(&self) {
        for (i, c) in self.cols.iter().enumerate() {
            assert!(
                !self.cols[..i].contains(c),
                "duplicate CSV column {c:?} — schemas are append-only and every name appears once"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("dasgd_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["k", "d"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.row(&[2.0, 1.25]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "k,d\n1,2.5\n2,1.25\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let dir = std::env::temp_dir().join("dasgd_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        let _ = w.row(&[1.0]);
    }

    #[test]
    fn schema_appends_only_at_the_end() {
        let s = Schema::new(&["k", "d"]).with("extra");
        assert_eq!(s.columns(), &["k", "d", "extra"]);
    }

    #[test]
    #[should_panic(expected = "append-only")]
    fn schema_rejects_duplicate_columns() {
        let _ = Schema::new(&["k", "d"]).with("k");
    }
}
