//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic number forms; used to read
//! `artifacts/manifest.json` and to dump experiment results.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or("bad hex in \\u escape")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(b) => {
                    // Re-sync to char boundary for multibyte UTF-8.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|e| e.to_string())?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": "x", "c": null}], "d": true}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(true)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Num(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_unicode_and_escapes() {
        let v = parse(r#""A\t\"ünïcode\"""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\"ünïcode\""));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"o":{"k":-3}}"#;
        let v = parse(doc).unwrap();
        let dumped = v.dump();
        assert_eq!(parse(&dumped).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} trailing").is_err());
    }

    #[test]
    fn reads_real_manifest_shape() {
        let doc = r#"{"format":"hlo-text","artifacts":[
            {"name":"a","file":"a.hlo.txt","inputs":[{"name":"w","shape":[50,10],"dtype":"f32"}],
             "outputs":[{"name":"o","shape":[1,1],"dtype":"f32"}]}]}"#;
        let v = parse(doc).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(50));
    }
}
