//! Deterministic pseudo-random number generation.
//!
//! The offline image has no `rand` crate, so this module implements the
//! generators the system needs from scratch:
//!
//! * [`SplitMix64`] — seeding / stream-splitting generator.
//! * [`Xoshiro256pp`] — the main generator (xoshiro256++ by Blackman &
//!   Vigna), used everywhere: fast, 256-bit state, passes BigCrush.
//! * Derived samplers: uniform floats, bounded integers (Lemire-style
//!   rejection), normal (Box–Muller with caching), geometric (the §IV-A
//!   distributed countdown mechanism), shuffles and choices.
//!
//! Every experiment takes explicit seeds so runs are reproducible.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse RNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
    /// Cached second Box–Muller output.
    gauss_cache: Option<f64>,
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_cache: None,
        }
    }

    /// Derive an independent child stream (for per-node RNGs).
    pub fn split(&mut self, tag: u64) -> Self {
        let a = self.next_u64();
        Self::seeded(a ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased integer in `[0, n)` (Lemire's nearly-divisionless method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.next_below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (second draw cached).
    pub fn next_gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_cache.take() {
            return v;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_cache = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with the given mean / std-dev, as f32.
    #[inline]
    pub fn gauss_f32(&mut self, mean: f32, std: f32) -> f32 {
        (self.next_gauss() as f32) * std + mean
    }

    /// Geometric countdown sample: number of slots until a process with
    /// per-slot firing probability `p` fires (support {1, 2, ...}).
    ///
    /// This is the §IV-A distributed node-selection primitive: every node
    /// counts down an independent Geometric(p) timer; whoever reaches 0
    /// "self-selects" without any controller.
    pub fn geometric(&mut self, p: f64) -> u64 {
        debug_assert!(p > 0.0 && p <= 1.0);
        if p >= 1.0 {
            return 1;
        }
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        (u.ln() / (1.0 - p).ln()).floor() as u64 + 1
    }

    /// Exponential with rate `lambda` (continuous-time selection clocks).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from an (unnormalized) non-negative weight vector.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut target = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Xoshiro256pp::seeded(42);
        let mut b = Xoshiro256pp::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256pp::seeded(1);
        let mut b = Xoshiro256pp::seeded(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Xoshiro256pp::seeded(7);
        let mut c1 = root.split(0);
        let mut c2 = root.split(1);
        let same = (0..100).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn uniform_f64_in_range_and_mean() {
        let mut r = Xoshiro256pp::seeded(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_unbiased() {
        let mut r = Xoshiro256pp::seeded(5);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 7;
            assert!(
                (c as i64 - expect as i64).abs() < (expect as i64) / 10,
                "counts={counts:?}"
            );
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Xoshiro256pp::seeded(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gauss();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn geometric_mean_close_to_1_over_p() {
        let mut r = Xoshiro256pp::seeded(13);
        for &p in &[0.1, 0.33, 0.5, 0.9] {
            let n = 50_000;
            let total: u64 = (0..n).map(|_| r.geometric(p)).sum();
            let mean = total as f64 / n as f64;
            let expect = 1.0 / p;
            assert!(
                (mean - expect).abs() < expect * 0.05,
                "p={p} mean={mean} expect={expect}"
            );
        }
    }

    #[test]
    fn geometric_support_starts_at_one() {
        let mut r = Xoshiro256pp::seeded(17);
        assert!((0..1000).all(|_| r.geometric(0.7) >= 1));
        assert_eq!(r.geometric(1.0), 1);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seeded(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Xoshiro256pp::seeded(23);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Xoshiro256pp::seeded(29);
        let n = 50_000;
        let total: f64 = (0..n).map(|_| r.exponential(2.0)).sum();
        let mean = total / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }
}
