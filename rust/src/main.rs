//! `dasgd` — CLI entrypoint for the Fully Distributed & Asynchronized
//! SGD system. Every paper figure, ablation, and the live asynchronous
//! cluster are runnable from here; `cargo bench` wraps the same
//! experiment modules.

use dasgd::cli::{self, Args};
use dasgd::coordinator::{AsyncCluster, AsyncConfig, EngineKind, Objective, PjrtArtifacts, StepSize};
use dasgd::data::stream::DEFAULT_BLOCK_ROWS;
use dasgd::data::{ascii_art, load_libsvm, render_glyph, GlyphStyle, LibsvmOptions, NotMnistGen};
use dasgd::experiments::{self, compare, fig2, fig3, fig4, fig6, heterogeneity, lemma1, straggler};
use dasgd::metrics::Table;
use dasgd::node_logic::StrategyKind;
use dasgd::net::{
    run_join_worker, run_launch, run_worker, LaunchConfig, WorkerConfig, WorkerPlanSource,
};
use dasgd::runtime::{Engine, ExecutorService};
use dasgd::sim::{simnet_run_plan, SimConfig, SpeedModel};
use dasgd::transport::{LatencyModel, PartitionWindow, SimNetConfig, TransportKind};
use dasgd::util::rng::Xoshiro256pp;
use dasgd::workload::PlanSpec;

const USAGE: &str = "\
dasgd — Fully Distributed and Asynchronized SGD for Networked Systems

USAGE: dasgd <command> [--scale S] [--seed N] [flags]

Figure reproduction (paper §V):
  fig2        consensus distance, 4- vs 15-regular, N=30
  fig3        prediction error, 2- vs 10-regular, N=30
  fig4        final error vs network size (10..30), degree 4 vs 10
  fig6        notMNIST-like corpus, 4- vs 15-regular + centralized SGD
  lemma1      spectral eta bound vs measured DF contraction
  glyphs      render sample glyphs (Fig. 5 stand-in)

Ablations / extensions:
  losses      §II loss families: decentralized SVM + Lasso through the
              same trainer as logreg, on both backends
  comm        §IV-B: p_grad sweep (messages vs consensus)
  conflicts   §IV-C: distributed selection, lock-up vs ignore
  topology    consensus across graph families
  straggler   async vs sync DSGD vs server-worker in virtual time
  heterogeneity  consensus/error vs per-node skew: Dirichlet label-skew
              sweep, quantity skew, feature shift, mixed hinge+lasso
  compare     strategy zoo head-to-head: every --strategies entry runs
              the *same* SimNet seed/latency/drop/partition schedule;
              one CSV holds every consensus+accuracy curve, tagged by a
              trailing strategy column (--strategies a,b,... --nodes N
              --degree K --horizon S --latency-ms L --jitter-ms J
              --drop-prob P --partition T0:T1:CUT --samples M
              --objective ... --csv PATH)

System:
  train       one Alg. 2 run (--nodes N --degree K --iters I
              --objective logreg|hinge|lasso
              --backend native|pjrt
              --dataset synth|notmnist|libsvm:PATH
              --csv PATH to dump the series)
  cluster     live asynchronous cluster on the work-stealing executor
              pool (--secs S --kill N --kill-after T to crash N nodes
              at time T --backend native|pjrt --rate HZ --spread X
              --executors E pool threads, 0 = one per core; E=1 with
              a fixed seed is deterministic --transport
              shared|channel|socket --plan P --dirichlet-alpha A)
  sim         delay/drop-aware virtual-time simulation, 10k+ nodes
              (--nodes N --degree K --horizon S --latency-ms L
              --jitter-ms J --drop-prob P --objective logreg|hinge|lasso
              --partition T0:T1:CUT --samples M --straggle X
              --plan P --dirichlet-alpha A)
  launch      multi-process deployment on this machine: spawn K worker
              processes, stream each its workload shards over TCP,
              monitor them (--workers K --nodes N --degree D --horizon U
              applied updates --secs S cap --rate HZ --objective ...
              --plan P --dirichlet-alpha A --samples M per node
              --dataset synth|libsvm:PATH --csv PATH); shards of any
              size stream as checksummed row blocks
              (--stream-block-rows R, default 4096) under a per-worker
              staging budget (--staging-mb M, default 1024) — workers
              start stepping on their first block; --executors E pool
              threads per worker (0 = one per core) and --flush-bytes B
              / --flush-micros U tune per-peer frame coalescing
              (B=0 turns batching off); membership churn: --join-addr
              H:P listens for mid-run `worker --join` replacements
              (the monitor prints `dasgd-launch join-addr=...`),
              --chaos-kill R@F SIGKILLs rank R once the update count
              passes fraction F of the horizon, --chaos-join F spawns
              a --join replacement past fraction F (implies a
              loopback join listener)
  worker      one deployment worker process (--rank R
              --peers host:port,host:port,... --nodes N --degree D
              --secs S --rate HZ --objective ... --plan P|wire
              --samples M --param-len L with wire --staging-mb M
              --executors E --flush-bytes B --flush-micros U);
              `launch` spawns these. --join H:P instead of
              --rank/--peers dials a running monitor's join listener
              and adopts a vacant rank (plan, peers, and shards arrive
              over the wire); --leave-after S departs gracefully after
              S seconds (LeaveNotice — the monitor repairs the
              topology). See docs/membership.md
  artifacts   verify the AOT artifact set loads + executes

Workload plans (--plan): synth (default, the §V-A per-node world),
dirichlet (label-skew split of a pooled world), quantity (skewed shard
sizes), feature-shift (per-node covariate shift), mixed (dirichlet +
alternating hinge/lasso objectives). --dirichlet-alpha A is the
Dirichlet skew knob (default 0.5, must be > 0); feature-shift's offset
scale has its own flag, --shift-sigma S (when omitted, α doubles as σ —
the legacy fallback). See docs/heterogeneity.md.

Update strategies (--strategy, on cluster / sim / launch / worker):
dasgd (the paper's Alg. 2 baseline, default), dcasgd (Taylor delay
compensation), delay-agnostic (staleness-keyed fixed stepsize), rfast
(gossiped gradient tracking). launch ships each node's strategy to its
worker inside PlanAssign; train runs the figure trainer and accepts
only dasgd. See docs/algorithms.md for the math and the trait contract.

Common flags:
  --scale S   fraction of the paper's iteration budget (default 1.0)
  --seed N    RNG seed (default 0)

Observability (train / cluster / sim / launch / worker):
  --metrics-jsonl PATH  append one {\"kind\":\"metrics\",...} JSON line per
              evaluation round (launch: the cluster-wide aggregate)
  --trace-jsonl PATH    arm the structured tracer; the event ring dumps
              to PATH on exit, on panic, or when the run ends (launch
              also arms every worker: rank N dumps to PATH's sibling
              <stem>.rankN.<ext>, the monitor to PATH itself)
  --log-level L         error|warn|info|debug (default info); launch
              forwards it to every worker
  --metrics-addr H:P    (launch, worker) serve Prometheus text on H:P —
              launch serves the aggregate, a worker its own registry
See docs/observability.md for the metric catalog and schemas.

Unknown flags and unknown flag values are rejected with a did-you-mean
suggestion.
";

/// Flags every command accepts.
const COMMON_FLAGS: &[&str] = &["scale", "seed"];

/// Validate the command line against the command's known flags. Every
/// dasgd flag takes a value, so a bare `--flag` is also an error.
fn check_flags(args: &Args, extra: &[&str]) -> anyhow::Result<()> {
    let mut known: Vec<&str> = COMMON_FLAGS.to_vec();
    known.extend_from_slice(extra);
    args.reject_unknown(&known).map_err(anyhow::Error::msg)?;
    args.require_values(&known).map_err(anyhow::Error::msg)
}

/// Error for a flag whose *value* is outside its vocabulary, with the
/// same did-you-mean treatment unknown flags get (`--transport chanel`
/// → "did you mean \"channel\"?").
fn unknown_value(flag: &str, got: &str, known: &[&str]) -> anyhow::Error {
    let mut msg = format!(
        "unknown {flag} {got:?} (choose one of: {})",
        known.join(", ")
    );
    if let Some(best) = cli::did_you_mean(got, known) {
        msg.push_str(&format!(" — did you mean {best:?}?"));
    }
    anyhow::Error::msg(msg)
}

/// The `--dataset` vocabulary (the `libsvm` family takes a `:PATH`
/// payload; the built-in generators take none).
const DATASET_NAMES: [&str; 3] = ["synth", "notmnist", "libsvm"];

/// Split `--dataset` into `(family, payload)`, rejecting unknown
/// families with a suggestion and malformed payloads with the exact
/// shape the family expects.
fn parse_dataset(value: &str) -> anyhow::Result<(&str, Option<&str>)> {
    let (family, payload) = match value.split_once(':') {
        Some((f, p)) => (f, Some(p)),
        None => (value, None),
    };
    if !DATASET_NAMES.contains(&family) {
        return Err(unknown_value("dataset", family, &DATASET_NAMES));
    }
    match (family, payload) {
        ("libsvm", None | Some("")) => {
            anyhow::bail!("--dataset libsvm needs a file: --dataset libsvm:PATH")
        }
        ("libsvm", some) => Ok((family, some)),
        (_, Some(_)) => {
            anyhow::bail!("--dataset {family} takes no \":PATH\" payload (got {value:?})")
        }
        (_, None) => Ok((family, None)),
    }
}

/// Load a libsvm file and split it into `n` contiguous per-node shards
/// plus a held-out test tail, mirroring the synthetic worlds' shape.
fn libsvm_world(
    path: &str,
    n: usize,
    test_n: usize,
) -> anyhow::Result<(Vec<dasgd::data::Dataset>, dasgd::data::Dataset)> {
    let base = load_libsvm(
        path,
        LibsvmOptions {
            cache: true,
            ..Default::default()
        },
    )?;
    if base.len() < n + test_n {
        anyhow::bail!(
            "libsvm dataset {path} has {} rows — need at least {} \
             ({n} nodes + {test_n} test rows)",
            base.len(),
            n + test_n
        );
    }
    let split = base.len() - test_n;
    let test = base.subset(&(split..base.len()).collect::<Vec<usize>>());
    let per = split / n;
    let mut shards = Vec::with_capacity(n);
    for i in 0..n {
        let start = i * per;
        let end = if i + 1 == n { split } else { start + per };
        shards.push(base.subset(&(start..end).collect::<Vec<usize>>()));
    }
    Ok((shards, test))
}

/// Parse `--objective`, rejecting unknown names with a suggestion.
fn parse_objective(args: &Args) -> anyhow::Result<Objective> {
    let name = args.get_str("objective", "logreg");
    Objective::parse(name).ok_or_else(|| unknown_value("objective", name, &Objective::NAMES))
}

/// Parse `--strategy`, rejecting unknown names with a suggestion.
fn parse_strategy(args: &Args) -> anyhow::Result<StrategyKind> {
    let name = args.get_str("strategy", StrategyKind::Dasgd.name());
    StrategyKind::parse(name).ok_or_else(|| unknown_value("strategy", name, &StrategyKind::NAMES))
}

/// Parse the `--strategies` list for `compare` (comma-separated,
/// deduplicated in the order given, same did-you-mean as `--strategy`).
fn parse_strategies(args: &Args) -> anyhow::Result<Vec<StrategyKind>> {
    let list = args.get_str("strategies", "dasgd,dcasgd,delay-agnostic,rfast");
    let mut strategies = Vec::new();
    for name in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let Some(kind) = StrategyKind::parse(name) else {
            return Err(unknown_value("strategies entry", name, &StrategyKind::NAMES));
        };
        if !strategies.contains(&kind) {
            strategies.push(kind);
        }
    }
    if strategies.is_empty() {
        anyhow::bail!("--strategies names at least one strategy (got {list:?})");
    }
    Ok(strategies)
}

/// Parse `--partition T0:T1:CUT` — sever edges across {<CUT} | {>=CUT}
/// for virtual time [T0, T1). Shared by `sim` and `compare`.
fn parse_partitions(args: &Args) -> anyhow::Result<Vec<PartitionWindow>> {
    match args.get("partition") {
        None => Ok(Vec::new()),
        Some(spec) => {
            let parts: Vec<&str> = spec.split(':').collect();
            let [t0, t1, cut] = parts.as_slice() else {
                anyhow::bail!("--partition wants T0:T1:CUT, got {spec:?}");
            };
            Ok(vec![PartitionWindow {
                start_secs: t0.parse().map_err(|e| anyhow::anyhow!("T0 {t0:?}: {e}"))?,
                end_secs: t1.parse().map_err(|e| anyhow::anyhow!("T1 {t1:?}: {e}"))?,
                boundary: cut.parse().map_err(|e| anyhow::anyhow!("CUT {cut:?}: {e}"))?,
            }])
        }
    }
}

/// Validate the skew knobs against the chosen plan name: α must be a
/// drawable Dirichlet parameter, and the dedicated `--shift-sigma`
/// knob is rejected (not silently ignored) on any plan without a σ.
/// Shared by every verb that takes `--plan`, including the worker's
/// `wire` mode — flags must not change meaning by verb.
fn validate_skew_knobs(args: &Args, plan_name: &str) -> anyhow::Result<(f64, Option<f64>)> {
    let alpha = args
        .get_f64("dirichlet-alpha", PlanSpec::DEFAULT_ALPHA)
        .map_err(anyhow::Error::msg)?;
    if !alpha.is_finite() || alpha <= 0.0 {
        anyhow::bail!(
            "--dirichlet-alpha must be a positive α, got {alpha} — α → 0 is the one-hot \
             skew limit, which the Dirichlet sampler cannot draw; did you mean a small \
             positive value like 0.01 (extreme skew) or 100 (near-IID)?"
        );
    }
    let sigma = match args.get("shift-sigma") {
        None => None,
        Some(_) => {
            let s = args.get_f64("shift-sigma", 0.0).map_err(anyhow::Error::msg)?;
            if !s.is_finite() || s < 0.0 {
                anyhow::bail!("--shift-sigma must be a finite offset scale ≥ 0, got {s}");
            }
            if plan_name != "feature-shift" {
                anyhow::bail!(
                    "--shift-sigma only applies to --plan feature-shift (got --plan {plan_name}); \
                     the Dirichlet recipes take --dirichlet-alpha"
                );
            }
            Some(s)
        }
    };
    Ok((alpha, sigma))
}

/// Parse `--plan` + `--dirichlet-alpha` + `--shift-sigma` into a
/// workload recipe, rejecting unknown names and out-of-domain knobs
/// with a suggestion. `also` extends the name vocabulary listed in
/// errors (the worker verb additionally speaks `wire`).
fn parse_plan_with(args: &Args, also: &[&str]) -> anyhow::Result<PlanSpec> {
    let name = args.get_str("plan", "synth");
    let (alpha, sigma) = validate_skew_knobs(args, name)?;
    let mut known: Vec<&str> = PlanSpec::NAMES.to_vec();
    known.extend_from_slice(also);
    PlanSpec::parse_spec(name, alpha, sigma)
        .ok_or_else(|| unknown_value("plan", name, &known))
}

/// [`parse_plan_with`] for the commands whose `--plan` vocabulary is
/// exactly the recipe names.
fn parse_plan(args: &Args) -> anyhow::Result<PlanSpec> {
    parse_plan_with(args, &[])
}

/// Parse `--samples` (rows per node in the built world). Zero would
/// panic the partitioners' need-a-row-per-node asserts far from the
/// flag that caused it — refuse at the CLI instead.
fn parse_samples(args: &Args, default: usize) -> anyhow::Result<usize> {
    let samples = args
        .get_usize("samples", default)
        .map_err(anyhow::Error::msg)?;
    if samples == 0 {
        anyhow::bail!("--samples must be ≥ 1 (every node needs at least one data row)");
    }
    Ok(samples)
}

/// Parse and apply the observability flags shared by every run verb:
/// `--log-level` sets the process log level, `--trace-jsonl` arms the
/// structured tracer (the ring dumps on exit or panic). Returns the
/// `--metrics-jsonl` path for the verb to append its snapshot lines to.
fn apply_obs_flags(args: &Args) -> anyhow::Result<Option<std::path::PathBuf>> {
    if let Some(name) = args.get("log-level") {
        let Some(lvl) = dasgd::obs::Level::parse(name) else {
            return Err(unknown_value("log-level", name, &dasgd::obs::Level::NAMES));
        };
        dasgd::obs::set_log_level(lvl);
    }
    if let Some(path) = args.get("trace-jsonl") {
        dasgd::obs::trace_to(std::path::Path::new(path));
    }
    Ok(args.get("metrics-jsonl").map(std::path::PathBuf::from))
}

/// End-of-run observability flush: append the process-local registry as
/// one JSONL line (when `--metrics-jsonl` was given) and dump the trace
/// ring (a no-op unless `--trace-jsonl` armed it).
fn finish_obs(
    metrics_jsonl: Option<&std::path::Path>,
    scope: &str,
    t_secs: f64,
    k: u64,
) -> anyhow::Result<()> {
    if let Some(path) = metrics_jsonl {
        dasgd::obs::append_jsonl(path, &dasgd::obs::snapshot().jsonl(scope, t_secs, k))
            .map_err(|e| anyhow::anyhow!("writing --metrics-jsonl {}: {e}", path.display()))?;
        println!("wrote metrics line to {}", path.display());
    }
    dasgd::obs::trace_dump();
    Ok(())
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn print_notes(notes: &[String]) {
    for n in notes {
        println!("  {n}");
    }
}

/// Per-command flag vocabulary (beyond [`COMMON_FLAGS`]); `None` means
/// the command itself is unknown.
fn extra_flags(cmd: &str) -> Option<&'static [&'static str]> {
    Some(match cmd {
        "fig2" | "fig3" | "fig4" | "fig6" | "lemma1" | "glyphs" | "losses" | "comm"
        | "conflicts" | "topology" | "straggler" | "heterogeneity" | "artifacts" => &[],
        "compare" => &[
            "strategies",
            "nodes",
            "degree",
            "horizon",
            "eval-every",
            "latency-ms",
            "jitter-ms",
            "drop-prob",
            "partition",
            "objective",
            "samples",
            "csv",
        ],
        "train" => &[
            "nodes",
            "degree",
            "iters",
            "backend",
            "dataset",
            "objective",
            "strategy",
            "csv",
            "metrics-jsonl",
            "trace-jsonl",
            "log-level",
        ],
        "cluster" => &[
            "nodes",
            "degree",
            "secs",
            "rate",
            "spread",
            "kill",
            "kill-after",
            "backend",
            "transport",
            "executors",
            "strategy",
            "plan",
            "dirichlet-alpha",
            "shift-sigma",
            "metrics-jsonl",
            "trace-jsonl",
            "log-level",
        ],
        "sim" => &[
            "nodes",
            "degree",
            "horizon",
            "eval-every",
            "latency-ms",
            "jitter-ms",
            "drop-prob",
            "partition",
            "objective",
            "strategy",
            "samples",
            "straggle",
            "plan",
            "dirichlet-alpha",
            "shift-sigma",
            "csv",
            "metrics-jsonl",
            "trace-jsonl",
            "log-level",
        ],
        "launch" => &[
            "workers",
            "nodes",
            "degree",
            "horizon",
            "secs",
            "eval-every",
            "rate",
            "objective",
            "strategy",
            "plan",
            "dirichlet-alpha",
            "shift-sigma",
            "samples",
            "dataset",
            "staging-mb",
            "stream-block-rows",
            "executors",
            "flush-bytes",
            "flush-micros",
            "csv",
            "metrics-jsonl",
            "trace-jsonl",
            "log-level",
            "metrics-addr",
            "join-addr",
            "chaos-kill",
            "chaos-join",
        ],
        "worker" => &[
            "rank",
            "peers",
            "join",
            "leave-after",
            "nodes",
            "degree",
            "secs",
            "rate",
            "objective",
            "strategy",
            "plan",
            "dirichlet-alpha",
            "shift-sigma",
            "samples",
            "param-len",
            "staging-mb",
            "executors",
            "flush-bytes",
            "flush-micros",
            "metrics-jsonl",
            "trace-jsonl",
            "log-level",
            "metrics-addr",
        ],
        _ => return None,
    })
}

fn run(args: &Args) -> anyhow::Result<()> {
    let scale = args.get_f64("scale", 1.0).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 0).map_err(anyhow::Error::msg)?;
    if let Some(extra) = args.command.as_deref().and_then(extra_flags) {
        check_flags(args, extra)?;
    }
    match args.command.as_deref() {
        Some("fig2") => {
            let r = fig2::run(scale, seed)?;
            println!("Fig. 2 — distance to global consensus ({} updates)", r.iters);
            r.table().print();
            print_notes(&fig2::check_shape(&r));
        }
        Some("fig3") => {
            let r = fig3::run(scale, seed)?;
            println!("Fig. 3 — prediction error ({} iterations)", r.iters);
            r.table().print();
            print_notes(&fig3::check_shape(&r));
        }
        Some("fig4") => {
            let r = fig4::run(scale, seed)?;
            println!("Fig. 4 — final error vs network size ({} iters/point)", r.iters);
            r.table().print();
            print_notes(&fig4::check_shape(&r));
        }
        Some("fig6") => {
            let r = fig6::run(scale, seed)?;
            println!("Fig. 6 — notMNIST-like prediction error ({} iters)", r.iters);
            r.table().print();
            print_notes(&fig6::check_shape(&r));
        }
        Some("lemma1") => {
            let r = lemma1::run(scale, seed)?;
            println!("Lemma 1 — spectral bound vs measured contraction (N={})", r.n);
            r.table().print();
            print_notes(&lemma1::check_shape(&r));
        }
        Some("glyphs") => {
            let mut rng = Xoshiro256pp::seeded(seed);
            let gen = NotMnistGen::new(4, seed);
            println!("Clean skeletons (A, E, J) and node-styled samples (Fig. 5 stand-in):");
            for class in [0usize, 4, 9] {
                let img = render_glyph(class, &GlyphStyle::default(), &mut rng);
                println!("class {class}:\n{}", ascii_art(&img));
            }
            for node in 0..2 {
                let (img, label) = gen.draw(node, &mut rng);
                println!("node {node} sample (label {label}):\n{}", ascii_art(&img));
            }
        }
        Some("losses") => {
            let rows = experiments::losses::run(scale, seed)?;
            println!("§II loss families — decentralized SVM + Lasso (both backends)");
            experiments::losses::table(&rows).print();
        }
        Some("comm") => {
            let rows = experiments::ablations::comm_overhead(scale, seed)?;
            println!("§IV-B — communication overhead vs p_grad");
            experiments::ablations::comm_table(&rows).print();
        }
        Some("conflicts") => {
            let rows = experiments::ablations::conflicts(scale, seed)?;
            println!("§IV-C — update conflicts under distributed selection");
            experiments::ablations::conflict_table(&rows).print();
        }
        Some("topology") => {
            let rows = experiments::ablations::topologies(scale, seed)?;
            println!("Topology families — consensus + error at equal budgets");
            experiments::ablations::topology_table(&rows).print();
        }
        Some("straggler") => {
            let rows = straggler::run(scale, seed)?;
            println!("Stragglers — async vs synchronized schemes (virtual time)");
            straggler::table(&rows).print();
            print_notes(&straggler::check_shape(&rows));
        }
        Some("heterogeneity") => {
            let rows = heterogeneity::run(scale, seed)?;
            println!("Heterogeneous workloads — consensus/error vs per-node skew");
            heterogeneity::table(&rows).print();
            print_notes(&heterogeneity::check_shape(&rows));
        }
        Some("compare") => cmd_compare(args, scale, seed)?,
        Some("train") => cmd_train(args, scale, seed)?,
        Some("cluster") => cmd_cluster(args, seed)?,
        Some("sim") => cmd_sim(args, scale, seed)?,
        Some("launch") => cmd_launch(args, seed)?,
        Some("worker") => cmd_worker(args, seed)?,
        Some("artifacts") => {
            let engine = Engine::load_default()?;
            println!(
                "loaded + compiled {} artifacts:",
                engine.manifest().artifacts.len()
            );
            let mut t = Table::new(&["artifact", "inputs", "outputs"]);
            for (name, spec) in &engine.manifest().artifacts {
                t.row(&[
                    name.clone(),
                    format!("{}", spec.inputs.len()),
                    format!("{}", spec.outputs.len()),
                ]);
            }
            t.print();
        }
        Some(other) => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
        None => {
            println!("{USAGE}");
        }
    }
    Ok(())
}

/// Head-to-head strategy comparison: every `--strategies` entry runs
/// the same SimNet schedule (identical seed/latency/drop/partition),
/// so the curves differ only by update rule; one CSV holds them all.
fn cmd_compare(args: &Args, scale: f64, seed: u64) -> anyhow::Result<()> {
    let strategies = parse_strategies(args)?;
    let n = args.get_usize("nodes", 12).map_err(anyhow::Error::msg)?;
    let degree = args.get_usize("degree", 4).map_err(anyhow::Error::msg)?;
    let horizon = args
        .get_f64("horizon", 40.0 * scale.max(0.05))
        .map_err(anyhow::Error::msg)?;
    let eval_every = args
        .get_f64("eval-every", horizon / 8.0)
        .map_err(anyhow::Error::msg)?;
    if !(horizon.is_finite() && horizon > 0.0 && eval_every.is_finite() && eval_every > 0.0) {
        anyhow::bail!("--horizon and --eval-every must be > 0 (got {horizon}, {eval_every})");
    }
    let latency_ms = args.get_f64("latency-ms", 2.0).map_err(anyhow::Error::msg)?;
    let jitter_ms = args.get_f64("jitter-ms", 0.0).map_err(anyhow::Error::msg)?;
    let drop_prob = args.get_f64("drop-prob", 0.0).map_err(anyhow::Error::msg)?;
    if !(0.0..=1.0).contains(&drop_prob) {
        anyhow::bail!("--drop-prob must be in [0, 1], got {drop_prob}");
    }
    let samples = parse_samples(args, 40)?;
    let objective = parse_objective(args)?;
    let partitions = parse_partitions(args)?;
    let cfg = compare::CompareConfig {
        strategies,
        n,
        degree,
        objective,
        p_grad: 0.5,
        horizon,
        eval_every,
        net: SimNetConfig {
            latency: LatencyModel {
                min_secs: latency_ms / 2000.0, // edges span [L/2, L] ms
                max_secs: latency_ms / 1000.0,
                jitter_secs: jitter_ms / 1000.0,
            },
            drop_prob,
            partitions,
            seed,
        },
        seed,
        samples_per_node: samples,
        test_n: 512,
    };
    println!(
        "compare: {} on one schedule — {n} nodes, degree {degree}, horizon {horizon}s, \
         latency ≤{latency_ms}ms, drop {:.1}%, objective {objective}",
        cfg.strategies
            .iter()
            .map(|s| s.name())
            .collect::<Vec<_>>()
            .join(" vs "),
        drop_prob * 100.0,
    );
    let curves = compare::run(&cfg)?;
    compare::table(&curves).print();
    if let Some(csv) = args.get("csv") {
        compare::write_csv(&curves, csv)?;
        println!("wrote {csv} (one block per strategy, trailing strategy column)");
    }
    Ok(())
}

fn cmd_train(args: &Args, scale: f64, seed: u64) -> anyhow::Result<()> {
    use dasgd::coordinator::{Backend, TrainConfig};
    let metrics_jsonl = apply_obs_flags(args)?;
    let n = args.get_usize("nodes", 30).map_err(anyhow::Error::msg)?;
    let degree = args.get_usize("degree", 4).map_err(anyhow::Error::msg)?;
    let iters = args
        .get_u64("iters", experiments::scaled(20_000, scale, 500))
        .map_err(anyhow::Error::msg)?;
    let backend = match args.get_str("backend", "native") {
        "pjrt" => Backend::Pjrt,
        "native" => Backend::Native,
        other => return Err(unknown_value("backend", other, &["native", "pjrt"])),
    };
    let objective = parse_objective(args)?;
    // The figure trainer is the paper baseline; the strategy zoo lives
    // in the asynchronous engines. Validate (with did-you-mean) rather
    // than silently ignore.
    let strategy = parse_strategy(args)?;
    if strategy != StrategyKind::Dasgd {
        anyhow::bail!(
            "train runs the figure trainer, which is the paper baseline only — \
             use `cluster`, `sim`, `launch`, or `compare` for --strategy {strategy}"
        );
    }
    let dataset = args.get_str("dataset", "synth");
    let (shards, test) = match parse_dataset(dataset)? {
        ("notmnist", _) => fig6::notmnist_world(n, 400, 512, seed),
        ("synth", _) => experiments::synth_world(n, 500, 512, seed),
        ("libsvm", Some(path)) => libsvm_world(path, n, 512)?,
        _ => unreachable!("parse_dataset admits only known families"),
    };
    let cfg = TrainConfig::objective_default(objective, n)
        .with_seed(seed)
        .with_backend(backend);
    let rec = experiments::run_alg2(
        &cfg,
        experiments::make_regular(n, degree),
        shards,
        &test,
        iters,
        (iters / 10).max(1),
        "train",
    )?;
    println!(
        "Alg. 2: N={n}, degree {degree}, {iters} updates, objective {objective}, backend {}",
        args.get_str("backend", "native")
    );
    if objective != Objective::LogReg {
        println!(
            "  (the err column is the {objective} metric: {})",
            match objective {
                Objective::Hinge { .. } => "binary misclassification rate",
                _ => "prediction RMSE",
            }
        );
    }
    let mut t = Table::new(&["k", "d^k", "test loss", "test err", "msgs"]);
    for r in &rec.records {
        t.row(&[
            format!("{}", r.k),
            format!("{:.3}", r.consensus),
            format!("{:.3}", r.test_loss),
            format!("{:.3}", r.test_err),
            format!("{}", r.messages),
        ]);
    }
    t.print();
    if let Some(csv) = args.get("csv") {
        rec.write_csv(csv)?;
        println!("wrote {csv}");
    }
    let last = rec.records.last();
    finish_obs(
        metrics_jsonl.as_deref(),
        "train",
        last.map(|r| r.time_secs).unwrap_or(0.0),
        last.map(|r| r.k).unwrap_or(0),
    )
}

fn cmd_cluster(args: &Args, seed: u64) -> anyhow::Result<()> {
    let metrics_jsonl = apply_obs_flags(args)?;
    let n = args.get_usize("nodes", 12).map_err(anyhow::Error::msg)?;
    let degree = args.get_usize("degree", 4).map_err(anyhow::Error::msg)?;
    let secs = args.get_f64("secs", 3.0).map_err(anyhow::Error::msg)?;
    let rate = args.get_f64("rate", 300.0).map_err(anyhow::Error::msg)?;
    let spread = args.get_f64("spread", 0.0).map_err(anyhow::Error::msg)?;
    let backend_name = args.get_str("backend", "native");
    if !matches!(backend_name, "native" | "pjrt") {
        return Err(unknown_value("backend", backend_name, &["native", "pjrt"]));
    }
    let transport_name = args.get_str("transport", "shared");
    let Some(transport) = TransportKind::parse(transport_name) else {
        return Err(unknown_value(
            "transport",
            transport_name,
            &TransportKind::NAMES,
        ));
    };
    let executors = args.get_usize("executors", 0).map_err(anyhow::Error::msg)?;
    let strategy = parse_strategy(args)?;
    let plan_spec = parse_plan(args)?;
    let (plan, test) = plan_spec.build(Objective::LogReg, n, 300, 512, seed);
    let plan = plan.with_uniform_strategy(strategy);
    let mut cluster = AsyncCluster::from_plan(experiments::make_regular(n, degree), plan);
    let _service: Option<ExecutorService>;
    if backend_name == "pjrt" {
        let service = ExecutorService::start("artifacts", 2)?;
        cluster = cluster.with_executor(service.handle(), PjrtArtifacts::synth());
        _service = Some(service);
    } else {
        _service = None;
    }
    let cfg = AsyncConfig {
        p_grad: 0.5,
        stepsize: StepSize::paper_default(n),
        rate_hz: rate,
        speed_spread: spread,
        duration_secs: secs,
        eval_every_secs: (secs / 8.0).max(0.1),
        gossip_hold_secs: 0.0,
        kill_after_secs: args.get("kill-after").map(|v| v.parse().unwrap_or(0.0)),
        kill_nodes: args.get_usize("kill", 0).map_err(anyhow::Error::msg)?,
        transport,
        engine: EngineKind::Executors(executors),
        deterministic_events: None,
        seed,
    };
    println!(
        "async cluster: {n} node tasks on {} executors, degree {degree}, {secs}s @ {rate}/s/node \
         (spread {spread}, transport {}, plan {})",
        if executors == 0 {
            "auto".to_string()
        } else {
            executors.to_string()
        },
        transport.name(),
        plan_spec.name()
    );
    if strategy != StrategyKind::Dasgd {
        println!("  update strategy: {strategy}");
    }
    let rep = cluster.run(&cfg, &test)?;
    let mut t = Table::new(&["t (s)", "k", "d^k", "test err", "conflicts"]);
    for r in &rep.recorder.records {
        t.row(&[
            format!("{:.2}", r.time_secs),
            format!("{}", r.k),
            format!("{:.3}", r.consensus),
            format!("{:.3}", r.test_err),
            format!("{}", r.conflicts),
        ]);
    }
    t.print();
    println!(
        "{} updates ({} grad, {} proj) — {:.0} updates/s, {} messages, {} lock conflicts",
        rep.updates,
        rep.grad_steps,
        rep.proj_steps,
        rep.updates_per_sec,
        rep.messages,
        rep.conflicts
    );
    let last = rep.recorder.records.last();
    finish_obs(
        metrics_jsonl.as_deref(),
        "cluster",
        last.map(|r| r.time_secs).unwrap_or(0.0),
        rep.updates,
    )
}

/// The delay/drop-aware virtual-time simulation: Alg. 2 over a `SimNet`
/// with per-edge latency, drop probability, and optional partitions —
/// cheap at 10,000+ nodes (incremental parameters + O(dim) snapshots).
fn cmd_sim(args: &Args, scale: f64, seed: u64) -> anyhow::Result<()> {
    let metrics_jsonl = apply_obs_flags(args)?;
    let n = args.get_usize("nodes", 64).map_err(anyhow::Error::msg)?;
    let degree = args.get_usize("degree", 3).map_err(anyhow::Error::msg)?;
    let horizon = args
        .get_f64("horizon", 60.0 * scale.max(0.05))
        .map_err(anyhow::Error::msg)?;
    let eval_every = args
        .get_f64("eval-every", horizon / 8.0)
        .map_err(anyhow::Error::msg)?;
    let cadence_valid =
        horizon.is_finite() && horizon > 0.0 && eval_every.is_finite() && eval_every > 0.0;
    if !cadence_valid {
        anyhow::bail!("--horizon and --eval-every must be > 0 (got {horizon}, {eval_every})");
    }
    let latency_ms = args.get_f64("latency-ms", 5.0).map_err(anyhow::Error::msg)?;
    let jitter_ms = args.get_f64("jitter-ms", 0.0).map_err(anyhow::Error::msg)?;
    let drop_prob = args.get_f64("drop-prob", 0.0).map_err(anyhow::Error::msg)?;
    if !(0.0..=1.0).contains(&drop_prob) {
        anyhow::bail!("--drop-prob must be in [0, 1], got {drop_prob}");
    }
    let samples = parse_samples(args, 60)?;
    let straggle = args.get_f64("straggle", 1.0).map_err(anyhow::Error::msg)?;
    let objective = parse_objective(args)?;
    let strategy = parse_strategy(args)?;
    let partitions = parse_partitions(args)?;

    let plan_spec = parse_plan(args)?;
    let (plan, test) = plan_spec.build(objective, n, samples, 512, seed);
    let plan = plan.with_uniform_strategy(strategy);
    let g = experiments::make_regular(n, degree);
    let speeds = if straggle > 1.0 {
        SpeedModel::with_stragglers(n, 1.0, (n / 10).max(1), straggle)
    } else {
        SpeedModel::homogeneous(n, 1.0)
    };
    let cfg = SimConfig {
        p_grad: 0.5,
        stepsize: objective.default_stepsize(n),
        objective,
        horizon,
        eval_every,
        net: SimNetConfig {
            latency: LatencyModel {
                min_secs: latency_ms / 2000.0, // edges span [L/2, L] ms
                max_secs: latency_ms / 1000.0,
                jitter_secs: jitter_ms / 1000.0,
            },
            drop_prob,
            partitions,
            seed,
        },
        seed,
    };
    println!(
        "simnet: {n} nodes, degree {degree}, horizon {horizon}s, latency ≤{latency_ms}ms \
         (+Exp jitter {jitter_ms}ms), drop {:.1}%, objective {objective}, plan {}",
        drop_prob * 100.0,
        plan_spec.name()
    );
    if strategy != StrategyKind::Dasgd {
        println!("  update strategy: {strategy}");
    }
    let wall = std::time::Instant::now();
    let rep = simnet_run_plan(&g, &plan, &test, &speeds, &cfg);
    let wall = wall.elapsed().as_secs_f64();
    let consensus_col = if n <= dasgd::sim::EXACT_SCAN_MAX {
        "d^k"
    } else {
        "L2 resid"
    };
    let mut t = Table::new(&["t (virt s)", "k", consensus_col, "test err", "msgs"]);
    for r in &rep.recorder.records {
        t.row(&[
            format!("{:.1}", r.time_secs),
            format!("{}", r.k),
            format!("{:.3}", r.consensus),
            format!("{:.3}", r.test_err),
            format!("{}", r.messages),
        ]);
    }
    t.print();
    println!(
        "{} updates ({} grad, {} proj), {} messages, {} dropped legs, {} isolated \
         rounds — simulated in {wall:.2}s wall",
        rep.updates, rep.grad_steps, rep.proj_steps, rep.messages, rep.drops, rep.isolated
    );
    if let Some(csv) = args.get("csv") {
        rep.recorder.write_csv(csv)?;
        println!("wrote {csv}");
    }
    let last = rep.recorder.records.last();
    finish_obs(
        metrics_jsonl.as_deref(),
        "sim",
        last.map(|r| r.time_secs).unwrap_or(0.0),
        rep.updates,
    )
}

/// Multi-process deployment on this machine: spawn K workers from this
/// binary, monitor their shards to the update horizon, print the same
/// table the in-process cluster prints.
fn cmd_launch(args: &Args, seed: u64) -> anyhow::Result<()> {
    let metrics_jsonl = apply_obs_flags(args)?;
    let workers = args.get_usize("workers", 2).map_err(anyhow::Error::msg)?;
    let nodes = args.get_usize("nodes", 8).map_err(anyhow::Error::msg)?;
    let degree = args.get_usize("degree", 2).map_err(anyhow::Error::msg)?;
    let horizon = args.get_u64("horizon", 2000).map_err(anyhow::Error::msg)?;
    let secs = args.get_f64("secs", 30.0).map_err(anyhow::Error::msg)?;
    let eval_every = args
        .get_f64("eval-every", 0.25)
        .map_err(anyhow::Error::msg)?;
    let rate = args.get_f64("rate", 300.0).map_err(anyhow::Error::msg)?;
    let objective = parse_objective(args)?;
    let strategy = parse_strategy(args)?;
    let plan = parse_plan(args)?;
    let samples = parse_samples(args, dasgd::net::SAMPLES_PER_NODE)?;
    let staging_mb = args
        .get_usize("staging-mb", 1024)
        .map_err(anyhow::Error::msg)?;
    let stream_block_rows = args
        .get_usize("stream-block-rows", DEFAULT_BLOCK_ROWS)
        .map_err(anyhow::Error::msg)?;
    let executors = args.get_usize("executors", 0).map_err(anyhow::Error::msg)?;
    let flush_bytes = args
        .get_usize("flush-bytes", 16 * 1024)
        .map_err(anyhow::Error::msg)?;
    let flush_micros = args
        .get_u64("flush-micros", 500)
        .map_err(anyhow::Error::msg)?;
    // The streamed shards come from the plan's own generator unless a
    // real corpus is named; notMNIST stays a `train`-only world (its
    // glyph renderer has no per-node partition recipe to stream).
    let base_data = match parse_dataset(args.get_str("dataset", "synth"))? {
        ("synth", _) => None,
        ("libsvm", Some(path)) => Some(load_libsvm(
            path,
            LibsvmOptions {
                cache: true,
                ..Default::default()
            },
        )?),
        ("notmnist", _) => {
            anyhow::bail!("--dataset notmnist is not available for launch (use train)")
        }
        _ => unreachable!("parse_dataset admits only known families"),
    };
    // Deterministic churn injection (the CI smoke and the acceptance
    // test): both knobs are fractions of the update horizon.
    let chaos_kill = match args.get("chaos-kill") {
        Some(spec) => {
            let (r, f) = spec.split_once('@').ok_or_else(|| {
                anyhow::anyhow!("--chaos-kill wants RANK@FRAC (e.g. 2@0.3), got {spec:?}")
            })?;
            let rank: u32 = r.trim().parse().map_err(|_| {
                anyhow::anyhow!("--chaos-kill rank {r:?} is not an unsigned integer")
            })?;
            let frac: f64 = f
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("--chaos-kill fraction {f:?} is not a number"))?;
            if !(0.0..=1.0).contains(&frac) {
                anyhow::bail!("--chaos-kill fraction must be in [0, 1], got {frac}");
            }
            if rank as usize >= workers {
                anyhow::bail!("--chaos-kill rank {rank} is out of range ({workers} workers)");
            }
            Some((rank, frac))
        }
        None => None,
    };
    let chaos_join = match args.get("chaos-join") {
        Some(_) => {
            let frac = args
                .get_f64("chaos-join", 0.0)
                .map_err(anyhow::Error::msg)?;
            if !(0.0..=1.0).contains(&frac) {
                anyhow::bail!("--chaos-join fraction must be in [0, 1], got {frac}");
            }
            Some(frac)
        }
        None => None,
    };
    let cfg = LaunchConfig {
        workers,
        nodes,
        degree,
        horizon_updates: horizon,
        secs_cap: secs,
        eval_every_secs: eval_every,
        rate_hz: rate,
        objective,
        strategy,
        plan,
        samples_per_node: samples,
        seed,
        binary: None,
        stream_block_rows,
        staging_mb,
        executors,
        flush_bytes,
        flush_micros,
        base_data,
        metrics_jsonl: metrics_jsonl.clone(),
        metrics_addr: args.get("metrics-addr").map(String::from),
        log_level: args.get("log-level").map(String::from),
        trace_jsonl: args.get("trace-jsonl").map(std::path::PathBuf::from),
        join_addr: args.get("join-addr").map(String::from),
        chaos_kill,
        chaos_join,
    };
    println!(
        "launch: {workers} worker processes over {nodes} nodes (degree {degree}), \
         horizon {horizon} updates, objective {objective}, plan {} \
         (shards stream as {stream_block_rows}-row blocks, {staging_mb} MiB staging)",
        plan.name()
    );
    let rep = run_launch(&cfg)?;
    let mut t = Table::new(&["t (s)", "k", "d^k", "test err", "conflicts"]);
    for r in &rep.recorder.records {
        t.row(&[
            format!("{:.2}", r.time_secs),
            format!("{}", r.k),
            format!("{:.3}", r.consensus),
            format!("{:.3}", r.test_err),
            format!("{}", r.conflicts),
        ]);
    }
    t.print();
    println!(
        "{} updates ({} grad, {} proj), {} messages, {} conflicts — \
         {}/{} workers live at shutdown, {:.2}s wall",
        rep.counts.updates(),
        rep.counts.grad_steps,
        rep.counts.proj_steps,
        rep.counts.messages,
        rep.counts.conflicts,
        rep.live_workers,
        workers,
        rep.elapsed_secs
    );
    if let Some(csv) = args.get("csv") {
        rep.recorder.write_csv(csv)?;
        println!("wrote {csv}");
    }
    if !rep.reached_horizon {
        anyhow::bail!(
            "run hit the {secs}s wall-clock cap at {} of {horizon} updates — \
             the deployment stalled",
            rep.counts.updates()
        );
    }
    // The monitor loop already appended the per-round aggregate lines;
    // here only the trace ring is left to flush.
    finish_obs(None, "launch", rep.elapsed_secs, rep.counts.updates())
}

/// One deployment worker process (normally spawned by `launch`; run it
/// by hand with an explicit `--peers` list to span machines).
fn cmd_worker(args: &Args, seed: u64) -> anyhow::Result<()> {
    let metrics_jsonl = apply_obs_flags(args)?;
    let rank = args.get_u64("rank", 0).map_err(anyhow::Error::msg)? as u32;
    // A worker serves its *own* registry (the launch monitor serves the
    // cluster-wide aggregate).
    if let Some(addr) = args.get("metrics-addr") {
        match dasgd::obs::serve_metrics(addr, || dasgd::obs::snapshot().prometheus_text()) {
            Ok(bound) => {
                dasgd::log!(Info, "worker", "serving metrics on http://{bound}/metrics")
            }
            Err(e) => dasgd::log!(Warn, "worker", "--metrics-addr {addr} failed to bind: {e}"),
        }
    }
    let leave_after = match args.get("leave-after") {
        Some(_) => {
            let secs = args
                .get_f64("leave-after", 0.0)
                .map_err(anyhow::Error::msg)?;
            if secs <= 0.0 {
                anyhow::bail!("--leave-after wants a positive number of seconds, got {secs}");
            }
            Some(secs)
        }
        None => None,
    };
    // `--join ADDR` replaces the whole static bootstrap: rank, peers,
    // plan, and shards all arrive from the monitor's join listener.
    if let Some(join_addr) = args.get("join") {
        if args.get("peers").is_some() || args.get("rank").is_some() {
            anyhow::bail!("--join gets its rank and peer list from the monitor; drop --rank/--peers");
        }
        let summary = run_join_worker(join_addr, leave_after)?;
        return finish_obs(
            metrics_jsonl.as_deref(),
            "worker",
            0.0,
            summary.counts.updates(),
        );
    }
    let Some(peers_raw) = args.get("peers") else {
        anyhow::bail!("worker needs --peers host:port,host:port,... (one per rank)");
    };
    let peers: Vec<String> = peers_raw
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    // A worker either derives its plan locally from a recipe (identical
    // on every machine given the seed) or — `--plan wire` — receives it
    // from the launch monitor, which then must also say `--param-len`
    // so the engine can bind before the data arrives.
    let plan = if args.get_str("plan", "synth") == "wire" {
        // The shipped plan carries its own skew, but the knobs are
        // still validated — a typo'd --shift-sigma or --dirichlet-alpha
        // must not be silently dropped just because the plan is wired.
        validate_skew_knobs(args, "wire")?;
        let param_len = args.get_usize("param-len", 0).map_err(anyhow::Error::msg)?;
        if param_len == 0 {
            anyhow::bail!("--plan wire needs --param-len L (the launcher supplies it)");
        }
        WorkerPlanSource::Wire { param_len }
    } else {
        // The shared parser validates the skew knobs exactly as
        // `launch`/`sim`/`cluster` do — a standalone `worker
        // --dirichlet-alpha 0` fails here with guidance instead of
        // panicking inside the Dirichlet sampler.
        WorkerPlanSource::Local(parse_plan_with(args, &["wire"])?)
    };
    let cfg = WorkerConfig {
        rank,
        peers,
        nodes: args.get_usize("nodes", 8).map_err(anyhow::Error::msg)?,
        degree: args.get_usize("degree", 2).map_err(anyhow::Error::msg)?,
        secs: args.get_f64("secs", 30.0).map_err(anyhow::Error::msg)?,
        rate_hz: args.get_f64("rate", 300.0).map_err(anyhow::Error::msg)?,
        objective: parse_objective(args)?,
        strategy: parse_strategy(args)?,
        plan,
        samples_per_node: parse_samples(args, dasgd::net::SAMPLES_PER_NODE)?,
        seed,
        staging_mb: args
            .get_usize("staging-mb", 1024)
            .map_err(anyhow::Error::msg)?,
        executors: args.get_usize("executors", 0).map_err(anyhow::Error::msg)?,
        flush_bytes: args
            .get_usize("flush-bytes", 16 * 1024)
            .map_err(anyhow::Error::msg)?,
        flush_micros: args
            .get_u64("flush-micros", 500)
            .map_err(anyhow::Error::msg)?,
        leave_after,
    };
    let summary = run_worker(&cfg)?;
    finish_obs(
        metrics_jsonl.as_deref(),
        "worker",
        0.0,
        summary.counts.updates(),
    )
}
