//! Topology generators.
//!
//! The paper's experiments use k-regular graphs on 30 nodes (Figs. 2/3/6)
//! and degree-4/degree-10 graphs with 10–30 nodes (Fig. 4). We provide the
//! circulant construction (deterministic k-regular), random k-regular via
//! the pairing model, and several extra families for topology ablations.

use super::Graph;
use crate::util::rng::Xoshiro256pp;

/// Deterministic k-regular circulant graph: node i connects to
/// i ± 1, ..., i ± k/2 (mod n); for odd k additionally to i + n/2.
///
/// Requires `k < n` and (for odd k) even `n`.
pub fn regular_circulant(n: usize, k: usize) -> Graph {
    assert!(n >= 2, "need at least 2 nodes");
    assert!(k >= 1 && k < n, "degree must be in [1, n)");
    if k % 2 == 1 {
        assert!(n % 2 == 0, "odd-degree circulant requires even n");
    }
    let mut g = Graph::empty(n);
    for i in 0..n {
        for d in 1..=(k / 2) {
            g.add_edge(i, (i + d) % n);
        }
        if k % 2 == 1 {
            g.add_edge(i, (i + n / 2) % n);
        }
    }
    debug_assert_eq!(g.is_regular(), Some(k));
    g
}

/// Random k-regular graph: start from the deterministic circulant and
/// randomize with degree-preserving double-edge swaps (retrying any swap
/// that would break simplicity), keeping connectivity. This always
/// terminates, unlike naive configuration-model resampling which stalls
/// for dense k.
pub fn random_regular(n: usize, k: usize, rng: &mut Xoshiro256pp) -> Graph {
    assert!(k < n, "degree must be < n");
    assert!((n * k) % 2 == 0, "n*k must be even");
    // Circulant needs even n for odd k; the assertion above guarantees it.
    let g = regular_circulant(n, k);
    let mut edges: Vec<(usize, usize)> = Vec::with_capacity(g.edge_count());
    for u in 0..n {
        for &v in g.neighbors(u) {
            if u < v {
                edges.push((u, v));
            }
        }
    }
    for _ in 0..50 {
        // Randomization sweep: ~10·|E| attempted swaps.
        let mut adj = g.clone();
        let mut es = edges.clone();
        let attempts = 10 * es.len();
        randomize_by_swaps(&mut adj, &mut es, attempts, rng);
        if adj.is_connected() {
            return adj;
        }
    }
    // Extremely unlikely fallback: the deterministic circulant itself.
    g
}

/// Degree-preserving double-edge swaps: pick edges (a,b), (c,d) and
/// rewire to (a,d), (c,b) when that keeps the graph simple.
fn randomize_by_swaps(
    g: &mut Graph,
    edges: &mut [(usize, usize)],
    attempts: usize,
    rng: &mut Xoshiro256pp,
) {
    let m = edges.len();
    for _ in 0..attempts {
        let i = rng.index(m);
        let j = rng.index(m);
        if i == j {
            continue;
        }
        let (a, b) = edges[i];
        let (c, d) = edges[j];
        // Orient the second edge randomly for unbiased mixing.
        let (c, d) = if rng.next_u64() & 1 == 0 { (c, d) } else { (d, c) };
        if a == c || a == d || b == c || b == d {
            continue;
        }
        if g.has_edge(a, d) || g.has_edge(c, b) {
            continue;
        }
        g.remove_edge(a, b);
        g.remove_edge(c, d);
        g.add_edge(a, d);
        g.add_edge(c, b);
        edges[i] = (a.min(d), a.max(d));
        edges[j] = (c.min(b), c.max(b));
    }
}

/// Erdős–Rényi G(n, p), retried until connected.
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Xoshiro256pp) -> Graph {
    assert!((0.0..=1.0).contains(&p));
    for _ in 0..10_000 {
        let mut g = Graph::empty(n);
        for u in 0..n {
            for v in (u + 1)..n {
                if rng.next_f64() < p {
                    g.add_edge(u, v);
                }
            }
        }
        if g.is_connected() {
            return g;
        }
    }
    panic!("erdos_renyi({n}, {p}): failed to sample a connected graph");
}

/// Cycle graph (2-regular).
pub fn ring(n: usize) -> Graph {
    regular_circulant(n, 2)
}

/// Star graph: node 0 is the hub — the paper's server-worker strawman
/// expressed as a topology.
pub fn star(n: usize) -> Graph {
    assert!(n >= 2);
    let mut g = Graph::empty(n);
    for i in 1..n {
        g.add_edge(0, i);
    }
    g
}

/// Complete graph K_n.
pub fn complete(n: usize) -> Graph {
    let mut g = Graph::empty(n);
    for u in 0..n {
        for v in (u + 1)..n {
            g.add_edge(u, v);
        }
    }
    g
}

/// Two dense clusters joined by a single bridge edge — a worst-case
/// bottleneck topology for consensus (ablation).
pub fn two_clusters(cluster: usize) -> Graph {
    assert!(cluster >= 2);
    let n = cluster * 2;
    let mut g = Graph::empty(n);
    for u in 0..cluster {
        for v in (u + 1)..cluster {
            g.add_edge(u, v);
            g.add_edge(cluster + u, cluster + v);
        }
    }
    g.add_edge(cluster - 1, cluster); // the bridge
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn circulant_matches_paper_settings() {
        // The paper's topologies: 4-regular and 15-regular on 30 nodes,
        // 2-regular and 10-regular on 30 nodes.
        for k in [2, 4, 10, 15] {
            let g = regular_circulant(30, k);
            assert_eq!(g.is_regular(), Some(k), "k={k}");
            assert!(g.is_connected(), "k={k}");
        }
    }

    #[test]
    fn circulant_small_and_complete_limit() {
        let g = regular_circulant(4, 3); // K4
        assert_eq!(g.edge_count(), 6);
        assert_eq!(g.is_regular(), Some(3));
    }

    #[test]
    #[should_panic(expected = "even n")]
    fn odd_degree_odd_n_rejected() {
        regular_circulant(5, 3);
    }

    #[test]
    fn random_regular_is_regular_connected() {
        let mut rng = Xoshiro256pp::seeded(0);
        for &(n, k) in &[(10, 4), (30, 4), (30, 10), (12, 3)] {
            let g = random_regular(n, k, &mut rng);
            assert_eq!(g.is_regular(), Some(k), "n={n} k={k}");
            assert!(g.is_connected());
        }
    }

    #[test]
    fn erdos_renyi_connected() {
        let mut rng = Xoshiro256pp::seeded(1);
        let g = erdos_renyi(20, 0.3, &mut rng);
        assert!(g.is_connected());
        assert_eq!(g.len(), 20);
    }

    #[test]
    fn special_families() {
        assert_eq!(ring(6).is_regular(), Some(2));
        let s = star(5);
        assert_eq!(s.degree(0), 4);
        assert_eq!(s.degree(3), 1);
        assert!(s.is_connected());
        let k5 = complete(5);
        assert_eq!(k5.edge_count(), 10);
        assert_eq!(k5.diameter(), Some(1));
        let tc = two_clusters(4);
        assert!(tc.is_connected());
        assert_eq!(tc.len(), 8);
        assert_eq!(tc.edge_count(), 2 * 6 + 1);
    }
}
