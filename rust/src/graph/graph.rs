//! Core undirected-graph type.

use std::collections::VecDeque;

/// A simple undirected graph over nodes `0..n`, stored as sorted
/// adjacency lists. Self-loops and parallel edges are rejected.
#[derive(Clone, Debug)]
pub struct Graph {
    adj: Vec<Vec<usize>>,
}

impl Graph {
    /// Empty graph with `n` isolated nodes.
    pub fn empty(n: usize) -> Self {
        Self {
            adj: vec![Vec::new(); n],
        }
    }

    /// Build from an edge list (deduplicated, validated).
    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Self {
        let mut g = Self::empty(n);
        for &(u, v) in edges {
            g.add_edge(u, v);
        }
        g
    }

    /// Add an undirected edge; no-op if already present.
    pub fn add_edge(&mut self, u: usize, v: usize) {
        assert!(u < self.len() && v < self.len(), "edge out of range");
        assert_ne!(u, v, "self-loops are not allowed");
        if let Err(pos) = self.adj[u].binary_search(&v) {
            self.adj[u].insert(pos, v);
            let pos = self.adj[v].binary_search(&u).unwrap_err();
            self.adj[v].insert(pos, u);
        }
    }

    /// Remove an undirected edge; no-op if absent.
    pub fn remove_edge(&mut self, u: usize, v: usize) {
        if let Ok(pos) = self.adj[u].binary_search(&v) {
            self.adj[u].remove(pos);
            let pos = self.adj[v].binary_search(&u).unwrap();
            self.adj[v].remove(pos);
        }
    }

    pub fn len(&self) -> usize {
        self.adj.len()
    }

    pub fn is_empty(&self) -> bool {
        self.adj.is_empty()
    }

    /// Neighbors of `u` (sorted).
    pub fn neighbors(&self, u: usize) -> &[usize] {
        &self.adj[u]
    }

    pub fn degree(&self, u: usize) -> usize {
        self.adj[u].len()
    }

    pub fn max_degree(&self) -> usize {
        self.adj.iter().map(Vec::len).max().unwrap_or(0)
    }

    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(Vec::len).sum::<usize>() / 2
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.adj[u].binary_search(&v).is_ok()
    }

    /// The closed neighborhood {u} ∪ N(u), sorted.
    pub fn closed_neighborhood(&self, u: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.degree(u) + 1);
        let pos = self.adj[u].binary_search(&u).unwrap_err();
        out.extend_from_slice(&self.adj[u][..pos]);
        out.push(u);
        out.extend_from_slice(&self.adj[u][pos..]);
        out
    }

    /// True iff every node has the same degree `k` (k-regular).
    pub fn is_regular(&self) -> Option<usize> {
        let k = self.degree(0);
        self.adj.iter().all(|a| a.len() == k).then_some(k)
    }

    /// BFS connectivity test. Consensus constraints only imply global
    /// consensus on a connected graph (paper §III-A).
    pub fn is_connected(&self) -> bool {
        if self.len() == 0 {
            return true;
        }
        let mut seen = vec![false; self.len()];
        let mut queue = VecDeque::from([0usize]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &v in self.neighbors(u) {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == self.len()
    }

    /// Graph diameter via all-pairs BFS (∞ ⇒ None).
    pub fn diameter(&self) -> Option<usize> {
        let mut best = 0;
        for s in 0..self.len() {
            let dist = self.bfs_distances(s);
            for d in &dist {
                match d {
                    None => return None,
                    Some(d) => best = best.max(*d),
                }
            }
        }
        Some(best)
    }

    /// Single-source BFS distances.
    pub fn bfs_distances(&self, source: usize) -> Vec<Option<usize>> {
        let mut dist = vec![None; self.len()];
        dist[source] = Some(0);
        let mut queue = VecDeque::from([source]);
        while let Some(u) = queue.pop_front() {
            let du = dist[u].unwrap();
            for &v in self.neighbors(u) {
                if dist[v].is_none() {
                    dist[v] = Some(du + 1);
                    queue.push_back(v);
                }
            }
        }
        dist
    }

    /// Do `u` and `v` conflict under §IV-C (adjacent or sharing a
    /// neighbor, i.e. their closed neighborhoods intersect)?
    pub fn closed_neighborhoods_intersect(&self, u: usize, v: usize) -> bool {
        if u == v || self.has_edge(u, v) {
            return true;
        }
        // Sorted-list intersection of N(u) and {v} ∪ N(v).
        let (a, b) = (self.neighbors(u), self.neighbors(v));
        let (mut i, mut j) = (0, 0);
        while i < a.len() && j < b.len() {
            match a[i].cmp(&b[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => return true,
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Graph {
        Graph::from_edges(3, &[(0, 1), (1, 2)])
    }

    #[test]
    fn basic_structure() {
        let g = path3();
        assert_eq!(g.len(), 3);
        assert_eq!(g.edge_count(), 2);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(0, 2));
    }

    #[test]
    fn duplicate_edges_ignored() {
        let g = Graph::from_edges(2, &[(0, 1), (1, 0), (0, 1)]);
        assert_eq!(g.edge_count(), 1);
    }

    #[test]
    #[should_panic(expected = "self-loops")]
    fn rejects_self_loop() {
        let mut g = Graph::empty(2);
        g.add_edge(1, 1);
    }

    #[test]
    fn closed_neighborhood_sorted_and_includes_self() {
        let g = path3();
        assert_eq!(g.closed_neighborhood(1), vec![0, 1, 2]);
        assert_eq!(g.closed_neighborhood(0), vec![0, 1]);
    }

    #[test]
    fn connectivity_and_diameter() {
        let g = path3();
        assert!(g.is_connected());
        assert_eq!(g.diameter(), Some(2));
        let disconnected = Graph::from_edges(4, &[(0, 1), (2, 3)]);
        assert!(!disconnected.is_connected());
        assert_eq!(disconnected.diameter(), None);
    }

    #[test]
    fn regularity() {
        let ring = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        assert_eq!(ring.is_regular(), Some(2));
        assert_eq!(path3().is_regular(), None);
    }

    #[test]
    fn conflict_detection() {
        // 0-1-2-3 path: 0 and 2 share neighbor 1 → conflict; 0 and 3 do not.
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert!(g.closed_neighborhoods_intersect(0, 1)); // adjacent
        assert!(g.closed_neighborhoods_intersect(0, 2)); // shared neighbor
        assert!(!g.closed_neighborhoods_intersect(0, 3)); // disjoint
        assert!(g.closed_neighborhoods_intersect(2, 2)); // same node
    }
}
