//! Undirected-graph substrate: the network topology of the paper.
//!
//! Provides the adjacency structure, topology generators (§V uses
//! k-regular graphs; we add more families for ablations), BFS-based
//! structural properties, and the spectral analysis behind Lemma 1.

mod generators;
mod graph;
pub mod spectral;

pub use generators::{
    complete, erdos_renyi, random_regular, regular_circulant, ring, star, two_clusters,
};
pub use graph::Graph;
