//! Spectral analysis of the local-averaging matrix — the Lemma 1 substrate.
//!
//! The paper defines `A = [a_ij]` with `a_ij = 1/(1+|N_i|)` for
//! `j ∈ {i} ∪ N_i` (row-stochastic local averaging). Lemma 1 bounds the
//! linear-regularity constant of the consensus polytope for a k-regular
//! graph by `η ≥ (1 − σ₂²) (k+1)/N`, where σ₂ is the second-largest
//! singular value of A. For k-regular graphs A is symmetric (hence σ₂ =
//! |λ₂|) and doubly stochastic, with top eigenvector 𝟙/√N.
//!
//! σ₂ is computed by power iteration on `A` restricted to the complement
//! of the consensus direction (deflating the known top eigenpair), which
//! is exact for the symmetric case and a good estimate otherwise.

use super::Graph;
use crate::linalg::Matrix;

/// Build the local-averaging matrix A of the paper (§III-C).
pub fn averaging_matrix(g: &Graph) -> Matrix {
    let n = g.len();
    let mut a = Matrix::zeros(n, n);
    for i in 0..n {
        let w = 1.0 / (1.0 + g.degree(i) as f32);
        a[(i, i)] = w;
        for &j in g.neighbors(i) {
            a[(i, j)] = w;
        }
    }
    a
}

/// Second-largest singular value of the averaging matrix.
///
/// Power iteration on `B = A^T A` with the consensus direction deflated:
/// every iterate is re-orthogonalized against 𝟙 (the top right-singular
/// vector for doubly-stochastic A; for non-regular graphs A is only
/// row-stochastic and we deflate the numerically-computed top vector
/// instead).
pub fn sigma2(g: &Graph, iters: usize) -> f64 {
    let a = averaging_matrix(g);
    let n = g.len();
    if n < 2 {
        return 0.0;
    }

    // Top singular pair of A via power iteration on A^T A.
    let (s1_sq, v1) = top_eig_ata(&a, None, iters);
    let _ = s1_sq; // s1 = 1 for doubly-stochastic A; not needed below.

    // Second pair: deflate v1.
    let (s2_sq, _) = top_eig_ata(&a, Some(&v1), iters);
    s2_sq.max(0.0).sqrt()
}

/// Largest eigenpair of A^T A, optionally deflating a known eigenvector.
fn top_eig_ata(a: &Matrix, deflate: Option<&[f32]>, iters: usize) -> (f64, Vec<f32>) {
    let n = a.rows();
    // Deterministic, non-degenerate start vector.
    let mut v: Vec<f32> = (0..n).map(|i| 1.0 + (i as f32 * 0.7).sin()).collect();
    if let Some(d) = deflate {
        orthogonalize(&mut v, d);
    }
    normalize(&mut v);
    let mut lambda = 0.0f64;
    for _ in 0..iters {
        // w = A^T (A v)
        let av = a.matvec(&v);
        let mut w = a.matvec_t(&av);
        if let Some(d) = deflate {
            orthogonalize(&mut w, d);
        }
        lambda = w.iter().zip(&v).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
        let norm = crate::linalg::norm2(&w);
        if norm < 1e-30 {
            return (0.0, v);
        }
        for x in &mut w {
            *x /= norm;
        }
        v = w;
    }
    (lambda, v)
}

fn orthogonalize(v: &mut [f32], against: &[f32]) {
    let dot = crate::linalg::dot(v, against);
    let nrm = crate::linalg::dot(against, against);
    if nrm > 0.0 {
        crate::linalg::axpy(-dot / nrm, against, v);
    }
}

fn normalize(v: &mut [f32]) {
    let n = crate::linalg::norm2(v);
    if n > 0.0 {
        for x in v {
            *x /= n;
        }
    }
}

/// Lemma 1 lower bound on the linear-regularity constant η for a
/// k-regular graph: `η ≥ (1 − σ₂²)(k+1)/N`.
pub fn lemma1_eta_lower_bound(g: &Graph) -> f64 {
    let k = g
        .is_regular()
        .expect("Lemma 1 bound is stated for regular graphs");
    let s2 = sigma2(g, 200);
    (1.0 - s2 * s2) * (k as f64 + 1.0) / g.len() as f64
}

/// The convergence constant `C = η/N` of Theorem 2, using the Lemma 1
/// bound for η. Larger C ⇒ faster DF contraction `(1 − C/4)`.
pub fn theorem2_c_bound(g: &Graph) -> f64 {
    lemma1_eta_lower_bound(g) / g.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{complete, regular_circulant, ring};

    #[test]
    fn averaging_matrix_rows_sum_to_one() {
        let g = regular_circulant(10, 4);
        let a = averaging_matrix(&g);
        for i in 0..10 {
            let s: f32 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "row {i} sums to {s}");
        }
    }

    #[test]
    fn averaging_matrix_symmetric_for_regular() {
        let g = regular_circulant(12, 4);
        let a = averaging_matrix(&g);
        for i in 0..12 {
            for j in 0..12 {
                assert!((a[(i, j)] - a[(j, i)]).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn complete_graph_sigma2_is_zero() {
        // A(K_n) = (1/n) 𝟙𝟙^T: rank one, σ₂ = 0.
        let g = complete(8);
        let s2 = sigma2(&g, 100);
        assert!(s2 < 1e-3, "sigma2={s2}");
    }

    #[test]
    fn ring_sigma2_matches_closed_form() {
        // Ring averaging A = (I + C + C^T)/3: eigenvalues
        // (1 + 2cos(2πj/n))/3 → σ₂ = (1 + 2cos(2π/n))/3.
        let n = 16;
        let g = ring(n);
        let s2 = sigma2(&g, 400);
        let expect = (1.0 + 2.0 * (2.0 * std::f64::consts::PI / n as f64).cos()) / 3.0;
        assert!((s2 - expect).abs() < 1e-3, "s2={s2} expect={expect}");
    }

    #[test]
    fn sigma2_decreases_with_connectivity() {
        // Paper remark (b): denser graph ⇒ smaller σ₂ ⇒ faster convergence.
        let s_sparse = sigma2(&regular_circulant(30, 4), 300);
        let s_dense = sigma2(&regular_circulant(30, 14), 300);
        assert!(
            s_dense < s_sparse,
            "sigma2 dense={s_dense} sparse={s_sparse}"
        );
    }

    #[test]
    fn lemma1_bound_ordering_matches_paper() {
        // Larger k ⇒ larger η bound (paper Remark (a)).
        let eta4 = lemma1_eta_lower_bound(&regular_circulant(30, 4));
        let eta14 = lemma1_eta_lower_bound(&regular_circulant(30, 14));
        assert!(eta14 > eta4, "eta14={eta14} eta4={eta4}");
        // And the bound lives in (0, 1].
        assert!(eta4 > 0.0 && eta4 <= 1.0);
        // Smaller N ⇒ larger bound at equal k.
        let eta_small = lemma1_eta_lower_bound(&regular_circulant(10, 4));
        assert!(eta_small > eta4);
    }

    #[test]
    fn theorem2_c_is_eta_over_n() {
        let g = regular_circulant(20, 4);
        let c = theorem2_c_bound(&g);
        let eta = lemma1_eta_lower_bound(&g);
        assert!((c - eta / 20.0).abs() < 1e-12);
    }
}
