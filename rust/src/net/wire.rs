//! Length-prefixed binary wire codec for the SocketNet deployment.
//!
//! One frame on the wire is
//!
//! ```text
//! [len: u32 LE] [version: u8] [tag: u8] [body ...]
//! ```
//!
//! where `len` counts everything after the length prefix. The message
//! set is the ChannelNet projection protocol (`CollectRequest` /
//! `CollectReply` / `Busy` / `Abort` / `ApplyAverage`) plus the control
//! plane (`Hello` / `Heartbeat` / `SnapshotRequest` / `SnapshotReply` /
//! `Shutdown`) and the workload-plan shipping frames (`PlanAssign` /
//! `PlanStart` — real data shards travel to workers, see
//! docs/heterogeneity.md). All integers are little-endian; `f32`
//! vectors are raw LE bit patterns (NaN-safe round trips).
//!
//! Decoding is total: malformed input — truncated bodies, unknown
//! versions or tags, length prefixes that would allocate more than
//! [`MAX_FRAME_LEN`], trailing garbage — returns a [`WireError`], never
//! panics and never allocates proportionally to attacker-controlled
//! lengths beyond the frame cap.

use std::io::{Read, Write};

/// Codec version stamped into every frame. Bump on any layout change;
/// decoders reject mismatches outright (a deployment never mixes
/// versions — workers are all spawned from the same binary).
///
/// v2 added the workload-plan control frames
/// ([`PlanAssign`](WireMsg::PlanAssign) / [`PlanStart`](WireMsg::PlanStart)).
pub const WIRE_VERSION: u8 = 2;

/// Upper bound on one frame's payload (version + tag + body). A frame
/// carries at most one parameter vector per node of a snapshot shard;
/// 16 MiB is orders of magnitude above anything the system produces and
/// small enough that a garbage length prefix cannot balloon memory.
pub const MAX_FRAME_LEN: usize = 1 << 24;

/// The rank [`Hello`](WireMsg::Hello) uses to identify the monitor
/// (launcher) control connection rather than a worker peer.
pub const MONITOR_RANK: u32 = u32::MAX;

/// Everything that crosses a SocketNet TCP connection.
#[derive(Clone, Debug, PartialEq)]
pub enum WireMsg {
    /// First frame on every connection: who is dialing. Worker ranks
    /// are `0..workers`; [`MONITOR_RANK`] marks the launcher's control
    /// connection.
    Hello { rank: u32 },
    /// Periodic liveness beacon between worker peers.
    Heartbeat { rank: u32, seq: u64 },
    /// Initiator `from` asks member `to` to join projection round
    /// `token` (ChannelNet `Collect` over the wire).
    CollectRequest { from: u32, to: u32, token: u64 },
    /// Member `from` grants the round and ships its parameter vector
    /// (ChannelNet `Params`).
    CollectReply {
        from: u32,
        to: u32,
        token: u64,
        w: Vec<f32>,
    },
    /// Member `from` refuses: it is captured or itself initiating — the
    /// §IV-C lock-up expressed as a message.
    Busy { from: u32, to: u32, token: u64 },
    /// Initiator `from` aborts round `token`: member `to` drops its
    /// capture and keeps its value (ChannelNet `Release`).
    Abort { from: u32, to: u32, token: u64 },
    /// Initiator `from` completes round `token`: member `to` adopts the
    /// neighborhood average `w` and unlocks (ChannelNet `Apply`).
    ApplyAverage {
        from: u32,
        to: u32,
        token: u64,
        w: Vec<f32>,
    },
    /// Monitor → worker: report your shard.
    SnapshotRequest,
    /// Worker → monitor: cumulative counters in the canonical
    /// convention (`grad_steps`, `proj_steps`, `messages`, `conflicts`)
    /// plus every owned node's current parameter vector.
    SnapshotReply {
        rank: u32,
        counts: [u64; 4],
        params: Vec<(u32, Vec<f32>)>,
    },
    /// Monitor → worker: stop node threads and exit cleanly.
    Shutdown,
    /// Monitor → worker: one node's workload assignment — its §II
    /// objective (as a `(code, λ)` pair, see
    /// [`crate::workload::objective_code`]) plus its *actual* data
    /// shard, so workers never regenerate the global world from the
    /// seed. `features` is row-major `labels.len() × dim`.
    PlanAssign {
        node: u32,
        obj_code: u8,
        lam: f32,
        dim: u32,
        classes: u32,
        labels: Vec<u32>,
        features: Vec<f32>,
    },
    /// Monitor → worker: the plan is fully shipped (`assigned` frames
    /// for a `nodes`-node deployment); start driving the shard.
    /// `mixed` is the deployment-wide loss-family verdict — a worker's
    /// own slice can look homogeneous even when the system is mixed,
    /// and the per-family stepsize policy hangs on it.
    PlanStart {
        nodes: u32,
        assigned: u32,
        mixed: bool,
    },
}

impl WireMsg {
    fn tag(&self) -> u8 {
        match self {
            WireMsg::Hello { .. } => 0,
            WireMsg::Heartbeat { .. } => 1,
            WireMsg::CollectRequest { .. } => 2,
            WireMsg::CollectReply { .. } => 3,
            WireMsg::Busy { .. } => 4,
            WireMsg::Abort { .. } => 5,
            WireMsg::ApplyAverage { .. } => 6,
            WireMsg::SnapshotRequest => 7,
            WireMsg::SnapshotReply { .. } => 8,
            WireMsg::Shutdown => 9,
            WireMsg::PlanAssign { .. } => 10,
            WireMsg::PlanStart { .. } => 11,
        }
    }
}

/// Why a frame failed to decode (or a stream failed to deliver one).
#[derive(Debug)]
pub enum WireError {
    /// Stream-level failure (includes EOF mid-frame).
    Io(std::io::Error),
    /// The body ended before the fields it promises.
    Truncated,
    /// Version byte we do not speak.
    Version { got: u8 },
    /// Tag byte outside the message set.
    UnknownTag { got: u8 },
    /// Length prefix beyond [`MAX_FRAME_LEN`] (or an element count the
    /// remaining bytes cannot possibly hold).
    Oversize { len: usize },
    /// Bytes left over after the last field — the frame lied about its
    /// own layout.
    Trailing { extra: usize },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
            WireError::Truncated => write!(f, "frame body truncated"),
            WireError::Version { got } => {
                write!(f, "wire version {got} (this build speaks {WIRE_VERSION})")
            }
            WireError::UnknownTag { got } => write!(f, "unknown frame tag {got}"),
            WireError::Oversize { len } => {
                write!(f, "frame length {len} exceeds the {MAX_FRAME_LEN}-byte cap")
            }
            WireError::Trailing { extra } => {
                write!(f, "{extra} trailing bytes after the last field")
            }
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, w: &[f32]) {
    put_u32(buf, w.len() as u32);
    for &v in w {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_u32s(buf: &mut Vec<u8>, v: &[u32]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Serialize one message into a complete frame (length prefix included).
pub fn encode(msg: &WireMsg) -> Vec<u8> {
    let mut body = Vec::with_capacity(32);
    body.push(WIRE_VERSION);
    body.push(msg.tag());
    match msg {
        WireMsg::Hello { rank } => put_u32(&mut body, *rank),
        WireMsg::Heartbeat { rank, seq } => {
            put_u32(&mut body, *rank);
            put_u64(&mut body, *seq);
        }
        WireMsg::CollectRequest { from, to, token }
        | WireMsg::Busy { from, to, token }
        | WireMsg::Abort { from, to, token } => {
            put_u32(&mut body, *from);
            put_u32(&mut body, *to);
            put_u64(&mut body, *token);
        }
        WireMsg::CollectReply { from, to, token, w }
        | WireMsg::ApplyAverage { from, to, token, w } => {
            put_u32(&mut body, *from);
            put_u32(&mut body, *to);
            put_u64(&mut body, *token);
            put_f32s(&mut body, w);
        }
        WireMsg::SnapshotRequest | WireMsg::Shutdown => {}
        WireMsg::SnapshotReply {
            rank,
            counts,
            params,
        } => {
            put_u32(&mut body, *rank);
            for &c in counts {
                put_u64(&mut body, c);
            }
            put_u32(&mut body, params.len() as u32);
            for (node, w) in params {
                put_u32(&mut body, *node);
                put_f32s(&mut body, w);
            }
        }
        WireMsg::PlanAssign {
            node,
            obj_code,
            lam,
            dim,
            classes,
            labels,
            features,
        } => {
            put_u32(&mut body, *node);
            body.push(*obj_code);
            put_f32(&mut body, *lam);
            put_u32(&mut body, *dim);
            put_u32(&mut body, *classes);
            put_u32s(&mut body, labels);
            put_f32s(&mut body, features);
        }
        WireMsg::PlanStart {
            nodes,
            assigned,
            mixed,
        } => {
            put_u32(&mut body, *nodes);
            put_u32(&mut body, *assigned);
            body.push(u8::from(*mixed));
        }
    }
    debug_assert!(body.len() <= MAX_FRAME_LEN);
    let mut frame = Vec::with_capacity(4 + body.len());
    put_u32(&mut frame, body.len() as u32);
    frame.extend_from_slice(&body);
    frame
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Bounds-checked reader over a frame body.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated);
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// A length-prefixed u32 vector, count-validated before allocation
    /// (same discipline as [`Cursor::f32s`]).
    fn u32s(&mut self) -> Result<Vec<u32>, WireError> {
        let count = self.u32()? as usize;
        if count.checked_mul(4).map(|b| b > self.remaining()).unwrap_or(true) {
            return Err(WireError::Oversize { len: count });
        }
        let bytes = self.take(count * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// A length-prefixed f32 vector. The count is validated against the
    /// bytes actually remaining before any allocation, so a garbage
    /// count cannot balloon memory.
    fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let count = self.u32()? as usize;
        if count.checked_mul(4).map(|b| b > self.remaining()).unwrap_or(true) {
            return Err(WireError::Oversize { len: count });
        }
        let bytes = self.take(count * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn done(&self) -> Result<(), WireError> {
        match self.remaining() {
            0 => Ok(()),
            extra => Err(WireError::Trailing { extra }),
        }
    }
}

/// Decode one frame *body* (the bytes after the length prefix).
pub fn decode_body(body: &[u8]) -> Result<WireMsg, WireError> {
    let mut c = Cursor::new(body);
    let version = c.u8()?;
    if version != WIRE_VERSION {
        return Err(WireError::Version { got: version });
    }
    let tag = c.u8()?;
    let msg = match tag {
        0 => WireMsg::Hello { rank: c.u32()? },
        1 => WireMsg::Heartbeat {
            rank: c.u32()?,
            seq: c.u64()?,
        },
        2 => WireMsg::CollectRequest {
            from: c.u32()?,
            to: c.u32()?,
            token: c.u64()?,
        },
        3 => WireMsg::CollectReply {
            from: c.u32()?,
            to: c.u32()?,
            token: c.u64()?,
            w: c.f32s()?,
        },
        4 => WireMsg::Busy {
            from: c.u32()?,
            to: c.u32()?,
            token: c.u64()?,
        },
        5 => WireMsg::Abort {
            from: c.u32()?,
            to: c.u32()?,
            token: c.u64()?,
        },
        6 => WireMsg::ApplyAverage {
            from: c.u32()?,
            to: c.u32()?,
            token: c.u64()?,
            w: c.f32s()?,
        },
        7 => WireMsg::SnapshotRequest,
        8 => {
            let rank = c.u32()?;
            let mut counts = [0u64; 4];
            for slot in &mut counts {
                *slot = c.u64()?;
            }
            let n = c.u32()? as usize;
            // Each entry needs at least a node id + an (empty) vector
            // count: 8 bytes. Reject counts the body cannot hold.
            if n.checked_mul(8).map(|b| b > c.remaining()).unwrap_or(true) {
                return Err(WireError::Oversize { len: n });
            }
            let mut params = Vec::with_capacity(n);
            for _ in 0..n {
                let node = c.u32()?;
                params.push((node, c.f32s()?));
            }
            WireMsg::SnapshotReply {
                rank,
                counts,
                params,
            }
        }
        9 => WireMsg::Shutdown,
        10 => WireMsg::PlanAssign {
            node: c.u32()?,
            obj_code: c.u8()?,
            lam: c.f32()?,
            dim: c.u32()?,
            classes: c.u32()?,
            labels: c.u32s()?,
            features: c.f32s()?,
        },
        11 => WireMsg::PlanStart {
            nodes: c.u32()?,
            assigned: c.u32()?,
            mixed: c.u8()? != 0,
        },
        got => return Err(WireError::UnknownTag { got }),
    };
    c.done()?;
    Ok(msg)
}

/// Decode from a growing byte buffer (e.g. accumulated TCP reads).
/// Returns `Ok(None)` when `buf` holds only a prefix of a frame (read
/// more and retry), `Ok(Some((msg, consumed)))` on success, and an
/// error for malformed input.
pub fn decode(buf: &[u8]) -> Result<Option<(WireMsg, usize)>, WireError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_LEN {
        return Err(WireError::Oversize { len });
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    let msg = decode_body(&buf[4..4 + len])?;
    Ok(Some((msg, 4 + len)))
}

/// Write one frame to a blocking stream.
pub fn write_frame(w: &mut impl Write, msg: &WireMsg) -> Result<(), WireError> {
    w.write_all(&encode(msg))?;
    w.flush()?;
    Ok(())
}

/// Read exactly one frame from a blocking stream. EOF or a timeout
/// mid-frame surfaces as [`WireError::Io`].
pub fn read_frame(r: &mut impl Read) -> Result<WireMsg, WireError> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes)?;
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len > MAX_FRAME_LEN {
        return Err(WireError::Oversize { len });
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode_body(&body)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: WireMsg) {
        let frame = encode(&msg);
        let (back, consumed) = decode(&frame).unwrap().expect("complete frame");
        assert_eq!(consumed, frame.len());
        assert_eq!(back, msg);
        // The streaming reader agrees.
        let mut cursor = std::io::Cursor::new(frame);
        assert_eq!(read_frame(&mut cursor).unwrap(), msg);
    }

    #[test]
    fn every_variant_round_trips() {
        roundtrip(WireMsg::Hello { rank: 3 });
        roundtrip(WireMsg::Hello { rank: MONITOR_RANK });
        roundtrip(WireMsg::Heartbeat { rank: 0, seq: u64::MAX });
        roundtrip(WireMsg::CollectRequest {
            from: 7,
            to: 12,
            token: 99,
        });
        roundtrip(WireMsg::CollectReply {
            from: 12,
            to: 7,
            token: 99,
            w: vec![1.0, -2.5, 0.0],
        });
        roundtrip(WireMsg::CollectReply {
            from: 0,
            to: 1,
            token: 0,
            w: vec![],
        });
        roundtrip(WireMsg::Busy {
            from: 2,
            to: 3,
            token: 5,
        });
        roundtrip(WireMsg::Abort {
            from: 4,
            to: 5,
            token: 6,
        });
        roundtrip(WireMsg::ApplyAverage {
            from: 1,
            to: 2,
            token: 3,
            w: vec![0.25; 200],
        });
        roundtrip(WireMsg::SnapshotRequest);
        roundtrip(WireMsg::SnapshotReply {
            rank: 1,
            counts: [10, 20, 30, 40],
            params: vec![(4, vec![1.5, 2.5]), (5, vec![])],
        });
        roundtrip(WireMsg::Shutdown);
        roundtrip(WireMsg::PlanAssign {
            node: 6,
            obj_code: 2,
            lam: 1e-3,
            dim: 3,
            classes: 4,
            labels: vec![0, 3, 1],
            features: vec![0.5; 9],
        });
        roundtrip(WireMsg::PlanAssign {
            node: 0,
            obj_code: 0,
            lam: 0.0,
            dim: 50,
            classes: 10,
            labels: vec![],
            features: vec![],
        });
        roundtrip(WireMsg::PlanStart {
            nodes: 8,
            assigned: 4,
            mixed: true,
        });
        roundtrip(WireMsg::PlanStart {
            nodes: 2,
            assigned: 1,
            mixed: false,
        });
    }

    #[test]
    fn plan_assign_label_count_is_bounded() {
        // A lying label count must refuse before allocating.
        let mut body = vec![WIRE_VERSION, 10]; // PlanAssign
        body.extend_from_slice(&0u32.to_le_bytes()); // node
        body.push(1); // obj_code
        body.extend_from_slice(&0.0f32.to_le_bytes()); // lam
        body.extend_from_slice(&3u32.to_le_bytes()); // dim
        body.extend_from_slice(&2u32.to_le_bytes()); // classes
        body.extend_from_slice(&(500_000u32).to_le_bytes()); // labels count, no data
        let mut frame = Vec::new();
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        assert!(matches!(decode(&frame), Err(WireError::Oversize { .. })));
    }

    #[test]
    fn nan_and_infinity_survive_by_bits() {
        let w = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0];
        let frame = encode(&WireMsg::CollectReply {
            from: 0,
            to: 1,
            token: 2,
            w: w.clone(),
        });
        let (back, _) = decode(&frame).unwrap().unwrap();
        let WireMsg::CollectReply { w: got, .. } = back else {
            panic!("wrong variant");
        };
        let bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = w.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, want);
    }

    #[test]
    fn incomplete_prefixes_ask_for_more() {
        let frame = encode(&WireMsg::Heartbeat { rank: 1, seq: 2 });
        for cut in 0..frame.len() {
            assert!(
                decode(&frame[..cut]).unwrap().is_none(),
                "prefix of {cut} bytes should be incomplete, not an error"
            );
        }
    }

    #[test]
    fn malformed_frames_error_not_panic() {
        // Wrong version.
        let mut frame = encode(&WireMsg::Shutdown);
        frame[4] = WIRE_VERSION + 1;
        assert!(matches!(
            decode(&frame),
            Err(WireError::Version { .. })
        ));
        // Unknown tag.
        let mut frame = encode(&WireMsg::Shutdown);
        frame[5] = 200;
        assert!(matches!(decode(&frame), Err(WireError::UnknownTag { got: 200 })));
        // Body shorter than the fields it promises.
        let good = encode(&WireMsg::Heartbeat { rank: 1, seq: 2 });
        let mut lying = good.clone();
        lying[0..4].copy_from_slice(&((good.len() as u32) - 4 - 3).to_le_bytes());
        assert!(matches!(
            decode(&lying[..lying.len() - 3]),
            Err(WireError::Truncated)
        ));
        // Trailing garbage inside the declared frame length.
        let mut padded = encode(&WireMsg::Shutdown);
        padded.extend_from_slice(&[0xAA, 0xBB]);
        padded[0..4].copy_from_slice(&4u32.to_le_bytes()); // version+tag+2 junk
        assert!(matches!(decode(&padded), Err(WireError::Trailing { extra: 2 })));
        // Oversize length prefix refuses before allocating.
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        huge.extend_from_slice(&[WIRE_VERSION, 0]);
        assert!(matches!(decode(&huge), Err(WireError::Oversize { .. })));
        // Vector count larger than the remaining bytes.
        let mut body = vec![WIRE_VERSION, 3]; // CollectReply
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&2u64.to_le_bytes());
        body.extend_from_slice(&(1_000_000u32).to_le_bytes()); // count, no data
        let mut frame = Vec::new();
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        assert!(matches!(decode(&frame), Err(WireError::Oversize { .. })));
    }

    #[test]
    fn two_frames_in_one_buffer_decode_in_order() {
        let mut buf = encode(&WireMsg::Hello { rank: 9 });
        buf.extend_from_slice(&encode(&WireMsg::SnapshotRequest));
        let (first, used) = decode(&buf).unwrap().unwrap();
        assert_eq!(first, WireMsg::Hello { rank: 9 });
        let (second, used2) = decode(&buf[used..]).unwrap().unwrap();
        assert_eq!(second, WireMsg::SnapshotRequest);
        assert_eq!(used + used2, buf.len());
    }
}
