//! Length-prefixed binary wire codec for the SocketNet deployment.
//!
//! One frame on the wire is
//!
//! ```text
//! [len: u32 LE] [version: u8] [tag: u8] [body ...]
//! ```
//!
//! where `len` counts everything after the length prefix. The message
//! set is the ChannelNet projection protocol (`CollectRequest` /
//! `CollectReply` / `Busy` / `Abort` / `ApplyAverage`) plus the control
//! plane (`Hello` / `Heartbeat` / `SnapshotRequest` / `SnapshotReply` /
//! `Shutdown`), the workload-plan shipping frames (`PlanAssign` /
//! `PlanStart` — real data shards travel to workers, see
//! docs/heterogeneity.md), the streaming data plane (`ShardBlock` /
//! `ShardComplete` / `ShardCredit` — row blocks of a shard ship
//! incrementally under backpressure credits, see docs/data.md), the
//! chunk envelope (`ChunkBegin` / `ChunkData` / `ChunkEnd`), the
//! batch envelope (`Batch` — several small logical messages coalesced
//! into one frame, see docs/deployment.md), and the elastic-membership
//! protocol (`JoinRequest` / `JoinGrant` / `JoinReady` / `PeerUpdate` /
//! `LeaveNotice` / `TopologyPatch` / `HandoffBegin` / `HandoffEnd` —
//! workers join and leave mid-run, see docs/membership.md). All
//! integers are
//! little-endian; `f32` vectors are raw LE bit patterns (NaN-safe round
//! trips).
//!
//! # Logical messages vs frames
//!
//! A *frame* is capped at [`MAX_FRAME_LEN`] so a garbage length prefix
//! can never balloon memory. A *logical message* may be far larger (a
//! quantity-skewed data shard easily is): [`encode_message`] splits any
//! message whose body exceeds the frame cap into an ordered
//! `ChunkBegin{total_bytes, chunk_count}` / `ChunkData`⋯ /
//! `ChunkEnd{checksum}` envelope, and the receiving side's
//! [`ChunkAssembler`] reassembles it with bounded staging (at most
//! [`MAX_MESSAGE_LEN`] bytes, allocated only as real bytes arrive).
//! Messages that fit one frame pass through the assembler untouched, so
//! every connection can simply route *all* inbound frames through one
//! per-peer assembler.
//!
//! Decoding is total at both layers: malformed input — truncated
//! bodies, unknown versions or tags, length prefixes beyond the caps,
//! trailing garbage, interleaved or short chunk streams, checksum
//! mismatches — returns a [`WireError`], never panics and never
//! desyncs silently (the caller drops the connection on error).
//!
//! Encoding is total too: element counts are converted with
//! `u32::try_from` and a body that cannot fit its framing returns
//! [`WireError::Oversize`] instead of silently truncating a length.

use std::io::{Read, Write};

/// Codec version stamped into every frame. Bump on any layout change;
/// decoders reject mismatches outright (a deployment never mixes
/// versions — workers are all spawned from the same binary).
///
/// v2 added the workload-plan control frames
/// ([`PlanAssign`](WireMsg::PlanAssign) / [`PlanStart`](WireMsg::PlanStart)).
/// v3 added the chunk envelope ([`ChunkBegin`](WireMsg::ChunkBegin) /
/// [`ChunkData`](WireMsg::ChunkData) / [`ChunkEnd`](WireMsg::ChunkEnd))
/// and the plan-integrity checksum on `PlanStart`.
/// v4 added the streaming data plane
/// ([`ShardBlock`](WireMsg::ShardBlock) /
/// [`ShardComplete`](WireMsg::ShardComplete) /
/// [`ShardCredit`](WireMsg::ShardCredit)), the `streaming` flag on
/// `PlanStart`, and the stream-status fields on `SnapshotReply`.
/// v5 added the [`Batch`](WireMsg::Batch) envelope — the per-peer send
/// coalescer ships many small protocol frames as one wire write.
/// v6 added the observability control frames
/// ([`MetricsRequest`](WireMsg::MetricsRequest) /
/// [`MetricsReply`](WireMsg::MetricsReply)) — the monitor polls every
/// worker's [`crate::obs`] registry snapshot and aggregates a
/// cluster-wide view (see docs/observability.md).
/// v7 added the elastic-membership frames
/// ([`JoinRequest`](WireMsg::JoinRequest) /
/// [`JoinGrant`](WireMsg::JoinGrant) / [`JoinReady`](WireMsg::JoinReady) /
/// [`PeerUpdate`](WireMsg::PeerUpdate) / [`LeaveNotice`](WireMsg::LeaveNotice) /
/// [`TopologyPatch`](WireMsg::TopologyPatch) /
/// [`HandoffBegin`](WireMsg::HandoffBegin) /
/// [`HandoffEnd`](WireMsg::HandoffEnd)) — workers join and leave a
/// running deployment, with topology repair and checksummed state
/// handoff (see docs/membership.md).
/// v8 added the strategy plumbing (see docs/algorithms.md): an opaque
/// per-node aux blob on [`CollectReply`](WireMsg::CollectReply) and
/// [`ApplyAverage`](WireMsg::ApplyAverage) (gradient-tracking strategies
/// gossip their tracker beside `w`; empty for the baseline — zero extra
/// bytes) and a strategy code on [`PlanAssign`](WireMsg::PlanAssign)
/// and [`JoinGrant`](WireMsg::JoinGrant) so every worker drives the
/// node update rule the launcher planned.
pub const WIRE_VERSION: u8 = 8;

/// Upper bound on one frame's payload (version + tag + body). Small
/// enough that a garbage length prefix cannot balloon memory; logical
/// messages larger than this ride the chunk envelope.
pub const MAX_FRAME_LEN: usize = 1 << 24;

/// Payload bytes carried by one [`ChunkData`](WireMsg::ChunkData)
/// frame. Well under [`MAX_FRAME_LEN`] so chunk frames themselves never
/// need chunking, and small enough that per-frame write timeouts stay
/// meaningful on slow links.
pub const CHUNK_PAYLOAD: usize = 1 << 22;

/// Upper bound on one *logical* message (the chunk reassembly cap).
/// 1 GiB: orders of magnitude above any realistic shard while still
/// bounding what a hostile `ChunkBegin` can make a peer stage.
pub const MAX_MESSAGE_LEN: usize = 1 << 30;

/// The rank [`Hello`](WireMsg::Hello) uses to identify the monitor
/// (launcher) control connection rather than a worker peer.
pub const MONITOR_RANK: u32 = u32::MAX;

/// Everything that crosses a SocketNet TCP connection.
#[derive(Clone, Debug, PartialEq)]
pub enum WireMsg {
    /// First frame on every connection: who is dialing. Worker ranks
    /// are `0..workers`; [`MONITOR_RANK`] marks the launcher's control
    /// connection.
    Hello { rank: u32 },
    /// Periodic liveness beacon between worker peers.
    Heartbeat { rank: u32, seq: u64 },
    /// Initiator `from` asks member `to` to join projection round
    /// `token` (ChannelNet `Collect` over the wire).
    CollectRequest { from: u32, to: u32, token: u64 },
    /// Member `from` grants the round and ships its parameter vector
    /// plus its opaque strategy aux blob (ChannelNet `Params`). The
    /// blob is whatever the node's strategy published — a gossiped
    /// gradient tracker, or empty for the baseline.
    CollectReply {
        from: u32,
        to: u32,
        token: u64,
        w: Vec<f32>,
        aux: Vec<u8>,
    },
    /// Member `from` refuses: it is captured or itself initiating — the
    /// §IV-C lock-up expressed as a message.
    Busy { from: u32, to: u32, token: u64 },
    /// Initiator `from` aborts round `token`: member `to` drops its
    /// capture and keeps its value (ChannelNet `Release`).
    Abort { from: u32, to: u32, token: u64 },
    /// Initiator `from` completes round `token`: member `to` adopts the
    /// mixed parameters `w` and strategy aux blob `aux` and unlocks
    /// (ChannelNet `Apply`).
    ApplyAverage {
        from: u32,
        to: u32,
        token: u64,
        w: Vec<f32>,
        aux: Vec<u8>,
    },
    /// Monitor → worker: report your shard.
    SnapshotRequest,
    /// Worker → monitor: cumulative counters in the canonical
    /// convention (`grad_steps`, `proj_steps`, `messages`, `conflicts`)
    /// plus every owned node's current parameter vector. One logical
    /// message per request — the chunk envelope carries it when the
    /// shard outgrows a frame.
    SnapshotReply {
        rank: u32,
        counts: [u64; 4],
        params: Vec<(u32, Vec<f32>)>,
        /// High-water mark of bytes staged in the worker's streaming
        /// [`BlockBuffer`](crate::data::stream::BlockBuffer) — the peak,
        /// not the instantaneous level, so the monitor's max over all
        /// replies is the run's true staging peak (0 when the plan was
        /// not streamed).
        staging_bytes: u64,
        /// Every owned node's shard stream has completed (trivially true
        /// for non-streamed plans).
        stream_done: bool,
        /// The worker's applied-update count at the moment its last
        /// owned [`ShardComplete`](WireMsg::ShardComplete) validated —
        /// lets the monitor assert race-free that stepping started
        /// before the data finished arriving (`u64::MAX` until then).
        updates_at_stream_complete: u64,
    },
    /// Monitor → worker: stop node threads and exit cleanly.
    Shutdown,
    /// Monitor → worker: one node's workload assignment — its §II
    /// objective (as a `(code, λ)` pair, see
    /// [`crate::workload::objective_code`]) plus its *actual* data
    /// shard, so workers never regenerate the global world from the
    /// seed. `features` is row-major `labels.len() × dim`. `strategy`
    /// is the node's update-rule code (see
    /// [`crate::node_logic::StrategyKind::code`]). Ships chunked
    /// whenever the shard outgrows [`MAX_FRAME_LEN`].
    PlanAssign {
        node: u32,
        obj_code: u8,
        lam: f32,
        dim: u32,
        classes: u32,
        labels: Vec<u32>,
        features: Vec<f32>,
        strategy: u8,
    },
    /// Monitor → worker: the plan is fully shipped (`assigned` frames
    /// for a `nodes`-node deployment); start driving the shard.
    /// `mixed` is the deployment-wide loss-family verdict — a worker's
    /// own slice can look homogeneous even when the system is mixed,
    /// and the per-family stepsize policy hangs on it. `checksum` is
    /// the FNV-1a fold of every shipped assignment's
    /// [`message_checksum`] in ship order: the worker recomputes it
    /// over what actually arrived and refuses to start on a mismatch,
    /// so a run that starts certifies bit-identical delivery.
    PlanStart {
        nodes: u32,
        assigned: u32,
        mixed: bool,
        checksum: u64,
        /// When true the shipped `PlanAssign` frames carried metadata
        /// only (empty shards): the data itself follows as
        /// [`ShardBlock`](WireMsg::ShardBlock) streams and workers may
        /// start stepping as soon as their first block lands.
        streaming: bool,
    },
    /// Monitor → worker: one row block of node `node`'s shard, shipped
    /// in `seq` order (0-based, in-order per node; blocks of different
    /// nodes may interleave). Self-describing: `encoding` (currently
    /// only [`crate::data::stream::ENCODING_DENSE_F32`]), `rows`
    /// labeled rows of `dim` features each, and a per-block `checksum`
    /// ([`fnv1a64`] over the labels' LE bytes followed by the features'
    /// LE bytes) validated before any row is staged.
    ShardBlock {
        node: u32,
        seq: u32,
        encoding: u8,
        rows: u32,
        dim: u32,
        classes: u32,
        labels: Vec<u32>,
        features: Vec<f32>,
        checksum: u64,
    },
    /// Monitor → worker: node `node`'s stream is complete —
    /// `block_count` blocks totalling `total_rows` rows shipped, and
    /// `checksum` is the [`Fnv64`] fold over every block's payload
    /// bytes in `seq` order. The worker refuses the stream on any
    /// mismatch, so a completed stream certifies the reassembled shard
    /// bit-identical to the plan's.
    ShardComplete {
        node: u32,
        block_count: u32,
        total_rows: u64,
        checksum: u64,
    },
    /// Worker → monitor: backpressure credit — `bytes` of staged block
    /// payload were consumed by node threads, so the sender's flow
    /// window reopens by that much.
    ShardCredit { bytes: u64 },
    /// Chunk envelope: the next `chunk_count` [`ChunkData`] frames
    /// carry `total_bytes` bytes of one encoded logical message body.
    ChunkBegin { total_bytes: u64, chunk_count: u32 },
    /// One ordered slice of the in-flight chunked message.
    ChunkData { bytes: Vec<u8> },
    /// End of the chunked message; `checksum` is [`fnv1a64`] over the
    /// reassembled body.
    ChunkEnd { checksum: u64 },
    /// Batch envelope: several complete logical messages coalesced into
    /// one frame (the per-peer send coalescer's unit of work — many
    /// small projection-protocol frames become one wire write). Each
    /// entry is itself a full encoded body (version + tag + fields), so
    /// decoding is total per entry; chunk frames and nested batches are
    /// refused on both sides. Empty batches are malformed.
    Batch { msgs: Vec<WireMsg> },
    /// Monitor → worker: report your [`crate::obs`] metrics snapshot.
    MetricsRequest,
    /// Worker → monitor: the flattened metrics snapshot — `counters`
    /// is the counter values followed by the gauge values, `hist_data`
    /// is `(count, sum, 64 buckets)` per histogram (see
    /// [`crate::obs::MetricsSnapshot::to_wire`]). Layout-tolerant on
    /// decode so a newer monitor can read an older worker's reply.
    MetricsReply {
        rank: u32,
        counters: Vec<u64>,
        hist_data: Vec<u64>,
    },
    /// Joiner → monitor: a fresh `dasgd worker --join ADDR` process
    /// asks to be admitted into a vacant rank (one whose original
    /// worker was heartbeat-evicted or left gracefully).
    JoinRequest,
    /// Monitor → joiner: admission granted. Carries everything the
    /// joiner needs to reconstruct the vacant rank's worker
    /// configuration — deployment shape, run parameters, the §II
    /// objective as a `(code, λ)` pair, transport tuning, and the
    /// current peer address table (the joiner's own slot holds the
    /// address it must replace). The granted rank's node assignments
    /// and live state follow as plan frames and the handoff stream on
    /// the same connection.
    JoinGrant {
        rank: u32,
        nodes: u32,
        degree: u32,
        param_len: u32,
        seed: u64,
        secs: f64,
        rate_hz: f64,
        obj_code: u8,
        lam: f32,
        staging_mb: u32,
        executors: u32,
        flush_bytes: u32,
        flush_micros: u64,
        /// The deployment's update-rule code (see
        /// [`crate::node_logic::StrategyKind::code`]) — encoded before
        /// `peers` so the peer table stays the body's final field.
        strategy: u8,
        peers: Vec<String>,
    },
    /// Joiner → monitor: bound and listening on `addr` as rank `rank`;
    /// the monitor may now broadcast the [`PeerUpdate`](WireMsg::PeerUpdate)
    /// and begin the handoff.
    JoinReady { rank: u32, addr: String },
    /// Monitor → worker: rank `rank` is now reachable at `addr` (a
    /// replacement joined). Dial loops pick the new address up on
    /// their next pass.
    PeerUpdate { rank: u32, addr: String },
    /// Worker → monitor: graceful departure — treat me exactly like a
    /// heartbeat eviction (vacate my rank, repair the topology, hand
    /// my shards to my replacement when one joins).
    LeaveNotice { rank: u32 },
    /// Monitor → worker: atomic neighbor-set replacement. Each entry
    /// is one node's *complete* new sorted neighbor list (an empty
    /// list deactivates the node). `version` is monotonic — stale
    /// patches are ignored, so reordered deliveries cannot regress the
    /// topology. Workers swap the view between collect rounds: an
    /// in-flight round keeps the neighborhood it sampled.
    TopologyPatch {
        version: u64,
        entries: Vec<(u32, Vec<u32>)>,
    },
    /// Monitor → joiner: opens node `node`'s state handoff — `w` is
    /// the node's last-known parameter vector, so the adopted node
    /// resumes from live state instead of re-initializing. The node's
    /// data shard follows as the usual credit-gated
    /// [`ShardBlock`](WireMsg::ShardBlock) stream.
    HandoffBegin { node: u32, w: Vec<f32> },
    /// Monitor → joiner: node `node`'s handoff is complete. `checksum`
    /// is the [`Fnv64`] fold over the re-streamed blocks' payloads —
    /// equal to the original launch-time fold, certifying the adopted
    /// shard bit-identical (no row lost or duplicated).
    HandoffEnd { node: u32, checksum: u64 },
}

impl WireMsg {
    fn tag(&self) -> u8 {
        match self {
            WireMsg::Hello { .. } => 0,
            WireMsg::Heartbeat { .. } => 1,
            WireMsg::CollectRequest { .. } => 2,
            WireMsg::CollectReply { .. } => 3,
            WireMsg::Busy { .. } => 4,
            WireMsg::Abort { .. } => 5,
            WireMsg::ApplyAverage { .. } => 6,
            WireMsg::SnapshotRequest => 7,
            WireMsg::SnapshotReply { .. } => 8,
            WireMsg::Shutdown => 9,
            WireMsg::PlanAssign { .. } => 10,
            WireMsg::PlanStart { .. } => 11,
            WireMsg::ChunkBegin { .. } => 12,
            WireMsg::ChunkData { .. } => 13,
            WireMsg::ChunkEnd { .. } => 14,
            WireMsg::ShardBlock { .. } => 15,
            WireMsg::ShardComplete { .. } => 16,
            WireMsg::ShardCredit { .. } => 17,
            WireMsg::Batch { .. } => 18,
            WireMsg::MetricsRequest => 19,
            WireMsg::MetricsReply { .. } => 20,
            WireMsg::JoinRequest => 21,
            WireMsg::JoinGrant { .. } => 22,
            WireMsg::JoinReady { .. } => 23,
            WireMsg::PeerUpdate { .. } => 24,
            WireMsg::LeaveNotice { .. } => 25,
            WireMsg::TopologyPatch { .. } => 26,
            WireMsg::HandoffBegin { .. } => 27,
            WireMsg::HandoffEnd { .. } => 28,
        }
    }

    /// May this message ride inside a [`Batch`](WireMsg::Batch)?
    /// Chunk frames would desync the per-peer assembler and nested
    /// batches would allow unbounded recursion — both are refused.
    /// May this message ride inside a [`Batch`](WireMsg::Batch)
    /// envelope? Chunk frames carry their own framing state and batches
    /// do not nest — everything else is a plain logical message.
    pub fn is_batchable(&self) -> bool {
        !self.is_chunk_frame() && !matches!(self, WireMsg::Batch { .. })
    }

    fn is_chunk_frame(&self) -> bool {
        matches!(
            self,
            WireMsg::ChunkBegin { .. } | WireMsg::ChunkData { .. } | WireMsg::ChunkEnd { .. }
        )
    }
}

/// Why a frame failed to decode (or a stream failed to deliver one).
#[derive(Debug)]
pub enum WireError {
    /// Stream-level failure (includes EOF mid-frame).
    Io(std::io::Error),
    /// The body ended before the fields it promises.
    Truncated,
    /// Version byte we do not speak.
    Version { got: u8 },
    /// Tag byte outside the message set.
    UnknownTag { got: u8 },
    /// A length beyond the caps — a frame prefix past [`MAX_FRAME_LEN`],
    /// an element count the remaining bytes cannot hold, a chunked
    /// message past [`MAX_MESSAGE_LEN`], or (encode side) a vector too
    /// long for its `u32` length prefix.
    Oversize { len: usize },
    /// Bytes left over after the last field — the frame lied about its
    /// own layout.
    Trailing { extra: usize },
    /// The chunk envelope was violated: data without a begin, a second
    /// begin mid-message, a non-chunk frame interleaved into a chunked
    /// message, counts/bytes that disagree with the announcement, or a
    /// checksum mismatch.
    Chunk { reason: &'static str },
    /// The batch envelope was violated: an empty batch, a chunk frame
    /// or nested batch among the entries, or an entry whose announced
    /// length disagrees with the bytes present.
    Batch { reason: &'static str },
    /// A chunked message announced more bytes than this connection's
    /// configured staging budget allows.
    Staging { len: usize, limit: usize },
    /// A string field was not valid UTF-8.
    Utf8,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
            WireError::Truncated => write!(f, "frame body truncated"),
            WireError::Version { got } => {
                write!(
                    f,
                    "peer speaks wire version {got}, this build speaks {WIRE_VERSION} — \
                     upgrade the older end (pre-v8 peers cannot speak the strategy \
                     aux blobs or the elastic-membership protocol)"
                )
            }
            WireError::UnknownTag { got } => write!(f, "unknown frame tag {got}"),
            WireError::Oversize { len } => {
                write!(
                    f,
                    "length {len} exceeds the wire caps ({MAX_FRAME_LEN}-byte frames, \
                     {MAX_MESSAGE_LEN}-byte messages)"
                )
            }
            WireError::Trailing { extra } => {
                write!(f, "{extra} trailing bytes after the last field")
            }
            WireError::Chunk { reason } => write!(f, "chunk stream violation: {reason}"),
            WireError::Batch { reason } => write!(f, "batch envelope violation: {reason}"),
            WireError::Staging { len, limit } => {
                write!(
                    f,
                    "a {len}-byte logical message exceeds this connection's {limit}-byte \
                     chunk-staging budget — raise --staging-mb (or stream the payload in \
                     smaller blocks)"
                )
            }
            WireError::Utf8 => write!(f, "string field is not valid UTF-8"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

fn chunk_err(reason: &'static str) -> WireError {
    WireError::Chunk { reason }
}

fn batch_err(reason: &'static str) -> WireError {
    WireError::Batch { reason }
}

/// FNV-1a 64-bit over a byte slice — the chunk/plan integrity checksum.
/// Not cryptographic; it catches corruption and mis-assembly, not
/// adversaries (the deployment trusts its own processes).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

/// Incremental [`fnv1a64`] — fold many byte runs into one checksum.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64 {
    state: u64,
}

impl Fnv64 {
    pub fn new() -> Self {
        Self {
            state: 0xcbf2_9ce4_8422_2325,
        }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= u64::from(b);
            self.state = self.state.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Element-count prefix, total: a count past `u32` refuses instead of
/// silently truncating (the old `as u32` cast).
fn put_len(buf: &mut Vec<u8>, len: usize) -> Result<(), WireError> {
    let n = u32::try_from(len).map_err(|_| WireError::Oversize { len })?;
    put_u32(buf, n);
    Ok(())
}

fn put_f32s(buf: &mut Vec<u8>, w: &[f32]) -> Result<(), WireError> {
    put_len(buf, w.len())?;
    for &v in w {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    Ok(())
}

fn put_u32s(buf: &mut Vec<u8>, v: &[u32]) -> Result<(), WireError> {
    put_len(buf, v.len())?;
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    Ok(())
}

fn put_u64s(buf: &mut Vec<u8>, v: &[u64]) -> Result<(), WireError> {
    put_len(buf, v.len())?;
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    Ok(())
}

fn put_bytes(buf: &mut Vec<u8>, b: &[u8]) -> Result<(), WireError> {
    put_len(buf, b.len())?;
    buf.extend_from_slice(b);
    Ok(())
}

/// A string is its UTF-8 bytes, length-prefixed like any byte run.
fn put_str(buf: &mut Vec<u8>, s: &str) -> Result<(), WireError> {
    put_bytes(buf, s.as_bytes())
}

fn put_strs(buf: &mut Vec<u8>, v: &[String]) -> Result<(), WireError> {
    put_len(buf, v.len())?;
    for s in v {
        put_str(buf, s)?;
    }
    Ok(())
}

/// Serialize one message *body* (version + tag + fields, no length
/// prefix). Bodies are not frame-capped — [`encode`] enforces the cap,
/// [`encode_message`] chunks past it.
fn encode_body(msg: &WireMsg) -> Result<Vec<u8>, WireError> {
    let mut body = Vec::with_capacity(32);
    encode_body_append(msg, &mut body)?;
    Ok(body)
}

/// [`encode_body`], appended to a caller-owned buffer (nothing is
/// cleared). This is the hot-path primitive — the per-peer send
/// coalescer re-encodes thousands of small frames per second through
/// one reused buffer, allocation-free at steady state. On error the
/// buffer may hold a partial body; callers truncate back to their mark.
fn encode_body_append(msg: &WireMsg, body: &mut Vec<u8>) -> Result<(), WireError> {
    body.push(WIRE_VERSION);
    body.push(msg.tag());
    match msg {
        WireMsg::Hello { rank } => put_u32(body, *rank),
        WireMsg::Heartbeat { rank, seq } => {
            put_u32(body, *rank);
            put_u64(body, *seq);
        }
        WireMsg::CollectRequest { from, to, token }
        | WireMsg::Busy { from, to, token }
        | WireMsg::Abort { from, to, token } => {
            put_u32(body, *from);
            put_u32(body, *to);
            put_u64(body, *token);
        }
        WireMsg::CollectReply { from, to, token, w, aux }
        | WireMsg::ApplyAverage { from, to, token, w, aux } => {
            put_u32(body, *from);
            put_u32(body, *to);
            put_u64(body, *token);
            put_f32s(body, w)?;
            put_bytes(body, aux)?;
        }
        WireMsg::SnapshotRequest | WireMsg::Shutdown => {}
        WireMsg::SnapshotReply {
            rank,
            counts,
            params,
            staging_bytes,
            stream_done,
            updates_at_stream_complete,
        } => {
            put_u32(body, *rank);
            for &c in counts {
                put_u64(body, c);
            }
            put_len(body, params.len())?;
            for (node, w) in params {
                put_u32(body, *node);
                put_f32s(body, w)?;
            }
            put_u64(body, *staging_bytes);
            body.push(u8::from(*stream_done));
            put_u64(body, *updates_at_stream_complete);
        }
        WireMsg::PlanAssign {
            node,
            obj_code,
            lam,
            dim,
            classes,
            labels,
            features,
            strategy,
        } => {
            put_u32(body, *node);
            body.push(*obj_code);
            put_f32(body, *lam);
            put_u32(body, *dim);
            put_u32(body, *classes);
            put_u32s(body, labels)?;
            put_f32s(body, features)?;
            body.push(*strategy);
        }
        WireMsg::PlanStart {
            nodes,
            assigned,
            mixed,
            checksum,
            streaming,
        } => {
            put_u32(body, *nodes);
            put_u32(body, *assigned);
            body.push(u8::from(*mixed));
            put_u64(body, *checksum);
            body.push(u8::from(*streaming));
        }
        WireMsg::ShardBlock {
            node,
            seq,
            encoding,
            rows,
            dim,
            classes,
            labels,
            features,
            checksum,
        } => {
            put_u32(body, *node);
            put_u32(body, *seq);
            body.push(*encoding);
            put_u32(body, *rows);
            put_u32(body, *dim);
            put_u32(body, *classes);
            put_u32s(body, labels)?;
            put_f32s(body, features)?;
            put_u64(body, *checksum);
        }
        WireMsg::ShardComplete {
            node,
            block_count,
            total_rows,
            checksum,
        } => {
            put_u32(body, *node);
            put_u32(body, *block_count);
            put_u64(body, *total_rows);
            put_u64(body, *checksum);
        }
        WireMsg::ShardCredit { bytes } => put_u64(body, *bytes),
        WireMsg::ChunkBegin {
            total_bytes,
            chunk_count,
        } => {
            put_u64(body, *total_bytes);
            put_u32(body, *chunk_count);
        }
        WireMsg::ChunkData { bytes } => put_bytes(body, bytes)?,
        WireMsg::ChunkEnd { checksum } => put_u64(body, *checksum),
        WireMsg::Batch { msgs } => {
            if msgs.is_empty() {
                return Err(batch_err("a batch must carry at least one message"));
            }
            put_len(body, msgs.len())?;
            for m in msgs {
                if !m.is_batchable() {
                    return Err(batch_err(
                        "batch entries must be plain logical messages (no chunk \
                         frames, no nested batches)",
                    ));
                }
                let inner = encode_body(m)?;
                put_bytes(body, &inner)?;
            }
        }
        WireMsg::MetricsRequest => {}
        WireMsg::MetricsReply {
            rank,
            counters,
            hist_data,
        } => {
            put_u32(body, *rank);
            put_u64s(body, counters)?;
            put_u64s(body, hist_data)?;
        }
        WireMsg::JoinRequest => {}
        WireMsg::JoinGrant {
            rank,
            nodes,
            degree,
            param_len,
            seed,
            secs,
            rate_hz,
            obj_code,
            lam,
            staging_mb,
            executors,
            flush_bytes,
            flush_micros,
            strategy,
            peers,
        } => {
            put_u32(body, *rank);
            put_u32(body, *nodes);
            put_u32(body, *degree);
            put_u32(body, *param_len);
            put_u64(body, *seed);
            put_f64(body, *secs);
            put_f64(body, *rate_hz);
            body.push(*obj_code);
            put_f32(body, *lam);
            put_u32(body, *staging_mb);
            put_u32(body, *executors);
            put_u32(body, *flush_bytes);
            put_u64(body, *flush_micros);
            body.push(*strategy);
            put_strs(body, peers)?;
        }
        WireMsg::JoinReady { rank, addr } | WireMsg::PeerUpdate { rank, addr } => {
            put_u32(body, *rank);
            put_str(body, addr)?;
        }
        WireMsg::LeaveNotice { rank } => put_u32(body, *rank),
        WireMsg::TopologyPatch { version, entries } => {
            put_u64(body, *version);
            put_len(body, entries.len())?;
            for (node, hood) in entries {
                put_u32(body, *node);
                put_u32s(body, hood)?;
            }
        }
        WireMsg::HandoffBegin { node, w } => {
            put_u32(body, *node);
            put_f32s(body, w)?;
        }
        WireMsg::HandoffEnd { node, checksum } => {
            put_u32(body, *node);
            put_u64(body, *checksum);
        }
    }
    Ok(())
}

/// Wrap an encoded body in its length prefix.
fn frame_body(body: Vec<u8>) -> Result<Vec<u8>, WireError> {
    if body.len() > MAX_FRAME_LEN {
        return Err(WireError::Oversize { len: body.len() });
    }
    let mut frame = Vec::with_capacity(4 + body.len());
    put_u32(&mut frame, body.len() as u32);
    frame.extend_from_slice(&body);
    Ok(frame)
}

/// Serialize one message into a complete single frame (length prefix
/// included). Total: a message whose body exceeds [`MAX_FRAME_LEN`]
/// (or whose element counts overflow their `u32` prefixes) returns
/// [`WireError::Oversize`] — use [`encode_message`] for messages that
/// may need the chunk envelope.
pub fn encode(msg: &WireMsg) -> Result<Vec<u8>, WireError> {
    frame_body(encode_body(msg)?)
}

/// Drive `sink` with each frame of `msg`'s logical encoding, in order:
/// one plain frame when the body fits [`MAX_FRAME_LEN`], otherwise the
/// `ChunkBegin` / `ChunkData`⋯ / `ChunkEnd` envelope. The single place
/// the envelope is emitted — [`encode_message`] collects, and
/// [`write_message`] streams (one frame live at a time, so a near-cap
/// message never doubles in memory).
fn for_each_frame(
    msg: &WireMsg,
    sink: &mut dyn FnMut(Vec<u8>) -> Result<(), WireError>,
) -> Result<(), WireError> {
    let body = encode_body(msg)?;
    if body.len() <= MAX_FRAME_LEN {
        return sink(frame_body(body)?);
    }
    if msg.is_chunk_frame() {
        return Err(chunk_err("chunk frames cannot themselves be chunked"));
    }
    if body.len() > MAX_MESSAGE_LEN {
        return Err(WireError::Oversize { len: body.len() });
    }
    let checksum = fnv1a64(&body);
    let chunk_count = body.len().div_ceil(CHUNK_PAYLOAD);
    sink(encode(&WireMsg::ChunkBegin {
        total_bytes: body.len() as u64,
        chunk_count: chunk_count as u32,
    })?)?;
    for part in body.chunks(CHUNK_PAYLOAD) {
        sink(encode(&WireMsg::ChunkData {
            bytes: part.to_vec(),
        })?)?;
    }
    sink(encode(&WireMsg::ChunkEnd { checksum })?)
}

/// Serialize one logical message into the frame sequence that carries
/// it (see [`for_each_frame`]; prefer [`write_message`] on a stream —
/// it does not materialize the whole sequence).
pub fn encode_message(msg: &WireMsg) -> Result<Vec<Vec<u8>>, WireError> {
    let mut frames = Vec::new();
    for_each_frame(msg, &mut |frame| {
        frames.push(frame);
        Ok(())
    })?;
    Ok(frames)
}

/// The canonical checksum of one logical message ([`fnv1a64`] over its
/// encoded body) — what `ChunkEnd` carries for that message, and the
/// unit the `PlanStart` plan checksum folds over.
pub fn message_checksum(msg: &WireMsg) -> Result<u64, WireError> {
    Ok(fnv1a64(&encode_body(msg)?))
}

/// Serialize one message as a complete single frame into a caller-owned
/// buffer: `out` is cleared and refilled, keeping its capacity (the
/// allocation-free sibling of [`encode`]). Same totality: a body past
/// [`MAX_FRAME_LEN`] returns [`WireError::Oversize`].
pub fn encode_into(msg: &WireMsg, out: &mut Vec<u8>) -> Result<(), WireError> {
    out.clear();
    out.extend_from_slice(&[0u8; 4]); // length prefix, patched below
    encode_body_append(msg, out)?;
    let len = out.len() - 4;
    if len > MAX_FRAME_LEN {
        return Err(WireError::Oversize { len });
    }
    out[..4].copy_from_slice(&(len as u32).to_le_bytes());
    Ok(())
}

/// Builder for the per-peer send coalescer: accumulates small logical
/// messages and emits them as one frame — the message itself when only
/// one is pending (zero envelope overhead), a [`Batch`](WireMsg::Batch)
/// frame otherwise. All buffers are reused across
/// [`BatchBuilder::frame_into`] cycles, so a steady-state sender
/// allocates nothing.
pub struct BatchBuilder {
    /// Concatenated `[len: u32][body]` entries — exactly the Batch body
    /// layout after its count field.
    payload: Vec<u8>,
    count: u32,
}

impl Default for BatchBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl BatchBuilder {
    pub fn new() -> Self {
        Self {
            payload: Vec::new(),
            count: 0,
        }
    }

    /// Number of messages pending.
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Bytes the pending messages would occupy on the wire (payload
    /// only; the envelope adds a fixed few bytes).
    pub fn payload_bytes(&self) -> usize {
        self.payload.len()
    }

    /// Append one message to the pending batch. Refuses chunk frames
    /// and nested batches ([`WireError::Batch`]) and anything that
    /// would push the eventual frame past [`MAX_FRAME_LEN`]
    /// ([`WireError::Oversize`] — flush first, then retry). On any
    /// error the builder is unchanged.
    pub fn push(&mut self, msg: &WireMsg) -> Result<(), WireError> {
        if !msg.is_batchable() {
            return Err(batch_err(
                "batch entries must be plain logical messages (no chunk \
                 frames, no nested batches)",
            ));
        }
        let mark = self.payload.len();
        self.payload.extend_from_slice(&[0u8; 4]); // entry length, patched below
        if let Err(e) = encode_body_append(msg, &mut self.payload) {
            self.payload.truncate(mark);
            return Err(e);
        }
        let entry = self.payload.len() - mark - 4;
        // version + tag + count of the Batch envelope = 6 bytes.
        if 6 + self.payload.len() > MAX_FRAME_LEN {
            self.payload.truncate(mark);
            return Err(WireError::Oversize {
                len: 6 + mark + 4 + entry,
            });
        }
        self.payload[mark..mark + 4].copy_from_slice(&(entry as u32).to_le_bytes());
        self.count += 1;
        Ok(())
    }

    /// Emit everything pending as one complete frame into `out`
    /// (cleared first, capacity kept) and reset the builder for reuse.
    /// One pending message emits as its plain single frame — a batched
    /// stream therefore decodes to exactly the same message sequence as
    /// an unbatched one. An empty builder refuses.
    pub fn frame_into(&mut self, out: &mut Vec<u8>) -> Result<(), WireError> {
        if self.count == 0 {
            return Err(batch_err("a batch must carry at least one message"));
        }
        out.clear();
        if self.count == 1 {
            // The single entry is already a complete encoded body with
            // its own length prefix — reuse it as the frame directly.
            out.extend_from_slice(&self.payload);
        } else {
            let len = 2 + 4 + self.payload.len();
            debug_assert!(len <= MAX_FRAME_LEN, "push() enforces the frame cap");
            out.extend_from_slice(&(len as u32).to_le_bytes());
            out.push(WIRE_VERSION);
            out.push(18); // WireMsg::Batch
            out.extend_from_slice(&self.count.to_le_bytes());
            out.extend_from_slice(&self.payload);
        }
        self.payload.clear();
        self.count = 0;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Bounds-checked reader over a frame body.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated);
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A length-prefixed UTF-8 string; invalid bytes refuse with
    /// [`WireError::Utf8`] rather than lossy-replacing (an address
    /// that decodes differently than it encoded is worse than none).
    fn str(&mut self) -> Result<String, WireError> {
        let bytes = self.bytes()?;
        std::str::from_utf8(bytes)
            .map(str::to_owned)
            .map_err(|_| WireError::Utf8)
    }

    /// A length-prefixed raw byte run, count-validated against the
    /// bytes actually remaining before any allocation.
    fn bytes(&mut self) -> Result<&'a [u8], WireError> {
        let count = self.u32()? as usize;
        if count > self.remaining() {
            return Err(WireError::Oversize { len: count });
        }
        self.take(count)
    }

    /// A length-prefixed u64 vector, count-validated before allocation
    /// (same discipline as [`Cursor::f32s`]).
    fn u64s(&mut self) -> Result<Vec<u64>, WireError> {
        let count = self.u32()? as usize;
        if count.checked_mul(8).map(|b| b > self.remaining()).unwrap_or(true) {
            return Err(WireError::Oversize { len: count });
        }
        let bytes = self.take(count * 8)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// A length-prefixed u32 vector, count-validated before allocation
    /// (same discipline as [`Cursor::f32s`]).
    fn u32s(&mut self) -> Result<Vec<u32>, WireError> {
        let count = self.u32()? as usize;
        if count.checked_mul(4).map(|b| b > self.remaining()).unwrap_or(true) {
            return Err(WireError::Oversize { len: count });
        }
        let bytes = self.take(count * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// A length-prefixed f32 vector. The count is validated against the
    /// bytes actually remaining before any allocation, so a garbage
    /// count cannot balloon memory.
    fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let count = self.u32()? as usize;
        if count.checked_mul(4).map(|b| b > self.remaining()).unwrap_or(true) {
            return Err(WireError::Oversize { len: count });
        }
        let bytes = self.take(count * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn done(&self) -> Result<(), WireError> {
        match self.remaining() {
            0 => Ok(()),
            extra => Err(WireError::Trailing { extra }),
        }
    }
}

/// Decode one frame *body* (the bytes after the length prefix).
pub fn decode_body(body: &[u8]) -> Result<WireMsg, WireError> {
    let mut c = Cursor::new(body);
    let version = c.u8()?;
    if version != WIRE_VERSION {
        return Err(WireError::Version { got: version });
    }
    let tag = c.u8()?;
    let msg = match tag {
        0 => WireMsg::Hello { rank: c.u32()? },
        1 => WireMsg::Heartbeat {
            rank: c.u32()?,
            seq: c.u64()?,
        },
        2 => WireMsg::CollectRequest {
            from: c.u32()?,
            to: c.u32()?,
            token: c.u64()?,
        },
        3 => WireMsg::CollectReply {
            from: c.u32()?,
            to: c.u32()?,
            token: c.u64()?,
            w: c.f32s()?,
            aux: c.bytes()?.to_vec(),
        },
        4 => WireMsg::Busy {
            from: c.u32()?,
            to: c.u32()?,
            token: c.u64()?,
        },
        5 => WireMsg::Abort {
            from: c.u32()?,
            to: c.u32()?,
            token: c.u64()?,
        },
        6 => WireMsg::ApplyAverage {
            from: c.u32()?,
            to: c.u32()?,
            token: c.u64()?,
            w: c.f32s()?,
            aux: c.bytes()?.to_vec(),
        },
        7 => WireMsg::SnapshotRequest,
        8 => {
            let rank = c.u32()?;
            let mut counts = [0u64; 4];
            for slot in &mut counts {
                *slot = c.u64()?;
            }
            let n = c.u32()? as usize;
            // Each entry needs at least a node id + an (empty) vector
            // count: 8 bytes. Reject counts the body cannot hold.
            if n.checked_mul(8).map(|b| b > c.remaining()).unwrap_or(true) {
                return Err(WireError::Oversize { len: n });
            }
            let mut params = Vec::with_capacity(n);
            for _ in 0..n {
                let node = c.u32()?;
                params.push((node, c.f32s()?));
            }
            WireMsg::SnapshotReply {
                rank,
                counts,
                params,
                staging_bytes: c.u64()?,
                stream_done: c.u8()? != 0,
                updates_at_stream_complete: c.u64()?,
            }
        }
        9 => WireMsg::Shutdown,
        10 => WireMsg::PlanAssign {
            node: c.u32()?,
            obj_code: c.u8()?,
            lam: c.f32()?,
            dim: c.u32()?,
            classes: c.u32()?,
            labels: c.u32s()?,
            features: c.f32s()?,
            strategy: c.u8()?,
        },
        11 => WireMsg::PlanStart {
            nodes: c.u32()?,
            assigned: c.u32()?,
            mixed: c.u8()? != 0,
            checksum: c.u64()?,
            streaming: c.u8()? != 0,
        },
        12 => WireMsg::ChunkBegin {
            total_bytes: c.u64()?,
            chunk_count: c.u32()?,
        },
        13 => WireMsg::ChunkData {
            bytes: c.bytes()?.to_vec(),
        },
        14 => WireMsg::ChunkEnd { checksum: c.u64()? },
        15 => WireMsg::ShardBlock {
            node: c.u32()?,
            seq: c.u32()?,
            encoding: c.u8()?,
            rows: c.u32()?,
            dim: c.u32()?,
            classes: c.u32()?,
            labels: c.u32s()?,
            features: c.f32s()?,
            checksum: c.u64()?,
        },
        16 => WireMsg::ShardComplete {
            node: c.u32()?,
            block_count: c.u32()?,
            total_rows: c.u64()?,
            checksum: c.u64()?,
        },
        17 => WireMsg::ShardCredit { bytes: c.u64()? },
        18 => {
            let count = c.u32()? as usize;
            if count == 0 {
                return Err(batch_err("a batch must carry at least one message"));
            }
            // Each entry needs at least a length prefix plus a
            // version + tag pair: reject counts the body cannot hold
            // before allocating.
            if count.checked_mul(6).map(|b| b > c.remaining()).unwrap_or(true) {
                return Err(WireError::Oversize { len: count });
            }
            let mut msgs = Vec::with_capacity(count);
            for _ in 0..count {
                let inner = decode_body(c.bytes()?)?;
                if !inner.is_batchable() {
                    return Err(batch_err(
                        "batch entries must be plain logical messages (no chunk \
                         frames, no nested batches)",
                    ));
                }
                msgs.push(inner);
            }
            WireMsg::Batch { msgs }
        }
        19 => WireMsg::MetricsRequest,
        20 => WireMsg::MetricsReply {
            rank: c.u32()?,
            counters: c.u64s()?,
            hist_data: c.u64s()?,
        },
        21 => WireMsg::JoinRequest,
        22 => {
            let rank = c.u32()?;
            let nodes = c.u32()?;
            let degree = c.u32()?;
            let param_len = c.u32()?;
            let seed = c.u64()?;
            let secs = c.f64()?;
            let rate_hz = c.f64()?;
            let obj_code = c.u8()?;
            let lam = c.f32()?;
            let staging_mb = c.u32()?;
            let executors = c.u32()?;
            let flush_bytes = c.u32()?;
            let flush_micros = c.u64()?;
            let strategy = c.u8()?;
            let n = c.u32()? as usize;
            // Each peer entry needs at least its (possibly zero)
            // length prefix: 4 bytes. Reject counts the body cannot
            // hold before allocating.
            if n.checked_mul(4).map(|b| b > c.remaining()).unwrap_or(true) {
                return Err(WireError::Oversize { len: n });
            }
            let mut peers = Vec::with_capacity(n);
            for _ in 0..n {
                peers.push(c.str()?);
            }
            WireMsg::JoinGrant {
                rank,
                nodes,
                degree,
                param_len,
                seed,
                secs,
                rate_hz,
                obj_code,
                lam,
                staging_mb,
                executors,
                flush_bytes,
                flush_micros,
                strategy,
                peers,
            }
        }
        23 => WireMsg::JoinReady {
            rank: c.u32()?,
            addr: c.str()?,
        },
        24 => WireMsg::PeerUpdate {
            rank: c.u32()?,
            addr: c.str()?,
        },
        25 => WireMsg::LeaveNotice { rank: c.u32()? },
        26 => {
            let version = c.u64()?;
            let n = c.u32()? as usize;
            // Each entry needs at least a node id + an (empty)
            // neighbor count: 8 bytes.
            if n.checked_mul(8).map(|b| b > c.remaining()).unwrap_or(true) {
                return Err(WireError::Oversize { len: n });
            }
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                let node = c.u32()?;
                entries.push((node, c.u32s()?));
            }
            WireMsg::TopologyPatch { version, entries }
        }
        27 => WireMsg::HandoffBegin {
            node: c.u32()?,
            w: c.f32s()?,
        },
        28 => WireMsg::HandoffEnd {
            node: c.u32()?,
            checksum: c.u64()?,
        },
        got => return Err(WireError::UnknownTag { got }),
    };
    c.done()?;
    Ok(msg)
}

/// Decode from a growing byte buffer (e.g. accumulated TCP reads).
/// Returns `Ok(None)` when `buf` holds only a prefix of a frame (read
/// more and retry), `Ok(Some((msg, consumed)))` on success, and an
/// error for malformed input.
pub fn decode(buf: &[u8]) -> Result<Option<(WireMsg, usize)>, WireError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_LEN {
        return Err(WireError::Oversize { len });
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    let msg = decode_body(&buf[4..4 + len])?;
    Ok(Some((msg, 4 + len)))
}

// ---------------------------------------------------------------------------
// Chunk reassembly
// ---------------------------------------------------------------------------

struct Staging {
    total: usize,
    chunk_count: u32,
    seen: u32,
    bytes: Vec<u8>,
}

/// Per-connection reassembly state for chunked logical messages.
///
/// Feed it *every* decoded frame from one connection, in order:
/// non-chunk frames pass straight through (`Ok(Some(msg))`), chunk
/// frames stage (`Ok(None)`) until the envelope completes and the inner
/// message decodes. Any envelope violation returns a
/// [`WireError::Chunk`] and clears the staging — the caller must treat
/// that connection as broken (the stream can no longer be trusted to
/// frame correctly), which is exactly what every SocketNet read path
/// does with a wire error.
///
/// Memory is bounded: at most `limit` staged bytes per assembler
/// ([`MAX_MESSAGE_LEN`] by default, [`ChunkAssembler::with_limit`] to
/// tighten — the `--staging-mb` flag does), allocated only as real
/// bytes arrive (a hostile `ChunkBegin` announcing a huge total
/// reserves nothing).
pub struct ChunkAssembler {
    staging: Option<Staging>,
    limit: usize,
}

impl Default for ChunkAssembler {
    fn default() -> Self {
        Self::new()
    }
}

impl ChunkAssembler {
    pub fn new() -> Self {
        Self::with_limit(MAX_MESSAGE_LEN)
    }

    /// An assembler whose staging budget is `limit` bytes (capped at
    /// [`MAX_MESSAGE_LEN`]) instead of the hard-coded 1 GiB: a
    /// `ChunkBegin` announcing more refuses with
    /// [`WireError::Staging`], whose message names `--staging-mb`.
    pub fn with_limit(limit: usize) -> Self {
        Self {
            staging: None,
            limit: limit.min(MAX_MESSAGE_LEN),
        }
    }

    /// Is a chunked message currently mid-reassembly? (A stream that
    /// ends here was truncated.)
    pub fn in_progress(&self) -> bool {
        self.staging.is_some()
    }

    /// Accept the next decoded frame from the connection.
    pub fn accept(&mut self, msg: WireMsg) -> Result<Option<WireMsg>, WireError> {
        match msg {
            WireMsg::ChunkBegin {
                total_bytes,
                chunk_count,
            } => {
                if self.staging.take().is_some() {
                    return Err(chunk_err(
                        "ChunkBegin while another chunked message is in flight",
                    ));
                }
                let total = usize::try_from(total_bytes)
                    .ok()
                    .filter(|&t| t <= MAX_MESSAGE_LEN)
                    .ok_or_else(|| WireError::Oversize {
                        len: total_bytes.min(usize::MAX as u64) as usize,
                    })?;
                if total > self.limit {
                    return Err(WireError::Staging {
                        len: total,
                        limit: self.limit,
                    });
                }
                if chunk_count == 0 || chunk_count as usize != total.div_ceil(CHUNK_PAYLOAD) {
                    return Err(chunk_err("chunk count disagrees with the announced total"));
                }
                self.staging = Some(Staging {
                    total,
                    chunk_count,
                    seen: 0,
                    bytes: Vec::new(),
                });
                Ok(None)
            }
            WireMsg::ChunkData { bytes } => {
                let Some(st) = &mut self.staging else {
                    return Err(chunk_err("ChunkData without a ChunkBegin"));
                };
                if st.seen >= st.chunk_count || st.bytes.len() + bytes.len() > st.total {
                    self.staging = None;
                    return Err(chunk_err("more chunk data than announced"));
                }
                st.bytes.extend_from_slice(&bytes);
                st.seen += 1;
                crate::obs::gauge_max(crate::obs::Gauge::ChunkHighWater, st.bytes.len() as u64);
                Ok(None)
            }
            WireMsg::ChunkEnd { checksum } => {
                let Some(st) = self.staging.take() else {
                    return Err(chunk_err("ChunkEnd without a ChunkBegin"));
                };
                if st.seen != st.chunk_count || st.bytes.len() != st.total {
                    return Err(chunk_err("chunked message ended before its announced bytes"));
                }
                if fnv1a64(&st.bytes) != checksum {
                    return Err(chunk_err("chunk checksum mismatch"));
                }
                let inner = decode_body(&st.bytes)?;
                if inner.is_chunk_frame() {
                    return Err(chunk_err("a chunked message cannot itself be a chunk frame"));
                }
                Ok(Some(inner))
            }
            other => {
                if self.staging.take().is_some() {
                    return Err(chunk_err(
                        "non-chunk frame interleaved into a chunked message",
                    ));
                }
                Ok(Some(other))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Blocking-stream helpers
// ---------------------------------------------------------------------------

/// Write one single-frame message to a blocking stream. Errors (instead
/// of truncating) when the message needs chunking — use
/// [`write_message`] on any path that can carry large payloads.
pub fn write_frame(w: &mut impl Write, msg: &WireMsg) -> Result<(), WireError> {
    w.write_all(&encode(msg)?)?;
    w.flush()?;
    Ok(())
}

/// Write one logical message to a blocking stream, chunking as needed.
/// Frames stream out one at a time — peak memory stays at the message
/// body plus one chunk, not the body plus its whole framed copy.
pub fn write_message(w: &mut impl Write, msg: &WireMsg) -> Result<(), WireError> {
    for_each_frame(msg, &mut |frame| {
        w.write_all(&frame)?;
        Ok(())
    })?;
    w.flush()?;
    Ok(())
}

/// Read exactly one frame from a blocking stream. EOF or a timeout
/// mid-frame surfaces as [`WireError::Io`].
pub fn read_frame(r: &mut impl Read) -> Result<WireMsg, WireError> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes)?;
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len > MAX_FRAME_LEN {
        return Err(WireError::Oversize { len });
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode_body(&body)
}

/// Read exactly one *logical* message from a blocking stream, running
/// every frame through `asm` (chunk envelopes reassemble; a stream that
/// ends mid-envelope surfaces the underlying [`WireError::Io`]).
pub fn read_message(r: &mut impl Read, asm: &mut ChunkAssembler) -> Result<WireMsg, WireError> {
    loop {
        if let Some(msg) = asm.accept(read_frame(r)?)? {
            return Ok(msg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: WireMsg) {
        let frame = encode(&msg).unwrap();
        let (back, consumed) = decode(&frame).unwrap().expect("complete frame");
        assert_eq!(consumed, frame.len());
        assert_eq!(back, msg);
        // The streaming reader agrees.
        let mut cursor = std::io::Cursor::new(&frame);
        assert_eq!(read_frame(&mut cursor).unwrap(), msg);
        // And the logical-message path is byte-identical for frames
        // that fit the cap.
        if !msg.is_chunk_frame() {
            assert_eq!(encode_message(&msg).unwrap(), vec![frame]);
        }
    }

    #[test]
    fn every_variant_round_trips() {
        roundtrip(WireMsg::Hello { rank: 3 });
        roundtrip(WireMsg::Hello { rank: MONITOR_RANK });
        roundtrip(WireMsg::Heartbeat { rank: 0, seq: u64::MAX });
        roundtrip(WireMsg::CollectRequest {
            from: 7,
            to: 12,
            token: 99,
        });
        roundtrip(WireMsg::CollectReply {
            from: 12,
            to: 7,
            token: 99,
            w: vec![1.0, -2.5, 0.0],
            aux: vec![0xDE, 0xAD, 0x00],
        });
        roundtrip(WireMsg::CollectReply {
            from: 0,
            to: 1,
            token: 0,
            w: vec![],
            aux: vec![],
        });
        roundtrip(WireMsg::Busy {
            from: 2,
            to: 3,
            token: 5,
        });
        roundtrip(WireMsg::Abort {
            from: 4,
            to: 5,
            token: 6,
        });
        roundtrip(WireMsg::ApplyAverage {
            from: 1,
            to: 2,
            token: 3,
            w: vec![0.25; 200],
            aux: vec![0x7F; 800],
        });
        roundtrip(WireMsg::SnapshotRequest);
        roundtrip(WireMsg::SnapshotReply {
            rank: 1,
            counts: [10, 20, 30, 40],
            params: vec![(4, vec![1.5, 2.5]), (5, vec![])],
            staging_bytes: 4096,
            stream_done: true,
            updates_at_stream_complete: 17,
        });
        roundtrip(WireMsg::SnapshotReply {
            rank: 0,
            counts: [0; 4],
            params: vec![],
            staging_bytes: 0,
            stream_done: false,
            updates_at_stream_complete: u64::MAX,
        });
        roundtrip(WireMsg::Shutdown);
        roundtrip(WireMsg::PlanAssign {
            node: 6,
            obj_code: 2,
            lam: 1e-3,
            dim: 3,
            classes: 4,
            labels: vec![0, 3, 1],
            features: vec![0.5; 9],
            strategy: 3,
        });
        roundtrip(WireMsg::PlanAssign {
            node: 0,
            obj_code: 0,
            lam: 0.0,
            dim: 50,
            classes: 10,
            labels: vec![],
            features: vec![],
            strategy: 0,
        });
        roundtrip(WireMsg::PlanStart {
            nodes: 8,
            assigned: 4,
            mixed: true,
            checksum: 0xDEAD_BEEF_u64,
            streaming: true,
        });
        roundtrip(WireMsg::PlanStart {
            nodes: 2,
            assigned: 1,
            mixed: false,
            checksum: 0,
            streaming: false,
        });
        roundtrip(WireMsg::ShardBlock {
            node: 3,
            seq: 2,
            encoding: 0,
            rows: 3,
            dim: 2,
            classes: 4,
            labels: vec![0, 3, 1],
            features: vec![0.5, -1.0, 2.0, 0.0, 3.5, f32::MIN],
            checksum: 0x1234_5678_9ABC_DEF0,
        });
        roundtrip(WireMsg::ShardBlock {
            node: 0,
            seq: 0,
            encoding: 0,
            rows: 0,
            dim: 50,
            classes: 10,
            labels: vec![],
            features: vec![],
            checksum: 0,
        });
        roundtrip(WireMsg::ShardComplete {
            node: 7,
            block_count: 12,
            total_rows: 48_000,
            checksum: u64::MAX,
        });
        roundtrip(WireMsg::ShardCredit { bytes: 1 << 20 });
        roundtrip(WireMsg::ChunkBegin {
            total_bytes: 123_456_789,
            chunk_count: 30,
        });
        roundtrip(WireMsg::ChunkData {
            bytes: vec![7, 8, 9, 0xFF],
        });
        roundtrip(WireMsg::ChunkEnd { checksum: u64::MAX });
        roundtrip(WireMsg::MetricsRequest);
        roundtrip(WireMsg::MetricsReply {
            rank: 1,
            counters: vec![3, 0, 7, 12, 1, 1 << 30, 0],
            hist_data: vec![0xABCD; 2 * 66],
        });
        roundtrip(WireMsg::MetricsReply {
            rank: 0,
            counters: vec![],
            hist_data: vec![],
        });
        roundtrip(WireMsg::JoinRequest);
        roundtrip(WireMsg::JoinGrant {
            rank: 2,
            nodes: 64,
            degree: 4,
            param_len: 51,
            seed: 0xFEED,
            secs: 12.5,
            rate_hz: 300.0,
            obj_code: 1,
            lam: 1e-4,
            staging_mb: 1024,
            executors: 0,
            flush_bytes: 16 * 1024,
            flush_micros: 500,
            strategy: 2,
            peers: vec![
                "127.0.0.1:9000".into(),
                "127.0.0.1:9001".into(),
                String::new(),
            ],
        });
        roundtrip(WireMsg::JoinGrant {
            rank: 0,
            nodes: 0,
            degree: 0,
            param_len: 0,
            seed: 0,
            secs: 0.0,
            rate_hz: 0.0,
            obj_code: 0,
            lam: 0.0,
            staging_mb: 0,
            executors: 0,
            flush_bytes: 0,
            flush_micros: 0,
            strategy: 0,
            peers: vec![],
        });
        roundtrip(WireMsg::JoinReady {
            rank: 1,
            addr: "127.0.0.1:41234".into(),
        });
        roundtrip(WireMsg::PeerUpdate {
            rank: 2,
            addr: "[::1]:7".into(),
        });
        roundtrip(WireMsg::LeaveNotice { rank: 0 });
        roundtrip(WireMsg::TopologyPatch {
            version: 3,
            entries: vec![(0, vec![1, 2, 5]), (7, vec![]), (2, vec![0])],
        });
        roundtrip(WireMsg::TopologyPatch {
            version: u64::MAX,
            entries: vec![],
        });
        roundtrip(WireMsg::HandoffBegin {
            node: 12,
            w: vec![0.5, -1.5, f32::MIN],
        });
        roundtrip(WireMsg::HandoffBegin { node: 0, w: vec![] });
        roundtrip(WireMsg::HandoffEnd {
            node: 12,
            checksum: u64::MAX,
        });
        roundtrip(WireMsg::Batch {
            msgs: vec![WireMsg::Hello { rank: 1 }],
        });
        roundtrip(WireMsg::Batch {
            msgs: vec![
                WireMsg::CollectRequest {
                    from: 0,
                    to: 1,
                    token: 2,
                },
                WireMsg::Busy {
                    from: 1,
                    to: 0,
                    token: 2,
                },
                WireMsg::ApplyAverage {
                    from: 0,
                    to: 1,
                    token: 2,
                    w: vec![0.5; 32],
                    aux: vec![1, 2, 3, 4],
                },
            ],
        });
    }

    #[test]
    fn plan_assign_label_count_is_bounded() {
        // A lying label count must refuse before allocating.
        let mut body = vec![WIRE_VERSION, 10]; // PlanAssign
        body.extend_from_slice(&0u32.to_le_bytes()); // node
        body.push(1); // obj_code
        body.extend_from_slice(&0.0f32.to_le_bytes()); // lam
        body.extend_from_slice(&3u32.to_le_bytes()); // dim
        body.extend_from_slice(&2u32.to_le_bytes()); // classes
        body.extend_from_slice(&(500_000u32).to_le_bytes()); // labels count, no data
        let mut frame = Vec::new();
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        assert!(matches!(decode(&frame), Err(WireError::Oversize { .. })));
    }

    #[test]
    fn nan_and_infinity_survive_by_bits() {
        let w = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0];
        let frame = encode(&WireMsg::CollectReply {
            from: 0,
            to: 1,
            token: 2,
            w: w.clone(),
            aux: vec![],
        })
        .unwrap();
        let (back, _) = decode(&frame).unwrap().unwrap();
        let WireMsg::CollectReply { w: got, .. } = back else {
            panic!("wrong variant");
        };
        let bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = w.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, want);
    }

    #[test]
    fn incomplete_prefixes_ask_for_more() {
        let frame = encode(&WireMsg::Heartbeat { rank: 1, seq: 2 }).unwrap();
        for cut in 0..frame.len() {
            assert!(
                decode(&frame[..cut]).unwrap().is_none(),
                "prefix of {cut} bytes should be incomplete, not an error"
            );
        }
    }

    #[test]
    fn malformed_frames_error_not_panic() {
        // Wrong version — and the error names the upgrade path.
        let mut frame = encode(&WireMsg::Shutdown).unwrap();
        frame[4] = 2;
        match decode(&frame) {
            Err(e @ WireError::Version { got: 2 }) => {
                assert!(e.to_string().contains("upgrade"), "{e}");
            }
            other => panic!("expected a version error, got {other:?}"),
        }
        // Unknown tag.
        let mut frame = encode(&WireMsg::Shutdown).unwrap();
        frame[5] = 200;
        assert!(matches!(decode(&frame), Err(WireError::UnknownTag { got: 200 })));
        // Body shorter than the fields it promises.
        let good = encode(&WireMsg::Heartbeat { rank: 1, seq: 2 }).unwrap();
        let mut lying = good.clone();
        lying[0..4].copy_from_slice(&((good.len() as u32) - 4 - 3).to_le_bytes());
        assert!(matches!(
            decode(&lying[..lying.len() - 3]),
            Err(WireError::Truncated)
        ));
        // Trailing garbage inside the declared frame length.
        let mut padded = encode(&WireMsg::Shutdown).unwrap();
        padded.extend_from_slice(&[0xAA, 0xBB]);
        padded[0..4].copy_from_slice(&4u32.to_le_bytes()); // version+tag+2 junk
        assert!(matches!(decode(&padded), Err(WireError::Trailing { extra: 2 })));
        // Oversize length prefix refuses before allocating.
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        huge.extend_from_slice(&[WIRE_VERSION, 0]);
        assert!(matches!(decode(&huge), Err(WireError::Oversize { .. })));
        // Vector count larger than the remaining bytes.
        let mut body = vec![WIRE_VERSION, 3]; // CollectReply
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&2u64.to_le_bytes());
        body.extend_from_slice(&(1_000_000u32).to_le_bytes()); // count, no data
        let mut frame = Vec::new();
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        assert!(matches!(decode(&frame), Err(WireError::Oversize { .. })));
    }

    #[test]
    fn membership_string_fields_are_strict_utf8() {
        // Corrupt the address bytes of a JoinReady frame: decode must
        // refuse with the UTF-8 error, never lossy-replace or panic.
        let msg = WireMsg::JoinReady {
            rank: 1,
            addr: "abcd".into(),
        };
        let mut frame = encode(&msg).unwrap();
        let n = frame.len();
        frame[n - 1] = 0xFF; // invalid UTF-8 continuation byte
        assert!(matches!(decode(&frame), Err(WireError::Utf8)));

        // A lying peer count in JoinGrant refuses before allocating.
        let good = encode(&WireMsg::JoinGrant {
            rank: 0,
            nodes: 1,
            degree: 0,
            param_len: 1,
            seed: 0,
            secs: 1.0,
            rate_hz: 1.0,
            obj_code: 0,
            lam: 0.0,
            staging_mb: 1,
            executors: 0,
            flush_bytes: 0,
            flush_micros: 0,
            strategy: 0,
            peers: vec![],
        })
        .unwrap();
        let mut lying = good.clone();
        let n = lying.len();
        lying[n - 4..].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(decode(&lying), Err(WireError::Oversize { .. })));

        // And a lying TopologyPatch entry count likewise.
        let mut body = vec![WIRE_VERSION, 26];
        body.extend_from_slice(&1u64.to_le_bytes()); // version
        body.extend_from_slice(&(u32::MAX).to_le_bytes()); // entries, no data
        let mut frame = Vec::new();
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        assert!(matches!(decode(&frame), Err(WireError::Oversize { .. })));
    }

    #[test]
    fn two_frames_in_one_buffer_decode_in_order() {
        let mut buf = encode(&WireMsg::Hello { rank: 9 }).unwrap();
        buf.extend_from_slice(&encode(&WireMsg::SnapshotRequest).unwrap());
        let (first, used) = decode(&buf).unwrap().unwrap();
        assert_eq!(first, WireMsg::Hello { rank: 9 });
        let (second, used2) = decode(&buf[used..]).unwrap().unwrap();
        assert_eq!(second, WireMsg::SnapshotRequest);
        assert_eq!(used + used2, buf.len());
    }

    #[test]
    fn oversize_body_errors_on_encode_and_chunks_on_encode_message() {
        // ~20 MiB of features: past the frame cap, within the message cap.
        let msg = WireMsg::PlanAssign {
            node: 1,
            obj_code: 0,
            lam: 0.0,
            dim: 50,
            classes: 10,
            labels: vec![0; 100_000],
            features: vec![0.5; 100_000 * 50],
            strategy: 1,
        };
        assert!(matches!(encode(&msg), Err(WireError::Oversize { .. })));
        let frames = encode_message(&msg).unwrap();
        assert!(frames.len() > 3, "expected an envelope, got {} frames", frames.len());
        for f in &frames {
            assert!(f.len() <= 4 + MAX_FRAME_LEN);
        }
        // Reassembly restores the exact message.
        let mut asm = ChunkAssembler::new();
        let mut out = None;
        for f in &frames {
            let (frame_msg, used) = decode(f).unwrap().expect("complete frame");
            assert_eq!(used, f.len());
            if let Some(m) = asm.accept(frame_msg).unwrap() {
                out = Some(m);
            }
        }
        assert!(!asm.in_progress());
        assert_eq!(out.expect("assembled message"), msg);
    }

    /// A hand-rolled single-chunk envelope around `msg` (small payloads
    /// welcome — `encode_message` only chunks past the frame cap, but
    /// the assembler accepts any well-formed envelope).
    fn envelope(msg: &WireMsg) -> (Vec<u8>, Vec<WireMsg>) {
        let frame = encode(msg).unwrap();
        let body = frame[4..].to_vec();
        let frames = vec![
            WireMsg::ChunkBegin {
                total_bytes: body.len() as u64,
                chunk_count: 1,
            },
            WireMsg::ChunkData {
                bytes: body.clone(),
            },
            WireMsg::ChunkEnd {
                checksum: fnv1a64(&body),
            },
        ];
        (body, frames)
    }

    #[test]
    fn assembler_accepts_a_well_formed_envelope() {
        let msg = WireMsg::Heartbeat { rank: 4, seq: 77 };
        let (_, frames) = envelope(&msg);
        let mut asm = ChunkAssembler::new();
        assert!(asm.accept(frames[0].clone()).unwrap().is_none());
        assert!(asm.in_progress());
        assert!(asm.accept(frames[1].clone()).unwrap().is_none());
        assert_eq!(asm.accept(frames[2].clone()).unwrap(), Some(msg));
        assert!(!asm.in_progress());
        // Checksums agree with the canonical per-message checksum.
        let WireMsg::ChunkEnd { checksum } = &frames[2] else { unreachable!() };
        assert_eq!(
            *checksum,
            message_checksum(&WireMsg::Heartbeat { rank: 4, seq: 77 }).unwrap()
        );
    }

    #[test]
    fn chunk_stream_violations_error_not_panic() {
        let msg = WireMsg::Heartbeat { rank: 1, seq: 2 };
        let (body, frames) = envelope(&msg);

        // Data without a begin.
        let mut asm = ChunkAssembler::new();
        assert!(matches!(
            asm.accept(frames[1].clone()),
            Err(WireError::Chunk { .. })
        ));
        // End without a begin.
        assert!(matches!(
            asm.accept(frames[2].clone()),
            Err(WireError::Chunk { .. })
        ));

        // A second begin mid-message.
        let mut asm = ChunkAssembler::new();
        asm.accept(frames[0].clone()).unwrap();
        assert!(matches!(
            asm.accept(frames[0].clone()),
            Err(WireError::Chunk { .. })
        ));

        // A non-chunk frame interleaved into the envelope.
        let mut asm = ChunkAssembler::new();
        asm.accept(frames[0].clone()).unwrap();
        assert!(matches!(
            asm.accept(WireMsg::SnapshotRequest),
            Err(WireError::Chunk { .. })
        ));

        // Ending before the announced bytes arrived.
        let mut asm = ChunkAssembler::new();
        asm.accept(WireMsg::ChunkBegin {
            total_bytes: (body.len() + 4) as u64,
            chunk_count: 1,
        })
        .unwrap();
        asm.accept(frames[1].clone()).unwrap();
        assert!(matches!(
            asm.accept(frames[2].clone()),
            Err(WireError::Chunk { .. })
        ));

        // Checksum mismatch.
        let mut asm = ChunkAssembler::new();
        asm.accept(frames[0].clone()).unwrap();
        asm.accept(frames[1].clone()).unwrap();
        assert!(matches!(
            asm.accept(WireMsg::ChunkEnd {
                checksum: fnv1a64(&body) ^ 1
            }),
            Err(WireError::Chunk { .. })
        ));

        // Chunk count disagreeing with the total.
        let mut asm = ChunkAssembler::new();
        assert!(matches!(
            asm.accept(WireMsg::ChunkBegin {
                total_bytes: body.len() as u64,
                chunk_count: 2,
            }),
            Err(WireError::Chunk { .. })
        ));

        // An announced total beyond the message cap refuses up front.
        let mut asm = ChunkAssembler::new();
        assert!(matches!(
            asm.accept(WireMsg::ChunkBegin {
                total_bytes: (MAX_MESSAGE_LEN as u64) + 1,
                chunk_count: u32::MAX,
            }),
            Err(WireError::Oversize { .. })
        ));

        // A tightened staging budget refuses within the cap too, and
        // the error names the flag that raises it.
        let mut asm = ChunkAssembler::with_limit(1 << 20);
        match asm.accept(WireMsg::ChunkBegin {
            total_bytes: (1 << 20) + 1,
            chunk_count: 1,
        }) {
            Err(e @ WireError::Staging { .. }) => {
                assert!(e.to_string().contains("--staging-mb"), "{e}");
            }
            other => panic!("expected a staging error, got {other:?}"),
        }
        assert!(!asm.in_progress());

        // An envelope whose inner message is itself a chunk frame.
        let end_frame = encode(&WireMsg::ChunkEnd { checksum: 0 }).unwrap();
        let inner = end_frame[4..].to_vec();
        let mut asm = ChunkAssembler::new();
        asm.accept(WireMsg::ChunkBegin {
            total_bytes: inner.len() as u64,
            chunk_count: 1,
        })
        .unwrap();
        asm.accept(WireMsg::ChunkData {
            bytes: inner.clone(),
        })
        .unwrap();
        assert!(matches!(
            asm.accept(WireMsg::ChunkEnd {
                checksum: fnv1a64(&inner)
            }),
            Err(WireError::Chunk { .. })
        ));

        // After any error the assembler is clean again.
        assert!(!asm.in_progress());
        let (_, ok_frames) = envelope(&msg);
        let mut last = None;
        for f in ok_frames {
            if let Some(m) = asm.accept(f).unwrap() {
                last = Some(m);
            }
        }
        assert_eq!(last, Some(msg));
    }

    #[test]
    fn write_message_and_read_message_agree_across_sizes() {
        let small = WireMsg::CollectReply {
            from: 1,
            to: 2,
            token: 3,
            w: vec![0.5; 16],
            aux: vec![9; 5],
        };
        let big = WireMsg::SnapshotReply {
            rank: 0,
            counts: [1, 2, 3, 4],
            params: (0..12u32).map(|i| (i, vec![i as f32; 400_000])).collect(),
            staging_bytes: 0,
            stream_done: true,
            updates_at_stream_complete: 500,
        };
        for msg in [small, big] {
            let mut buf = Vec::new();
            write_message(&mut buf, &msg).unwrap();
            let mut cursor = std::io::Cursor::new(&buf);
            let mut asm = ChunkAssembler::new();
            assert_eq!(read_message(&mut cursor, &mut asm).unwrap(), msg);
            assert_eq!(cursor.position() as usize, buf.len());
        }
    }

    #[test]
    fn batch_round_trips_and_preserves_order() {
        let msgs = vec![
            WireMsg::CollectRequest {
                from: 0,
                to: 1,
                token: 7,
            },
            WireMsg::Busy {
                from: 1,
                to: 0,
                token: 7,
            },
            WireMsg::ApplyAverage {
                from: 0,
                to: 1,
                token: 7,
                w: vec![1.0, -2.5, f32::NAN],
                aux: vec![0xAB, 0xCD],
            },
            WireMsg::Heartbeat { rank: 2, seq: 9 },
        ];
        let batch = WireMsg::Batch { msgs: msgs.clone() };
        let frame = encode(&batch).unwrap();
        let (back, used) = decode(&frame).unwrap().unwrap();
        assert_eq!(used, frame.len());
        let WireMsg::Batch { msgs: got } = back else {
            panic!("wrong variant");
        };
        // Bit-exact per entry (NaN payload included).
        assert_eq!(got.len(), msgs.len());
        for (a, b) in got.iter().zip(&msgs) {
            assert_eq!(encode(a).unwrap(), encode(b).unwrap());
        }
        // The assembler passes a batch through like any non-chunk frame.
        let mut asm = ChunkAssembler::new();
        let passed = asm.accept(WireMsg::Batch { msgs: msgs.clone() }).unwrap();
        assert_eq!(passed, Some(WireMsg::Batch { msgs }));
    }

    #[test]
    fn batch_envelope_violations_error_not_panic() {
        // Empty batches refuse on encode...
        assert!(matches!(
            encode(&WireMsg::Batch { msgs: vec![] }),
            Err(WireError::Batch { .. })
        ));
        // ...and on decode (hand-built zero count).
        let mut body = vec![WIRE_VERSION, 18];
        body.extend_from_slice(&0u32.to_le_bytes());
        let mut frame = Vec::new();
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        assert!(matches!(decode(&frame), Err(WireError::Batch { .. })));

        // Nested batches and chunk frames refuse on encode.
        for bad in [
            WireMsg::Batch {
                msgs: vec![WireMsg::Shutdown],
            },
            WireMsg::ChunkEnd { checksum: 0 },
        ] {
            assert!(matches!(
                encode(&WireMsg::Batch { msgs: vec![bad] }),
                Err(WireError::Batch { .. })
            ));
        }

        // ...and on decode: hand-build a batch whose single entry is a
        // chunk frame, then one whose entry is itself a batch.
        for inner in [
            encode(&WireMsg::ChunkEnd { checksum: 0 }).unwrap(),
            encode(&WireMsg::Batch {
                msgs: vec![WireMsg::Shutdown],
            })
            .unwrap(),
        ] {
            let entry = &inner[4..]; // strip the frame length prefix
            let mut body = vec![WIRE_VERSION, 18];
            body.extend_from_slice(&1u32.to_le_bytes());
            body.extend_from_slice(&(entry.len() as u32).to_le_bytes());
            body.extend_from_slice(entry);
            let mut frame = Vec::new();
            frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
            frame.extend_from_slice(&body);
            assert!(matches!(decode(&frame), Err(WireError::Batch { .. })));
        }

        // A mixed-version entry errors with the version diagnostic.
        let entry = {
            let f = encode(&WireMsg::Shutdown).unwrap();
            let mut e = f[4..].to_vec();
            e[0] = 4; // pre-batch peer
            e
        };
        let mut body = vec![WIRE_VERSION, 18];
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&(entry.len() as u32).to_le_bytes());
        body.extend_from_slice(&entry);
        let mut frame = Vec::new();
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        assert!(matches!(decode(&frame), Err(WireError::Version { got: 4 })));

        // A lying count refuses before allocating.
        let mut body = vec![WIRE_VERSION, 18];
        body.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut frame = Vec::new();
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        assert!(matches!(decode(&frame), Err(WireError::Oversize { .. })));

        // An entry truncated mid-body surfaces the inner decode error.
        let good = encode(&WireMsg::Heartbeat { rank: 1, seq: 2 }).unwrap();
        let entry = &good[4..];
        let mut body = vec![WIRE_VERSION, 18];
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&(entry.len() as u32).to_le_bytes());
        body.extend_from_slice(&entry[..entry.len() - 3]); // short payload
        let mut frame = Vec::new();
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        assert!(decode(&frame).is_err());
    }

    #[test]
    fn encode_into_matches_encode_and_reuses_capacity() {
        let msg = WireMsg::CollectReply {
            from: 3,
            to: 4,
            token: 5,
            w: vec![0.5; 64],
            aux: vec![1, 2, 3],
        };
        let mut buf = Vec::new();
        encode_into(&msg, &mut buf).unwrap();
        assert_eq!(buf, encode(&msg).unwrap());
        let cap = buf.capacity();
        // Re-encoding a smaller message keeps the allocation.
        encode_into(&WireMsg::Shutdown, &mut buf).unwrap();
        assert_eq!(buf, encode(&WireMsg::Shutdown).unwrap());
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn batch_builder_single_message_emits_the_plain_frame() {
        let msg = WireMsg::Abort {
            from: 1,
            to: 2,
            token: 3,
        };
        let mut b = BatchBuilder::new();
        b.push(&msg).unwrap();
        assert_eq!(b.len(), 1);
        let mut out = Vec::new();
        b.frame_into(&mut out).unwrap();
        // One pending message: zero envelope overhead, byte-identical
        // to the unbatched wire.
        assert_eq!(out, encode(&msg).unwrap());
        assert!(b.is_empty());
    }

    #[test]
    fn batch_builder_stream_decodes_to_the_unbatched_sequence() {
        let msgs = vec![
            WireMsg::CollectRequest {
                from: 0,
                to: 1,
                token: 1,
            },
            WireMsg::CollectReply {
                from: 1,
                to: 0,
                token: 1,
                w: vec![2.0; 8],
                aux: vec![4; 12],
            },
            WireMsg::ApplyAverage {
                from: 0,
                to: 1,
                token: 1,
                w: vec![1.5; 8],
                aux: vec![],
            },
            WireMsg::Heartbeat { rank: 0, seq: 1 },
            WireMsg::Abort {
                from: 2,
                to: 3,
                token: 9,
            },
        ];
        // Unbatched: five frames.
        let unbatched: Vec<WireMsg> = msgs
            .iter()
            .map(|m| {
                let f = encode(m).unwrap();
                decode(&f).unwrap().unwrap().0
            })
            .collect();
        // Batched: 2 + 3 across two flushes, then flattened on read.
        let mut b = BatchBuilder::new();
        let mut stream = Vec::new();
        let mut out = Vec::new();
        for (i, m) in msgs.iter().enumerate() {
            b.push(m).unwrap();
            if i == 1 || i == msgs.len() - 1 {
                b.frame_into(&mut out).unwrap();
                stream.extend_from_slice(&out);
            }
        }
        let mut flat = Vec::new();
        let mut rest = &stream[..];
        while !rest.is_empty() {
            let (m, used) = decode(rest).unwrap().unwrap();
            match m {
                WireMsg::Batch { msgs } => flat.extend(msgs),
                other => flat.push(other),
            }
            rest = &rest[used..];
        }
        assert_eq!(flat, unbatched);
        // The builder is reusable after its flushes and its buffers
        // survive with capacity intact.
        assert!(b.is_empty());
        assert_eq!(b.payload_bytes(), 0);
        b.push(&WireMsg::Shutdown).unwrap();
        b.frame_into(&mut out).unwrap();
        assert_eq!(out, encode(&WireMsg::Shutdown).unwrap());
    }

    #[test]
    fn batch_builder_refuses_unbatchable_and_empty_flush() {
        let mut b = BatchBuilder::new();
        assert!(matches!(
            b.push(&WireMsg::ChunkEnd { checksum: 0 }),
            Err(WireError::Batch { .. })
        ));
        assert!(matches!(
            b.push(&WireMsg::Batch {
                msgs: vec![WireMsg::Shutdown]
            }),
            Err(WireError::Batch { .. })
        ));
        // Rejected pushes leave nothing pending.
        assert!(b.is_empty());
        let mut out = Vec::new();
        assert!(matches!(
            b.frame_into(&mut out),
            Err(WireError::Batch { .. })
        ));
        // A good message after the refusals still works.
        b.push(&WireMsg::SnapshotRequest).unwrap();
        b.frame_into(&mut out).unwrap();
        assert_eq!(out, encode(&WireMsg::SnapshotRequest).unwrap());
    }

    #[test]
    fn batch_builder_enforces_the_frame_cap() {
        // Each entry is ~4 MiB; the fifth would push the frame past
        // 16 MiB and must refuse, leaving the first four intact.
        let big = WireMsg::CollectReply {
            from: 0,
            to: 1,
            token: 0,
            w: vec![1.0; (1 << 20) - 64],
            aux: vec![],
        };
        let mut b = BatchBuilder::new();
        for _ in 0..4 {
            b.push(&big).unwrap();
        }
        assert!(matches!(b.push(&big), Err(WireError::Oversize { .. })));
        assert_eq!(b.len(), 4);
        let mut out = Vec::new();
        b.frame_into(&mut out).unwrap();
        assert!(out.len() <= 4 + MAX_FRAME_LEN);
        let (m, used) = decode(&out).unwrap().unwrap();
        assert_eq!(used, out.len());
        let WireMsg::Batch { msgs } = m else {
            panic!("wrong variant");
        };
        assert_eq!(msgs.len(), 4);
    }

    #[test]
    fn fnv_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
        // Incremental = one-shot.
        let mut h = Fnv64::new();
        h.update(b"foo");
        h.update(b"bar");
        assert_eq!(h.finish(), fnv1a64(b"foobar"));
    }
}
