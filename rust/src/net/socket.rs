//! `SocketNet` — the ChannelNet collect/broadcast protocol carried over
//! real TCP connections, for multi-process deployments.
//!
//! Each worker process owns a contiguous shard of nodes (a
//! [`ShardMap`] block). Traffic between two nodes of the same shard
//! short-circuits through in-process mailboxes — byte-for-byte the
//! ChannelNet path, no serialization. Traffic that crosses a shard
//! boundary is framed by [`wire`](super::wire) and flows over one
//! persistent TCP connection per worker pair (the higher rank dials,
//! the lower rank accepts; the dialer owns reconnect).
//!
//! Liveness is leased everywhere, so a dead process degrades, never
//! deadlocks:
//!
//! * every initiator wait is deadline-bounded (a silent peer times the
//!   round out into a `Conflict`);
//! * member-side captures expire on the ChannelNet lease, so a crashed
//!   remote initiator cannot pin a member;
//! * peers exchange heartbeats; a link silent past the liveness window
//!   is marked dead and [`Transport::reachable`] turns false for every
//!   node it owns, letting engines filter neighborhoods *before*
//!   initiating (a dead peer costs `Conflict`/`Isolated`, not a
//!   timeout per round).

use std::collections::VecDeque;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::transport::{ProjectionOutcome, Transport};

use super::wire::{self, WireMsg, MONITOR_RANK};

/// Contiguous block partition of nodes `0..n` over `workers` ranks.
/// Rank `i` owns a block of `n/workers` nodes (the first `n % workers`
/// ranks own one extra).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardMap {
    n: usize,
    workers: usize,
}

impl ShardMap {
    pub fn new(n: usize, workers: usize) -> Self {
        assert!(workers >= 1, "need at least one worker");
        assert!(workers <= n, "more workers ({workers}) than nodes ({n})");
        Self { n, workers }
    }

    pub fn nodes(&self) -> usize {
        self.n
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Which rank owns `node`.
    pub fn owner(&self, node: usize) -> u32 {
        debug_assert!(node < self.n);
        let q = self.n / self.workers;
        let r = self.n % self.workers;
        let fat = r * (q + 1); // nodes covered by the r larger shards
        if node < fat {
            (node / (q + 1)) as u32
        } else {
            (r + (node - fat) / q) as u32
        }
    }

    /// The node block rank `rank` owns.
    pub fn range(&self, rank: u32) -> Range<usize> {
        let rank = rank as usize;
        assert!(rank < self.workers);
        let q = self.n / self.workers;
        let r = self.n % self.workers;
        let start = rank * q + rank.min(r);
        let len = q + usize::from(rank < r);
        start..start + len
    }
}

/// Timing knobs for the socket substrate.
#[derive(Clone, Copy, Debug)]
pub struct SocketConfig {
    /// Deadline for one collect round (covers a peer's longest
    /// inter-poll sleep plus a loopback round trip).
    pub timeout: Duration,
    /// Modeled projection hold the capture lease must survive (mirror
    /// of `ChannelNet::with_round_budget`).
    pub hold_budget: Duration,
    /// Heartbeat send cadence between worker peers.
    pub heartbeat: Duration,
    /// A link silent for longer than this is dead.
    pub liveness: Duration,
    /// Redial cadence for a dead link (dialer side only).
    pub reconnect: Duration,
    /// Per-peer-connection chunk-staging cap in bytes (`--staging-mb`):
    /// a chunked logical message announcing more than this is refused
    /// before any payload is buffered. Defaults to the codec's absolute
    /// 1 GiB cap, so nothing changes unless the flag tightens it.
    pub staging_limit: usize,
    /// Send-coalescer threshold (`--flush-bytes`): same-destination
    /// frames accumulate in a per-peer [`wire::BatchBuilder`] and flush
    /// as one batched wire write once this many payload bytes are
    /// pending. `0` disables coalescing entirely — every message is its
    /// own wire write, the pre-v5 behavior.
    pub flush_bytes: usize,
    /// Send-coalescer staleness bound (`--flush-micros`): a pending
    /// batch older than this is flushed by the background sweeper even
    /// if under the byte threshold, so a quiet peer never waits long
    /// for a half-full buffer.
    pub flush_micros: u64,
}

impl Default for SocketConfig {
    fn default() -> Self {
        Self {
            timeout: Duration::from_millis(150),
            hold_budget: Duration::ZERO,
            heartbeat: Duration::from_millis(200),
            liveness: Duration::from_millis(1000),
            reconnect: Duration::from_millis(200),
            staging_limit: wire::MAX_MESSAGE_LEN,
            flush_bytes: 16 * 1024,
            flush_micros: 500,
        }
    }
}

/// Mailbox messages — the ChannelNet protocol vocabulary. Identical
/// semantics whether a leg traveled in-process or over a wire frame.
/// `Params`/`Apply` carry the member's strategy aux blob beside `w`
/// (wire v8) — empty for the baseline.
enum NodeMsg {
    Collect { from: usize, token: u64 },
    Params { from: usize, token: u64, w: Vec<f32>, aux: Vec<u8> },
    Busy { token: u64 },
    Apply { from: usize, token: u64, w: Vec<f32>, aux: Vec<u8> },
    Release { from: usize, token: u64 },
}

/// Largest aux blob accepted off the wire, as a multiple of the
/// parameter vector's byte size: in-tree strategies publish at most one
/// f32 vector (`4·param_len` bytes), so ×4 is generous headroom — an
/// inbound blob past it is corruption, not a strategy.
const MAX_AUX_FACTOR: usize = 4;

/// One owned node's parameter slot (same state machine as ChannelNet).
struct Slot {
    w: Vec<f32>,
    /// The node's published strategy aux blob (travels with `w`).
    aux: Vec<u8>,
    locked_by: Option<(usize, u64)>,
    locked_at: Option<Instant>,
    initiating: bool,
}

/// Reply state of an in-flight collect round.
struct Round {
    token: u64,
    replies: Vec<(usize, Vec<f32>, Vec<u8>)>,
    busy: bool,
}

/// Per-peer send coalescer state: the pending batch, one reusable
/// frame buffer for everything this link writes, and the age of the
/// oldest pending message (what the sweeper checks). All buffers keep
/// their capacity across flushes — the steady-state send path
/// allocates nothing.
struct SendBuf {
    batch: wire::BatchBuilder,
    /// Scratch for encoded frames (batched flushes and unbatched
    /// single-frame sends alike).
    frame: Vec<u8>,
    /// When the oldest currently-pending message was enqueued.
    oldest: Option<Instant>,
}

impl SendBuf {
    fn new() -> Self {
        Self {
            batch: wire::BatchBuilder::new(),
            frame: Vec::new(),
            oldest: None,
        }
    }
}

/// One peer rank's connection state.
struct Link {
    /// Dial address (set by [`SocketNet::connect_peers`]; the accept
    /// side can run without one).
    addr: Mutex<Option<String>>,
    /// Write half of the live connection. `None` while down.
    writer: Mutex<Option<TcpStream>>,
    /// Outbound coalescer. Lock order: `sendbuf` before `writer`,
    /// always — every wire write flows through one of the helpers
    /// below, which uphold it.
    sendbuf: Mutex<SendBuf>,
    alive: AtomicBool,
    /// Set once any connection has been installed — distinguishes a
    /// true reconnect after a dropped link from the dial retries every
    /// worker burns while its peers are still coming up.
    ever_connected: AtomicBool,
    last_seen: Mutex<Instant>,
}

impl Link {
    fn new() -> Self {
        Self {
            addr: Mutex::new(None),
            writer: Mutex::new(None),
            sendbuf: Mutex::new(SendBuf::new()),
            alive: AtomicBool::new(false),
            ever_connected: AtomicBool::new(false),
            last_seen: Mutex::new(Instant::now()),
        }
    }

    fn mark_dead(&self) {
        self.alive.store(false, Ordering::SeqCst);
        if let Some(s) = self.writer.lock().unwrap().take() {
            let _ = s.shutdown(Shutdown::Both);
        }
    }

    fn install(&self, stream: TcpStream) {
        *self.last_seen.lock().unwrap() = Instant::now();
        // Drop any stale socket before installing the fresh one.
        if let Some(old) = self.writer.lock().unwrap().replace(stream) {
            let _ = old.shutdown(Shutdown::Both);
        }
        self.alive.store(true, Ordering::SeqCst);
        self.ever_connected.store(true, Ordering::SeqCst);
    }

    fn touch(&self) {
        *self.last_seen.lock().unwrap() = Instant::now();
    }
}

struct Inner {
    rank: u32,
    shard: ShardMap,
    cfg: SocketConfig,
    /// Member-side capture lease (ChannelNet sizing: survives a healthy
    /// round's timeout + hold, frees a dead initiator's capture after).
    lease: Duration,
    /// First node of the owned block (slot/inbox index offset).
    base: usize,
    /// Flat parameter length — inbound vectors of any other length are
    /// dropped at dispatch (a corrupt frame must not poison a slot).
    param_len: usize,
    slots: Vec<Mutex<Slot>>,
    inboxes: Vec<Mutex<VecDeque<NodeMsg>>>,
    next_token: AtomicU64,
    /// Indexed by rank; `None` at our own rank.
    links: Vec<Option<Link>>,
    local_addr: SocketAddr,
    /// Monitor (launcher) connections handed to the worker main loop.
    control: Mutex<VecDeque<TcpStream>>,
    hb_seq: AtomicU64,
    stop: AtomicBool,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// The multi-process TCP transport. Cheap to clone (an `Arc` handle);
/// call [`SocketNet::shutdown`] once per deployment to stop the
/// background threads.
#[derive(Clone)]
pub struct SocketNet {
    inner: Arc<Inner>,
}

impl SocketNet {
    /// Bind `listen` (use port 0 for an OS-assigned port), start the
    /// accept + heartbeat threads, and return the handle. Peers connect
    /// later via [`SocketNet::connect_peers`] / inbound dials.
    pub fn bind(
        rank: u32,
        shard: ShardMap,
        param_len: usize,
        listen: &str,
        cfg: SocketConfig,
    ) -> std::io::Result<Self> {
        assert!((rank as usize) < shard.workers(), "rank out of range");
        let listener = TcpListener::bind(listen)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let owned = shard.range(rank);
        let inner = Arc::new(Inner {
            rank,
            shard,
            cfg,
            lease: cfg
                .timeout
                .saturating_mul(4)
                .max(Duration::from_millis(20))
                .saturating_add(cfg.hold_budget.saturating_mul(2)),
            base: owned.start,
            param_len,
            slots: owned
                .clone()
                .map(|_| {
                    Mutex::new(Slot {
                        w: vec![0.0f32; param_len],
                        aux: Vec::new(),
                        locked_by: None,
                        locked_at: None,
                        initiating: false,
                    })
                })
                .collect(),
            inboxes: owned.map(|_| Mutex::new(VecDeque::new())).collect(),
            next_token: AtomicU64::new(1),
            links: (0..shard.workers() as u32)
                .map(|r| (r != rank).then(Link::new))
                .collect(),
            local_addr,
            control: Mutex::new(VecDeque::new()),
            hb_seq: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            threads: Mutex::new(Vec::new()),
        });
        spawn_tracked(&inner, {
            let inner = Arc::clone(&inner);
            move || accept_loop(inner, listener)
        });
        spawn_tracked(&inner, {
            let inner = Arc::clone(&inner);
            move || heartbeat_loop(inner)
        });
        if cfg.flush_bytes > 0 {
            spawn_tracked(&inner, {
                let inner = Arc::clone(&inner);
                move || flusher_loop(inner)
            });
        }
        Ok(Self { inner })
    }

    /// The address the listener actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.inner.local_addr
    }

    /// Our rank's node block.
    pub fn local_nodes(&self) -> Range<usize> {
        self.inner.shard.range(self.inner.rank)
    }

    /// Record every rank's dial address and start dialer threads for
    /// the ranks we are responsible for reaching (every rank below
    /// ours — "higher dials lower", so exactly one side of each pair
    /// owns reconnect). `peers[r]` is rank r's address; our own entry
    /// is ignored.
    pub fn connect_peers(&self, peers: &[String]) {
        assert_eq!(peers.len(), self.inner.shard.workers());
        for (r, addr) in peers.iter().enumerate() {
            let r = r as u32;
            if r == self.inner.rank {
                continue;
            }
            if let Some(link) = &self.inner.links[r as usize] {
                *link.addr.lock().unwrap() = Some(addr.clone());
            }
            if r < self.inner.rank {
                spawn_tracked(&self.inner, {
                    let inner = Arc::clone(&self.inner);
                    move || dial_loop(inner, r)
                });
            }
        }
    }

    /// Replace one peer rank's dial address (membership churn: a
    /// replacement worker took over `rank` at a new address). The
    /// current link is torn down so the dialer thread — which re-reads
    /// the address every pass — reconnects to the new worker; on the
    /// accept side the stale socket just dies and the replacement's
    /// inbound dial installs the fresh one.
    pub fn update_peer_addr(&self, rank: u32, addr: &str) {
        if let Some(link) = self
            .inner
            .links
            .get(rank as usize)
            .and_then(|l| l.as_ref())
        {
            *link.addr.lock().unwrap() = Some(addr.to_string());
            link.mark_dead();
        }
    }

    /// Wait until every peer link is up, or `deadline` passes. Returns
    /// whether the deployment is fully connected.
    pub fn wait_connected(&self, deadline: Duration) -> bool {
        let until = Instant::now() + deadline;
        loop {
            let all_up = self
                .inner
                .links
                .iter()
                .flatten()
                .all(|l| l.alive.load(Ordering::SeqCst));
            if all_up {
                return true;
            }
            if Instant::now() >= until {
                return false;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Is the link to `rank` currently up?
    pub fn peer_alive(&self, rank: u32) -> bool {
        self.inner.links[rank as usize]
            .as_ref()
            .map(|l| l.alive.load(Ordering::SeqCst))
            .unwrap_or(true)
    }

    /// Every owned node's `(id, params)` — the worker's shard of a
    /// monitor snapshot.
    pub fn local_params(&self) -> Vec<(usize, Vec<f32>)> {
        self.local_nodes()
            .map(|id| {
                (
                    id,
                    self.inner.slots[id - self.inner.base].lock().unwrap().w.clone(),
                )
            })
            .collect()
    }

    /// Next monitor control connection accepted by the listener, if any
    /// (worker main loops poll this).
    pub fn take_control(&self) -> Option<TcpStream> {
        self.inner.control.lock().unwrap().pop_front()
    }

    /// Stop background threads and close every connection. Idempotent.
    pub fn shutdown(&self) {
        let inner = &self.inner;
        inner.stop.store(true, Ordering::SeqCst);
        for link in inner.links.iter().flatten() {
            link.mark_dead();
        }
        for s in inner.control.lock().unwrap().drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        // Readers exit on their closed sockets; loops exit on `stop`.
        // New reader handles cannot appear after the accept loop exits,
        // so drain-until-empty terminates.
        loop {
            let handles: Vec<_> = inner.threads.lock().unwrap().drain(..).collect();
            if handles.is_empty() {
                break;
            }
            for h in handles {
                let _ = h.join();
            }
        }
    }
}

fn spawn_tracked(inner: &Arc<Inner>, f: impl FnOnce() + Send + 'static) {
    let handle = std::thread::spawn(f);
    inner.threads.lock().unwrap().push(handle);
}

/// Configure a fresh connection: low-latency small frames, bounded
/// writes so a wedged peer surfaces as an error instead of a block.
fn tune(stream: &TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
}

// ---------------------------------------------------------------------------
// Background threads
// ---------------------------------------------------------------------------

fn accept_loop(inner: Arc<Inner>, listener: TcpListener) {
    while !inner.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => handshake_inbound(&inner, stream),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// First frame on an inbound connection must be `Hello`; route the
/// stream to a peer link or the control queue accordingly.
fn handshake_inbound(inner: &Arc<Inner>, stream: TcpStream) {
    tune(&stream);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let mut reader = match stream.try_clone() {
        Ok(r) => r,
        Err(_) => return,
    };
    let hello = wire::read_frame(&mut reader);
    let _ = stream.set_read_timeout(None);
    match hello {
        Ok(WireMsg::Hello { rank }) if rank == MONITOR_RANK => {
            inner.control.lock().unwrap().push_back(stream);
        }
        Ok(WireMsg::Hello { rank }) if (rank as usize) < inner.links.len() => {
            if let Some(link) = &inner.links[rank as usize] {
                link.install(stream);
                spawn_tracked(inner, {
                    let inner = Arc::clone(inner);
                    move || reader_loop(inner, rank, reader)
                });
            }
        }
        // A peer from an older/newer build: refuse with a message a
        // human can act on, instead of silently dropping garbage.
        Err(e @ wire::WireError::Version { .. }) => {
            crate::log_rl!(
                Warn,
                "socket",
                "rank={}: rejected inbound connection — {e}",
                inner.rank
            );
            let _ = stream.shutdown(Shutdown::Both);
        }
        _ => {
            let _ = stream.shutdown(Shutdown::Both);
        }
    }
}

/// Dialer for one lower-ranked peer: (re)connect whenever the link is
/// down, send `Hello`, install the stream, spawn its reader.
fn dial_loop(inner: Arc<Inner>, rank: u32) {
    while !inner.stop.load(Ordering::SeqCst) {
        let link = inner.links[rank as usize].as_ref().expect("peer link");
        if link.alive.load(Ordering::SeqCst) {
            std::thread::sleep(inner.cfg.reconnect);
            continue;
        }
        let Some(addr) = link.addr.lock().unwrap().clone() else {
            std::thread::sleep(inner.cfg.reconnect);
            continue;
        };
        // Bounded dial: a black-holed host (no RST) must not pin this
        // thread for the OS SYN timeout — shutdown() joins us.
        let Some(target) = std::net::ToSocketAddrs::to_socket_addrs(addr.as_str())
            .ok()
            .and_then(|mut a| a.next())
        else {
            std::thread::sleep(inner.cfg.reconnect);
            continue;
        };
        // Only a re-dial after an established link dropped counts as a
        // reconnect; cold dials while a peer is still binding its
        // listener are normal cluster startup, not churn.
        if link.ever_connected.load(Ordering::SeqCst) {
            crate::obs::add(crate::obs::Counter::Reconnects, 1);
            crate::obs::trace("socket", "reconnect", rank as u64, 0);
        }
        match TcpStream::connect_timeout(&target, Duration::from_secs(2)) {
            Ok(stream) => {
                tune(&stream);
                let hello = WireMsg::Hello { rank: inner.rank };
                let ok = {
                    let mut s = &stream;
                    wire::write_frame(&mut s, &hello).is_ok()
                };
                if let (true, Ok(reader)) = (ok, stream.try_clone()) {
                    link.install(stream);
                    spawn_tracked(&inner, {
                        let inner = Arc::clone(&inner);
                        move || reader_loop(inner, rank, reader)
                    });
                } else {
                    let _ = stream.shutdown(Shutdown::Both);
                }
            }
            Err(_) => std::thread::sleep(inner.cfg.reconnect),
        }
    }
}

/// Drain one peer connection, dispatching protocol frames into local
/// node mailboxes. Frames pass through a per-peer [`ChunkAssembler`],
/// so a logical message larger than one frame (a huge parameter
/// vector) reassembles transparently. Exits when the socket dies or
/// the chunk stream is violated (the link is then marked dead;
/// reconnect is the dialer's job).
fn reader_loop(inner: Arc<Inner>, rank: u32, mut stream: TcpStream) {
    let mut asm = wire::ChunkAssembler::with_limit(inner.cfg.staging_limit);
    loop {
        if inner.stop.load(Ordering::SeqCst) {
            return;
        }
        match wire::read_frame(&mut stream).and_then(|frame| asm.accept(frame)) {
            Ok(completed) => {
                if let Some(link) = &inner.links[rank as usize] {
                    link.touch();
                }
                if let Some(msg) = completed {
                    dispatch(&inner, msg);
                }
            }
            Err(e) => {
                if matches!(e, wire::WireError::Version { .. }) {
                    crate::log!(
                        Warn,
                        "socket",
                        "rank={}: peer link {rank} dropped — {e}",
                        inner.rank
                    );
                } else if !inner.stop.load(Ordering::SeqCst) {
                    crate::log_rl!(
                        Debug,
                        "socket",
                        "rank={}: peer link {rank} read failed — {e}",
                        inner.rank
                    );
                }
                crate::obs::trace("socket", "link_drop", rank as u64, 0);
                if let Some(link) = &inner.links[rank as usize] {
                    // Only kill the link if this socket is still the
                    // installed one (a reconnect may have replaced it).
                    // The (local, peer) address pair identifies a
                    // socket on both the dial side (distinct local
                    // ephemeral port) and the accept side (distinct
                    // peer ephemeral port).
                    if link.alive.load(Ordering::SeqCst) {
                        let installed = link
                            .writer
                            .lock()
                            .unwrap()
                            .as_ref()
                            .map(|w| (w.local_addr().ok(), w.peer_addr().ok()))
                            == Some((stream.local_addr().ok(), stream.peer_addr().ok()));
                        if installed {
                            link.mark_dead();
                        }
                    }
                }
                return;
            }
        }
    }
}

/// Inbound wire frame → local mailbox message. Node ids are validated
/// here — `to` must be ours, `from` must exist — so a corrupt or
/// malicious frame is dropped instead of panicking a later reply's
/// routing.
fn dispatch(inner: &Inner, msg: WireMsg) {
    let n = inner.shard.nodes();
    let push = |from: u32, to: u32, m: NodeMsg| {
        let (from, to) = (from as usize, to as usize);
        if from < n && to < n && inner.shard.owner(to) == inner.rank {
            inner.inboxes[to - inner.base].lock().unwrap().push_back(m);
        }
    };
    match msg {
        // A coalesced flush: unpack and dispatch each entry in order.
        // The decoder rejects nested batches, so this recurses at most
        // one level.
        WireMsg::Batch { msgs } => {
            for m in msgs {
                dispatch(inner, m);
            }
        }
        WireMsg::CollectRequest { from, to, token } => push(
            from,
            to,
            NodeMsg::Collect {
                from: from as usize,
                token,
            },
        ),
        WireMsg::CollectReply { from, to, token, w, aux } => {
            if w.len() == inner.param_len && aux.len() <= MAX_AUX_FACTOR * 4 * inner.param_len {
                push(
                    from,
                    to,
                    NodeMsg::Params {
                        from: from as usize,
                        token,
                        w,
                        aux,
                    },
                );
            }
        }
        WireMsg::Busy { from, to, token } => push(from, to, NodeMsg::Busy { token }),
        WireMsg::Abort { from, to, token } => push(
            from,
            to,
            NodeMsg::Release {
                from: from as usize,
                token,
            },
        ),
        WireMsg::ApplyAverage { from, to, token, w, aux } => {
            if w.len() == inner.param_len && aux.len() <= MAX_AUX_FACTOR * 4 * inner.param_len {
                push(
                    from,
                    to,
                    NodeMsg::Apply {
                        from: from as usize,
                        token,
                        w,
                        aux,
                    },
                );
            }
        }
        // Heartbeats already touched the link. Control frames
        // (snapshots, plan shipping, shutdown, membership) are not
        // valid on peer links, and chunk frames never reach dispatch —
        // the reader's assembler consumed them (and a chunked *inner*
        // chunk frame is an assembler error).
        WireMsg::Heartbeat { .. }
        | WireMsg::Hello { .. }
        | WireMsg::SnapshotRequest
        | WireMsg::SnapshotReply { .. }
        | WireMsg::Shutdown
        | WireMsg::PlanAssign { .. }
        | WireMsg::PlanStart { .. }
        | WireMsg::ShardBlock { .. }
        | WireMsg::ShardComplete { .. }
        | WireMsg::ShardCredit { .. }
        | WireMsg::ChunkBegin { .. }
        | WireMsg::ChunkData { .. }
        | WireMsg::ChunkEnd { .. }
        | WireMsg::MetricsRequest
        | WireMsg::MetricsReply { .. }
        | WireMsg::JoinRequest { .. }
        | WireMsg::JoinGrant { .. }
        | WireMsg::JoinReady { .. }
        | WireMsg::PeerUpdate { .. }
        | WireMsg::LeaveNotice { .. }
        | WireMsg::TopologyPatch { .. }
        | WireMsg::HandoffBegin { .. }
        | WireMsg::HandoffEnd { .. } => {}
    }
}

/// Send heartbeats and expire silent links.
fn heartbeat_loop(inner: Arc<Inner>) {
    while !inner.stop.load(Ordering::SeqCst) {
        std::thread::sleep(inner.cfg.heartbeat);
        let seq = inner.hb_seq.fetch_add(1, Ordering::Relaxed);
        for (r, link) in inner.links.iter().enumerate() {
            let Some(link) = link else { continue };
            if !link.alive.load(Ordering::SeqCst) {
                continue;
            }
            if link.last_seen.lock().unwrap().elapsed() > inner.cfg.liveness {
                link.mark_dead();
                crate::log!(
                    Warn,
                    "socket",
                    "rank={}: peer link {r} silent past the {}ms liveness window — marked dead",
                    inner.rank,
                    inner.cfg.liveness.as_millis()
                );
                crate::obs::trace("socket", "link_dead", r as u64, 0);
                continue;
            }
            send_wire(
                &inner,
                r as u32,
                &WireMsg::Heartbeat {
                    rank: inner.rank,
                    seq,
                },
            );
        }
    }
}

/// Write one logical message to a peer rank. With coalescing enabled
/// (`flush_bytes > 0`) small protocol frames accumulate in the link's
/// per-peer [`SendBuf`] and go out as one batched wire write — flushed
/// here when the byte threshold fills, or by [`flusher_loop`] when the
/// buffer goes stale. A failed write kills the link (pending messages
/// are lost — the protocol's deadlines absorb loss as Conflict).
fn send_wire(inner: &Inner, rank: u32, msg: &WireMsg) {
    let Some(link) = &inner.links[rank as usize] else {
        return;
    };
    if inner.cfg.flush_bytes == 0 || !msg.is_batchable() {
        send_direct(link, msg);
        return;
    }
    let mut buf = link.sendbuf.lock().unwrap();
    match buf.batch.push(msg) {
        Ok(()) => {}
        Err(wire::WireError::Oversize { .. }) => {
            // The pending batch is at the frame cap — flush it, then
            // retry. A second refusal means the message alone cannot
            // fit one frame: hand it to the chunked direct path.
            flush_locked(link, &mut buf);
            if buf.batch.push(msg).is_err() {
                drop(buf);
                send_direct(link, msg);
                return;
            }
        }
        Err(_) => return,
    }
    if buf.oldest.is_none() {
        buf.oldest = Some(Instant::now());
    }
    if buf.batch.payload_bytes() >= inner.cfg.flush_bytes {
        flush_locked(link, &mut buf);
    }
}

/// Write `msg` immediately, bypassing the coalescer — the disabled-
/// batching path, non-batchable frames, and anything past the frame
/// cap (which goes out under the chunk envelope). Pending batched
/// messages flush first so the peer never sees this frame reordered
/// ahead of ones enqueued before it.
fn send_direct(link: &Link, msg: &WireMsg) {
    let mut buf = link.sendbuf.lock().unwrap();
    flush_locked(link, &mut buf);
    match wire::encode_into(msg, &mut buf.frame) {
        Ok(()) => {
            let frame = std::mem::take(&mut buf.frame);
            write_bytes(link, &frame);
            buf.frame = frame;
        }
        Err(wire::WireError::Oversize { .. }) => {
            // Larger than one frame: the chunk envelope streams it
            // without materializing the sequence.
            let mut writer = link.writer.lock().unwrap();
            let Some(stream) = writer.as_mut() else {
                return;
            };
            if wire::write_message(stream, msg).is_err() {
                if let Some(s) = writer.take() {
                    let _ = s.shutdown(Shutdown::Both);
                }
                link.alive.store(false, Ordering::SeqCst);
            }
        }
        Err(_) => {}
    }
}

/// Flush the link's pending batch as one wire write. Caller holds the
/// `sendbuf` lock.
fn flush_locked(link: &Link, buf: &mut SendBuf) {
    buf.oldest = None;
    if buf.batch.is_empty() {
        return;
    }
    // frame_into cannot fail on a non-empty builder; a defensive error
    // still clears the batch so the buffer never wedges.
    let mut frame = std::mem::take(&mut buf.frame);
    if buf.batch.frame_into(&mut frame).is_ok() {
        write_bytes(link, &frame);
    }
    buf.frame = frame;
}

/// Write pre-encoded frame bytes to the link, killing it on failure.
fn write_bytes(link: &Link, bytes: &[u8]) {
    crate::obs::observe(crate::obs::Hist::FlushBytes, bytes.len() as u64);
    crate::obs::trace("socket", "flush", 0, bytes.len() as u64);
    let mut writer = link.writer.lock().unwrap();
    let Some(stream) = writer.as_mut() else {
        return;
    };
    if std::io::Write::write_all(stream, bytes).is_err() {
        if let Some(s) = writer.take() {
            let _ = s.shutdown(Shutdown::Both);
        }
        link.alive.store(false, Ordering::SeqCst);
    }
}

/// Background sweeper: flush any per-peer batch whose oldest pending
/// message has waited `flush_micros`, so coalescing trades at most a
/// bounded sliver of latency for its write amplification win.
fn flusher_loop(inner: Arc<Inner>) {
    let stale = Duration::from_micros(inner.cfg.flush_micros.max(1));
    // Sweep at twice the staleness bound (floor 50µs keeps this thread
    // from busy-spinning under an aggressive flag).
    let sweep = (stale / 2).max(Duration::from_micros(50));
    while !inner.stop.load(Ordering::SeqCst) {
        std::thread::sleep(sweep);
        for link in inner.links.iter().flatten() {
            let mut buf = link.sendbuf.lock().unwrap();
            if buf.oldest.map(|t| t.elapsed() >= stale).unwrap_or(false) {
                flush_locked(link, &mut buf);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The protocol (ChannelNet semantics, routed local-or-wire).
//
// This is transport/channel.rs's member/initiator state machine with
// routing swapped from local deques to wire frames — protocol changes
// there must land here too (and vice versa).
// ---------------------------------------------------------------------------

impl Inner {
    fn is_local(&self, node: usize) -> bool {
        self.shard.owner(node) == self.rank
    }

    fn slot(&self, node: usize) -> &Mutex<Slot> {
        debug_assert!(self.is_local(node), "node {node} is not owned here");
        &self.slots[node - self.base]
    }

    /// Route a protocol message to `to`: local mailbox or wire frame.
    fn send(&self, from: usize, to: usize, msg: NodeMsg) {
        if self.is_local(to) {
            self.inboxes[to - self.base].lock().unwrap().push_back(msg);
            return;
        }
        let (f, t) = (from as u32, to as u32);
        let frame = match msg {
            NodeMsg::Collect { token, .. } => WireMsg::CollectRequest { from: f, to: t, token },
            NodeMsg::Params { token, w, aux, .. } => WireMsg::CollectReply {
                from: f,
                to: t,
                token,
                w,
                aux,
            },
            NodeMsg::Busy { token } => WireMsg::Busy { from: f, to: t, token },
            NodeMsg::Apply { token, w, aux, .. } => WireMsg::ApplyAverage {
                from: f,
                to: t,
                token,
                w,
                aux,
            },
            NodeMsg::Release { token, .. } => WireMsg::Abort { from: f, to: t, token },
        };
        send_wire(self, self.shard.owner(to), &frame);
    }

    fn recv(&self, id: usize) -> Option<NodeMsg> {
        self.inboxes[id - self.base].lock().unwrap().pop_front()
    }

    fn expire_stale_capture(&self, id: usize) {
        let mut slot = self.slot(id).lock().unwrap();
        if slot.locked_by.is_some()
            && slot
                .locked_at
                .map(|t| t.elapsed() > self.lease)
                .unwrap_or(false)
        {
            slot.locked_by = None;
            slot.locked_at = None;
        }
    }

    /// Process one inbound message for `id` — the ChannelNet state
    /// machine verbatim, with replies routed local-or-wire.
    fn handle(&self, id: usize, msg: NodeMsg, round: &mut Option<&mut Round>) {
        match msg {
            NodeMsg::Collect { from, token } => {
                let reply = {
                    let mut slot = self.slot(id).lock().unwrap();
                    if slot.initiating || slot.locked_by.is_some() {
                        None
                    } else {
                        slot.locked_by = Some((from, token));
                        slot.locked_at = Some(Instant::now());
                        Some((slot.w.clone(), slot.aux.clone()))
                    }
                };
                match reply {
                    Some((w, aux)) => {
                        self.send(id, from, NodeMsg::Params { from: id, token, w, aux })
                    }
                    None => self.send(id, from, NodeMsg::Busy { token }),
                }
            }
            NodeMsg::Params { from, token, w, aux } => match round {
                Some(r) if r.token == token => r.replies.push((from, w, aux)),
                // Stale reply: the member is captured by our dead
                // round's token — free it.
                _ => self.send(id, from, NodeMsg::Release { from: id, token }),
            },
            NodeMsg::Busy { token } => {
                if let Some(r) = round {
                    if r.token == token {
                        r.busy = true;
                    }
                }
            }
            NodeMsg::Apply { from, token, w, aux } => {
                let mut slot = self.slot(id).lock().unwrap();
                if slot.locked_by == Some((from, token)) {
                    slot.w = w;
                    slot.aux = aux;
                    slot.locked_by = None;
                    slot.locked_at = None;
                }
            }
            NodeMsg::Release { from, token } => {
                let mut slot = self.slot(id).lock().unwrap();
                if slot.locked_by == Some((from, token)) {
                    slot.locked_by = None;
                    slot.locked_at = None;
                }
            }
        }
    }

    fn drain(&self, id: usize, mut round: Option<&mut Round>) {
        while let Some(msg) = self.recv(id) {
            self.handle(id, msg, &mut round);
        }
    }
}

impl Transport for SocketNet {
    fn len(&self) -> usize {
        self.inner.shard.nodes()
    }

    fn update_own(&self, id: usize, f: &mut dyn FnMut(&mut Vec<f32>)) {
        let mut slot = self.inner.slot(id).lock().unwrap();
        f(&mut slot.w);
    }

    fn update_own_with_aux(&self, id: usize, f: &mut dyn FnMut(&mut Vec<f32>, &mut Vec<u8>)) {
        let mut slot = self.inner.slot(id).lock().unwrap();
        let Slot { w, aux, .. } = &mut *slot;
        f(w, aux);
    }

    fn busy(&self, id: usize) -> bool {
        self.inner.expire_stale_capture(id);
        self.inner.slot(id).lock().unwrap().locked_by.is_some()
    }

    fn poll(&self, id: usize) {
        self.inner.expire_stale_capture(id);
        self.inner.drain(id, None);
    }

    fn reachable(&self, id: usize) -> bool {
        let owner = self.inner.shard.owner(id);
        owner == self.inner.rank
            || self.inner.links[owner as usize]
                .as_ref()
                .map(|l| l.alive.load(Ordering::SeqCst))
                .unwrap_or(false)
    }

    fn try_project(
        &self,
        id: usize,
        hood: &[usize],
        hold: Duration,
        mix: &mut dyn FnMut(&[&[f32]], &[&[u8]]) -> (Vec<f32>, Vec<u8>),
    ) -> ProjectionOutcome {
        let inner = &*self.inner;
        debug_assert!(hood.contains(&id));
        debug_assert!(inner.is_local(id), "only the owner initiates for {id}");
        if hood.len() < 2 {
            return ProjectionOutcome::Isolated;
        }
        let token = inner.next_token.fetch_add(1, Ordering::Relaxed);
        let (own, own_aux) = {
            let mut slot = inner.slot(id).lock().unwrap();
            if slot.locked_by.is_some() {
                return ProjectionOutcome::Conflict;
            }
            slot.initiating = true;
            (slot.w.clone(), slot.aux.clone())
        };
        let peers: Vec<usize> = hood.iter().copied().filter(|&j| j != id).collect();
        let round_start = Instant::now();
        crate::obs::trace("socket", "collect", id as u64, peers.len() as u64);
        for &j in &peers {
            inner.send(id, j, NodeMsg::Collect { from: id, token });
        }
        let mut round = Round {
            token,
            replies: Vec::with_capacity(peers.len()),
            busy: false,
        };
        let deadline = Instant::now() + inner.cfg.timeout;
        while round.replies.len() < peers.len() && !round.busy {
            inner.drain(id, Some(&mut round));
            if round.replies.len() >= peers.len() || round.busy {
                break;
            }
            if Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
        let complete = round.replies.len() == peers.len() && !round.busy;
        if complete {
            // Full collect round-trip over the wire: the closest thing a
            // worker has to a per-projection message-delay sample.
            crate::obs::observe(
                crate::obs::Hist::MessageDelayUs,
                round_start.elapsed().as_micros() as u64,
            );
        } else {
            for (from, _, _) in &round.replies {
                inner.send(id, *from, NodeMsg::Release { from: id, token });
            }
            inner.slot(id).lock().unwrap().initiating = false;
            return ProjectionOutcome::Conflict;
        }
        if hold > Duration::ZERO {
            std::thread::sleep(hold);
        }
        // Mix in hood order (self row in place of `id`), params and aux
        // blobs aligned.
        let reply_for = |j: usize| {
            round
                .replies
                .iter()
                .find(|(from, _, _)| *from == j)
                .expect("complete round has every peer's reply")
        };
        let rows: Vec<&[f32]> = hood
            .iter()
            .map(|&j| {
                if j == id {
                    own.as_slice()
                } else {
                    reply_for(j).1.as_slice()
                }
            })
            .collect();
        let aux_rows: Vec<&[u8]> = hood
            .iter()
            .map(|&j| {
                if j == id {
                    own_aux.as_slice()
                } else {
                    reply_for(j).2.as_slice()
                }
            })
            .collect();
        let (mean, mean_aux) = mix(&rows, &aux_rows);
        for &j in &peers {
            inner.send(
                id,
                j,
                NodeMsg::Apply {
                    from: id,
                    token,
                    w: mean.clone(),
                    aux: mean_aux.clone(),
                },
            );
        }
        let mut slot = inner.slot(id).lock().unwrap();
        slot.w = mean;
        slot.aux = mean_aux;
        slot.initiating = false;
        ProjectionOutcome::Applied {
            participants: hood.len(),
        }
    }

    /// Owned nodes report real parameters; nodes of other shards are
    /// empty vectors (a worker cannot see them — monitor-side snapshot
    /// aggregation in [`crate::net::cluster`] composes the shards).
    fn snapshot(&self) -> Vec<Vec<f32>> {
        (0..self.inner.shard.nodes())
            .map(|id| {
                if self.inner.is_local(id) {
                    self.inner.slot(id).lock().unwrap().w.clone()
                } else {
                    Vec::new()
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node_logic::neighborhood_average;

    fn fast_cfg() -> SocketConfig {
        SocketConfig {
            timeout: Duration::from_millis(200),
            heartbeat: Duration::from_millis(40),
            liveness: Duration::from_millis(250),
            reconnect: Duration::from_millis(40),
            ..SocketConfig::default()
        }
    }

    /// Two ranks over loopback TCP, nodes 0..4 split 2+2.
    fn pair(param_len: usize) -> (SocketNet, SocketNet) {
        pair_with(param_len, fast_cfg())
    }

    fn pair_with(param_len: usize, cfg: SocketConfig) -> (SocketNet, SocketNet) {
        let shard = ShardMap::new(4, 2);
        let a = SocketNet::bind(0, shard, param_len, "127.0.0.1:0", cfg).unwrap();
        let b = SocketNet::bind(1, shard, param_len, "127.0.0.1:0", cfg).unwrap();
        let peers = vec![a.local_addr().to_string(), b.local_addr().to_string()];
        a.connect_peers(&peers);
        b.connect_peers(&peers);
        assert!(a.wait_connected(Duration::from_secs(5)), "a never connected");
        assert!(b.wait_connected(Duration::from_secs(5)), "b never connected");
        (a, b)
    }

    fn pump(net: &SocketNet, ids: Vec<usize>, stop: Arc<AtomicBool>) -> std::thread::JoinHandle<()> {
        let net = net.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                for &j in &ids {
                    net.poll(j);
                }
                std::thread::sleep(Duration::from_micros(100));
            }
        })
    }

    #[test]
    fn shard_map_blocks_cover_all_nodes() {
        for (n, workers) in [(4, 2), (8, 3), (10, 4), (7, 7), (5, 1)] {
            let s = ShardMap::new(n, workers);
            let mut seen = vec![false; n];
            for r in 0..workers as u32 {
                for node in s.range(r) {
                    assert_eq!(s.owner(node), r, "n={n} w={workers} node={node}");
                    assert!(!seen[node]);
                    seen[node] = true;
                }
            }
            assert!(seen.iter().all(|&v| v), "n={n} w={workers}");
        }
    }

    #[test]
    fn cross_shard_projection_round_trips_over_tcp() {
        let (a, b) = pair(2);
        // World: node 1 (rank 0) initiates over {0, 1, 2}; node 2 lives
        // on rank 1, across the wire.
        a.update_own(0, &mut |w| w.copy_from_slice(&[3.0, 0.0]));
        b.update_own(2, &mut |w| w.copy_from_slice(&[0.0, 6.0]));
        let stop = Arc::new(AtomicBool::new(false));
        let pumps = vec![pump(&a, vec![0], stop.clone()), pump(&b, vec![2, 3], stop.clone())];
        let out = a.try_project(1, &[0, 1, 2], Duration::ZERO, &mut |rows, _aux| {
            (neighborhood_average(rows), Vec::new())
        });
        assert_eq!(out, ProjectionOutcome::Applied { participants: 3 });
        // Wait for the Apply to land on rank 1's node 2.
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            let w2 = b.local_params()[0].1.clone();
            if w2 == vec![1.0, 2.0] {
                break;
            }
            assert!(Instant::now() < deadline, "Apply never landed: {w2:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(a.local_params()[0].1, vec![1.0, 2.0]);
        assert_eq!(a.local_params()[1].1, vec![1.0, 2.0]);
        stop.store(true, Ordering::Relaxed);
        for p in pumps {
            p.join().unwrap();
        }
        a.shutdown();
        b.shutdown();
    }

    #[test]
    fn aux_blobs_cross_the_wire_with_params() {
        let (a, b) = pair(1);
        // Node 2 (rank 1) publishes an aux blob; node 0 (rank 0)
        // projects over {0, 2}: the blob must cross the wire in the
        // CollectReply and the mixed blob must land back via the Apply.
        b.update_own_with_aux(2, &mut |w, aux| {
            w[0] = 4.0;
            aux.extend_from_slice(&[1, 2, 3]);
        });
        let stop = Arc::new(AtomicBool::new(false));
        let pumps = vec![pump(&b, vec![2, 3], stop.clone())];
        let out = a.try_project(0, &[0, 2], Duration::ZERO, &mut |rows, aux_rows| {
            assert_eq!(aux_rows, &[&[][..], &[1u8, 2, 3][..]]);
            (neighborhood_average(rows), vec![7, 7])
        });
        assert_eq!(out, ProjectionOutcome::Applied { participants: 2 });
        // Wait for the Apply (with aux) to land on rank 1's node 2.
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            let mut landed = false;
            b.update_own_with_aux(2, &mut |w, aux| {
                landed = w[0] == 2.0 && aux == &vec![7, 7];
            });
            if landed {
                break;
            }
            assert!(Instant::now() < deadline, "aux Apply never landed");
            std::thread::sleep(Duration::from_millis(5));
        }
        a.update_own_with_aux(0, &mut |_w, aux| assert_eq!(aux, &vec![7, 7]));
        stop.store(true, Ordering::Relaxed);
        for p in pumps {
            p.join().unwrap();
        }
        a.shutdown();
        b.shutdown();
    }

    /// The same cross-shard round as above, once with coalescing
    /// disabled (`--flush-bytes 0`, every frame its own write) and once
    /// with a sweeper-dependent policy (threshold too large to fill, so
    /// every flush is the staleness sweeper's) — the protocol outcome
    /// is identical either way.
    #[test]
    fn projection_outcome_is_policy_independent() {
        let unbatched = SocketConfig {
            flush_bytes: 0,
            ..fast_cfg()
        };
        let sweeper_only = SocketConfig {
            flush_bytes: 1 << 20,
            flush_micros: 200,
            ..fast_cfg()
        };
        for cfg in [unbatched, sweeper_only] {
            let (a, b) = pair_with(2, cfg);
            a.update_own(0, &mut |w| w.copy_from_slice(&[3.0, 0.0]));
            b.update_own(2, &mut |w| w.copy_from_slice(&[0.0, 6.0]));
            let stop = Arc::new(AtomicBool::new(false));
            let pumps = vec![pump(&a, vec![0], stop.clone()), pump(&b, vec![2, 3], stop.clone())];
            let out = a.try_project(1, &[0, 1, 2], Duration::ZERO, &mut |rows, _aux| {
                (neighborhood_average(rows), Vec::new())
            });
            assert_eq!(out, ProjectionOutcome::Applied { participants: 3 });
            let deadline = Instant::now() + Duration::from_secs(2);
            loop {
                let w2 = b.local_params()[0].1.clone();
                if w2 == vec![1.0, 2.0] {
                    break;
                }
                assert!(Instant::now() < deadline, "Apply never landed: {w2:?}");
                std::thread::sleep(Duration::from_millis(5));
            }
            stop.store(true, Ordering::Relaxed);
            for p in pumps {
                p.join().unwrap();
            }
            a.shutdown();
            b.shutdown();
        }
    }

    #[test]
    fn dead_peer_times_out_as_conflict_and_goes_unreachable() {
        let (a, b) = pair(1);
        assert!(a.reachable(2));
        // Kill rank 1 without ceremony (a crashed worker).
        b.shutdown();
        // A round over the dead peer's node must abort, not hang.
        let t0 = Instant::now();
        let out = a.try_project(1, &[1, 2], Duration::ZERO, &mut |rows, _aux| {
            (neighborhood_average(rows), Vec::new())
        });
        assert_eq!(out, ProjectionOutcome::Conflict);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "round must be deadline-bounded"
        );
        // Liveness marks the peer's nodes unreachable soon after.
        let deadline = Instant::now() + Duration::from_secs(3);
        while a.reachable(2) {
            assert!(Instant::now() < deadline, "peer never went unreachable");
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(a.reachable(0), "own nodes stay reachable");
        a.shutdown();
    }

    #[test]
    fn older_wire_version_peer_is_refused_cleanly() {
        // A v2 peer dialing a v3 worker: the handshake decode fails
        // with a Version error and the connection is closed — the v2
        // side sees a clean EOF (its own decoder rejects v3 frames
        // symmetrically), never protocol garbage.
        let net = SocketNet::bind(0, ShardMap::new(2, 1), 4, "127.0.0.1:0", fast_cfg()).unwrap();
        let mut s = TcpStream::connect(net.local_addr()).unwrap();
        // A version-2 Hello frame: [len=6][version=2][tag=0][rank u32].
        let mut frame = 6u32.to_le_bytes().to_vec();
        frame.extend_from_slice(&[2u8, 0u8]);
        frame.extend_from_slice(&1u32.to_le_bytes());
        std::io::Write::write_all(&mut s, &frame).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut buf = [0u8; 16];
        let n = std::io::Read::read(&mut s, &mut buf).unwrap_or(0);
        assert_eq!(n, 0, "a v2 connection must be closed, not answered");
        net.shutdown();
    }

    #[test]
    fn reconnect_restores_the_link() {
        let (a, b) = pair(1);
        // Drop rank 1's view of the link; the dialer (rank 1) must
        // re-establish it.
        if let Some(link) = &b.inner.links[0] {
            link.mark_dead();
        }
        assert!(
            b.wait_connected(Duration::from_secs(5)),
            "dialer should reconnect a dropped link"
        );
        a.shutdown();
        b.shutdown();
    }
}
