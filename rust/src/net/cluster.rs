//! Worker and launcher entrypoints for multi-process deployments.
//!
//! A deployment is K *worker* processes (`dasgd worker --rank R
//! --peers a0,a1,...`), each owning one [`ShardMap`] block of nodes and
//! driving it with the same [`spawn_shard_with_feeds`] engine the
//! in-process cluster uses — just over a [`SocketNet`] instead of a
//! local substrate. Workers rendezvous by address list: every rank binds its
//! own entry of `--peers` and dials every lower rank.
//!
//! Workloads are [`WorkloadPlan`]s. The *launcher* (`dasgd launch
//! --workers K [--plan P --dirichlet-alpha A]`) builds the plan once
//! and **streams each worker its owned shards over the wire**: the
//! `PlanAssign`/`PlanStart` frames on the control connection now carry
//! metadata only (objectives, shapes — empty shards), and the data
//! itself follows as a stream of fixed-budget [`RowBlock`]s
//! (`ShardBlock` frames, interleaved round-robin across the rank's
//! nodes, each block checksummed before a row is staged). A worker
//! starts stepping as soon as its first block lands — it never holds a
//! whole shard in transit, because staging is bounded by
//! `--staging-mb` and the launcher's send window closes until the
//! worker returns `ShardCredit` for drained bytes (see docs/data.md
//! for the protocol). A final `ShardComplete` per node carries the
//! whole-shard checksum fold, so a stream that completes certifies the
//! reassembled shard bit-identical to the plan's. Only the topology is
//! re-derived from `(nodes, degree)`, which is deterministic and
//! cheap. A standalone worker (spanning machines, no launcher) instead
//! derives its plan locally from `--plan <spec>`: the builders are
//! bit-deterministic in `(spec, nodes, seed)`, so every rank
//! reconstructs identical shards.
//!
//! After shipping, the launcher plays *monitor* — it polls every
//! worker's shard over the control connection
//! (`SnapshotRequest`/`SnapshotReply`), aggregates parameters and
//! counters, and feeds the same [`Probe`]/[`Recorder`] path every other
//! engine records through (mixed-objective cohorts evaluate under the
//! [`Probe::mixed`] convention). The run ends when the aggregate
//! applied-update count reaches `--horizon` (or the wall-clock cap), at
//! which point the monitor broadcasts `Shutdown`.
//!
//! Failure semantics: a worker that dies mid-run simply drops out of
//! monitor aggregation (metrics continue over the live cohort, exactly
//! like fault-injected kills in-process), and its peers' liveness
//! filtering degrades its nodes' projections to `Conflict`/`Isolated`
//! — survivors never hang.

use std::collections::VecDeque;
use std::io::{Read as _, Write as _};
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{spawn_shard_with_feeds, AsyncConfig, EngineKind, ShardRun};
use crate::data::stream::{fold_payloads, BlockBuffer, RowBlock, StreamProgress, DEFAULT_BLOCK_ROWS};
use crate::data::Dataset;
use crate::experiments::make_regular;
use crate::membership::Membership;
use crate::metrics::Recorder;
use crate::node_logic::{Counts, Probe, StrategyKind};
use crate::objective::Objective;
use crate::transport::{Transport, TransportKind};
use crate::util::Stopwatch;
use crate::workload::{objective_code, objective_from_code, NodeAssignment, PlanSpec, WorkloadPlan};

use super::socket::{ShardMap, SocketConfig, SocketNet};
use super::wire::{self, WireMsg, MONITOR_RANK};

/// Default samples per node in a deployment's synthetic world (matches
/// the in-process `cluster` command, so cross-mode runs are
/// comparable). Override with `--samples` / the config fields — large
/// values are how quantity-skewed plans grow shards past the wire's
/// frame cap.
pub const SAMPLES_PER_NODE: usize = 300;
const TEST_SAMPLES: usize = 512;

/// One control-plane connection: the TCP stream plus the read buffer
/// and chunk-reassembly staging that make *logical* messages resumable.
/// A frame split across a read timeout resumes on the next call, and a
/// chunked message (a large `PlanAssign` or `SnapshotReply`) staged
/// across several calls completes when its envelope does — neither ever
/// desyncs the stream.
struct ControlConn {
    stream: TcpStream,
    buf: Vec<u8>,
    assembler: wire::ChunkAssembler,
}

impl ControlConn {
    fn new(stream: TcpStream) -> Self {
        Self::with_limit(stream, wire::MAX_MESSAGE_LEN)
    }

    /// A connection whose chunk staging is capped at `limit` bytes
    /// (`--staging-mb`) instead of the codec's absolute 1 GiB.
    fn with_limit(stream: TcpStream, limit: usize) -> Self {
        Self {
            stream,
            buf: Vec::new(),
            assembler: wire::ChunkAssembler::with_limit(limit),
        }
    }

    fn set_write_timeout(&self, dur: Duration) {
        let _ = self.stream.set_write_timeout(Some(dur));
    }

    /// Re-cap the chunk-reassembly staging (a joiner learns its
    /// `--staging-mb` from the `JoinGrant`, after the connection
    /// already exists). Only sound between logical messages — the
    /// join handshake guarantees that.
    fn set_staging_limit(&mut self, limit: usize) {
        self.assembler = wire::ChunkAssembler::with_limit(limit);
    }

    /// Read one logical message. Returns `Ok(None)` when nothing
    /// complete arrived by `deadline` (a transient stall, not an
    /// error); buffered bytes and chunk staging persist across calls.
    fn read_msg(&mut self, deadline: Instant) -> Result<Option<WireMsg>, wire::WireError> {
        loop {
            // Drain frames already buffered before touching the socket.
            while let Some((frame_msg, used)) = wire::decode(&self.buf)? {
                self.buf.drain(..used);
                if let Some(msg) = self.assembler.accept(frame_msg)? {
                    return Ok(Some(msg));
                }
            }
            if Instant::now() >= deadline {
                return Ok(None);
            }
            let mut tmp = [0u8; 65536];
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    return Err(wire::WireError::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "control connection closed",
                    )))
                }
                Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) => {}
                Err(e) => return Err(wire::WireError::Io(e)),
            }
        }
    }

    /// Write one logical message (chunked past the frame cap).
    fn write_msg(&mut self, msg: &WireMsg) -> Result<(), wire::WireError> {
        wire::write_message(&mut self.stream, msg)
    }
}

// ---------------------------------------------------------------------------
// Plan ⇄ wire
// ---------------------------------------------------------------------------

/// Encode node `id`'s assignment as a `PlanAssign` control message.
/// Total for any shard size: the wire layer's chunk envelope carries
/// what a single frame cannot (pre-v3 this hard-errored past 16 MiB).
pub fn plan_assign_msg(id: usize, a: &NodeAssignment) -> WireMsg {
    let (obj_code, lam) = objective_code(a.objective);
    WireMsg::PlanAssign {
        node: id as u32,
        obj_code,
        lam,
        dim: a.shard.dim() as u32,
        classes: a.shard.classes() as u32,
        labels: a.shard.labels().iter().map(|&l| l as u32).collect(),
        features: a.shard.features_flat().to_vec(),
        strategy: a.strategy.code(),
    }
}

/// Decode a `PlanAssign` frame back into `(node, assignment)`,
/// validating everything a hostile or corrupt frame could lie about
/// (shape mismatches, out-of-range labels, unknown objective codes).
pub fn assignment_from_msg(msg: &WireMsg) -> Result<(usize, NodeAssignment)> {
    let WireMsg::PlanAssign {
        node,
        obj_code,
        lam,
        dim,
        classes,
        labels,
        features,
        strategy,
    } = msg
    else {
        bail!("not a PlanAssign frame");
    };
    let (dim, classes) = (*dim as usize, *classes as usize);
    if dim == 0 || classes == 0 {
        bail!("plan frame with zero dim/classes");
    }
    let Some(objective) = objective_from_code(*obj_code, *lam) else {
        bail!("unknown objective code {obj_code}");
    };
    let Some(strategy) = StrategyKind::from_code(*strategy) else {
        bail!("unknown strategy code {strategy}");
    };
    if features.len() != labels.len() * dim {
        bail!(
            "plan frame shape lies: {} labels × {dim} features ≠ {} values",
            labels.len(),
            features.len()
        );
    }
    let mut shard = crate::data::Dataset::with_capacity(dim, classes, labels.len());
    for (i, &label) in labels.iter().enumerate() {
        let label = label as usize;
        if label >= classes {
            bail!("plan frame label {label} out of range for {classes} classes");
        }
        shard.push(&features[i * dim..(i + 1) * dim], label);
    }
    Ok((
        *node as usize,
        NodeAssignment {
            objective,
            shard,
            strategy,
        },
    ))
}

// ---------------------------------------------------------------------------
// Worker
// ---------------------------------------------------------------------------

/// Where a worker's workload comes from.
#[derive(Clone, Copy, Debug)]
pub enum WorkerPlanSource {
    /// Derive the plan locally from a deterministic recipe — every
    /// rank rebuilds identical shards from `(spec, nodes, seed)`. The
    /// standalone multi-machine mode.
    Local(PlanSpec),
    /// Receive the plan from the launch monitor over the control
    /// connection (`PlanAssign`/`PlanStart`). The engine binds before
    /// the data arrives, so the parameter length must be given up
    /// front (`--param-len`; the launcher computes it from the plan).
    Wire { param_len: usize },
}

/// One worker process's configuration.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub rank: u32,
    /// Every rank's `host:port`, rank-ordered; ours is bound, lower
    /// ranks are dialed.
    pub peers: Vec<String>,
    pub nodes: usize,
    pub degree: usize,
    /// Wall-clock cap: exit even if no `Shutdown` ever arrives (a dead
    /// monitor must not leave worker processes behind).
    pub secs: f64,
    pub rate_hz: f64,
    /// The uniform loss family for local plan specs (and the stepsize
    /// base); per-node objectives of a shipped or mixed plan supersede
    /// it.
    pub objective: Objective,
    /// The uniform update strategy for local plan specs (`--strategy`);
    /// per-node strategies of a shipped plan supersede it.
    pub strategy: StrategyKind,
    pub plan: WorkerPlanSource,
    /// Samples per node for locally-derived plans (ignored for
    /// `--plan wire`, where the launcher decides).
    pub samples_per_node: usize,
    pub seed: u64,
    /// Staging budget in MiB (`--staging-mb`): bounds both the
    /// streaming [`BlockBuffer`] (blocks staged but not yet consumed by
    /// node tasks) and every connection's chunk-reassembly staging.
    pub staging_mb: usize,
    /// Executor threads driving this rank's node tasks
    /// (`--executors N`; 0 = one per CPU core).
    pub executors: usize,
    /// Per-peer coalescing byte threshold (`--flush-bytes`; 0 turns
    /// batching off — every frame ships alone, the pre-v5 wire shape).
    pub flush_bytes: usize,
    /// Staleness bound on a coalescing buffer (`--flush-micros`).
    pub flush_micros: u64,
    /// Depart gracefully after this many seconds (`--leave-after`):
    /// send the monitor a `LeaveNotice` and exit, exercising the same
    /// vacate-repair-handoff path a heartbeat eviction takes.
    pub leave_after: Option<f64>,
}

/// What a finished worker reports.
#[derive(Debug)]
pub struct WorkerSummary {
    pub counts: Counts,
    /// True when the monitor ended the run (vs the wall-clock cap).
    pub shutdown_by_monitor: bool,
}

/// Wait for the launch monitor's control connection and drain its
/// `PlanAssign` stream up to `PlanStart`. Returns the worker's partial
/// plan, the control connection (so the serve loop continues on the
/// very same stream), and whether the shard data follows as a
/// `ShardBlock` stream (`PlanStart.streaming`) rather than riding the
/// assignments themselves. The `PlanStart` checksum is verified
/// against what actually arrived — a corrupted shipment refuses to
/// start instead of training on wrong bits.
fn receive_wire_plan(
    net: &SocketNet,
    nodes: usize,
    param_len: usize,
    deadline: Instant,
    staging_limit: usize,
) -> Result<(WorkloadPlan, ControlConn, bool)> {
    let conn = loop {
        if let Some(c) = net.take_control() {
            break c;
        }
        if Instant::now() >= deadline {
            bail!("no monitor connected to ship the workload plan");
        }
        std::thread::sleep(Duration::from_millis(25));
    };
    let _ = conn.set_read_timeout(Some(Duration::from_millis(50)));
    let _ = conn.set_write_timeout(Some(Duration::from_secs(1)));
    let mut conn = ControlConn::with_limit(conn, staging_limit);
    let (plan, streaming) = receive_plan_on(&mut conn, nodes, param_len, deadline)?;
    Ok((plan, conn, streaming))
}

/// Drain one control connection's `PlanAssign` stream up to
/// `PlanStart` — the body of [`receive_wire_plan`], split out so a
/// joiner (which already holds its monitor connection from the
/// `JoinRequest` handshake) can receive its plan on the same stream.
fn receive_plan_on(
    conn: &mut ControlConn,
    nodes: usize,
    param_len: usize,
    deadline: Instant,
) -> Result<(WorkloadPlan, bool)> {
    let mut assigned: Vec<(usize, NodeAssignment)> = Vec::new();
    let mut received_sum = wire::Fnv64::new();
    let (global_mixed, want_checksum, streaming) = loop {
        let frame_deadline = Instant::now() + Duration::from_millis(250);
        match conn.read_msg(frame_deadline) {
            Ok(Some(msg @ WireMsg::PlanAssign { .. })) => {
                // Fold the canonical per-message checksum of what we
                // actually decoded — bit-identical shipping makes this
                // land on the launcher's PlanStart value.
                let sum = wire::message_checksum(&msg)
                    .map_err(|e| anyhow!("re-encoding a received assignment: {e}"))?;
                received_sum.update(&sum.to_le_bytes());
                assigned.push(assignment_from_msg(&msg)?);
            }
            Ok(Some(WireMsg::PlanStart {
                nodes: n_total,
                assigned: count,
                mixed,
                checksum,
                streaming,
            })) => {
                if n_total as usize != nodes {
                    bail!("plan is for {n_total} nodes, this deployment has {nodes}");
                }
                if count as usize != assigned.len() {
                    bail!(
                        "monitor announced {count} assignments, {} arrived",
                        assigned.len()
                    );
                }
                break (mixed, checksum, streaming);
            }
            Ok(Some(_)) => {} // nothing else is meaningful pre-start
            Ok(None) => {
                if Instant::now() >= deadline {
                    bail!("workload plan never completed before the deadline");
                }
            }
            Err(e) => return Err(anyhow!("control stream failed mid-plan: {e}")),
        }
    };
    if received_sum.finish() != want_checksum {
        bail!(
            "shipped plan failed its integrity checksum (got {:#x}, monitor sent {want_checksum:#x}) \
             — refusing to train on corrupted shards",
            received_sum.finish()
        );
    }
    let Some((_, first)) = assigned.first() else {
        bail!("monitor started the run without shipping any assignment");
    };
    let (dim, classes) = (first.shard.dim(), first.shard.classes());
    let plan = WorkloadPlan::from_partial(nodes, dim, classes, assigned, global_mixed)?;
    if plan.param_len() != param_len {
        bail!(
            "shipped plan's parameter length {} does not match --param-len {param_len}",
            plan.param_len()
        );
    }
    Ok((plan, streaming))
}

/// Per-owned-node reassembly state a streaming worker keeps while its
/// `ShardBlock` stream is live.
struct NodeStreamState {
    progress: StreamProgress,
    done: bool,
    /// The certified whole-shard checksum fold, recorded when
    /// `ShardComplete` verified — what a later `HandoffEnd` must match.
    checksum: u64,
}

/// Run one worker to completion: bind, rendezvous, obtain the workload
/// plan (local recipe or shipped over the wire), drive the owned shard,
/// serve monitor snapshots, exit on `Shutdown` or the cap.
pub fn run_worker(cfg: &WorkerConfig) -> Result<WorkerSummary> {
    let workers = cfg.peers.len();
    if workers == 0 {
        bail!("--peers must list every worker's host:port");
    }
    if cfg.rank as usize >= workers {
        bail!("--rank {} out of range for {} peers", cfg.rank, workers);
    }
    if workers > cfg.nodes {
        bail!("more workers ({workers}) than nodes ({})", cfg.nodes);
    }
    let graph = make_regular(cfg.nodes, cfg.degree);
    let objective = cfg.objective;
    // A locally-derived plan exists before the engine binds; a shipped
    // one arrives after (its parameter length came on the CLI).
    let (local_plan, param_len) = match cfg.plan {
        WorkerPlanSource::Local(spec) => {
            let (plan, _test) = spec.build(
                objective,
                cfg.nodes,
                cfg.samples_per_node,
                TEST_SAMPLES,
                cfg.seed,
            );
            let plan = plan.with_uniform_strategy(cfg.strategy);
            let param_len = plan.param_len();
            (Some(plan), param_len)
        }
        WorkerPlanSource::Wire { param_len } => {
            if param_len == 0 {
                bail!("--plan wire needs --param-len (the launcher supplies it)");
            }
            (None, param_len)
        }
    };

    if cfg.staging_mb == 0 {
        bail!("--staging-mb must be at least 1");
    }
    let staging_limit = cfg
        .staging_mb
        .saturating_mul(1 << 20)
        .min(wire::MAX_MESSAGE_LEN);
    let shard_map = ShardMap::new(cfg.nodes, workers);
    let net = SocketNet::bind(
        cfg.rank,
        shard_map,
        param_len,
        &cfg.peers[cfg.rank as usize],
        SocketConfig {
            staging_limit,
            flush_bytes: cfg.flush_bytes,
            flush_micros: cfg.flush_micros,
            ..SocketConfig::default()
        },
    )
    .with_context(|| format!("binding {}", cfg.peers[cfg.rank as usize]))?;
    let owned = net.local_nodes();
    println!(
        "dasgd-worker rank={} listening on {} (nodes {}..{} of {})",
        cfg.rank,
        net.local_addr(),
        owned.start,
        owned.end,
        cfg.nodes
    );
    let _ = std::io::stdout().flush();
    net.connect_peers(&cfg.peers);
    if !net.wait_connected(Duration::from_secs(10)) {
        crate::log!(
            Warn,
            "cluster",
            "rank={}: not all peers reachable after 10s; \
             continuing degraded (their nodes are filtered from neighborhoods)",
            cfg.rank
        );
    }

    let deadline = Instant::now() + Duration::from_secs_f64(cfg.secs.max(0.1));
    let mut controls: Vec<ControlConn> = Vec::new();
    let mut streaming = false;
    let plan = match local_plan {
        Some(plan) => plan,
        None => {
            let (plan, conn, is_streaming) =
                receive_wire_plan(&net, cfg.nodes, param_len, deadline, staging_limit)
                    .with_context(|| format!("rank {} receiving the workload plan", cfg.rank))?;
            controls.push(conn);
            streaming = is_streaming;
            plan
        }
    };
    // A streamed plan ships metadata-only assignments — its shards fill
    // in as blocks land, so "empty" is the expected starting state.
    if !streaming {
        for id in owned.clone() {
            if plan.shard(id).is_empty() {
                bail!("owned node {id} has no data in the plan");
            }
        }
    }

    let acfg = AsyncConfig {
        p_grad: 0.5,
        stepsize: objective.default_stepsize(cfg.nodes),
        rate_hz: cfg.rate_hz,
        speed_spread: 0.0,
        duration_secs: cfg.secs,
        eval_every_secs: cfg.secs,
        gossip_hold_secs: 0.0,
        kill_after_secs: None,
        kill_nodes: 0,
        transport: TransportKind::Socket,
        engine: EngineKind::Executors(cfg.executors),
        deterministic_events: None,
        seed: cfg.seed,
    };
    // Streaming staging buffer, shared with the node threads' sampler
    // feeds. `None` when the whole shard arrived with the plan.
    let buffer = streaming.then(|| BlockBuffer::new(cfg.nodes, staging_limit as u64));
    let transport: Arc<dyn Transport> = Arc::new(net.clone());
    let run = spawn_shard_with_feeds(
        &graph,
        &plan,
        &acfg,
        transport,
        owned.clone(),
        None,
        buffer.as_ref(),
    );

    let (plan_dim, plan_classes) = {
        let s = plan.shard(owned.start);
        (s.dim(), s.classes())
    };
    let outcome = serve_control(ServeArgs {
        rank: cfg.rank,
        net: &net,
        run: &run,
        buffer: buffer.as_ref(),
        controls,
        owned: owned.clone(),
        streaming,
        plan_dim,
        plan_classes,
        param_len,
        staging_limit,
        deadline,
        leave_after: cfg.leave_after.map(Duration::from_secs_f64),
    });

    if let Some(buffer) = buffer.as_ref() {
        buffer.stop();
    }
    let counts = run.stop_and_join();
    net.shutdown();
    if let Some(e) = outcome.stream_failure {
        bail!("rank {}: shard stream refused — {e}", cfg.rank);
    }
    println!(
        "dasgd-worker rank={} done: {} updates ({} grad, {} proj), {} messages, {} conflicts",
        cfg.rank,
        counts.updates(),
        counts.grad_steps,
        counts.proj_steps,
        counts.messages,
        counts.conflicts
    );
    Ok(WorkerSummary {
        counts,
        shutdown_by_monitor: outcome.shutdown_by_monitor,
    })
}

/// Everything the control-plane serve loop needs — one bundle so the
/// launch path ([`run_worker`]) and the join path ([`run_join_worker`])
/// share the identical protocol implementation.
struct ServeArgs<'a> {
    rank: u32,
    net: &'a SocketNet,
    run: &'a ShardRun,
    buffer: Option<&'a Arc<BlockBuffer>>,
    controls: Vec<ControlConn>,
    owned: Range<usize>,
    streaming: bool,
    plan_dim: usize,
    plan_classes: usize,
    param_len: usize,
    staging_limit: usize,
    deadline: Instant,
    leave_after: Option<Duration>,
}

/// What the serve loop reports back to its caller.
struct ServeOutcome {
    shutdown_by_monitor: bool,
    stream_failure: Option<String>,
}

/// Serve the control plane until `Shutdown`, the wall-clock cap, or a
/// scheduled graceful leave.
fn serve_control(args: ServeArgs<'_>) -> ServeOutcome {
    let ServeArgs {
        rank,
        net,
        run,
        buffer,
        mut controls,
        owned,
        streaming,
        plan_dim,
        plan_classes,
        param_len,
        staging_limit,
        deadline,
        leave_after,
    } = args;
    let mut streams: Vec<NodeStreamState> = owned
        .clone()
        .map(|_| NodeStreamState {
            progress: StreamProgress::default(),
            done: !streaming,
            checksum: 0,
        })
        .collect();
    let mut updates_at_stream_complete: u64 = if streaming { u64::MAX } else { 0 };
    let mut stream_failure: Option<String> = None;
    let leave_at = leave_after.map(|d| Instant::now() + d);

    let mut shutdown_by_monitor = false;
    'serve: while Instant::now() < deadline {
        if let Some(t) = leave_at {
            if Instant::now() >= t {
                // Graceful departure: tell the monitor once, then exit.
                // The monitor vacates this rank and repairs the
                // topology exactly as for a heartbeat eviction.
                if let Some(conn) = controls.first_mut() {
                    let _ = conn.write_msg(&WireMsg::LeaveNotice { rank });
                }
                crate::obs::trace("worker", "leave", rank as u64, 0);
                break 'serve;
            }
        }
        while let Some(conn) = net.take_control() {
            let _ = conn.set_read_timeout(Some(Duration::from_millis(25)));
            let _ = conn.set_write_timeout(Some(Duration::from_secs(1)));
            controls.push(ControlConn::with_limit(conn, staging_limit));
        }
        if controls.is_empty() {
            std::thread::sleep(Duration::from_millis(25));
            continue;
        }
        let mut dropped = Vec::new();
        for (ci, conn) in controls.iter_mut().enumerate() {
            let frame_deadline = Instant::now() + Duration::from_millis(25);
            match conn.read_msg(frame_deadline) {
                Ok(Some(WireMsg::SnapshotRequest)) => {
                    // One logical reply with the whole shard; the wire
                    // layer's chunk envelope carries it when it
                    // outgrows a frame (the monitor reassembles
                    // transparently through its own ControlConn).
                    let c = run.counts();
                    let reply = WireMsg::SnapshotReply {
                        rank,
                        counts: [c.grad_steps, c.proj_steps, c.messages, c.conflicts],
                        params: net
                            .local_params()
                            .into_iter()
                            .map(|(id, w)| (id as u32, w))
                            .collect(),
                        staging_bytes: buffer.as_ref().map(|b| b.max_staged()).unwrap_or(0),
                        stream_done: streams.iter().all(|s| s.done),
                        updates_at_stream_complete,
                    };
                    if conn.write_msg(&reply).is_err() {
                        dropped.push(ci);
                    }
                }
                Ok(Some(WireMsg::MetricsRequest)) => {
                    // The process-wide observability registry, flattened
                    // for monitor-side aggregation (layout-tolerant on
                    // the decode side — see obs::MetricsSnapshot).
                    let (counters, hist_data) = crate::obs::snapshot().to_wire();
                    let reply = WireMsg::MetricsReply {
                        rank,
                        counters,
                        hist_data,
                    };
                    if conn.write_msg(&reply).is_err() {
                        dropped.push(ci);
                    }
                }
                Ok(Some(WireMsg::ShardBlock {
                    node,
                    seq,
                    encoding,
                    rows,
                    dim,
                    classes,
                    labels,
                    features,
                    checksum,
                })) => {
                    let staged = (|| -> std::result::Result<(), String> {
                        let Some(buffer) = buffer.as_ref() else {
                            return Err("ShardBlock on a non-streamed plan".into());
                        };
                        let node = node as usize;
                        if !owned.contains(&node) {
                            return Err(format!("block for node {node}, not owned by this rank"));
                        }
                        if rows as usize != labels.len() {
                            return Err(format!(
                                "block announces {rows} rows but carries {} labels",
                                labels.len()
                            ));
                        }
                        let block = RowBlock {
                            node,
                            seq,
                            encoding,
                            dim: dim as usize,
                            classes: classes as usize,
                            labels,
                            features,
                            checksum,
                        };
                        block.validate(plan_dim, plan_classes)?;
                        let state = &mut streams[node - owned.start];
                        if state.done {
                            return Err(format!("block after ShardComplete for node {node}"));
                        }
                        state.progress.fold(&block)?;
                        buffer.push(block)
                    })();
                    if let Err(e) = staged {
                        stream_failure = Some(e);
                        break 'serve;
                    }
                }
                Ok(Some(WireMsg::ShardComplete {
                    node,
                    block_count,
                    total_rows,
                    checksum,
                })) => {
                    let completed = (|| -> std::result::Result<(), String> {
                        let Some(buffer) = buffer.as_ref() else {
                            return Err("ShardComplete on a non-streamed plan".into());
                        };
                        let node = node as usize;
                        if !owned.contains(&node) {
                            return Err(format!(
                                "stream end for node {node}, not owned by this rank"
                            ));
                        }
                        let state = &mut streams[node - owned.start];
                        if state.done {
                            return Err(format!("duplicate ShardComplete for node {node}"));
                        }
                        state.progress.verify_complete(block_count, total_rows, checksum)?;
                        state.done = true;
                        state.checksum = checksum;
                        buffer.mark_complete(node);
                        Ok(())
                    })();
                    match completed {
                        Ok(()) => {
                            if updates_at_stream_complete == u64::MAX
                                && streams.iter().all(|s| s.done)
                            {
                                // The applied-update count the instant
                                // the last owned stream validated —
                                // race-free evidence for the monitor
                                // that stepping started before the data
                                // finished arriving.
                                updates_at_stream_complete = run.counts().updates();
                            }
                        }
                        Err(e) => {
                            stream_failure = Some(e);
                            break 'serve;
                        }
                    }
                }
                Ok(Some(WireMsg::TopologyPatch { version, entries })) => {
                    // Atomic neighbor-set swap: node threads sample
                    // their neighborhood per collect round, so the new
                    // view takes effect between rounds, never inside
                    // one. Stale/malformed patches are refused by the
                    // view itself.
                    if run.topology().apply(version, &entries) {
                        crate::obs::trace("worker", "topology_patch", version, entries.len() as u64);
                    }
                }
                Ok(Some(WireMsg::PeerUpdate { rank: peer, addr })) => {
                    if peer != rank {
                        net.update_peer_addr(peer, &addr);
                        crate::obs::trace("worker", "peer_update", peer as u64, 0);
                    }
                }
                Ok(Some(WireMsg::HandoffBegin { node, w })) => {
                    // Adopt a vacated node's live parameters; its data
                    // shard follows as the usual checksummed block
                    // stream on this connection.
                    let adopted = (|| -> std::result::Result<(), String> {
                        let node = node as usize;
                        if !owned.contains(&node) {
                            return Err(format!(
                                "handoff for node {node}, not owned by this rank"
                            ));
                        }
                        if w.len() != param_len {
                            return Err(format!(
                                "handoff params for node {node} have length {}, engine \
                                 expects {param_len}",
                                w.len()
                            ));
                        }
                        net.update_own(node, &mut |p| p.clone_from(&w));
                        crate::obs::trace("worker", "handoff_begin", node as u64, 0);
                        Ok(())
                    })();
                    if let Err(e) = adopted {
                        stream_failure = Some(e);
                        break 'serve;
                    }
                }
                Ok(Some(WireMsg::HandoffEnd { node, checksum })) => {
                    // The handoff certifies only if the re-streamed
                    // shard completed and its verified fold equals the
                    // monitor's — i.e. the adopted shard is
                    // bit-identical to the one the departed worker had.
                    let certified = owned.contains(&(node as usize)) && {
                        let state = &streams[node as usize - owned.start];
                        state.done && state.checksum == checksum
                    };
                    if certified {
                        crate::obs::trace("worker", "handoff_end", node as u64, checksum);
                    } else {
                        stream_failure = Some(format!(
                            "handoff for node {node} did not certify (stream incomplete \
                             or checksum mismatch)"
                        ));
                        break 'serve;
                    }
                }
                Ok(Some(WireMsg::Shutdown)) => {
                    shutdown_by_monitor = true;
                    break 'serve;
                }
                Ok(Some(_)) => {} // not meaningful on a control connection
                Ok(None) => {}    // nothing complete yet
                Err(_) => dropped.push(ci),
            }
            // Return backpressure credit for whatever the node threads
            // drained since the last pass. Credit goes to the plan
            // connection (controls[0]) — the stream's only sender.
            if ci == 0 {
                if let Some(buffer) = buffer.as_ref() {
                    let freed = buffer.take_freed();
                    if freed > 0
                        && conn.write_msg(&WireMsg::ShardCredit { bytes: freed }).is_err()
                    {
                        dropped.push(ci);
                    }
                }
            }
        }
        dropped.sort_unstable();
        dropped.dedup();
        for ci in dropped.into_iter().rev() {
            controls.remove(ci);
        }
    }

    ServeOutcome {
        shutdown_by_monitor,
        stream_failure,
    }
}

/// Run a worker that joins a *running* deployment (`dasgd worker
/// --join ADDR`): dial the monitor's join listener, hand-shake
/// `JoinRequest` → `JoinGrant` → `JoinReady`, reconstruct the vacated
/// rank's configuration from the grant, receive the plan metadata and
/// the credit-gated handoff stream on the same connection, and then
/// serve the identical control protocol a launch-spawned worker does.
pub fn run_join_worker(join_addr: &str, leave_after: Option<f64>) -> Result<WorkerSummary> {
    let stream = TcpStream::connect(join_addr)
        .with_context(|| format!("dialing the join listener at {join_addr}"))?;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let mut conn = ControlConn::new(stream);
    conn.write_msg(&WireMsg::JoinRequest)
        .map_err(|e| anyhow!("sending JoinRequest: {e}"))?;
    let grant_deadline = Instant::now() + Duration::from_secs(10);
    let grant = loop {
        match conn.read_msg(grant_deadline) {
            Ok(Some(msg @ WireMsg::JoinGrant { .. })) => break msg,
            Ok(Some(_)) => {}
            Ok(None) => bail!("the monitor never granted the join (no vacancy?)"),
            Err(e) => return Err(anyhow!("join handshake failed: {e}")),
        }
    };
    let WireMsg::JoinGrant {
        rank,
        nodes,
        degree,
        param_len,
        seed,
        secs,
        rate_hz,
        obj_code,
        lam,
        staging_mb,
        executors,
        flush_bytes,
        flush_micros,
        strategy,
        mut peers,
    } = grant
    else {
        unreachable!("matched above");
    };
    let (nodes, degree, param_len) = (nodes as usize, degree as usize, param_len as usize);
    let workers = peers.len();
    if (rank as usize) >= workers || workers > nodes || param_len == 0 || staging_mb == 0 {
        bail!("malformed JoinGrant: rank {rank} of {workers} peers, {nodes} nodes");
    }
    let Some(objective) = objective_from_code(obj_code, lam) else {
        bail!("JoinGrant carries unknown objective code {obj_code}");
    };
    // The deployment's uniform strategy; the per-node assignments that
    // follow on this connection carry the authoritative values.
    let Some(strategy) = StrategyKind::from_code(strategy) else {
        bail!("JoinGrant carries unknown strategy code {strategy}");
    };
    let staging_limit = (staging_mb as usize)
        .saturating_mul(1 << 20)
        .min(wire::MAX_MESSAGE_LEN);
    conn.set_staging_limit(staging_limit);

    let net = SocketNet::bind(
        rank,
        ShardMap::new(nodes, workers),
        param_len,
        "127.0.0.1:0",
        SocketConfig {
            staging_limit,
            flush_bytes: flush_bytes as usize,
            flush_micros,
            ..SocketConfig::default()
        },
    )
    .context("binding the joining worker's listener")?;
    let owned = net.local_nodes();
    peers[rank as usize] = net.local_addr().to_string();
    println!(
        "dasgd-worker rank={rank} joined via {join_addr}, listening on {} (nodes {}..{} of {nodes})",
        net.local_addr(),
        owned.start,
        owned.end,
    );
    let _ = std::io::stdout().flush();
    net.connect_peers(&peers);
    conn.write_msg(&WireMsg::JoinReady {
        rank,
        addr: net.local_addr().to_string(),
    })
    .map_err(|e| anyhow!("sending JoinReady: {e}"))?;
    crate::obs::trace("worker", "join", rank as u64, strategy.code() as u64);

    let deadline = Instant::now() + Duration::from_secs_f64(secs.max(0.1));
    let (plan, streaming) = receive_plan_on(&mut conn, nodes, param_len, deadline)
        .with_context(|| format!("joined rank {rank} receiving the workload plan"))?;

    let graph = make_regular(nodes, degree);
    let acfg = AsyncConfig {
        p_grad: 0.5,
        stepsize: objective.default_stepsize(nodes),
        rate_hz,
        speed_spread: 0.0,
        duration_secs: secs,
        eval_every_secs: secs,
        gossip_hold_secs: 0.0,
        kill_after_secs: None,
        kill_nodes: 0,
        transport: TransportKind::Socket,
        engine: EngineKind::Executors(executors as usize),
        deterministic_events: None,
        seed,
    };
    let buffer = streaming.then(|| BlockBuffer::new(nodes, staging_limit as u64));
    let transport: Arc<dyn Transport> = Arc::new(net.clone());
    let run = spawn_shard_with_feeds(
        &graph,
        &plan,
        &acfg,
        transport,
        owned.clone(),
        None,
        buffer.as_ref(),
    );
    let (plan_dim, plan_classes) = {
        let s = plan.shard(owned.start);
        (s.dim(), s.classes())
    };
    let outcome = serve_control(ServeArgs {
        rank,
        net: &net,
        run: &run,
        buffer: buffer.as_ref(),
        controls: vec![conn],
        owned: owned.clone(),
        streaming,
        plan_dim,
        plan_classes,
        param_len,
        staging_limit,
        deadline,
        leave_after: leave_after.map(Duration::from_secs_f64),
    });

    if let Some(buffer) = buffer.as_ref() {
        buffer.stop();
    }
    let counts = run.stop_and_join();
    net.shutdown();
    if let Some(e) = outcome.stream_failure {
        bail!("joined rank {rank}: shard stream refused — {e}");
    }
    println!(
        "dasgd-worker rank={rank} done: {} updates ({} grad, {} proj), {} messages, {} conflicts",
        counts.updates(),
        counts.grad_steps,
        counts.proj_steps,
        counts.messages,
        counts.conflicts
    );
    Ok(WorkerSummary {
        counts,
        shutdown_by_monitor: outcome.shutdown_by_monitor,
    })
}

// ---------------------------------------------------------------------------
// Launcher / monitor
// ---------------------------------------------------------------------------

/// Single-machine deployment configuration.
#[derive(Clone, Debug)]
pub struct LaunchConfig {
    pub workers: usize,
    pub nodes: usize,
    pub degree: usize,
    /// Stop once the aggregate applied-update count reaches this.
    pub horizon_updates: u64,
    /// Wall-clock safety cap for the whole run.
    pub secs_cap: f64,
    pub eval_every_secs: f64,
    pub rate_hz: f64,
    /// The uniform loss family (superseded per node by `mixed` plans).
    pub objective: Objective,
    /// The uniform update strategy (`--strategy`), shipped per node
    /// inside `PlanAssign` and forwarded to workers on their CLI.
    pub strategy: StrategyKind,
    /// The workload recipe; the launcher builds it once and ships each
    /// worker its owned shards over the wire.
    pub plan: PlanSpec,
    /// Samples per node in the built world — the lever that (with a
    /// skewed plan) pushes single shards past the wire frame cap.
    pub samples_per_node: usize,
    pub seed: u64,
    /// Rows per streamed [`RowBlock`] (`--stream-block-rows`).
    pub stream_block_rows: usize,
    /// Per-worker staging budget in MiB (`--staging-mb`): the
    /// launcher's credit window per rank, and each worker's
    /// [`BlockBuffer`] / chunk-staging bound.
    pub staging_mb: usize,
    /// Executor threads per worker (`--executors N`; 0 = one per core).
    pub executors: usize,
    /// Per-peer coalescing byte threshold forwarded to every worker
    /// (`--flush-bytes`; 0 disables batching).
    pub flush_bytes: usize,
    /// Coalescing staleness bound forwarded to every worker
    /// (`--flush-micros`).
    pub flush_micros: u64,
    /// A real base corpus (`--dataset libsvm:<path>`) partitioned by
    /// `plan` instead of generating the synthetic world; the last
    /// `TEST_SAMPLES` rows are held out as the monitor's evaluation
    /// set.
    pub base_data: Option<Dataset>,
    /// The worker binary. `None` = this executable (the CLI case);
    /// tests point it at the built `dasgd` binary.
    pub binary: Option<std::path::PathBuf>,
    /// Append one aggregated cluster-wide metrics line per monitor
    /// round to this JSONL file (`--metrics-jsonl`).
    pub metrics_jsonl: Option<std::path::PathBuf>,
    /// Serve the aggregate as Prometheus text on this `host:port`
    /// (`--metrics-addr`).
    pub metrics_addr: Option<String>,
    /// Log level forwarded to every worker (`--log-level`).
    pub log_level: Option<String>,
    /// Arm every worker's tracer too (`--trace-jsonl`): rank N dumps
    /// its ring to the sibling path `<stem>.rankN[.ext]`. The
    /// launcher's own ring (monitor round/evict events) is armed by
    /// the CLI and dumps to the path itself — the processes must not
    /// share one file, since each dump truncates it.
    pub trace_jsonl: Option<std::path::PathBuf>,
    /// Bind a membership join listener on this `host:port`
    /// (`--join-addr`; port 0 for OS-assigned) and admit `dasgd worker
    /// --join` processes into vacant ranks mid-run. The bound address
    /// is printed as `dasgd-launch join-addr=...`. Chaos joins imply a
    /// default listener on `127.0.0.1:0`.
    pub join_addr: Option<String>,
    /// Deterministic churn injection (`--chaos-kill RANK@FRAC`):
    /// SIGKILL worker `RANK` once the aggregate update count passes
    /// `FRAC` of the horizon — the CI churn smoke's mid-run crash.
    pub chaos_kill: Option<(u32, f64)>,
    /// Spawn a `worker --join` replacement once the aggregate update
    /// count passes this fraction of the horizon (`--chaos-join FRAC`).
    pub chaos_join: Option<f64>,
}

impl LaunchConfig {
    pub fn quick(workers: usize, nodes: usize) -> Self {
        Self {
            workers,
            nodes,
            degree: 2,
            horizon_updates: 2000,
            secs_cap: 30.0,
            eval_every_secs: 0.25,
            rate_hz: 300.0,
            objective: Objective::LogReg,
            strategy: StrategyKind::Dasgd,
            plan: PlanSpec::Synth,
            samples_per_node: SAMPLES_PER_NODE,
            seed: 0,
            stream_block_rows: DEFAULT_BLOCK_ROWS,
            staging_mb: 1024,
            executors: 0,
            flush_bytes: 16 * 1024,
            flush_micros: 500,
            base_data: None,
            binary: None,
            metrics_jsonl: None,
            metrics_addr: None,
            log_level: None,
            trace_jsonl: None,
            join_addr: None,
            chaos_kill: None,
            chaos_join: None,
        }
    }
}

/// Outcome of a launched deployment.
#[derive(Debug)]
pub struct LaunchReport {
    pub recorder: Recorder,
    pub counts: Counts,
    /// Workers still answering snapshots at the end.
    pub live_workers: usize,
    pub elapsed_secs: f64,
    /// True when the run ended by reaching `horizon_updates`; false
    /// means the wall-clock cap expired first (a stalled deployment —
    /// the CLI exits nonzero on it so CI smoke runs can fail).
    pub reached_horizon: bool,
    /// Highest staging high-water mark any worker reported over the
    /// run — by construction within the `--staging-mb` budget (a
    /// worker refuses an overrun as a flow-control violation).
    pub max_staging_bytes: u64,
    /// Some worker applied its first update strictly before its last
    /// owned shard stream completed — direct evidence that streaming
    /// overlapped compute with data arrival.
    pub stepped_before_stream_complete: bool,
    /// Workers admitted mid-run through the join listener.
    pub joins: u64,
    /// Workers vacated mid-run (heartbeat strikes or `LeaveNotice`).
    pub evictions: u64,
    /// Topology repair patches computed and broadcast.
    pub repairs: u64,
    /// Every `(node, checksum)` handoff shipped to a joiner — the fold
    /// equals the launch-time carve fold when the adopted shard is
    /// bit-identical, and each vacated node appears exactly once per
    /// admission.
    pub handoffs: Vec<(u32, u64)>,
}

/// One queued item of a rank's outbound shard stream.
enum StreamItem {
    Block(RowBlock),
    Complete {
        node: u32,
        block_count: u32,
        total_rows: u64,
        checksum: u64,
    },
}

fn block_msg(b: RowBlock) -> WireMsg {
    WireMsg::ShardBlock {
        node: b.node as u32,
        seq: b.seq,
        encoding: b.encoding,
        rows: b.labels.len() as u32,
        dim: b.dim as u32,
        classes: b.classes as u32,
        checksum: b.checksum,
        labels: b.labels,
        features: b.features,
    }
}

/// Reserve a free loopback port by binding port 0 and noting the
/// assignment. The tiny window between drop and the worker's bind is a
/// documented single-machine trade-off (docs/deployment.md).
fn reserve_port() -> Result<u16> {
    let l = TcpListener::bind("127.0.0.1:0").context("reserving a loopback port")?;
    Ok(l.local_addr()?.port())
}

/// Rank-qualified sibling of the launcher's `--trace-jsonl` path:
/// `trace.jsonl` becomes `trace.rank3.jsonl`.
fn per_rank_trace_path(path: &std::path::Path, rank: usize) -> std::path::PathBuf {
    let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("trace");
    let name = match path.extension().and_then(|s| s.to_str()) {
        Some(ext) => format!("{stem}.rank{rank}.{ext}"),
        None => format!("{stem}.rank{rank}"),
    };
    path.with_file_name(name)
}

fn kill_all(children: &mut [Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// Admit one joining worker into a vacant rank: handshake, peer-table
/// update, plan metadata, topology patches (repair to the incumbents,
/// the full current view to the joiner), and the credit-gated,
/// checksummed handoff of every vacated node's parameters and data
/// shard. Returns the admitted rank; on error the caller just drops
/// the connection (the deployment is unchanged — membership is only
/// mutated after the joiner is bound and ready).
#[allow(clippy::too_many_arguments)]
fn admit_join(
    stream: TcpStream,
    cfg: &LaunchConfig,
    plan: &WorkloadPlan,
    shard_map: &ShardMap,
    membership: &mut Membership,
    peers: &mut [String],
    vacant: &mut [bool],
    conns: &mut [Option<ControlConn>],
    last_params: &[Vec<f32>],
    budget: u64,
    handoffs: &mut Vec<(u32, u64)>,
) -> Result<usize> {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(60)));
    let mut conn = ControlConn::new(stream);
    let hello_deadline = Instant::now() + Duration::from_secs(2);
    loop {
        match conn.read_msg(hello_deadline) {
            Ok(Some(WireMsg::JoinRequest)) => break,
            Ok(Some(_)) => {}
            Ok(None) => bail!("join connection sent no JoinRequest"),
            Err(e) => bail!("join handshake read failed: {e}"),
        }
    }
    let Some(rank) = vacant.iter().position(|&v| v) else {
        bail!("join requested but every rank is occupied");
    };
    let (obj_code, lam) = objective_code(cfg.objective);
    conn.write_msg(&WireMsg::JoinGrant {
        rank: rank as u32,
        nodes: cfg.nodes as u32,
        degree: cfg.degree as u32,
        param_len: plan.param_len() as u32,
        seed: cfg.seed,
        secs: cfg.secs_cap + 10.0,
        rate_hz: cfg.rate_hz,
        obj_code,
        lam,
        staging_mb: cfg.staging_mb as u32,
        executors: cfg.executors as u32,
        flush_bytes: cfg.flush_bytes as u32,
        flush_micros: cfg.flush_micros,
        strategy: cfg.strategy.code(),
        peers: peers.to_vec(),
    })
    .map_err(|e| anyhow!("sending JoinGrant: {e}"))?;
    let ready_deadline = Instant::now() + Duration::from_secs(10);
    let addr = loop {
        match conn.read_msg(ready_deadline) {
            Ok(Some(WireMsg::JoinReady { rank: r, addr })) => {
                if r as usize != rank {
                    bail!("joiner bound as rank {r}, grant was for {rank}");
                }
                break addr;
            }
            Ok(Some(_)) => {}
            Ok(None) => bail!("joiner never sent JoinReady"),
            Err(e) => bail!("join handshake read failed: {e}"),
        }
    };
    peers[rank] = addr.clone();
    // Incumbents redial the replacement on their next dial-loop pass.
    for conn in conns.iter_mut().flatten() {
        let _ = conn.write_msg(&WireMsg::PeerUpdate {
            rank: rank as u32,
            addr: addr.clone(),
        });
    }

    // Plan metadata for the adopted block, checksum-certified exactly
    // like the launch-time shipment.
    let block = shard_map.range(rank as u32);
    let mut shipped_sum = wire::Fnv64::new();
    for id in block.clone() {
        let shard = plan.shard(id);
        let (obj_code, lam) = objective_code(plan.objective(id));
        let msg = WireMsg::PlanAssign {
            node: id as u32,
            obj_code,
            lam,
            dim: shard.dim() as u32,
            classes: shard.classes() as u32,
            labels: Vec::new(),
            features: Vec::new(),
            strategy: plan.strategy(id).code(),
        };
        let sum = wire::message_checksum(&msg)
            .map_err(|e| anyhow!("encoding node {id}'s assignment: {e}"))?;
        shipped_sum.update(&sum.to_le_bytes());
        conn.write_msg(&msg)
            .map_err(|e| anyhow!("shipping the plan to the joiner: {e}"))?;
    }
    conn.write_msg(&WireMsg::PlanStart {
        nodes: cfg.nodes as u32,
        assigned: block.len() as u32,
        mixed: plan.is_mixed(),
        checksum: shipped_sum.finish(),
        streaming: true,
    })
    .map_err(|e| anyhow!("shipping the plan to the joiner: {e}"))?;

    // Per-node handoff: live parameters, then the data shard re-carved
    // and re-streamed under the same credit window as the launch-time
    // stream, closed by the certifying fold.
    let mut credit = budget;
    let pump_deadline = Instant::now() + Duration::from_secs(60);
    for id in block.clone() {
        let w = if last_params[id].len() == plan.param_len() {
            last_params[id].clone()
        } else {
            vec![0.0; plan.param_len()]
        };
        conn.write_msg(&WireMsg::HandoffBegin { node: id as u32, w })
            .map_err(|e| anyhow!("handoff of node {id} failed: {e}"))?;
        let blocks = RowBlock::carve(id, plan.shard(id), cfg.stream_block_rows);
        let (block_count, total_rows) = (blocks.len() as u32, plan.shard(id).len() as u64);
        let fold = fold_payloads(&blocks);
        for b in blocks {
            let cost = b.payload_bytes();
            while cost > credit {
                if Instant::now() >= pump_deadline {
                    bail!("handoff of node {id} stalled: the joiner returned no credit");
                }
                match conn.read_msg(Instant::now() + Duration::from_millis(5)) {
                    Ok(Some(WireMsg::ShardCredit { bytes })) => {
                        credit = credit.saturating_add(bytes);
                    }
                    Ok(Some(_)) | Ok(None) => {}
                    Err(e) => bail!("handoff of node {id} failed: {e}"),
                }
            }
            credit -= cost;
            conn.write_msg(&block_msg(b))
                .map_err(|e| anyhow!("handoff of node {id} failed: {e}"))?;
        }
        conn.write_msg(&WireMsg::ShardComplete {
            node: id as u32,
            block_count,
            total_rows,
            checksum: fold,
        })
        .map_err(|e| anyhow!("handoff of node {id} failed: {e}"))?;
        conn.write_msg(&WireMsg::HandoffEnd {
            node: id as u32,
            checksum: fold,
        })
        .map_err(|e| anyhow!("handoff of node {id} failed: {e}"))?;
        handoffs.push((id as u32, fold));
        crate::obs::trace("monitor", "handoff", id as u64, fold);
    }

    // Only now — with the joiner bound, fed, and certified — mutate
    // membership: re-activate the block's nodes and repair the
    // topology around them. An admission that failed earlier left the
    // deployment exactly as it was. Incumbents get the
    // touched-neighborhood patch; the joiner (whose view is still the
    // launch graph) gets the full current adjacency at the same
    // version — both converge on one topology.
    let patch = membership.activate(&block.clone().collect::<Vec<_>>());
    let version = membership.version();
    if !patch.is_empty() {
        crate::obs::add(crate::obs::Counter::Repairs, 1);
        for c in conns.iter_mut().flatten() {
            let _ = c.write_msg(&WireMsg::TopologyPatch {
                version,
                entries: patch.clone(),
            });
        }
    }
    let full: Vec<(u32, Vec<u32>)> = (0..cfg.nodes)
        .map(|u| {
            (
                u as u32,
                membership.graph().neighbors(u).iter().map(|&v| v as u32).collect(),
            )
        })
        .collect();
    let _ = conn.write_msg(&WireMsg::TopologyPatch {
        version,
        entries: full,
    });

    conn.set_write_timeout(Duration::from_secs(1));
    vacant[rank] = false;
    conns[rank] = Some(conn);
    crate::obs::add(crate::obs::Counter::Joins, 1);
    crate::obs::trace("monitor", "join", rank as u64, version);
    Ok(rank)
}

/// Spawn `cfg.workers` local worker processes, ship each its slice of
/// the workload plan, monitor them to the horizon, shut them down, and
/// return the aggregated run record.
pub fn run_launch(cfg: &LaunchConfig) -> Result<LaunchReport> {
    if cfg.workers == 0 {
        bail!("--workers must be at least 1");
    }
    if cfg.workers > cfg.nodes {
        bail!("more workers ({}) than nodes ({})", cfg.workers, cfg.nodes);
    }
    if cfg.stream_block_rows == 0 {
        bail!("--stream-block-rows must be at least 1");
    }
    if cfg.staging_mb == 0 {
        bail!("--staging-mb must be at least 1");
    }
    // The whole deployment's workload, built exactly once. Workers get
    // their assignments over the wire — never regenerated from seed.
    // A real base corpus (libsvm) is partitioned by the same plan
    // recipes as the synthetic pool, with its tail held out for the
    // monitor's probe.
    let (plan, test) = match &cfg.base_data {
        Some(base) => {
            if base.len() <= TEST_SAMPLES {
                bail!(
                    "base dataset has {} rows — need more than {TEST_SAMPLES} \
                     (the held-out evaluation set)",
                    base.len()
                );
            }
            let split = base.len() - TEST_SAMPLES;
            let train_idx: Vec<usize> = (0..split).collect();
            let test_idx: Vec<usize> = (split..base.len()).collect();
            (
                cfg.plan.build_over(
                    &base.subset(&train_idx),
                    cfg.objective,
                    cfg.nodes,
                    cfg.seed,
                ),
                base.subset(&test_idx),
            )
        }
        None => cfg.plan.build(
            cfg.objective,
            cfg.nodes,
            cfg.samples_per_node,
            TEST_SAMPLES,
            cfg.seed,
        ),
    };
    let plan = plan.with_uniform_strategy(cfg.strategy);
    let param_len = plan.param_len();
    let shard_map = ShardMap::new(cfg.nodes, cfg.workers);
    // Carve every rank's outbound shard stream up front: per-node block
    // lists interleaved round-robin across the rank's nodes, each
    // node's `ShardComplete` (count, rows, whole-shard checksum fold)
    // queued right after its last block. Carving first also lets a
    // block that could never fit the staging budget fail before any
    // process spawns.
    let budget = ((cfg.staging_mb as u64) << 20).min(wire::MAX_MESSAGE_LEN as u64);
    let mut queues: Vec<VecDeque<StreamItem>> = Vec::with_capacity(cfg.workers);
    for rank in 0..cfg.workers {
        let mut per_node: Vec<(VecDeque<RowBlock>, Option<StreamItem>)> = Vec::new();
        for id in shard_map.range(rank as u32) {
            let blocks = RowBlock::carve(id, plan.shard(id), cfg.stream_block_rows);
            if let Some(big) = blocks.iter().find(|b| b.payload_bytes() > budget) {
                bail!(
                    "a {}-row block of node {id}'s shard is {} bytes — larger than the \
                     {budget}-byte staging budget; lower --stream-block-rows or raise \
                     --staging-mb",
                    big.rows(),
                    big.payload_bytes()
                );
            }
            let complete = StreamItem::Complete {
                node: id as u32,
                block_count: blocks.len() as u32,
                total_rows: plan.shard(id).len() as u64,
                checksum: fold_payloads(&blocks),
            };
            per_node.push((blocks.into_iter().collect(), Some(complete)));
        }
        let mut q = VecDeque::new();
        loop {
            let mut any = false;
            for (blocks, complete) in per_node.iter_mut() {
                if let Some(b) = blocks.pop_front() {
                    any = true;
                    q.push_back(StreamItem::Block(b));
                }
                if blocks.is_empty() {
                    if let Some(c) = complete.take() {
                        any = true;
                        q.push_back(c);
                    }
                }
            }
            if !any {
                break;
            }
        }
        queues.push(q);
    }
    let mut peers: Vec<String> = (0..cfg.workers)
        .map(|_| reserve_port().map(|p| format!("127.0.0.1:{p}")))
        .collect::<Result<_>>()?;
    let binary = match &cfg.binary {
        Some(p) => p.clone(),
        None => std::env::current_exe().context("locating this executable")?,
    };
    // Workers outlive the monitor's cap slightly so a slow shutdown
    // never races their own wall-clock exit.
    let worker_secs = cfg.secs_cap + 10.0;
    let mut children: Vec<Child> = Vec::with_capacity(cfg.workers);
    for rank in 0..cfg.workers {
        let mut cmd = Command::new(&binary);
        cmd.args([
                "worker",
                "--rank",
                &rank.to_string(),
                "--peers",
                &peers.join(","),
                "--nodes",
                &cfg.nodes.to_string(),
                "--degree",
                &cfg.degree.to_string(),
                "--secs",
                &format!("{worker_secs}"),
                "--rate",
                &format!("{}", cfg.rate_hz),
                "--objective",
                cfg.objective.name(),
                "--strategy",
                cfg.strategy.name(),
                "--plan",
                "wire",
                "--param-len",
                &param_len.to_string(),
                "--staging-mb",
                &cfg.staging_mb.to_string(),
                "--executors",
                &cfg.executors.to_string(),
                "--flush-bytes",
                &cfg.flush_bytes.to_string(),
                "--flush-micros",
                &cfg.flush_micros.to_string(),
                "--seed",
                &cfg.seed.to_string(),
            ]);
        if let Some(lvl) = &cfg.log_level {
            cmd.args(["--log-level", lvl]);
        }
        // Trace events fire inside the workers (node/socket/stream
        // callsites), so each rank gets its own armed tracer — the
        // launcher's ring only ever sees monitor events.
        if let Some(path) = &cfg.trace_jsonl {
            cmd.arg("--trace-jsonl").arg(per_rank_trace_path(path, rank));
        }
        let child = cmd.stdout(Stdio::null()).stderr(Stdio::inherit()).spawn();
        match child {
            Ok(c) => children.push(c),
            Err(e) => {
                kill_all(&mut children);
                return Err(anyhow!("spawning worker {rank}: {e}"));
            }
        }
    }

    // Monitor control connections (retry while workers come up).
    let mut conns: Vec<Option<ControlConn>> = Vec::with_capacity(cfg.workers);
    for (rank, addr) in peers.iter().enumerate() {
        let deadline = Instant::now() + Duration::from_secs(10);
        let conn = loop {
            if let Ok(mut s) = TcpStream::connect(addr) {
                let _ = s.set_nodelay(true);
                // Short socket timeout: read_msg's own deadline governs
                // how long a round waits.
                let _ = s.set_read_timeout(Some(Duration::from_millis(250)));
                let _ = s.set_write_timeout(Some(Duration::from_secs(1)));
                if wire::write_frame(&mut s, &WireMsg::Hello { rank: MONITOR_RANK }).is_ok() {
                    break Some(ControlConn::new(s));
                }
            }
            if Instant::now() >= deadline {
                break None;
            }
            std::thread::sleep(Duration::from_millis(50));
        };
        if conn.is_none() {
            kill_all(&mut children);
            bail!("worker {rank} at {addr} never accepted the monitor connection");
        }
        conns.push(conn);
    }

    // Ship each rank its plan *metadata*: one empty-shard `PlanAssign`
    // per owned node (objective + shape, no rows) and a streaming
    // `PlanStart`. The worker binds its engine on PlanStart and starts
    // stepping as blocks land. PlanStart still carries the fold of
    // every shipped assignment's checksum; the worker refuses to start
    // unless its own fold over what arrived matches (bit-for-bit
    // metadata delivery, certified — each block and stream then
    // carries its own checksum on top).
    for (rank, conn_slot) in conns.iter_mut().enumerate() {
        let conn = conn_slot.as_mut().expect("all connected above");
        conn.set_write_timeout(Duration::from_secs(60));
        let block = shard_map.range(rank as u32);
        let mut shipped_sum = wire::Fnv64::new();
        // Keep the concrete WireError: an encode-side refusal must
        // read as what it is, not as a dropped connection.
        let mut shipped: Result<(), wire::WireError> = Ok(());
        for id in block.clone() {
            let shard = plan.shard(id);
            let (obj_code, lam) = objective_code(plan.objective(id));
            let msg = WireMsg::PlanAssign {
                node: id as u32,
                obj_code,
                lam,
                dim: shard.dim() as u32,
                classes: shard.classes() as u32,
                labels: Vec::new(),
                features: Vec::new(),
                strategy: plan.strategy(id).code(),
            };
            // message_checksum re-encodes the body write_msg encodes
            // again (and the worker re-encodes once to verify). That
            // extra pass is deliberate: both ends hash one canonical
            // layout owned by the codec, instead of this module
            // hand-rolling a second byte path that could drift.
            match wire::message_checksum(&msg) {
                Ok(sum) => shipped_sum.update(&sum.to_le_bytes()),
                Err(e) => {
                    kill_all(&mut children);
                    return Err(anyhow!("encoding node {id}'s assignment: {e}"));
                }
            }
            if let Err(e) = conn.write_msg(&msg) {
                shipped = Err(e);
                break;
            }
        }
        if shipped.is_ok() {
            shipped = conn.write_msg(&WireMsg::PlanStart {
                nodes: cfg.nodes as u32,
                assigned: block.len() as u32,
                mixed: plan.is_mixed(),
                checksum: shipped_sum.finish(),
                streaming: true,
            });
        }
        if let Err(e) = shipped {
            kill_all(&mut children);
            bail!("shipping the plan to worker {rank} failed: {e}");
        }
    }

    // Pump the block streams, credit-gated per rank. Each window opens
    // at the worker's whole staging budget, narrows by every block's
    // payload, and reopens as `ShardCredit` frames return — so a
    // worker's staged-but-unconsumed payload provably never exceeds
    // `--staging-mb`, no matter how large its shard is. Ranks are
    // round-robined so every worker streams (and steps) concurrently;
    // a rank that dies mid-stream is dropped here and struck out by
    // the monitor loop below, exactly like a mid-run death.
    let mut credit: Vec<u64> = vec![budget; cfg.workers];
    let pump_deadline = Instant::now() + Duration::from_secs_f64(cfg.secs_cap.max(1.0));
    while queues.iter().any(|q| !q.is_empty()) {
        let mut progressed = false;
        for rank in 0..cfg.workers {
            if queues[rank].is_empty() {
                continue;
            }
            if conns[rank].is_none() {
                queues[rank].clear();
                continue;
            }
            let mut conn_ok = true;
            {
                let conn = conns[rank].as_mut().expect("checked above");
                // Only touch the socket when the window is too narrow
                // for the next block — credit frames arrive in bursts
                // and each read may block for the socket timeout.
                let need_credit = match queues[rank].front() {
                    Some(StreamItem::Block(b)) => b.payload_bytes() > credit[rank],
                    _ => false,
                };
                if need_credit {
                    // The stream is blocked on the worker draining its
                    // staging — a backpressure stall, counted.
                    crate::obs::add(crate::obs::Counter::CreditStalls, 1);
                    loop {
                        match conn.read_msg(Instant::now() + Duration::from_millis(5)) {
                            Ok(Some(WireMsg::ShardCredit { bytes })) => {
                                credit[rank] = credit[rank].saturating_add(bytes);
                            }
                            Ok(Some(_)) => {} // stale frames are meaningless here
                            Ok(None) => break,
                            Err(_) => {
                                conn_ok = false;
                                break;
                            }
                        }
                    }
                }
                while conn_ok {
                    let cost = match queues[rank].front() {
                        Some(StreamItem::Block(b)) => b.payload_bytes(),
                        Some(StreamItem::Complete { .. }) => 0,
                        None => break,
                    };
                    if cost > credit[rank] {
                        break;
                    }
                    let msg = match queues[rank].pop_front().expect("front checked") {
                        StreamItem::Block(b) => {
                            credit[rank] -= cost;
                            block_msg(b)
                        }
                        StreamItem::Complete {
                            node,
                            block_count,
                            total_rows,
                            checksum,
                        } => WireMsg::ShardComplete {
                            node,
                            block_count,
                            total_rows,
                            checksum,
                        },
                    };
                    if conn.write_msg(&msg).is_err() {
                        conn_ok = false;
                        break;
                    }
                    progressed = true;
                }
            }
            if !conn_ok {
                conns[rank] = None;
                queues[rank].clear();
            }
        }
        if conns.iter().flatten().count() == 0 {
            kill_all(&mut children);
            bail!("every worker died while its shard was still streaming");
        }
        if !progressed {
            if Instant::now() >= pump_deadline {
                kill_all(&mut children);
                bail!(
                    "shard streaming stalled: no worker returned credit before the \
                     wall-clock cap"
                );
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    for conn in conns.iter_mut().flatten() {
        conn.set_write_timeout(Duration::from_secs(1));
    }
    crate::obs::trace("monitor", "stream_done", 0, 0);

    // Membership control: the monitor's authoritative topology and
    // active-rank set, plus the join listener when churn is enabled
    // (`--join-addr`, or implicitly by `--chaos-join`).
    let mut membership = Membership::new(make_regular(cfg.nodes, cfg.degree), cfg.degree);
    let join_listener = {
        let addr = cfg
            .join_addr
            .clone()
            .or_else(|| cfg.chaos_join.map(|_| "127.0.0.1:0".to_string()));
        match addr {
            Some(addr) => match TcpListener::bind(&addr) {
                Ok(l) => {
                    let _ = l.set_nonblocking(true);
                    if let Ok(bound) = l.local_addr() {
                        println!("dasgd-launch join-addr={bound}");
                        let _ = std::io::stdout().flush();
                    }
                    Some(l)
                }
                Err(e) => {
                    kill_all(&mut children);
                    return Err(anyhow!("binding the join listener on {addr}: {e}"));
                }
            },
            None => None,
        }
    };
    let join_target = join_listener.as_ref().and_then(|l| l.local_addr().ok());
    let mut vacant = vec![false; cfg.workers];
    let mut leaving = vec![false; cfg.workers];
    // Counters of ranks that left the cohort, folded in so the
    // aggregate stays monotonic when a replacement restarts from zero.
    let mut retired = [0u64; 4];
    // Every node's last-snapshotted parameters — the `HandoffBegin`
    // payload a joiner adopts.
    let mut last_params: Vec<Vec<f32>> = vec![Vec::new(); cfg.nodes];
    let mut handoffs: Vec<(u32, u64)> = Vec::new();
    let (mut joins, mut evictions, mut repairs) = (0u64, 0u64, 0u64);
    let (mut chaos_killed, mut chaos_joined) = (false, false);

    // The monitor's evaluation set came from the plan build; mixed
    // cohorts evaluate under the weighted per-family convention.
    let probe = Probe::mixed(&plan.objectives(), &test);
    let mut rec = Recorder::new("socket");
    let sw = Stopwatch::new();
    // A worker misses a round on a transient stall; only repeated
    // silence evicts it from the cohort. Five 2s-deadline rounds also
    // cover a worker still inside its 10s peer-rendezvous wait (it
    // serves control only after that).
    let mut strikes = vec![0u32; cfg.workers];
    const MAX_STRIKES: u32 = 5;
    // Each rank's last-known cumulative counters. Summing these keeps
    // the aggregate monotonic when a worker misses a round (or dies —
    // its applied updates still happened).
    let mut last_known = vec![[0u64; 4]; cfg.workers];
    let mut max_staging_bytes = 0u64;
    let mut stepped_before_stream_complete = false;
    // Cluster-wide observability: the Prometheus endpoint serves this
    // shared text, refreshed each round from the aggregated replies.
    let prom = Arc::new(std::sync::Mutex::new(String::new()));
    if let Some(addr) = &cfg.metrics_addr {
        let text = Arc::clone(&prom);
        match crate::obs::serve_metrics(addr, move || text.lock().unwrap().clone()) {
            Ok(bound) => {
                crate::log!(Info, "monitor", "serving metrics on http://{bound}/metrics")
            }
            Err(e) => crate::log!(Warn, "monitor", "--metrics-addr {addr} failed to bind: {e}"),
        }
    }
    // (messages, steals, time) at the last stderr summary line — the
    // window the per-second rates are computed over.
    let mut top_mark: (u64, u64, f64) = (0, 0, 0.0);
    // Each worker's MetricsReply read carries a 500ms deadline, so a
    // slow or dead peer stalls the round by up to that per rank. With
    // a metrics sink configured (JSONL or the endpoint) freshness is
    // the point and the poll runs every round; without one, the only
    // consumers are the 2s stderr summary and the CSV quantile
    // columns, so the poll drops to that cadence and the columns carry
    // the last aggregate between polls (counters are cumulative).
    let poll_every_round = cfg.metrics_jsonl.is_some() || cfg.metrics_addr.is_some();
    let mut agg = crate::obs::MetricsSnapshot::ZERO;
    let (counts, reached_horizon) = loop {
        let now = sw.elapsed_secs();
        // Collect every live worker's shard: one logical SnapshotReply
        // per rank (the wire layer reassembles chunked replies).
        let mut params: Vec<(u32, Vec<f32>)> = Vec::with_capacity(cfg.nodes);
        let mut evicted_now: Vec<usize> = Vec::new();
        for (rank, conn_slot) in conns.iter_mut().enumerate() {
            let Some(conn) = conn_slot else { continue };
            // Discard stale replies completed after a previous round
            // timed out, so they don't answer this round's request (a
            // partially-read logical message stays staged and resumes) —
            // but a LeaveNotice in the backlog still counts.
            while let Ok(Some(msg)) = conn.read_msg(Instant::now()) {
                if matches!(msg, WireMsg::LeaveNotice { .. }) {
                    leaving[rank] = true;
                }
            }
            let block = shard_map.range(rank as u32);
            let expected = block.len();
            let mut reply = None;
            let ok = conn.write_msg(&WireMsg::SnapshotRequest).is_ok() && {
                let deadline = Instant::now() + Duration::from_secs(2);
                loop {
                    match conn.read_msg(deadline) {
                        Ok(Some(WireMsg::SnapshotReply {
                            counts,
                            params: shard,
                            staging_bytes,
                            stream_done,
                            updates_at_stream_complete,
                            ..
                        })) => {
                            // A reply must cover exactly the rank's
                            // block; anything else is corrupt (or a
                            // stale fragment) — keep listening until
                            // the deadline.
                            if shard.len() == expected
                                && shard.iter().all(|(id, _)| block.contains(&(*id as usize)))
                            {
                                reply = Some((
                                    counts,
                                    shard,
                                    staging_bytes,
                                    stream_done,
                                    updates_at_stream_complete,
                                ));
                                break true;
                            }
                        }
                        Ok(Some(WireMsg::LeaveNotice { .. })) => leaving[rank] = true,
                        Ok(Some(_)) => {}
                        Ok(None) | Err(_) => break false,
                    }
                }
            };
            if let (true, Some((counts, shard, staging, done, upd_at_complete))) = (ok, reply) {
                strikes[rank] = 0;
                last_known[rank] = counts;
                max_staging_bytes = max_staging_bytes.max(staging);
                if done && upd_at_complete != u64::MAX && upd_at_complete > 0 {
                    stepped_before_stream_complete = true;
                }
                for (id, w) in &shard {
                    last_params[*id as usize] = w.clone();
                }
                params.extend(shard);
            } else {
                strikes[rank] += 1;
                if strikes[rank] >= MAX_STRIKES {
                    // Dead worker: out of the cohort; survivors carry on.
                    crate::obs::trace("monitor", "evict", rank as u64, strikes[rank] as u64);
                    *conn_slot = None;
                    evicted_now.push(rank);
                }
            }
        }
        // A graceful leaver vacates through the same path as a strike
        // eviction: its rank goes vacant and its node block is repaired
        // out of the topology.
        for rank in 0..cfg.workers {
            if leaving[rank] && conns[rank].is_some() {
                conns[rank] = None;
                evicted_now.push(rank);
            }
            leaving[rank] = false;
        }
        for rank in evicted_now {
            if vacant[rank] {
                continue;
            }
            vacant[rank] = true;
            // Fold the departed rank's last-known counters into the
            // retired accumulator: a replacement restarts its counters
            // at zero, and the aggregate must stay monotonic across
            // that reset.
            for (d, s) in retired.iter_mut().zip(last_known[rank].iter()) {
                *d += *s;
            }
            last_known[rank] = [0; 4];
            evictions += 1;
            crate::obs::add(crate::obs::Counter::Evictions, 1);
            let block: Vec<usize> = shard_map.range(rank as u32).collect();
            let patch = membership.deactivate(&block);
            if !patch.is_empty() {
                repairs += 1;
                crate::obs::add(crate::obs::Counter::Repairs, 1);
                let version = membership.version();
                for conn in conns.iter_mut().flatten() {
                    let _ = conn.write_msg(&WireMsg::TopologyPatch {
                        version,
                        entries: patch.clone(),
                    });
                }
                crate::obs::trace("monitor", "repair", rank as u64, version);
            }
        }
        // Admit joiners into vacant ranks. Admission is synchronous —
        // plan metadata plus the full credit-gated shard handoff — so
        // it happens between snapshot rounds, never mid-collection.
        if let Some(listener) = &join_listener {
            while let Ok((stream, _)) = listener.accept() {
                match admit_join(
                    stream,
                    cfg,
                    &plan,
                    &shard_map,
                    &mut membership,
                    &mut peers,
                    &mut vacant,
                    &mut conns,
                    &last_params,
                    budget,
                    &mut handoffs,
                ) {
                    Ok(rank) => {
                        joins += 1;
                        repairs += 1;
                        strikes[rank] = 0;
                        crate::log!(Info, "monitor", "worker joined as rank {rank}");
                    }
                    Err(e) => {
                        crate::log!(Warn, "monitor", "join admission failed: {e}");
                    }
                }
            }
        }
        if conns.iter().flatten().count() == 0 {
            kill_all(&mut children);
            bail!("every worker died before the horizon");
        }
        let mut total = Counts::default();
        for [g, p, m, c] in &last_known {
            total.grad_steps += g;
            total.proj_steps += p;
            total.messages += m;
            total.conflicts += c;
        }
        total.grad_steps += retired[0];
        total.proj_steps += retired[1];
        total.messages += retired[2];
        total.conflicts += retired[3];
        // Deterministic churn injection for the CI smoke and the
        // acceptance test: SIGKILL one rank and/or spawn a `--join`
        // replacement once the aggregate passes a horizon fraction.
        if let Some((rank, frac)) = cfg.chaos_kill {
            if !chaos_killed && total.updates() as f64 >= frac * cfg.horizon_updates as f64 {
                chaos_killed = true;
                if let Some(c) = children.get_mut(rank as usize) {
                    let _ = c.kill();
                }
                crate::log!(
                    Info,
                    "monitor",
                    "chaos: killed worker {rank} at k={}",
                    total.updates()
                );
                crate::obs::trace("monitor", "chaos_kill", rank as u64, total.updates());
            }
        }
        if let (Some(frac), Some(target)) = (cfg.chaos_join, join_target) {
            if !chaos_joined
                && total.updates() as f64 >= frac * cfg.horizon_updates as f64
                && vacant.iter().any(|&v| v)
            {
                chaos_joined = true;
                let mut cmd = Command::new(&binary);
                cmd.args(["worker", "--join", &target.to_string()]);
                if let Some(lvl) = &cfg.log_level {
                    cmd.args(["--log-level", lvl]);
                }
                match cmd.stdout(Stdio::null()).stderr(Stdio::inherit()).spawn() {
                    Ok(c) => {
                        children.push(c);
                        crate::log!(
                            Info,
                            "monitor",
                            "chaos: spawned a --join replacement at k={}",
                            total.updates()
                        );
                        crate::obs::trace("monitor", "chaos_join", 0, total.updates());
                    }
                    Err(e) => crate::log!(Warn, "monitor", "chaos join spawn failed: {e}"),
                }
            }
        }
        // One MetricsRequest per live worker, merged (with the monitor
        // process's own counters) into the cluster-wide aggregate. A
        // rank missing one round is fine — counters are cumulative.
        let summary_due = now - top_mark.2 >= 2.0;
        if poll_every_round || summary_due {
            let mut fresh = crate::obs::snapshot();
            for (rank, conn_slot) in conns.iter_mut().enumerate() {
                let Some(conn) = conn_slot else { continue };
                if conn.write_msg(&WireMsg::MetricsRequest).is_err() {
                    continue;
                }
                let deadline = Instant::now() + Duration::from_millis(500);
                loop {
                    match conn.read_msg(deadline) {
                        Ok(Some(WireMsg::MetricsReply {
                            counters,
                            hist_data,
                            ..
                        })) => {
                            fresh.merge_from(&crate::obs::MetricsSnapshot::from_wire(
                                &counters, &hist_data,
                            ));
                            break;
                        }
                        Ok(Some(WireMsg::LeaveNotice { .. })) => leaving[rank] = true,
                        Ok(Some(_)) => {}
                        Ok(None) | Err(_) => break,
                    }
                }
            }
            agg = fresh;
        }
        crate::obs::trace("monitor", "round", 0, total.updates());
        let staleness = agg.hists[crate::obs::Hist::StalenessTicks as usize];
        let staging = agg.gauges[crate::obs::Gauge::StagingHighWater as usize]
            .max(max_staging_bytes);
        params.sort_by_key(|(id, _)| *id);
        let cohort: Vec<Vec<f32>> = params.into_iter().map(|(_, w)| w).collect();
        if !cohort.is_empty() {
            let mut record = probe.snapshot(total.updates(), now, &cohort, &total);
            record.staleness_p50 = staleness.quantile(0.5);
            record.staleness_p99 = staleness.quantile(0.99);
            record.staging_bytes = staging;
            rec.push(record);
        }
        if let Some(path) = &cfg.metrics_jsonl {
            if let Err(e) =
                crate::obs::append_jsonl(path, &agg.jsonl("cluster", now, total.updates()))
            {
                crate::log_rl!(Warn, "monitor", "writing --metrics-jsonl {}: {e}", path.display());
            }
        }
        if cfg.metrics_addr.is_some() {
            *prom.lock().unwrap() = agg.prometheus_text();
        }
        if summary_due {
            let dt = (now - top_mark.2).max(1e-9);
            let steals = agg.counters[crate::obs::Counter::Steals as usize];
            crate::log!(
                Info,
                "monitor",
                "k={} consensus={:.3} staleness p50/p99={:.0}/{:.0} msgs/s={:.0} \
                 steals/s={:.0} staging={:.1}MiB",
                total.updates(),
                rec.last().map(|r| r.consensus).unwrap_or(f64::NAN),
                staleness.quantile(0.5),
                staleness.quantile(0.99),
                total.messages.saturating_sub(top_mark.0) as f64 / dt,
                steals.saturating_sub(top_mark.1) as f64 / dt,
                staging as f64 / (1024.0 * 1024.0)
            );
            top_mark = (total.messages, steals, now);
        }
        if total.updates() >= cfg.horizon_updates {
            break (total, true);
        }
        if now >= cfg.secs_cap {
            break (total, false);
        }
        std::thread::sleep(Duration::from_secs_f64(cfg.eval_every_secs.max(0.01)));
    };

    // End the run: broadcast Shutdown, then reap.
    crate::obs::trace("monitor", "shutdown", 0, counts.updates());
    for conn in conns.iter_mut().flatten() {
        let _ = conn.write_msg(&WireMsg::Shutdown);
    }
    let reap_deadline = Instant::now() + Duration::from_secs(10);
    for c in children.iter_mut() {
        loop {
            match c.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if Instant::now() < reap_deadline => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                _ => {
                    let _ = c.kill();
                    let _ = c.wait();
                    break;
                }
            }
        }
    }
    Ok(LaunchReport {
        recorder: rec,
        counts,
        live_workers: conns.iter().flatten().count(),
        elapsed_secs: sw.elapsed_secs(),
        reached_horizon,
        max_staging_bytes,
        stepped_before_stream_complete,
        joins,
        evictions,
        repairs,
        handoffs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_rank_trace_paths_stay_siblings() {
        let p = per_rank_trace_path(std::path::Path::new("out/trace.jsonl"), 3);
        assert_eq!(p, std::path::Path::new("out/trace.rank3.jsonl"));
        let p = per_rank_trace_path(std::path::Path::new("trace"), 0);
        assert_eq!(p, std::path::Path::new("trace.rank0"));
    }

    #[test]
    fn launch_config_rejects_bad_shapes() {
        let mut cfg = LaunchConfig::quick(0, 8);
        assert!(run_launch(&cfg).is_err());
        cfg.workers = 9;
        cfg.nodes = 8;
        assert!(run_launch(&cfg).is_err());
    }

    #[test]
    fn worker_config_rejects_bad_shapes() {
        let base = WorkerConfig {
            rank: 0,
            peers: vec![],
            nodes: 8,
            degree: 2,
            secs: 0.1,
            rate_hz: 100.0,
            objective: Objective::LogReg,
            strategy: StrategyKind::Dasgd,
            plan: WorkerPlanSource::Local(PlanSpec::Synth),
            samples_per_node: SAMPLES_PER_NODE,
            seed: 0,
            staging_mb: 1024,
            executors: 0,
            flush_bytes: 16 * 1024,
            flush_micros: 500,
            leave_after: None,
        };
        assert!(run_worker(&base).is_err(), "empty peers must fail");
        let mut bad_rank = base.clone();
        bad_rank.peers = vec!["127.0.0.1:1".into()];
        bad_rank.rank = 1;
        assert!(run_worker(&bad_rank).is_err(), "rank beyond peers must fail");
        let mut too_many = base.clone();
        too_many.peers = (0..9).map(|i| format!("127.0.0.1:{}", 1 + i)).collect();
        assert!(too_many.peers.len() > too_many.nodes);
        assert!(run_worker(&too_many).is_err(), "9 workers for 8 nodes must fail");
        // Wire mode without a parameter length cannot bind an engine.
        let mut no_len = base;
        no_len.peers = vec!["127.0.0.1:0".into()];
        no_len.plan = WorkerPlanSource::Wire { param_len: 0 };
        assert!(run_worker(&no_len).is_err(), "wire plan needs --param-len");
    }

    #[test]
    fn plan_assignments_round_trip_the_wire_codec() {
        let (plan, _) =
            PlanSpec::Mixed { alpha: 0.3 }.build(Objective::LogReg, 4, 40, 16, 77);
        // Exercise per-node strategies, not just the baseline.
        let plan = plan
            .with_node_strategy(1, StrategyKind::Dcasgd)
            .with_node_strategy(3, StrategyKind::Rfast);
        for id in 0..plan.len() {
            let msg = plan_assign_msg(id, plan.node(id));
            let frame = wire::encode(&msg).unwrap();
            let (back, _) = wire::decode(&frame).unwrap().expect("complete frame");
            let (rid, a) = assignment_from_msg(&back).unwrap();
            assert_eq!(rid, id);
            assert_eq!(a.objective.name(), plan.objective(id).name());
            assert_eq!(a.strategy, plan.strategy(id));
            assert_eq!(a.shard.labels(), plan.shard(id).labels());
            assert_eq!(a.shard.features_flat(), plan.shard(id).features_flat());
        }
    }

    #[test]
    fn corrupt_plan_frames_error_not_panic() {
        // Shape lie: 2 labels but features for 1 row.
        let msg = WireMsg::PlanAssign {
            node: 0,
            obj_code: 1,
            lam: 0.0,
            dim: 3,
            classes: 2,
            labels: vec![0, 1],
            features: vec![0.0; 3],
            strategy: 0,
        };
        assert!(assignment_from_msg(&msg).is_err());
        // Label out of range.
        let msg = WireMsg::PlanAssign {
            node: 0,
            obj_code: 1,
            lam: 0.0,
            dim: 1,
            classes: 2,
            labels: vec![5],
            features: vec![0.0],
            strategy: 0,
        };
        assert!(assignment_from_msg(&msg).is_err());
        // Unknown objective code.
        let msg = WireMsg::PlanAssign {
            node: 0,
            obj_code: 42,
            lam: 0.0,
            dim: 1,
            classes: 2,
            labels: vec![0],
            features: vec![0.0],
            strategy: 0,
        };
        assert!(assignment_from_msg(&msg).is_err());
        // Unknown strategy code (this build doesn't speak it).
        let msg = WireMsg::PlanAssign {
            node: 0,
            obj_code: 1,
            lam: 0.0,
            dim: 1,
            classes: 2,
            labels: vec![0],
            features: vec![0.0],
            strategy: 9,
        };
        assert!(assignment_from_msg(&msg).is_err());
        // Not a plan frame at all.
        assert!(assignment_from_msg(&WireMsg::Shutdown).is_err());
    }
}
