//! Worker and launcher entrypoints for multi-process deployments.
//!
//! A deployment is K *worker* processes (`dasgd worker --rank R
//! --peers a0,a1,...`), each owning one [`ShardMap`] block of nodes and
//! driving it with the same [`spawn_shard`] engine the in-process
//! cluster uses — just over a [`SocketNet`] instead of a local
//! substrate. Workers rendezvous by address list: every rank binds its
//! own entry of `--peers` and dials every lower rank.
//!
//! Workloads are [`WorkloadPlan`]s. The *launcher* (`dasgd launch
//! --workers K [--plan P --dirichlet-alpha A]`) builds the plan once
//! and **ships each worker its owned assignments over the wire**
//! (`PlanAssign`/`PlanStart` frames on the control connection): real
//! non-IID shards and per-node objectives travel to the processes that
//! train on them — workers spawned with `--plan wire` never regenerate
//! the global world. Only the topology is re-derived from
//! `(nodes, degree)`, which is deterministic and cheap. A standalone
//! worker (spanning machines, no launcher) instead derives its plan
//! locally from `--plan <spec>`: the builders are bit-deterministic in
//! `(spec, nodes, seed)`, so every rank reconstructs identical shards.
//!
//! After shipping, the launcher plays *monitor* — it polls every
//! worker's shard over the control connection
//! (`SnapshotRequest`/`SnapshotReply`), aggregates parameters and
//! counters, and feeds the same [`Probe`]/[`Recorder`] path every other
//! engine records through (mixed-objective cohorts evaluate under the
//! [`Probe::mixed`] convention). The run ends when the aggregate
//! applied-update count reaches `--horizon` (or the wall-clock cap), at
//! which point the monitor broadcasts `Shutdown`.
//!
//! Failure semantics: a worker that dies mid-run simply drops out of
//! monitor aggregation (metrics continue over the live cohort, exactly
//! like fault-injected kills in-process), and its peers' liveness
//! filtering degrades its nodes' projections to `Conflict`/`Isolated`
//! — survivors never hang.

use std::io::{Read as _, Write as _};
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{spawn_shard, AsyncConfig};
use crate::experiments::make_regular;
use crate::metrics::Recorder;
use crate::node_logic::{Counts, Probe};
use crate::objective::Objective;
use crate::transport::{Transport, TransportKind};
use crate::util::Stopwatch;
use crate::workload::{objective_code, objective_from_code, NodeAssignment, PlanSpec, WorkloadPlan};

use super::socket::{ShardMap, SocketConfig, SocketNet};
use super::wire::{self, WireMsg, MONITOR_RANK};

/// Samples per node in the deployment's synthetic world (matches the
/// in-process `cluster` command, so cross-mode runs are comparable).
const SAMPLES_PER_NODE: usize = 300;
const TEST_SAMPLES: usize = 512;

/// How many nodes' parameter vectors one `SnapshotReply` frame carries:
/// sized so a frame stays ~4 MiB, far under the wire codec's 16 MiB
/// cap even for large shards (the monitor reassembles chunks — it
/// knows each rank's shard size from the same `ShardMap`).
fn snapshot_chunk_nodes(param_len: usize) -> usize {
    let bytes_per_node = param_len * 4 + 8;
    ((4 << 20) / bytes_per_node.max(1)).max(1)
}

/// Read one frame from a control connection without assuming frame
/// boundaries align with read timeouts: bytes accumulate in `buf`
/// across calls, so a frame split by a timeout resumes instead of
/// desyncing the stream. Returns `Ok(None)` when nothing complete
/// arrived by `deadline` (a transient stall, not an error).
fn read_control_frame(
    conn: &mut TcpStream,
    buf: &mut Vec<u8>,
    deadline: Instant,
) -> Result<Option<WireMsg>, wire::WireError> {
    loop {
        if let Some((msg, used)) = wire::decode(buf)? {
            buf.drain(..used);
            return Ok(Some(msg));
        }
        if Instant::now() >= deadline {
            return Ok(None);
        }
        let mut tmp = [0u8; 4096];
        match conn.read(&mut tmp) {
            Ok(0) => {
                return Err(wire::WireError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "control connection closed",
                )))
            }
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(e) => return Err(wire::WireError::Io(e)),
        }
    }
}

// ---------------------------------------------------------------------------
// Plan ⇄ wire
// ---------------------------------------------------------------------------

/// Encode node `id`'s assignment as a `PlanAssign` control frame.
/// Errors when the shard cannot fit the codec's frame cap (one frame
/// per node keeps reassembly trivial; a 16 MiB shard is ~80k rows of
/// the 50-feature world).
pub fn plan_assign_msg(id: usize, a: &NodeAssignment) -> Result<WireMsg> {
    let rows = a.shard.len();
    let dim = a.shard.dim();
    let approx_len = 32 + rows * 4 + rows * dim * 4;
    if approx_len > wire::MAX_FRAME_LEN {
        bail!(
            "node {id}'s shard ({rows} rows × {dim} features) exceeds the \
             {}-byte wire frame cap",
            wire::MAX_FRAME_LEN
        );
    }
    let (obj_code, lam) = objective_code(a.objective);
    Ok(WireMsg::PlanAssign {
        node: id as u32,
        obj_code,
        lam,
        dim: dim as u32,
        classes: a.shard.classes() as u32,
        labels: a.shard.labels().iter().map(|&l| l as u32).collect(),
        features: a.shard.features_flat().to_vec(),
    })
}

/// Decode a `PlanAssign` frame back into `(node, assignment)`,
/// validating everything a hostile or corrupt frame could lie about
/// (shape mismatches, out-of-range labels, unknown objective codes).
pub fn assignment_from_msg(msg: &WireMsg) -> Result<(usize, NodeAssignment)> {
    let WireMsg::PlanAssign {
        node,
        obj_code,
        lam,
        dim,
        classes,
        labels,
        features,
    } = msg
    else {
        bail!("not a PlanAssign frame");
    };
    let (dim, classes) = (*dim as usize, *classes as usize);
    if dim == 0 || classes == 0 {
        bail!("plan frame with zero dim/classes");
    }
    let Some(objective) = objective_from_code(*obj_code, *lam) else {
        bail!("unknown objective code {obj_code}");
    };
    if features.len() != labels.len() * dim {
        bail!(
            "plan frame shape lies: {} labels × {dim} features ≠ {} values",
            labels.len(),
            features.len()
        );
    }
    let mut shard = crate::data::Dataset::with_capacity(dim, classes, labels.len());
    for (i, &label) in labels.iter().enumerate() {
        let label = label as usize;
        if label >= classes {
            bail!("plan frame label {label} out of range for {classes} classes");
        }
        shard.push(&features[i * dim..(i + 1) * dim], label);
    }
    Ok((*node as usize, NodeAssignment { objective, shard }))
}

// ---------------------------------------------------------------------------
// Worker
// ---------------------------------------------------------------------------

/// Where a worker's workload comes from.
#[derive(Clone, Copy, Debug)]
pub enum WorkerPlanSource {
    /// Derive the plan locally from a deterministic recipe — every
    /// rank rebuilds identical shards from `(spec, nodes, seed)`. The
    /// standalone multi-machine mode.
    Local(PlanSpec),
    /// Receive the plan from the launch monitor over the control
    /// connection (`PlanAssign`/`PlanStart`). The engine binds before
    /// the data arrives, so the parameter length must be given up
    /// front (`--param-len`; the launcher computes it from the plan).
    Wire { param_len: usize },
}

/// One worker process's configuration.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub rank: u32,
    /// Every rank's `host:port`, rank-ordered; ours is bound, lower
    /// ranks are dialed.
    pub peers: Vec<String>,
    pub nodes: usize,
    pub degree: usize,
    /// Wall-clock cap: exit even if no `Shutdown` ever arrives (a dead
    /// monitor must not leave worker processes behind).
    pub secs: f64,
    pub rate_hz: f64,
    /// The uniform loss family for local plan specs (and the stepsize
    /// base); per-node objectives of a shipped or mixed plan supersede
    /// it.
    pub objective: Objective,
    pub plan: WorkerPlanSource,
    pub seed: u64,
}

/// What a finished worker reports.
#[derive(Debug)]
pub struct WorkerSummary {
    pub counts: Counts,
    /// True when the monitor ended the run (vs the wall-clock cap).
    pub shutdown_by_monitor: bool,
}

/// Wait for the launch monitor's control connection and drain its
/// `PlanAssign` stream up to `PlanStart`. Returns the worker's partial
/// plan plus the control connection (and its read buffer) so the serve
/// loop continues on the very same stream.
fn receive_wire_plan(
    net: &SocketNet,
    nodes: usize,
    param_len: usize,
    deadline: Instant,
) -> Result<(WorkloadPlan, TcpStream, Vec<u8>)> {
    let mut conn = loop {
        if let Some(c) = net.take_control() {
            break c;
        }
        if Instant::now() >= deadline {
            bail!("no monitor connected to ship the workload plan");
        }
        std::thread::sleep(Duration::from_millis(25));
    };
    let _ = conn.set_read_timeout(Some(Duration::from_millis(50)));
    let _ = conn.set_write_timeout(Some(Duration::from_secs(1)));
    let mut buf = Vec::new();
    let mut assigned: Vec<(usize, NodeAssignment)> = Vec::new();
    let global_mixed = loop {
        let frame_deadline = Instant::now() + Duration::from_millis(250);
        match read_control_frame(&mut conn, &mut buf, frame_deadline) {
            Ok(Some(msg @ WireMsg::PlanAssign { .. })) => {
                assigned.push(assignment_from_msg(&msg)?);
            }
            Ok(Some(WireMsg::PlanStart {
                nodes: n_total,
                assigned: count,
                mixed,
            })) => {
                if n_total as usize != nodes {
                    bail!("plan is for {n_total} nodes, this deployment has {nodes}");
                }
                if count as usize != assigned.len() {
                    bail!(
                        "monitor announced {count} assignments, {} arrived",
                        assigned.len()
                    );
                }
                break mixed;
            }
            Ok(Some(_)) => {} // nothing else is meaningful pre-start
            Ok(None) => {
                if Instant::now() >= deadline {
                    bail!("workload plan never completed before the deadline");
                }
            }
            Err(e) => return Err(anyhow!("control stream failed mid-plan: {e}")),
        }
    };
    let Some((_, first)) = assigned.first() else {
        bail!("monitor started the run without shipping any assignment");
    };
    let (dim, classes) = (first.shard.dim(), first.shard.classes());
    let plan = WorkloadPlan::from_partial(nodes, dim, classes, assigned, global_mixed)?;
    if plan.param_len() != param_len {
        bail!(
            "shipped plan's parameter length {} does not match --param-len {param_len}",
            plan.param_len()
        );
    }
    Ok((plan, conn, buf))
}

/// Run one worker to completion: bind, rendezvous, obtain the workload
/// plan (local recipe or shipped over the wire), drive the owned shard,
/// serve monitor snapshots, exit on `Shutdown` or the cap.
pub fn run_worker(cfg: &WorkerConfig) -> Result<WorkerSummary> {
    let workers = cfg.peers.len();
    if workers == 0 {
        bail!("--peers must list every worker's host:port");
    }
    if cfg.rank as usize >= workers {
        bail!("--rank {} out of range for {} peers", cfg.rank, workers);
    }
    if workers > cfg.nodes {
        bail!("more workers ({workers}) than nodes ({})", cfg.nodes);
    }
    let graph = make_regular(cfg.nodes, cfg.degree);
    let objective = cfg.objective;
    // A locally-derived plan exists before the engine binds; a shipped
    // one arrives after (its parameter length came on the CLI).
    let (local_plan, param_len) = match cfg.plan {
        WorkerPlanSource::Local(spec) => {
            let (plan, _test) =
                spec.build(objective, cfg.nodes, SAMPLES_PER_NODE, TEST_SAMPLES, cfg.seed);
            let param_len = plan.param_len();
            (Some(plan), param_len)
        }
        WorkerPlanSource::Wire { param_len } => {
            if param_len == 0 {
                bail!("--plan wire needs --param-len (the launcher supplies it)");
            }
            (None, param_len)
        }
    };

    let shard_map = ShardMap::new(cfg.nodes, workers);
    let net = SocketNet::bind(
        cfg.rank,
        shard_map,
        param_len,
        &cfg.peers[cfg.rank as usize],
        SocketConfig::default(),
    )
    .with_context(|| format!("binding {}", cfg.peers[cfg.rank as usize]))?;
    let owned = net.local_nodes();
    println!(
        "dasgd-worker rank={} listening on {} (nodes {}..{} of {})",
        cfg.rank,
        net.local_addr(),
        owned.start,
        owned.end,
        cfg.nodes
    );
    let _ = std::io::stdout().flush();
    net.connect_peers(&cfg.peers);
    if !net.wait_connected(Duration::from_secs(10)) {
        eprintln!(
            "dasgd-worker rank={}: not all peers reachable after 10s; \
             continuing degraded (their nodes are filtered from neighborhoods)",
            cfg.rank
        );
    }

    let deadline = Instant::now() + Duration::from_secs_f64(cfg.secs.max(0.1));
    let mut controls: Vec<(TcpStream, Vec<u8>)> = Vec::new();
    let plan = match local_plan {
        Some(plan) => plan,
        None => {
            let (plan, conn, buf) = receive_wire_plan(&net, cfg.nodes, param_len, deadline)
                .with_context(|| format!("rank {} receiving the workload plan", cfg.rank))?;
            controls.push((conn, buf));
            plan
        }
    };
    for id in owned.clone() {
        if plan.shard(id).is_empty() {
            bail!("owned node {id} has no data in the plan");
        }
    }

    let acfg = AsyncConfig {
        p_grad: 0.5,
        stepsize: objective.default_stepsize(cfg.nodes),
        rate_hz: cfg.rate_hz,
        speed_spread: 0.0,
        duration_secs: cfg.secs,
        eval_every_secs: cfg.secs,
        gossip_hold_secs: 0.0,
        kill_after_secs: None,
        kill_nodes: 0,
        transport: TransportKind::Socket,
        seed: cfg.seed,
    };
    let transport: Arc<dyn Transport> = Arc::new(net.clone());
    let run = spawn_shard(&graph, &plan, &acfg, transport, owned.clone(), None);

    // Serve the control plane until Shutdown or the wall-clock cap.
    let mut shutdown_by_monitor = false;
    'serve: while Instant::now() < deadline {
        while let Some(conn) = net.take_control() {
            let _ = conn.set_read_timeout(Some(Duration::from_millis(25)));
            let _ = conn.set_write_timeout(Some(Duration::from_secs(1)));
            controls.push((conn, Vec::new()));
        }
        if controls.is_empty() {
            std::thread::sleep(Duration::from_millis(25));
            continue;
        }
        let mut dropped = Vec::new();
        for (ci, (conn, buf)) in controls.iter_mut().enumerate() {
            let frame_deadline = Instant::now() + Duration::from_millis(25);
            match read_control_frame(conn, buf, frame_deadline) {
                Ok(Some(WireMsg::SnapshotRequest)) => {
                    // Chunked so a large shard never exceeds the frame
                    // cap; the monitor reassembles (it knows our shard
                    // size). Counters ride on every chunk — the last
                    // one read wins, and they only grow.
                    let c = run.counts();
                    let counts = [c.grad_steps, c.proj_steps, c.messages, c.conflicts];
                    let all: Vec<(u32, Vec<f32>)> = net
                        .local_params()
                        .into_iter()
                        .map(|(id, w)| (id as u32, w))
                        .collect();
                    for chunk in all.chunks(snapshot_chunk_nodes(param_len)) {
                        let reply = WireMsg::SnapshotReply {
                            rank: cfg.rank,
                            counts,
                            params: chunk.to_vec(),
                        };
                        if wire::write_frame(conn, &reply).is_err() {
                            dropped.push(ci);
                            break;
                        }
                    }
                }
                Ok(Some(WireMsg::Shutdown)) => {
                    shutdown_by_monitor = true;
                    break 'serve;
                }
                Ok(Some(_)) => {} // not meaningful on a control connection
                Ok(None) => {}    // nothing complete yet
                Err(_) => dropped.push(ci),
            }
        }
        for ci in dropped.into_iter().rev() {
            controls.remove(ci);
        }
    }

    let counts = run.stop_and_join();
    net.shutdown();
    println!(
        "dasgd-worker rank={} done: {} updates ({} grad, {} proj), {} messages, {} conflicts",
        cfg.rank,
        counts.updates(),
        counts.grad_steps,
        counts.proj_steps,
        counts.messages,
        counts.conflicts
    );
    Ok(WorkerSummary {
        counts,
        shutdown_by_monitor,
    })
}

// ---------------------------------------------------------------------------
// Launcher / monitor
// ---------------------------------------------------------------------------

/// Single-machine deployment configuration.
#[derive(Clone, Debug)]
pub struct LaunchConfig {
    pub workers: usize,
    pub nodes: usize,
    pub degree: usize,
    /// Stop once the aggregate applied-update count reaches this.
    pub horizon_updates: u64,
    /// Wall-clock safety cap for the whole run.
    pub secs_cap: f64,
    pub eval_every_secs: f64,
    pub rate_hz: f64,
    /// The uniform loss family (superseded per node by `mixed` plans).
    pub objective: Objective,
    /// The workload recipe; the launcher builds it once and ships each
    /// worker its owned shards over the wire.
    pub plan: PlanSpec,
    pub seed: u64,
    /// The worker binary. `None` = this executable (the CLI case);
    /// tests point it at the built `dasgd` binary.
    pub binary: Option<std::path::PathBuf>,
}

impl LaunchConfig {
    pub fn quick(workers: usize, nodes: usize) -> Self {
        Self {
            workers,
            nodes,
            degree: 2,
            horizon_updates: 2000,
            secs_cap: 30.0,
            eval_every_secs: 0.25,
            rate_hz: 300.0,
            objective: Objective::LogReg,
            plan: PlanSpec::Synth,
            seed: 0,
            binary: None,
        }
    }
}

/// Outcome of a launched deployment.
#[derive(Debug)]
pub struct LaunchReport {
    pub recorder: Recorder,
    pub counts: Counts,
    /// Workers still answering snapshots at the end.
    pub live_workers: usize,
    pub elapsed_secs: f64,
    /// True when the run ended by reaching `horizon_updates`; false
    /// means the wall-clock cap expired first (a stalled deployment —
    /// the CLI exits nonzero on it so CI smoke runs can fail).
    pub reached_horizon: bool,
}

/// Reserve a free loopback port by binding port 0 and noting the
/// assignment. The tiny window between drop and the worker's bind is a
/// documented single-machine trade-off (docs/deployment.md).
fn reserve_port() -> Result<u16> {
    let l = TcpListener::bind("127.0.0.1:0").context("reserving a loopback port")?;
    Ok(l.local_addr()?.port())
}

fn kill_all(children: &mut [Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// Spawn `cfg.workers` local worker processes, ship each its slice of
/// the workload plan, monitor them to the horizon, shut them down, and
/// return the aggregated run record.
pub fn run_launch(cfg: &LaunchConfig) -> Result<LaunchReport> {
    if cfg.workers == 0 {
        bail!("--workers must be at least 1");
    }
    if cfg.workers > cfg.nodes {
        bail!("more workers ({}) than nodes ({})", cfg.workers, cfg.nodes);
    }
    // The whole deployment's workload, built exactly once. Workers get
    // their assignments over the wire — never regenerated from seed.
    let (plan, test) = cfg.plan.build(
        cfg.objective,
        cfg.nodes,
        SAMPLES_PER_NODE,
        TEST_SAMPLES,
        cfg.seed,
    );
    let param_len = plan.param_len();
    let shard_map = ShardMap::new(cfg.nodes, cfg.workers);
    let peers: Vec<String> = (0..cfg.workers)
        .map(|_| reserve_port().map(|p| format!("127.0.0.1:{p}")))
        .collect::<Result<_>>()?;
    let binary = match &cfg.binary {
        Some(p) => p.clone(),
        None => std::env::current_exe().context("locating this executable")?,
    };
    // Workers outlive the monitor's cap slightly so a slow shutdown
    // never races their own wall-clock exit.
    let worker_secs = cfg.secs_cap + 10.0;
    let mut children: Vec<Child> = Vec::with_capacity(cfg.workers);
    for rank in 0..cfg.workers {
        let child = Command::new(&binary)
            .args([
                "worker",
                "--rank",
                &rank.to_string(),
                "--peers",
                &peers.join(","),
                "--nodes",
                &cfg.nodes.to_string(),
                "--degree",
                &cfg.degree.to_string(),
                "--secs",
                &format!("{worker_secs}"),
                "--rate",
                &format!("{}", cfg.rate_hz),
                "--objective",
                cfg.objective.name(),
                "--plan",
                "wire",
                "--param-len",
                &param_len.to_string(),
                "--seed",
                &cfg.seed.to_string(),
            ])
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn();
        match child {
            Ok(c) => children.push(c),
            Err(e) => {
                kill_all(&mut children);
                return Err(anyhow!("spawning worker {rank}: {e}"));
            }
        }
    }

    // Monitor control connections (retry while workers come up).
    let mut conns: Vec<Option<TcpStream>> = Vec::with_capacity(cfg.workers);
    for (rank, addr) in peers.iter().enumerate() {
        let deadline = Instant::now() + Duration::from_secs(10);
        let conn = loop {
            if let Ok(mut s) = TcpStream::connect(addr) {
                let _ = s.set_nodelay(true);
                // Short socket timeout: read_control_frame's own frame
                // deadline governs how long a round waits.
                let _ = s.set_read_timeout(Some(Duration::from_millis(250)));
                let _ = s.set_write_timeout(Some(Duration::from_secs(1)));
                if wire::write_frame(&mut s, &WireMsg::Hello { rank: MONITOR_RANK }).is_ok() {
                    break Some(s);
                }
            }
            if Instant::now() >= deadline {
                break None;
            }
            std::thread::sleep(Duration::from_millis(50));
        };
        if conn.is_none() {
            kill_all(&mut children);
            bail!("worker {rank} at {addr} never accepted the monitor connection");
        }
        conns.push(conn);
    }

    // Ship each rank its owned block of the plan. The write timeout is
    // generous here: a whole shard block crosses the socket, and a
    // worker still inside peer rendezvous drains it a few seconds
    // later.
    for (rank, conn_slot) in conns.iter_mut().enumerate() {
        let conn = conn_slot.as_mut().expect("all connected above");
        let _ = conn.set_write_timeout(Some(Duration::from_secs(30)));
        let block = shard_map.range(rank as u32);
        let mut ok = true;
        for id in block.clone() {
            let msg = match plan_assign_msg(id, plan.node(id)) {
                Ok(msg) => msg,
                Err(e) => {
                    kill_all(&mut children);
                    return Err(e);
                }
            };
            if wire::write_frame(conn, &msg).is_err() {
                ok = false;
                break;
            }
        }
        ok = ok
            && wire::write_frame(
                conn,
                &WireMsg::PlanStart {
                    nodes: cfg.nodes as u32,
                    assigned: block.len() as u32,
                    mixed: plan.is_mixed(),
                },
            )
            .is_ok();
        let _ = conn.set_write_timeout(Some(Duration::from_secs(1)));
        if !ok {
            kill_all(&mut children);
            bail!("worker {rank} dropped the control connection during plan shipping");
        }
    }

    // The monitor's evaluation set came from the plan build; mixed
    // cohorts evaluate under the weighted per-family convention.
    let probe = Probe::mixed(&plan.objectives(), &test);
    let mut rec = Recorder::new("socket");
    let sw = Stopwatch::new();
    let mut bufs: Vec<Vec<u8>> = (0..cfg.workers).map(|_| Vec::new()).collect();
    // A worker misses a round on a transient stall; only repeated
    // silence evicts it from the cohort. Five 2s-deadline rounds also
    // cover a worker still inside its 10s peer-rendezvous wait (it
    // serves control only after that).
    let mut strikes = vec![0u32; cfg.workers];
    const MAX_STRIKES: u32 = 5;
    // Each rank's last-known cumulative counters. Summing these keeps
    // the aggregate monotonic when a worker misses a round (or dies —
    // its applied updates still happened).
    let mut last_known = vec![[0u64; 4]; cfg.workers];
    let (counts, reached_horizon) = loop {
        let now = sw.elapsed_secs();
        // Collect every live worker's shard (chunked SnapshotReply
        // frames; each rank's expected node count comes from the
        // ShardMap both sides share).
        let mut params: Vec<(u32, Vec<f32>)> = Vec::with_capacity(cfg.nodes);
        for (rank, conn_slot) in conns.iter_mut().enumerate() {
            let Some(conn) = conn_slot else { continue };
            let buf = &mut bufs[rank];
            // Drain complete frames left over from a timed-out round
            // so stale chunks don't blend into this one (a partial
            // frame's bytes stay and resume cleanly).
            while let Ok(Some(_)) = read_control_frame(conn, buf, Instant::now()) {}
            // Reassemble by node id (a stale chunk from a previously
            // timed-out round may still arrive first; newest value for
            // an id wins, and completion counts distinct ids).
            let block = shard_map.range(rank as u32);
            let expected = block.len();
            let mut shard: Vec<Option<Vec<f32>>> = vec![None; expected];
            let mut got = 0usize;
            let mut last_counts = None;
            let ok = wire::write_frame(conn, &WireMsg::SnapshotRequest).is_ok() && {
                let deadline = Instant::now() + Duration::from_secs(2);
                loop {
                    match read_control_frame(conn, buf, deadline) {
                        Ok(Some(WireMsg::SnapshotReply {
                            counts,
                            params: chunk,
                            ..
                        })) => {
                            last_counts = Some(counts);
                            for (id, w) in chunk {
                                let id = id as usize;
                                if block.contains(&id) {
                                    let slot = &mut shard[id - block.start];
                                    if slot.is_none() {
                                        got += 1;
                                    }
                                    *slot = Some(w);
                                }
                            }
                            if got >= expected {
                                break true;
                            }
                        }
                        Ok(Some(_)) => {}
                        Ok(None) | Err(_) => break false,
                    }
                }
            };
            if ok {
                strikes[rank] = 0;
                last_known[rank] = last_counts.expect("ok round has counts");
                params.extend(
                    shard
                        .into_iter()
                        .enumerate()
                        .map(|(i, w)| ((block.start + i) as u32, w.expect("complete shard"))),
                );
            } else {
                strikes[rank] += 1;
                if strikes[rank] >= MAX_STRIKES {
                    // Dead worker: out of the cohort; survivors carry on.
                    *conn_slot = None;
                }
            }
        }
        if conns.iter().flatten().count() == 0 {
            kill_all(&mut children);
            bail!("every worker died before the horizon");
        }
        let mut total = Counts::default();
        for [g, p, m, c] in &last_known {
            total.grad_steps += g;
            total.proj_steps += p;
            total.messages += m;
            total.conflicts += c;
        }
        params.sort_by_key(|(id, _)| *id);
        let cohort: Vec<Vec<f32>> = params.into_iter().map(|(_, w)| w).collect();
        if !cohort.is_empty() {
            rec.push(probe.snapshot(total.updates(), now, &cohort, &total));
        }
        if total.updates() >= cfg.horizon_updates {
            break (total, true);
        }
        if now >= cfg.secs_cap {
            break (total, false);
        }
        std::thread::sleep(Duration::from_secs_f64(cfg.eval_every_secs.max(0.01)));
    };

    // End the run: broadcast Shutdown, then reap.
    for conn in conns.iter_mut().flatten() {
        let _ = wire::write_frame(conn, &WireMsg::Shutdown);
    }
    let reap_deadline = Instant::now() + Duration::from_secs(10);
    for c in children.iter_mut() {
        loop {
            match c.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if Instant::now() < reap_deadline => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                _ => {
                    let _ = c.kill();
                    let _ = c.wait();
                    break;
                }
            }
        }
    }
    Ok(LaunchReport {
        recorder: rec,
        counts,
        live_workers: conns.iter().flatten().count(),
        elapsed_secs: sw.elapsed_secs(),
        reached_horizon,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_config_rejects_bad_shapes() {
        let mut cfg = LaunchConfig::quick(0, 8);
        assert!(run_launch(&cfg).is_err());
        cfg.workers = 9;
        cfg.nodes = 8;
        assert!(run_launch(&cfg).is_err());
    }

    #[test]
    fn worker_config_rejects_bad_shapes() {
        let base = WorkerConfig {
            rank: 0,
            peers: vec![],
            nodes: 8,
            degree: 2,
            secs: 0.1,
            rate_hz: 100.0,
            objective: Objective::LogReg,
            plan: WorkerPlanSource::Local(PlanSpec::Synth),
            seed: 0,
        };
        assert!(run_worker(&base).is_err(), "empty peers must fail");
        let mut bad_rank = base.clone();
        bad_rank.peers = vec!["127.0.0.1:1".into()];
        bad_rank.rank = 1;
        assert!(run_worker(&bad_rank).is_err(), "rank beyond peers must fail");
        let mut too_many = base.clone();
        too_many.peers = (0..9).map(|i| format!("127.0.0.1:{}", 1 + i)).collect();
        assert!(too_many.peers.len() > too_many.nodes);
        assert!(run_worker(&too_many).is_err(), "9 workers for 8 nodes must fail");
        // Wire mode without a parameter length cannot bind an engine.
        let mut no_len = base;
        no_len.peers = vec!["127.0.0.1:0".into()];
        no_len.plan = WorkerPlanSource::Wire { param_len: 0 };
        assert!(run_worker(&no_len).is_err(), "wire plan needs --param-len");
    }

    #[test]
    fn plan_assignments_round_trip_the_wire_codec() {
        let (plan, _) =
            PlanSpec::Mixed { alpha: 0.3 }.build(Objective::LogReg, 4, 40, 16, 77);
        for id in 0..plan.len() {
            let msg = plan_assign_msg(id, plan.node(id)).unwrap();
            let frame = wire::encode(&msg);
            let (back, _) = wire::decode(&frame).unwrap().expect("complete frame");
            let (rid, a) = assignment_from_msg(&back).unwrap();
            assert_eq!(rid, id);
            assert_eq!(a.objective.name(), plan.objective(id).name());
            assert_eq!(a.shard.labels(), plan.shard(id).labels());
            assert_eq!(a.shard.features_flat(), plan.shard(id).features_flat());
        }
    }

    #[test]
    fn corrupt_plan_frames_error_not_panic() {
        // Shape lie: 2 labels but features for 1 row.
        let msg = WireMsg::PlanAssign {
            node: 0,
            obj_code: 1,
            lam: 0.0,
            dim: 3,
            classes: 2,
            labels: vec![0, 1],
            features: vec![0.0; 3],
        };
        assert!(assignment_from_msg(&msg).is_err());
        // Label out of range.
        let msg = WireMsg::PlanAssign {
            node: 0,
            obj_code: 1,
            lam: 0.0,
            dim: 1,
            classes: 2,
            labels: vec![5],
            features: vec![0.0],
        };
        assert!(assignment_from_msg(&msg).is_err());
        // Unknown objective code.
        let msg = WireMsg::PlanAssign {
            node: 0,
            obj_code: 42,
            lam: 0.0,
            dim: 1,
            classes: 2,
            labels: vec![0],
            features: vec![0.0],
        };
        assert!(assignment_from_msg(&msg).is_err());
        // Not a plan frame at all.
        assert!(assignment_from_msg(&WireMsg::Shutdown).is_err());
    }
}
