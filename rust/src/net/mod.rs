//! SocketNet: the multi-process deployment layer.
//!
//! The paper's system is *fully distributed* — no central controller,
//! no slot synchronization — but until this subsystem every engine in
//! the repo ran inside one OS process. `net` carries the Alg. 2
//! projection protocol over real sockets, in three layers:
//!
//! * [`wire`] — a versioned, length-prefixed binary codec for the
//!   ChannelNet message set (`CollectRequest` / `CollectReply` / `Busy`
//!   / `Abort` / `ApplyAverage`) plus the control plane (`Hello` /
//!   `Heartbeat` / `SnapshotRequest` / `SnapshotReply` / `Shutdown`)
//!   and a generic chunk envelope (`ChunkBegin` / `ChunkData` /
//!   `ChunkEnd`) that carries any logical message past the 16 MiB
//!   frame cap. Encoding and decoding are both total: overlong or
//!   malformed input errors, never panics or truncates.
//! * [`socket`] — [`SocketNet`], a [`Transport`](crate::transport::Transport)
//!   where each worker process owns a [`ShardMap`] block of nodes.
//!   Intra-shard traffic short-circuits through in-process mailboxes;
//!   cross-shard traffic flows over persistent TCP connections with
//!   reconnect and heartbeat-based liveness. A dead peer degrades to
//!   `Conflict`/`Isolated` — the leased-capture guarantee survives the
//!   network.
//! * [`cluster`] — the rendezvous layer: `dasgd worker --rank R
//!   --peers ...` runs one shard; `dasgd launch --workers K` spawns a
//!   single-machine deployment, ships each worker its
//!   [`WorkloadPlan`](crate::workload::WorkloadPlan) assignments over
//!   the wire (`PlanAssign`/`PlanStart` — real non-IID shards and
//!   per-node objectives, never regenerated from the seed), and plays
//!   monitor, aggregating worker snapshots into the same
//!   `Probe`/`Recorder` metrics path (and CSV output) every in-process
//!   engine uses. The monitor is also the membership controller:
//!   `--join-addr` admits mid-run `dasgd worker --join` replacements
//!   (rank grant, plan metadata, and a credit-gated shard handoff over
//!   the wire), heartbeat evictions and `LeaveNotice` departures vacate
//!   ranks, and every change ships `TopologyPatch` repairs computed by
//!   [`crate::membership`].
//!
//! See docs/deployment.md for the quickstart and failure semantics,
//! and docs/membership.md for the churn protocol.

pub mod cluster;
pub mod socket;
pub mod wire;

pub use cluster::{
    assignment_from_msg, plan_assign_msg, run_join_worker, run_launch, run_worker, LaunchConfig,
    LaunchReport, WorkerConfig, WorkerPlanSource, WorkerSummary, SAMPLES_PER_NODE,
};
pub use socket::{ShardMap, SocketConfig, SocketNet};
pub use wire::{WireError, WireMsg, MONITOR_RANK, WIRE_VERSION};
