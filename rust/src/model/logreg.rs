//! Native multinomial logistic regression: fused SGD step + evaluation.
//!
//! Mirrors the semantics of the Pallas `logreg_step` / `logreg_eval`
//! kernels exactly (same stable-softmax formulation) so integration tests
//! can assert the two paths agree to float tolerance.

/// Multinomial logistic regression with row-major W (dim × classes).
#[derive(Clone, Debug)]
pub struct LogReg {
    dim: usize,
    classes: usize,
    /// Row-major (dim × classes) weights.
    pub w: Vec<f32>,
}

/// Evaluation result over a batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LogRegEval {
    pub loss_sum: f32,
    pub err_count: usize,
    pub n: usize,
}

impl LogRegEval {
    pub fn mean_loss(&self) -> f32 {
        self.loss_sum / self.n as f32
    }

    pub fn error_rate(&self) -> f32 {
        self.err_count as f32 / self.n as f32
    }
}

impl LogReg {
    pub fn zeros(dim: usize, classes: usize) -> Self {
        Self {
            dim,
            classes,
            w: vec![0.0; dim * classes],
        }
    }

    pub fn from_weights(dim: usize, classes: usize, w: Vec<f32>) -> Self {
        assert_eq!(w.len(), dim * classes);
        Self { dim, classes, w }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    /// logits = x @ W for one sample row.
    fn logits(&self, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.dim);
        let c = self.classes;
        let mut out = vec![0.0f32; c];
        for (d, &xv) in x.iter().enumerate() {
            if xv != 0.0 {
                let wrow = &self.w[d * c..(d + 1) * c];
                for (o, wv) in out.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
        out
    }

    /// Stable log-softmax in place; returns (log_probs, max_index).
    fn log_softmax(logits: &[f32]) -> (Vec<f32>, usize) {
        let mut max = f32::NEG_INFINITY;
        let mut argmax = 0;
        for (i, &v) in logits.iter().enumerate() {
            if v > max {
                max = v;
                argmax = i;
            }
        }
        let lse = logits.iter().map(|v| (v - max).exp()).sum::<f32>().ln();
        let lp = logits.iter().map(|v| v - max - lse).collect();
        (lp, argmax)
    }

    /// One SGD step on a microbatch; returns the mean CE loss.
    ///
    /// `w ← w − lr·scale·(1/B)·Xᵀ(p − y)` — identical to the Pallas
    /// `logreg_step` kernel; `scale` carries the paper's 1/N factor.
    pub fn sgd_step(
        &mut self,
        xs: &[&[f32]],
        labels: &[usize],
        lr: f32,
        scale: f32,
    ) -> f32 {
        assert_eq!(xs.len(), labels.len());
        assert!(!xs.is_empty());
        let b = xs.len() as f32;
        let c = self.classes;
        let step = lr * scale / b;
        let mut loss = 0.0f32;
        // Accumulate the full batch gradient Xᵀ(p − y) first (true
        // minibatch semantics, matching the Pallas kernel), then apply.
        let mut grad = vec![0.0f32; self.w.len()];
        for (x, &label) in xs.iter().zip(labels) {
            let logits = self.logits(x);
            let (lp, _) = Self::log_softmax(&logits);
            loss -= lp[label];
            let mut delta: Vec<f32> = lp.iter().map(|v| v.exp()).collect();
            delta[label] -= 1.0;
            for (d, &xv) in x.iter().enumerate() {
                if xv != 0.0 {
                    let grow = &mut grad[d * c..(d + 1) * c];
                    for (gv, dv) in grow.iter_mut().zip(&delta) {
                        *gv += xv * dv;
                    }
                }
            }
        }
        for (wv, gv) in self.w.iter_mut().zip(&grad) {
            *wv -= step * gv;
        }
        loss / b
    }

    /// Evaluate loss-sum and error-count over a batch (mirrors
    /// `logreg_eval`).
    pub fn evaluate(&self, xs: &[f32], labels: &[usize]) -> LogRegEval {
        assert_eq!(xs.len(), labels.len() * self.dim);
        let mut loss_sum = 0.0f32;
        let mut err = 0usize;
        for (i, &label) in labels.iter().enumerate() {
            let x = &xs[i * self.dim..(i + 1) * self.dim];
            let logits = self.logits(x);
            let (lp, argmax) = Self::log_softmax(&logits);
            loss_sum -= lp[label];
            if argmax != label {
                err += 1;
            }
        }
        LogRegEval {
            loss_sum,
            err_count: err,
            n: labels.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn zero_weights_uniform_loss() {
        let m = LogReg::zeros(4, 10);
        let xs = vec![1.0f32; 4];
        let eval = m.evaluate(&xs, &[3]);
        // log(10) per sample at uniform predictions.
        assert!((eval.mean_loss() - (10f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn sgd_reduces_loss_on_separable_data() {
        let mut rng = Xoshiro256pp::seeded(0);
        let (dim, classes) = (12, 3);
        let mut m = LogReg::zeros(dim, classes);
        let means: Vec<Vec<f32>> = (0..classes)
            .map(|_| (0..dim).map(|_| rng.gauss_f32(0.0, 2.0)).collect())
            .collect();
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..300 {
            let label = rng.index(classes);
            let x: Vec<f32> = means[label]
                .iter()
                .map(|v| v + rng.gauss_f32(0.0, 0.3))
                .collect();
            let loss = m.sgd_step(&[&x], &[label], 0.5, 1.0);
            if step < 20 {
                first += loss;
            }
            if step >= 280 {
                last += loss;
            }
        }
        assert!(last < first * 0.5, "first={first} last={last}");
    }

    #[test]
    fn step_matches_manual_gradient() {
        // Single sample, small shapes: compare against hand-computed grad.
        let mut m = LogReg::from_weights(2, 2, vec![0.1, -0.2, 0.3, 0.0]);
        let x = [1.0f32, 2.0];
        let logits: [f32; 2] = [
            0.1 * 1.0 + 0.3 * 2.0, // class 0
            -0.2 * 1.0 + 0.0 * 2.0,
        ];
        let max = logits[0].max(logits[1]);
        let e0 = (logits[0] - max).exp();
        let e1 = (logits[1] - max).exp();
        let p = [e0 / (e0 + e1), e1 / (e0 + e1)];
        let label = 1usize;
        let lr = 0.1f32;
        let mut expect = m.w.clone();
        let delta = [p[0], p[1] - 1.0];
        for d in 0..2 {
            for c in 0..2 {
                expect[d * 2 + c] -= lr * x[d] * delta[c];
            }
        }
        let loss = m.sgd_step(&[&x], &[label], lr, 1.0);
        for (got, want) in m.w.iter().zip(&expect) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
        assert!((loss + p[1].ln()).abs() < 1e-5);
    }

    #[test]
    fn evaluate_counts_errors() {
        // W = identity-ish: class = argmax of x.
        let m = LogReg::from_weights(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let xs = vec![
            5.0, 0.0, // pred 0
            0.0, 5.0, // pred 1
            5.0, 0.0, // pred 0
        ];
        let eval = m.evaluate(&xs, &[0, 1, 1]);
        assert_eq!(eval.err_count, 1);
        assert_eq!(eval.n, 3);
        assert!((eval.error_rate() - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn minibatch_averages_gradients() {
        // Two identical samples in a batch must equal a single-sample step.
        let x = [0.5f32, -1.0, 2.0];
        let mut a = LogReg::zeros(3, 2);
        let mut b = LogReg::zeros(3, 2);
        a.sgd_step(&[&x], &[1], 0.2, 1.0);
        b.sgd_step(&[&x, &x], &[1, 1], 0.2, 1.0);
        for (u, v) in a.w.iter().zip(&b.w) {
            assert!((u - v).abs() < 1e-6);
        }
    }
}
