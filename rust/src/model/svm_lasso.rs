//! Native SVM (hinge) and Lasso subgradient steps, mirroring the Pallas
//! `hinge_step` / `lasso_step` kernels exactly.
//!
//! These are first-class production math (the native-backend step path
//! for [`crate::objective::Objective::Hinge`] / `Lasso`), not just
//! cross-checks: golden-vector tests in `tests/it_objectives.rs` pin
//! them to the kernels' outputs.

/// One hinge-loss subgradient step over a microbatch.
///
/// `w ← w − lr·scale·( −(1/B) Σ_{margin<1} y_k x_k + 2λw )`; returns the
/// regularized mean hinge loss. Labels are in {−1, +1}.
pub fn hinge_step_native(
    w: &mut [f32],
    xs: &[&[f32]],
    ys: &[f32],
    lr: f32,
    scale: f32,
    lam: f32,
) -> f32 {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    let b = xs.len() as f32;
    let mut g = vec![0.0f32; w.len()];
    let mut loss = 0.0f32;
    for (x, &y) in xs.iter().zip(ys) {
        assert_eq!(x.len(), w.len());
        let margin = y * crate::linalg::dot(w, x);
        loss += (1.0 - margin).max(0.0);
        if margin < 1.0 {
            crate::linalg::axpy(-y / b, x, &mut g);
        }
    }
    loss /= b;
    loss += lam * crate::linalg::dot(w, w);
    for (wi, gi) in w.iter_mut().zip(&g) {
        *wi -= lr * scale * (gi + 2.0 * lam * *wi);
    }
    loss
}

/// One Lasso subgradient step over a microbatch.
///
/// `w ← w − lr·scale·( (1/B) Xᵀ(Xw − y) + λ·sign(w) )`; returns the
/// regularized mean squared loss `(1/2B)Σ r² + λ‖w‖₁`.
pub fn lasso_step_native(
    w: &mut [f32],
    xs: &[&[f32]],
    ys: &[f32],
    lr: f32,
    scale: f32,
    lam: f32,
) -> f32 {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    let b = xs.len() as f32;
    let mut g = vec![0.0f32; w.len()];
    let mut loss = 0.0f32;
    for (x, &y) in xs.iter().zip(ys) {
        let r = crate::linalg::dot(w, x) - y;
        loss += 0.5 * r * r;
        crate::linalg::axpy(r / b, x, &mut g);
    }
    loss /= b;
    loss += lam * w.iter().map(|v| v.abs()).sum::<f32>();
    for (wi, gi) in w.iter_mut().zip(&g) {
        let sign = if *wi > 0.0 {
            1.0
        } else if *wi < 0.0 {
            -1.0
        } else {
            0.0
        };
        *wi -= lr * scale * (gi + lam * sign);
    }
    loss
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn hinge_learns_linear_separator() {
        let mut rng = Xoshiro256pp::seeded(1);
        let dim = 8;
        let true_w: Vec<f32> = (0..dim).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let mut w = vec![0.0f32; dim];
        let mut errors = 0;
        for step in 0..2000 {
            let x: Vec<f32> = (0..dim).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let y = if crate::linalg::dot(&true_w, &x) > 0.0 { 1.0 } else { -1.0 };
            if step >= 1500 && crate::linalg::dot(&w, &x) * y <= 0.0 {
                errors += 1;
            }
            hinge_step_native(&mut w, &[&x], &[y], 0.05, 1.0, 0.001);
        }
        assert!(errors < 50, "late errors={errors}/500");
    }

    #[test]
    fn hinge_inactive_margin_pure_shrinkage() {
        let mut w = vec![0.5f32; 4];
        let x: Vec<f32> = w.iter().map(|v| v * 100.0).collect();
        let before = w.clone();
        hinge_step_native(&mut w, &[&x], &[1.0], 0.1, 1.0, 0.05);
        for (a, b) in w.iter().zip(&before) {
            let expect = b - 0.1 * (2.0 * 0.05 * b);
            assert!((a - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn lasso_recovers_sparse_signal() {
        let mut rng = Xoshiro256pp::seeded(2);
        let dim = 10;
        let mut true_w = vec![0.0f32; dim];
        true_w[2] = 3.0;
        true_w[7] = -2.0;
        let mut w = vec![0.0f32; dim];
        for _ in 0..4000 {
            let x: Vec<f32> = (0..dim).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let y = crate::linalg::dot(&true_w, &x) + rng.gauss_f32(0.0, 0.05);
            lasso_step_native(&mut w, &[&x], &[y], 0.01, 1.0, 0.01);
        }
        assert!((w[2] - 3.0).abs() < 0.3, "w[2]={}", w[2]);
        assert!((w[7] + 2.0).abs() < 0.3, "w[7]={}", w[7]);
        // Off-support coordinates are shrunk near zero.
        let off: f32 = (0..dim)
            .filter(|&i| i != 2 && i != 7)
            .map(|i| w[i].abs())
            .sum();
        assert!(off / 8.0 < 0.15, "off-support mean |w|={}", off / 8.0);
    }

    #[test]
    fn lasso_loss_value_exact_fit() {
        let w = vec![1.0f32, -2.0];
        let x = [3.0f32, 1.0];
        let y = crate::linalg::dot(&w, &x);
        let loss = lasso_step_native(&mut w.clone(), &[&x], &[y], 0.0, 1.0, 0.5);
        assert!((loss - 0.5 * 3.0).abs() < 1e-6); // λ‖w‖₁ = 0.5·3
        let _ = w;
    }
}
