//! Rust-native model math: an independent second implementation of the
//! paper's loss families (§II) used to (a) cross-check the HLO/Pallas
//! path end-to-end, and (b) power the pure-rust baselines where spinning
//! up PJRT would be overkill.

mod logreg;
mod svm_lasso;

pub use logreg::{LogReg, LogRegEval};
pub use svm_lasso::{hinge_step_native, lasso_step_native};
