//! Rust-native model math: an independent second implementation of the
//! paper's loss families (§II). It (a) cross-checks the HLO/Pallas path
//! end-to-end, and (b) is the native-backend compute path behind
//! [`crate::objective::Objective`] — every loss the system trains
//! (logreg, hinge-SVM, lasso) dispatches here when PJRT is not in play.

mod logreg;
mod svm_lasso;

pub use logreg::{LogReg, LogRegEval};
pub use svm_lasso::{hinge_step_native, lasso_step_native};
