//! Shared-memory substrate: per-node `Mutex` slots with the §IV-C
//! lock-up implemented as sorted try-lock acquisition.
//!
//! This is the substrate the threaded wall-clock runtime has always
//! used, extracted behind [`Transport`]. Locks are acquired in sorted
//! node order and only with `try_lock` — non-blocking, so a busy
//! neighborhood means *back off and redraw* (a counted conflict), never
//! a deadlock. The sorted order additionally makes even a blocking
//! acquisition deadlock-free (no cycle in the wait-for graph can form
//! when every initiator acquires in a global total order); the property
//! suite pins that argument.
//!
//! Each slot carries the node's parameter vector and its published
//! strategy aux blob under one lock, so a projection captures both
//! atomically (empty blob for the baseline — zero extra bytes move).

use std::sync::Mutex;
use std::time::Duration;

use super::{ProjectionOutcome, Transport};

/// One node's shared state: parameters + published aux blob.
#[derive(Debug, Default)]
struct Slot {
    w: Vec<f32>,
    aux: Vec<u8>,
}

/// In-process shared-memory parameter store.
pub struct SharedMem {
    params: Vec<Mutex<Slot>>,
}

impl SharedMem {
    /// `n` nodes, each starting at the zero vector of `param_len` with
    /// an empty aux blob.
    pub fn new(n: usize, param_len: usize) -> Self {
        Self {
            params: (0..n)
                .map(|_| {
                    Mutex::new(Slot {
                        w: vec![0.0f32; param_len],
                        aux: Vec::new(),
                    })
                })
                .collect(),
        }
    }
}

impl Transport for SharedMem {
    fn len(&self) -> usize {
        self.params.len()
    }

    fn update_own(&self, id: usize, f: &mut dyn FnMut(&mut Vec<f32>)) {
        let mut guard = self.params[id].lock().unwrap();
        f(&mut guard.w);
    }

    fn update_own_with_aux(&self, id: usize, f: &mut dyn FnMut(&mut Vec<f32>, &mut Vec<u8>)) {
        let mut guard = self.params[id].lock().unwrap();
        let Slot { w, aux } = &mut *guard;
        f(w, aux);
    }

    fn try_project(
        &self,
        id: usize,
        hood: &[usize],
        hold: Duration,
        mix: &mut dyn FnMut(&[&[f32]], &[&[u8]]) -> (Vec<f32>, Vec<u8>),
    ) -> ProjectionOutcome {
        debug_assert!(hood.contains(&id));
        debug_assert!(hood.windows(2).all(|w| w[0] < w[1]), "hood must be sorted");
        if hood.len() < 2 {
            return ProjectionOutcome::Isolated;
        }
        // §IV-C lock-up: sorted try-lock over the closed neighborhood.
        let mut guards = Vec::with_capacity(hood.len());
        for &j in hood {
            match self.params[j].try_lock() {
                Ok(g) => guards.push(g),
                Err(_) => {
                    // A member is mid-update: release and back off.
                    drop(guards);
                    crate::obs::trace("shared_mem", "busy", id as u64, j as u64);
                    return ProjectionOutcome::Conflict;
                }
            }
        }
        // Collect + mix + broadcast (Eq. 7). A real deployment holds
        // the locks across the network round-trip.
        if hold > Duration::ZERO {
            std::thread::sleep(hold);
        }
        let rows: Vec<&[f32]> = guards.iter().map(|g| g.w.as_slice()).collect();
        let aux_rows: Vec<&[u8]> = guards.iter().map(|g| g.aux.as_slice()).collect();
        let (mean, aux) = mix(&rows, &aux_rows);
        for g in guards.iter_mut() {
            g.w.copy_from_slice(&mean);
            g.aux.clone_from(&aux);
        }
        ProjectionOutcome::Applied {
            participants: hood.len(),
        }
    }

    fn snapshot(&self) -> Vec<Vec<f32>> {
        self.params.iter().map(|m| m.lock().unwrap().w.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node_logic::neighborhood_average;

    /// The baseline mix: average the rows, publish no aux bytes.
    fn avg_mix(rows: &[&[f32]], _aux: &[&[u8]]) -> (Vec<f32>, Vec<u8>) {
        (neighborhood_average(rows), Vec::new())
    }

    #[test]
    fn update_and_project_roundtrip() {
        let t = SharedMem::new(3, 2);
        t.update_own(0, &mut |w| w.copy_from_slice(&[3.0, 0.0]));
        t.update_own(2, &mut |w| w.copy_from_slice(&[0.0, 6.0]));
        let out = t.try_project(1, &[0, 1, 2], Duration::ZERO, &mut avg_mix);
        assert_eq!(out, ProjectionOutcome::Applied { participants: 3 });
        let snap = t.snapshot();
        for w in &snap {
            assert_eq!(w, &vec![1.0, 2.0]);
        }
    }

    #[test]
    fn busy_member_aborts_projection() {
        let t = SharedMem::new(2, 1);
        // Hold node 1's lock from "another update".
        let _held = t.params[1].lock().unwrap();
        let out = t.try_project(0, &[0, 1], Duration::ZERO, &mut avg_mix);
        assert_eq!(out, ProjectionOutcome::Conflict);
    }

    #[test]
    fn singleton_hood_is_isolated() {
        let t = SharedMem::new(2, 1);
        let out = t.try_project(0, &[0], Duration::ZERO, &mut avg_mix);
        assert_eq!(out, ProjectionOutcome::Isolated);
    }

    #[test]
    fn aux_blobs_capture_and_broadcast_with_params() {
        let t = SharedMem::new(2, 1);
        t.update_own_with_aux(0, &mut |w, aux| {
            w[0] = 2.0;
            aux.extend_from_slice(&[7, 7]);
        });
        // The mixer sees both members' blobs in hood order and its
        // output blob lands on every participant.
        let out = t.try_project(0, &[0, 1], Duration::ZERO, &mut |rows, aux_rows| {
            assert_eq!(aux_rows, &[&[7u8, 7][..], &[][..]]);
            (neighborhood_average(rows), vec![9])
        });
        assert_eq!(out, ProjectionOutcome::Applied { participants: 2 });
        for id in 0..2 {
            t.update_own_with_aux(id, &mut |w, aux| {
                assert_eq!(w[0], 1.0);
                assert_eq!(aux, &vec![9]);
            });
        }
    }
}
