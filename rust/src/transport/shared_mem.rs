//! Shared-memory substrate: per-node `Mutex<Vec<f32>>` with the §IV-C
//! lock-up implemented as sorted try-lock acquisition.
//!
//! This is the substrate the threaded wall-clock runtime has always
//! used, extracted behind [`Transport`]. Locks are acquired in sorted
//! node order and only with `try_lock` — non-blocking, so a busy
//! neighborhood means *back off and redraw* (a counted conflict), never
//! a deadlock. The sorted order additionally makes even a blocking
//! acquisition deadlock-free (no cycle in the wait-for graph can form
//! when every initiator acquires in a global total order); the property
//! suite pins that argument.

use std::sync::Mutex;
use std::time::Duration;

use super::{ProjectionOutcome, Transport};

/// In-process shared-memory parameter store.
pub struct SharedMem {
    params: Vec<Mutex<Vec<f32>>>,
}

impl SharedMem {
    /// `n` nodes, each starting at the zero vector of `param_len`.
    pub fn new(n: usize, param_len: usize) -> Self {
        Self {
            params: (0..n).map(|_| Mutex::new(vec![0.0f32; param_len])).collect(),
        }
    }
}

impl Transport for SharedMem {
    fn len(&self) -> usize {
        self.params.len()
    }

    fn update_own(&self, id: usize, f: &mut dyn FnMut(&mut Vec<f32>)) {
        let mut guard = self.params[id].lock().unwrap();
        f(&mut guard);
    }

    fn try_project(
        &self,
        id: usize,
        hood: &[usize],
        hold: Duration,
        avg: &mut dyn FnMut(&[&[f32]]) -> Vec<f32>,
    ) -> ProjectionOutcome {
        debug_assert!(hood.contains(&id));
        debug_assert!(hood.windows(2).all(|w| w[0] < w[1]), "hood must be sorted");
        if hood.len() < 2 {
            return ProjectionOutcome::Isolated;
        }
        // §IV-C lock-up: sorted try-lock over the closed neighborhood.
        let mut guards = Vec::with_capacity(hood.len());
        for &j in hood {
            match self.params[j].try_lock() {
                Ok(g) => guards.push(g),
                Err(_) => {
                    // A member is mid-update: release and back off.
                    drop(guards);
                    crate::obs::trace("shared_mem", "busy", id as u64, j as u64);
                    return ProjectionOutcome::Conflict;
                }
            }
        }
        // Collect + average + broadcast (Eq. 7). A real deployment holds
        // the locks across the network round-trip.
        if hold > Duration::ZERO {
            std::thread::sleep(hold);
        }
        let rows: Vec<&[f32]> = guards.iter().map(|g| g.as_slice()).collect();
        let mean = avg(&rows);
        for g in guards.iter_mut() {
            g.copy_from_slice(&mean);
        }
        ProjectionOutcome::Applied {
            participants: hood.len(),
        }
    }

    fn snapshot(&self) -> Vec<Vec<f32>> {
        self.params.iter().map(|m| m.lock().unwrap().clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node_logic::neighborhood_average;

    #[test]
    fn update_and_project_roundtrip() {
        let t = SharedMem::new(3, 2);
        t.update_own(0, &mut |w| w.copy_from_slice(&[3.0, 0.0]));
        t.update_own(2, &mut |w| w.copy_from_slice(&[0.0, 6.0]));
        let out = t.try_project(1, &[0, 1, 2], Duration::ZERO, &mut |rows| {
            neighborhood_average(rows)
        });
        assert_eq!(out, ProjectionOutcome::Applied { participants: 3 });
        let snap = t.snapshot();
        for w in &snap {
            assert_eq!(w, &vec![1.0, 2.0]);
        }
    }

    #[test]
    fn busy_member_aborts_projection() {
        let t = SharedMem::new(2, 1);
        // Hold node 1's lock from "another update".
        let _held = t.params[1].lock().unwrap();
        let out = t.try_project(0, &[0, 1], Duration::ZERO, &mut |rows| {
            neighborhood_average(rows)
        });
        assert_eq!(out, ProjectionOutcome::Conflict);
    }

    #[test]
    fn singleton_hood_is_isolated() {
        let t = SharedMem::new(2, 1);
        let out = t.try_project(0, &[0], Duration::ZERO, &mut |rows| {
            neighborhood_average(rows)
        });
        assert_eq!(out, ProjectionOutcome::Isolated);
    }
}
