//! Communication substrates for the networked system.
//!
//! Alg. 2 needs exactly two communication primitives: update your own
//! variable (Eq. 6) and atomically average your closed neighborhood
//! (Eq. 7 behind the §IV-C lock-up). [`Transport`] abstracts them so
//! one [`NodeLogic`](crate::node_logic::NodeLogic) definition runs on
//! interchangeable substrates:
//!
//! * [`SharedMem`] — per-node `Mutex<Vec<f32>>` with sorted try-lock
//!   lock-up: the in-process wall-clock substrate the threaded runtime
//!   has always used (behavior preserved bit-for-bit where seeds allow).
//! * [`ChannelNet`] — message-passing collect/broadcast over per-node
//!   mailboxes: the shape of a real deployment (no shared parameter
//!   memory; a projection is a token-stamped collect → average → apply
//!   protocol with busy/abort replies standing in for the lock-up).
//! * [`SimNet`] — the virtual-time substrate for the discrete-event
//!   driver: configurable per-edge latency distributions, message drop
//!   probability, and partition schedules, with incremental parameter
//!   materialization and O(dim) consensus aggregates so 10,000+ node
//!   systems simulate in seconds.
//! * [`SocketNet`](crate::net::SocketNet) — the multi-process
//!   deployment substrate (`rust/src/net/`): each worker process owns a
//!   shard of nodes, intra-shard traffic short-circuits through local
//!   mailboxes, and cross-shard traffic carries the same
//!   collect/broadcast protocol over persistent TCP connections.

mod channel;
mod shared_mem;
mod simnet;

pub use channel::ChannelNet;
pub use shared_mem::SharedMem;
pub use simnet::{LatencyModel, PartitionWindow, SimNet, SimNetConfig};

/// Outcome of one §IV-C lock-up + Eq. (7) projection attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectionOutcome {
    /// The average was applied over `participants` closed-neighborhood
    /// members (initiator included).
    Applied { participants: usize },
    /// The neighborhood was busy (or unreachable mid-protocol): the
    /// initiator backed off. A counted conflict; no data-plane messages.
    Conflict,
    /// Fewer than 2 members were reachable — nothing to average with.
    Isolated,
}

/// A communication substrate the Alg. 2 engines drive.
///
/// Implementations must be safe to call from many node threads at once
/// (the wall-clock runtime) and from a single-threaded event driver
/// (the simulator).
pub trait Transport: Send + Sync {
    /// Number of nodes.
    fn len(&self) -> usize;

    /// True when no nodes exist (trait hygiene; engines never build
    /// empty systems).
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Apply `f` to node `id`'s own parameter vector (an Eq. (6)
    /// gradient step). Never blocks on other nodes' variables.
    fn update_own(&self, id: usize, f: &mut dyn FnMut(&mut Vec<f32>));

    /// Apply `f` to node `id`'s parameter vector *and* its published
    /// auxiliary strategy blob (wire v8: the opaque per-node state
    /// that rides the collect/apply frames beside `w`). The default
    /// feeds `f` a throwaway empty blob — correct for substrates the
    /// baseline strategy runs on; substrates that carry aux-publishing
    /// strategies (all four in-tree) store the blob beside `w`.
    fn update_own_with_aux(&self, id: usize, f: &mut dyn FnMut(&mut Vec<f32>, &mut Vec<u8>)) {
        let mut aux = Vec::new();
        self.update_own(id, &mut |w| f(w, &mut aux));
    }

    /// Attempt an atomic Eq. (7) projection over `hood` (the sorted
    /// closed neighborhood of `id`, liveness-filtered by the caller).
    /// On success the substrate gathers the members' vectors and aux
    /// blobs (same order), passes them to `mix`, holds the gathered
    /// state for `hold` (a modeled network round-trip, wall-clock
    /// substrates only), and writes the mixed `(w, aux)` back to every
    /// member.
    fn try_project(
        &self,
        id: usize,
        hood: &[usize],
        hold: std::time::Duration,
        mix: &mut dyn FnMut(&[&[f32]], &[&[u8]]) -> (Vec<f32>, Vec<u8>),
    ) -> ProjectionOutcome;

    /// True while node `id` is captured by a neighbor's in-flight
    /// projection and must not update its variable (message-passing
    /// substrates; shared memory resolves this with the lock itself).
    fn busy(&self, _id: usize) -> bool {
        false
    }

    /// True when node `id` is currently reachable through this
    /// substrate. In-process substrates always answer true; the
    /// multi-process [`SocketNet`](crate::net::SocketNet) answers false
    /// for nodes owned by a worker whose link is down, so engines can
    /// liveness-filter neighborhoods before initiating a round (a dead
    /// peer degrades to `Conflict`/`Isolated`, never a hang).
    fn reachable(&self, _id: usize) -> bool {
        true
    }

    /// Service node `id`'s inbound protocol traffic (no-op for
    /// substrates without mailboxes). Wall-clock node loops call this
    /// every iteration.
    fn poll(&self, _id: usize) {}

    /// Monitor-side copy of every node's current parameters.
    fn snapshot(&self) -> Vec<Vec<f32>>;
}

/// Which substrate the wall-clock threaded runtime runs on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process shared memory (sorted try-lock mutexes).
    #[default]
    SharedMem,
    /// Message-passing mailboxes (collect/broadcast protocol).
    Channel,
    /// Multi-process TCP deployment: the ChannelNet protocol over real
    /// sockets. Runs via `dasgd launch` / `dasgd worker`
    /// (see `rust/src/net/`); a single-process `cluster` run cannot
    /// construct it.
    Socket,
}

impl TransportKind {
    /// CLI names.
    pub const NAMES: [&'static str; 3] = ["shared", "channel", "socket"];

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "shared" | "shared-mem" | "sharedmem" => Some(TransportKind::SharedMem),
            "channel" | "channels" => Some(TransportKind::Channel),
            "socket" | "sockets" | "tcp" => Some(TransportKind::Socket),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::SharedMem => "shared",
            TransportKind::Channel => "channel",
            TransportKind::Socket => "socket",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parse() {
        assert_eq!(TransportKind::parse("shared"), Some(TransportKind::SharedMem));
        assert_eq!(TransportKind::parse("channel"), Some(TransportKind::Channel));
        assert_eq!(TransportKind::parse("socket"), Some(TransportKind::Socket));
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Socket));
        assert_eq!(TransportKind::parse("udp"), None);
        for n in TransportKind::NAMES {
            assert_eq!(TransportKind::parse(n).unwrap().name(), n);
        }
    }
}
