//! Message-passing substrate: per-node mailboxes, no shared parameter
//! memory — the shape of a real deployment.
//!
//! A projection is a token-stamped protocol round:
//!
//! ```text
//! initiator                 each closed-neighborhood member
//! ---------                 --------------------------------
//! Collect{token}  ───────▶  free?  ──yes──▶ lock to token, Params{w, aux}
//!                                 ──no───▶ Busy{token}
//! (all Params)    ───────▶  Apply{token, mix}   (unlock, adopt mix)
//! (any Busy/timeout) ────▶  Release{token}      (unlock, keep w)
//! ```
//!
//! The Busy reply is the §IV-C lock-up expressed as messages: a member
//! that is itself initiating (or already captured by another round)
//! refuses, and the initiator backs off — a counted conflict. Every
//! wait is deadline-bounded and initiators keep serving their own
//! mailbox while waiting, so no two rounds can block each other:
//! the protocol is abort-based, like the sorted try-lock it mirrors.
//!
//! `Params`/`Apply` carry the member's published strategy aux blob
//! beside `w` (wire v8 semantics) — empty for the baseline, so its
//! rounds move no extra bytes.
//!
//! [`SocketNet`](crate::net::SocketNet) carries this exact member /
//! initiator state machine across processes (`rust/src/net/socket.rs`,
//! with routing swapped from local deques to wire frames) — keep the
//! two in sync when touching protocol semantics.
//!
//! A member is *captured* between `Params` and `Apply`/`Release`; the
//! node loop checks [`Transport::busy`] before acting so a captured
//! variable is not updated mid-round. Captures are *leased*: if the
//! initiator dies before its `Apply`/`Release` arrives (so neither
//! ever will), the member drops the capture after a multiple of the
//! round timeout instead of staying pinned for the rest of the run. (The residual race — a gradient
//! step slipping in just as the capture lands — is resolved by the
//! `Apply` overwrite, the same "late update ignored" semantics a real
//! asynchronous deployment exhibits.)

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::{ProjectionOutcome, Transport};

enum Msg {
    Collect {
        from: usize,
        token: u64,
    },
    Params {
        from: usize,
        token: u64,
        w: Vec<f32>,
        aux: Vec<u8>,
    },
    Busy {
        token: u64,
    },
    Apply {
        from: usize,
        token: u64,
        w: Vec<f32>,
        aux: Vec<u8>,
    },
    Release {
        from: usize,
        token: u64,
    },
}

struct Slot {
    w: Vec<f32>,
    /// The node's published strategy aux blob (travels with `w`).
    aux: Vec<u8>,
    /// `Some((initiator, token))` while captured by an in-flight round.
    locked_by: Option<(usize, u64)>,
    /// When the capture was granted — captures expire after a lease so
    /// a dead initiator can never pin a member forever.
    locked_at: Option<Instant>,
    /// True while this node is itself running a collect round.
    initiating: bool,
}

/// Reply state of an in-flight collect round.
struct Round {
    token: u64,
    replies: Vec<(usize, Vec<f32>, Vec<u8>)>,
    busy: bool,
}

/// Mailbox-based message-passing transport.
pub struct ChannelNet {
    slots: Vec<Mutex<Slot>>,
    inboxes: Vec<Mutex<VecDeque<Msg>>>,
    next_token: AtomicU64,
    /// Deadline for one collect round (covers a peer's longest sleep
    /// between mailbox polls).
    timeout: Duration,
    /// Member-side capture lease: a granted lock self-expires after
    /// this long, so a crashed initiator (whose Release will never
    /// arrive) cannot pin a member for the rest of the run. Must
    /// comfortably exceed `timeout` plus any projection hold time.
    lease: Duration,
}

impl ChannelNet {
    /// `n` nodes at the zero vector; `timeout` bounds one collect round.
    /// The capture lease assumes no projection hold — use
    /// [`ChannelNet::with_round_budget`] when rounds sleep across a
    /// modeled RTT.
    pub fn new(n: usize, param_len: usize, timeout: Duration) -> Self {
        Self::with_round_budget(n, param_len, timeout, Duration::ZERO)
    }

    /// Like [`ChannelNet::new`], but sizes the capture lease to cover
    /// rounds that hold their captures across `hold` (the modeled
    /// collect/broadcast RTT): a member must not expire a capture while
    /// a healthy initiator is still mid-round.
    pub fn with_round_budget(
        n: usize,
        param_len: usize,
        timeout: Duration,
        hold: Duration,
    ) -> Self {
        Self {
            slots: (0..n)
                .map(|_| {
                    Mutex::new(Slot {
                        w: vec![0.0f32; param_len],
                        aux: Vec::new(),
                        locked_by: None,
                        locked_at: None,
                        initiating: false,
                    })
                })
                .collect(),
            inboxes: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            next_token: AtomicU64::new(1),
            timeout,
            lease: timeout
                .saturating_mul(4)
                .max(Duration::from_millis(20))
                .saturating_add(hold.saturating_mul(2)),
        }
    }

    /// Drop a capture whose lease ran out (dead initiator). A late
    /// `Apply` for the expired token is ignored by the token check —
    /// the member simply keeps its value, the usual abort semantics.
    fn expire_stale_capture(&self, id: usize) {
        let mut slot = self.slots[id].lock().unwrap();
        if slot.locked_by.is_some()
            && slot
                .locked_at
                .map(|t| t.elapsed() > self.lease)
                .unwrap_or(false)
        {
            slot.locked_by = None;
            slot.locked_at = None;
        }
    }

    /// Default round deadline: comfortably above the node loop's 50 ms
    /// maximum inter-poll sleep.
    pub fn with_default_timeout(n: usize, param_len: usize) -> Self {
        Self::new(n, param_len, Duration::from_millis(100))
    }

    fn send(&self, to: usize, msg: Msg) {
        self.inboxes[to].lock().unwrap().push_back(msg);
    }

    fn recv(&self, id: usize) -> Option<Msg> {
        self.inboxes[id].lock().unwrap().pop_front()
    }

    /// Process one inbound message for `id`. `round` is the in-flight
    /// collect state when `id` is currently initiating.
    fn handle(&self, id: usize, msg: Msg, round: &mut Option<&mut Round>) {
        match msg {
            Msg::Collect { from, token } => {
                let reply = {
                    let mut slot = self.slots[id].lock().unwrap();
                    if slot.initiating || slot.locked_by.is_some() {
                        None
                    } else {
                        slot.locked_by = Some((from, token));
                        slot.locked_at = Some(Instant::now());
                        Some((slot.w.clone(), slot.aux.clone()))
                    }
                };
                match reply {
                    Some((w, aux)) => self.send(
                        from,
                        Msg::Params {
                            from: id,
                            token,
                            w,
                            aux,
                        },
                    ),
                    None => self.send(from, Msg::Busy { token }),
                }
            }
            Msg::Params { from, token, w, aux } => match round {
                Some(r) if r.token == token => r.replies.push((from, w, aux)),
                // Stale reply (we already gave up on that round): the
                // sender is still captured by our dead token — free it.
                _ => self.send(from, Msg::Release { from: id, token }),
            },
            Msg::Busy { token } => {
                if let Some(r) = round {
                    if r.token == token {
                        r.busy = true;
                    }
                }
            }
            Msg::Apply { from, token, w, aux } => {
                let mut slot = self.slots[id].lock().unwrap();
                if slot.locked_by == Some((from, token)) {
                    slot.w = w;
                    slot.aux = aux;
                    slot.locked_by = None;
                    slot.locked_at = None;
                }
            }
            Msg::Release { from, token } => {
                let mut slot = self.slots[id].lock().unwrap();
                if slot.locked_by == Some((from, token)) {
                    slot.locked_by = None;
                    slot.locked_at = None;
                }
            }
        }
    }

    fn drain(&self, id: usize, mut round: Option<&mut Round>) {
        while let Some(msg) = self.recv(id) {
            self.handle(id, msg, &mut round);
        }
    }
}

impl Transport for ChannelNet {
    fn len(&self) -> usize {
        self.slots.len()
    }

    fn update_own(&self, id: usize, f: &mut dyn FnMut(&mut Vec<f32>)) {
        let mut slot = self.slots[id].lock().unwrap();
        f(&mut slot.w);
    }

    fn update_own_with_aux(&self, id: usize, f: &mut dyn FnMut(&mut Vec<f32>, &mut Vec<u8>)) {
        let mut slot = self.slots[id].lock().unwrap();
        let Slot { w, aux, .. } = &mut *slot;
        f(w, aux);
    }

    fn busy(&self, id: usize) -> bool {
        self.expire_stale_capture(id);
        self.slots[id].lock().unwrap().locked_by.is_some()
    }

    fn poll(&self, id: usize) {
        self.expire_stale_capture(id);
        self.drain(id, None);
    }

    fn try_project(
        &self,
        id: usize,
        hood: &[usize],
        hold: Duration,
        mix: &mut dyn FnMut(&[&[f32]], &[&[u8]]) -> (Vec<f32>, Vec<u8>),
    ) -> ProjectionOutcome {
        debug_assert!(hood.contains(&id));
        if hood.len() < 2 {
            return ProjectionOutcome::Isolated;
        }
        let token = self.next_token.fetch_add(1, Ordering::Relaxed);
        // Mark ourselves initiating (refusing inbound Collects) and take
        // our own row. If we are already captured, this round loses.
        let (own, own_aux) = {
            let mut slot = self.slots[id].lock().unwrap();
            if slot.locked_by.is_some() {
                return ProjectionOutcome::Conflict;
            }
            slot.initiating = true;
            (slot.w.clone(), slot.aux.clone())
        };
        let peers: Vec<usize> = hood.iter().copied().filter(|&j| j != id).collect();
        let round_start = Instant::now();
        for &j in &peers {
            self.send(j, Msg::Collect { from: id, token });
        }
        let mut round = Round {
            token,
            replies: Vec::with_capacity(peers.len()),
            busy: false,
        };
        let deadline = Instant::now() + self.timeout;
        while round.replies.len() < peers.len() && !round.busy {
            self.drain(id, Some(&mut round));
            if round.replies.len() >= peers.len() || round.busy {
                break;
            }
            if Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
        let complete = round.replies.len() == peers.len() && !round.busy;
        if complete {
            crate::obs::observe(
                crate::obs::Hist::MessageDelayUs,
                round_start.elapsed().as_micros() as u64,
            );
        } else {
            // Abort: free everyone who granted us their variable.
            for (from, _, _) in &round.replies {
                self.send(*from, Msg::Release { from: id, token });
            }
            self.slots[id].lock().unwrap().initiating = false;
            return ProjectionOutcome::Conflict;
        }
        // Hold across the modeled RTT, like a real round in flight.
        if hold > Duration::ZERO {
            std::thread::sleep(hold);
        }
        // Mix in hood order (self row in place of `id`), params and aux
        // blobs aligned.
        let reply_for = |j: usize| {
            round
                .replies
                .iter()
                .find(|(from, _, _)| *from == j)
                .expect("complete round has every peer's reply")
        };
        let rows: Vec<&[f32]> = hood
            .iter()
            .map(|&j| {
                if j == id {
                    own.as_slice()
                } else {
                    reply_for(j).1.as_slice()
                }
            })
            .collect();
        let aux_rows: Vec<&[u8]> = hood
            .iter()
            .map(|&j| {
                if j == id {
                    own_aux.as_slice()
                } else {
                    reply_for(j).2.as_slice()
                }
            })
            .collect();
        let (mean, mean_aux) = mix(&rows, &aux_rows);
        for &j in &peers {
            self.send(
                j,
                Msg::Apply {
                    from: id,
                    token,
                    w: mean.clone(),
                    aux: mean_aux.clone(),
                },
            );
        }
        let mut slot = self.slots[id].lock().unwrap();
        slot.w = mean;
        slot.aux = mean_aux;
        slot.initiating = false;
        ProjectionOutcome::Applied {
            participants: hood.len(),
        }
    }

    fn snapshot(&self) -> Vec<Vec<f32>> {
        self.slots
            .iter()
            .map(|s| s.lock().unwrap().w.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node_logic::neighborhood_average;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    /// The baseline mix: average the rows, publish no aux bytes.
    fn avg_mix(rows: &[&[f32]], _aux: &[&[u8]]) -> (Vec<f32>, Vec<u8>) {
        (neighborhood_average(rows), Vec::new())
    }

    /// Spawn poll pumps for `ids` so a single test thread can drive
    /// projections (peers must answer Collect requests).
    fn with_pumps<R>(
        net: &Arc<ChannelNet>,
        ids: &[usize],
        f: impl FnOnce() -> R,
    ) -> R {
        let stop = Arc::new(AtomicBool::new(false));
        let pumps: Vec<_> = ids
            .iter()
            .map(|&j| {
                let net = Arc::clone(net);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        net.poll(j);
                        std::thread::sleep(Duration::from_micros(50));
                    }
                })
            })
            .collect();
        let out = f();
        stop.store(true, Ordering::Relaxed);
        for p in pumps {
            p.join().unwrap();
        }
        out
    }

    #[test]
    fn collect_average_apply_roundtrip() {
        let net = Arc::new(ChannelNet::with_default_timeout(3, 2));
        net.update_own(0, &mut |w| w.copy_from_slice(&[3.0, 0.0]));
        net.update_own(2, &mut |w| w.copy_from_slice(&[0.0, 6.0]));
        let out = with_pumps(&net, &[0, 2], || {
            net.try_project(1, &[0, 1, 2], Duration::ZERO, &mut avg_mix)
        });
        assert_eq!(out, ProjectionOutcome::Applied { participants: 3 });
        // Peers adopt the average once they poll their Apply.
        net.poll(0);
        net.poll(2);
        for w in net.snapshot() {
            assert_eq!(w, vec![1.0, 2.0]);
        }
        assert!(!net.busy(0) && !net.busy(2));
    }

    #[test]
    fn aux_blobs_ride_the_collect_apply_round() {
        let net = Arc::new(ChannelNet::with_default_timeout(2, 1));
        net.update_own_with_aux(1, &mut |_w, aux| aux.extend_from_slice(&[5, 6]));
        let out = with_pumps(&net, &[1], || {
            net.try_project(0, &[0, 1], Duration::ZERO, &mut |rows, aux_rows| {
                // Hood order: node 0 (initiator, empty blob), node 1.
                assert_eq!(aux_rows, &[&[][..], &[5u8, 6][..]]);
                (neighborhood_average(rows), vec![8])
            })
        });
        assert_eq!(out, ProjectionOutcome::Applied { participants: 2 });
        net.poll(1);
        for id in 0..2 {
            net.update_own_with_aux(id, &mut |_w, aux| assert_eq!(aux, &vec![8]));
        }
    }

    #[test]
    fn unresponsive_peer_times_out_as_conflict() {
        // Node 1 never polls: the round must abort, not hang.
        let net = ChannelNet::new(2, 1, Duration::from_millis(5));
        let out = net.try_project(0, &[0, 1], Duration::ZERO, &mut avg_mix);
        assert_eq!(out, ProjectionOutcome::Conflict);
        // The initiator is free again afterwards.
        assert!(!net.busy(0));
    }

    #[test]
    fn captured_member_refuses_second_round() {
        let net = Arc::new(ChannelNet::new(3, 1, Duration::from_millis(5)));
        // Capture node 1 by hand: deliver a Collect and let it grant.
        net.send(1, Msg::Collect { from: 2, token: 99 });
        net.poll(1);
        assert!(net.busy(1));
        // A projection over {0, 1} must now abort with Busy.
        let out = with_pumps(&net, &[1], || {
            net.try_project(0, &[0, 1], Duration::ZERO, &mut avg_mix)
        });
        assert_eq!(out, ProjectionOutcome::Conflict);
        // Releasing token 99 frees the member.
        net.send(1, Msg::Release { from: 2, token: 99 });
        net.poll(1);
        assert!(!net.busy(1));
    }

    #[test]
    fn capture_lease_expires_when_initiator_dies() {
        // A Collect is granted, then the initiator vanishes: neither
        // Apply nor Release will ever arrive. The lease must free the
        // member on its own next poll.
        let net = ChannelNet::new(2, 1, Duration::from_millis(1));
        net.send(1, Msg::Collect { from: 0, token: 42 });
        net.poll(1);
        assert!(net.busy(1));
        std::thread::sleep(net.lease + Duration::from_millis(5));
        assert!(!net.busy(1), "lease should expire a dead capture");
        // A late Apply for the expired token is ignored.
        net.send(
            1,
            Msg::Apply {
                from: 0,
                token: 42,
                w: vec![9.0],
                aux: Vec::new(),
            },
        );
        net.poll(1);
        assert_eq!(net.snapshot()[1], vec![0.0]);
    }

    #[test]
    fn stale_params_reply_gets_released() {
        let net = ChannelNet::new(2, 1, Duration::from_millis(1));
        // Round times out (peer silent)...
        let out = net.try_project(0, &[0, 1], Duration::ZERO, &mut avg_mix);
        assert_eq!(out, ProjectionOutcome::Conflict);
        // ...then the peer wakes up, grants the stale Collect, and is
        // captured by a dead token.
        net.poll(1);
        assert!(net.busy(1));
        // The initiator's next poll sees the stale Params and frees it.
        net.poll(0);
        net.poll(1);
        assert!(!net.busy(1));
    }
}
