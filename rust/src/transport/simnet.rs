//! Virtual-time network substrate for the discrete-event driver:
//! per-edge latency distributions, message drops, partition schedules —
//! the network-realism scenarios delay-aware asynchronous optimization
//! studies, at 10,000+ node scale.
//!
//! Two things make the scale cheap:
//!
//! * **Incremental parameters** — a node's vector is materialized only
//!   on first touch (untouched nodes are implicit zeros), so a sparse
//!   early trajectory costs memory proportional to activity, not N.
//! * **Incremental snapshots** — a [`ConsensusTracker`] maintains
//!   Σβ_i and Σ‖β_i‖² under every update, so the driver reads the mean
//!   and the L2 consensus residual in O(dim) instead of scanning all N
//!   vectors per evaluation.
//!
//! The substrate implements [`Transport`] so the same `NodeLogic` the
//! wall-clock engines drive runs here unchanged; time does not advance
//! inside the transport — the driver sets it ([`SimNet::set_now`]) and
//! charges the communication delay the last projection accrued
//! ([`SimNet::take_last_comm`]).

use std::sync::Mutex;
use std::time::Duration;

use crate::node_logic::ConsensusTracker;
use crate::util::rng::Xoshiro256pp;

use super::{ProjectionOutcome, Transport};

/// Per-edge one-way latency model: a deterministic per-edge base drawn
/// from `[min, max]` (hashed from the edge, so edge (u,v) always has
/// the same base), plus optional exponential per-message jitter.
#[derive(Clone, Copy, Debug)]
pub struct LatencyModel {
    pub min_secs: f64,
    pub max_secs: f64,
    /// Mean of the per-message exponential jitter (0 = deterministic).
    pub jitter_secs: f64,
}

impl LatencyModel {
    /// Zero-latency network (the in-process memory-speed limit).
    pub fn zero() -> Self {
        Self::constant(0.0)
    }

    /// Every edge at exactly `secs` one-way.
    pub fn constant(secs: f64) -> Self {
        Self {
            min_secs: secs,
            max_secs: secs,
            jitter_secs: 0.0,
        }
    }

    /// This edge's deterministic base latency.
    pub fn edge_base(&self, u: usize, v: usize) -> f64 {
        if self.max_secs <= self.min_secs {
            return self.min_secs;
        }
        let (a, b) = if u < v { (u, v) } else { (v, u) };
        // SplitMix-style hash of the edge → uniform in [min, max].
        let mut h = (a as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (b as u64);
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        h ^= h >> 31;
        let u01 = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        self.min_secs + u01 * (self.max_secs - self.min_secs)
    }

    /// One message's latency on edge (u, v).
    pub fn draw(&self, u: usize, v: usize, rng: &mut Xoshiro256pp) -> f64 {
        let base = self.edge_base(u, v);
        if self.jitter_secs > 0.0 {
            base + rng.exponential(1.0 / self.jitter_secs)
        } else {
            base
        }
    }
}

/// A timed network partition: during `[start, end)` every edge crossing
/// the cut `{nodes < boundary} | {nodes ≥ boundary}` is down.
#[derive(Clone, Copy, Debug)]
pub struct PartitionWindow {
    pub start_secs: f64,
    pub end_secs: f64,
    pub boundary: usize,
}

impl PartitionWindow {
    /// True iff edge (u, v) is severed at virtual time `t`.
    pub fn cuts(&self, u: usize, v: usize, t: f64) -> bool {
        t >= self.start_secs && t < self.end_secs && (u < self.boundary) != (v < self.boundary)
    }
}

/// Network realism knobs of a [`SimNet`].
#[derive(Clone, Debug)]
pub struct SimNetConfig {
    pub latency: LatencyModel,
    /// Probability that one projection leg to a neighbor is lost (the
    /// neighbor silently drops out of that round).
    pub drop_prob: f64,
    pub partitions: Vec<PartitionWindow>,
    /// Seed of the network's own RNG stream (drops + jitter), separate
    /// from the node streams so enabling network noise does not perturb
    /// the nodes' algorithmic draws.
    pub seed: u64,
}

impl SimNetConfig {
    /// An ideal network: fixed one-way latency, no drops, no partitions.
    pub fn ideal(latency_secs: f64) -> Self {
        Self {
            latency: LatencyModel::constant(latency_secs),
            drop_prob: 0.0,
            partitions: Vec::new(),
            seed: 0,
        }
    }
}

struct Inner {
    n: usize,
    param_len: usize,
    /// Lazily materialized parameters: empty vec = still at zero init.
    params: Vec<Vec<f32>>,
    /// Per-node published strategy aux blobs (empty = absent — the
    /// baseline publishes nothing, so this stays all-empty for it).
    aux: Vec<Vec<u8>>,
    /// Shared read-only zeros row standing in for unmaterialized
    /// parameters (allocated once, not per projection).
    zeros: Vec<f32>,
    tracker: ConsensusTracker,
    cfg: SimNetConfig,
    net_rng: Xoshiro256pp,
    now: f64,
    /// Virtual comm time accrued by the last projection (collect +
    /// broadcast, gated on the slowest participating leg).
    last_comm: f64,
    messages: u64,
    drops: u64,
}

/// The virtual-time substrate (see module docs).
pub struct SimNet {
    inner: Mutex<Inner>,
}

impl SimNet {
    pub fn new(n: usize, param_len: usize, cfg: SimNetConfig) -> Self {
        let net_rng = Xoshiro256pp::seeded(cfg.seed ^ 0x5E7_CAFE);
        Self {
            inner: Mutex::new(Inner {
                n,
                param_len,
                params: vec![Vec::new(); n],
                aux: vec![Vec::new(); n],
                zeros: vec![0.0f32; param_len],
                tracker: ConsensusTracker::new(n, param_len),
                cfg,
                net_rng,
                now: 0.0,
                last_comm: 0.0,
                messages: 0,
                drops: 0,
            }),
        }
    }

    /// Advance the substrate's clock (the driver owns time).
    pub fn set_now(&self, t: f64) {
        self.inner.lock().unwrap().now = t;
    }

    /// Virtual communication delay of the most recent projection
    /// (consumed once; resets to 0).
    pub fn take_last_comm(&self) -> f64 {
        let mut inner = self.inner.lock().unwrap();
        std::mem::take(&mut inner.last_comm)
    }

    /// O(dim) incremental snapshot: (β̄, L2 consensus residual).
    pub fn mean_and_residual(&self) -> (Vec<f32>, f64) {
        let inner = self.inner.lock().unwrap();
        (inner.tracker.mean(), inner.tracker.residual())
    }

    /// (data-plane messages, dropped legs) so far.
    pub fn net_stats(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.messages, inner.drops)
    }
}

impl Transport for SimNet {
    fn len(&self) -> usize {
        self.inner.lock().unwrap().n
    }

    fn update_own(&self, id: usize, f: &mut dyn FnMut(&mut Vec<f32>)) {
        let mut inner = self.inner.lock().unwrap();
        let param_len = inner.param_len;
        let mut w = std::mem::take(&mut inner.params[id]);
        if w.is_empty() {
            w = vec![0.0f32; param_len];
        } else {
            inner.tracker.sub(&w);
        }
        f(&mut w);
        inner.tracker.add(&w);
        inner.params[id] = w;
    }

    fn update_own_with_aux(&self, id: usize, f: &mut dyn FnMut(&mut Vec<f32>, &mut Vec<u8>)) {
        let mut inner = self.inner.lock().unwrap();
        let param_len = inner.param_len;
        let mut w = std::mem::take(&mut inner.params[id]);
        let mut aux = std::mem::take(&mut inner.aux[id]);
        if w.is_empty() {
            w = vec![0.0f32; param_len];
        } else {
            inner.tracker.sub(&w);
        }
        f(&mut w, &mut aux);
        inner.tracker.add(&w);
        inner.params[id] = w;
        inner.aux[id] = aux;
    }

    fn try_project(
        &self,
        id: usize,
        hood: &[usize],
        _hold: Duration,
        mix: &mut dyn FnMut(&[&[f32]], &[&[u8]]) -> (Vec<f32>, Vec<u8>),
    ) -> ProjectionOutcome {
        let mut inner = self.inner.lock().unwrap();
        let now = inner.now;
        let drop_prob = inner.cfg.drop_prob;
        // Which neighbors this round actually reaches: partitioned edges
        // are down; each leg independently drops with `drop_prob`.
        let mut participants: Vec<usize> = Vec::with_capacity(hood.len());
        let mut worst_leg = 0.0f64;
        let mut dropped = 0u64;
        for &j in hood {
            if j == id {
                participants.push(j);
                continue;
            }
            if inner.cfg.partitions.iter().any(|p| p.cuts(id, j, now)) {
                continue;
            }
            if drop_prob > 0.0 && inner.net_rng.next_f64() < drop_prob {
                dropped += 1;
                continue;
            }
            let latency = {
                let lat = inner.cfg.latency;
                lat.draw(id, j, &mut inner.net_rng)
            };
            worst_leg = worst_leg.max(latency);
            participants.push(j);
        }
        inner.drops += dropped;
        if participants.len() < 2 {
            inner.last_comm = 0.0;
            return ProjectionOutcome::Isolated;
        }
        // Gather (implicit zeros for untouched nodes, empty aux blobs
        // for nodes that published none), mix, apply.
        let rows: Vec<&[f32]> = participants
            .iter()
            .map(|&j| {
                let w = &inner.params[j];
                if w.is_empty() {
                    inner.zeros.as_slice()
                } else {
                    w.as_slice()
                }
            })
            .collect();
        let aux_rows: Vec<&[u8]> = participants.iter().map(|&j| inner.aux[j].as_slice()).collect();
        let (mean, mean_aux) = mix(&rows, &aux_rows);
        drop(rows);
        drop(aux_rows);
        for &j in &participants {
            if !inner.params[j].is_empty() {
                let old = std::mem::take(&mut inner.params[j]);
                inner.tracker.sub(&old);
            }
            inner.tracker.add(&mean);
            inner.params[j] = mean.clone();
            inner.aux[j].clone_from(&mean_aux);
        }
        // Collect + broadcast, each gated on the slowest participating
        // leg (the initiator waits for every reply before averaging).
        inner.last_comm = 2.0 * worst_leg;
        // Virtual round-trip as the delay sample — the sim has no wall
        // clock, so charge what the driver will charge.
        crate::obs::observe(
            crate::obs::Hist::MessageDelayUs,
            (inner.last_comm * 1e6) as u64,
        );
        inner.messages += crate::node_logic::projection_messages(participants.len());
        ProjectionOutcome::Applied {
            participants: participants.len(),
        }
    }

    fn snapshot(&self) -> Vec<Vec<f32>> {
        let inner = self.inner.lock().unwrap();
        inner
            .params
            .iter()
            .map(|w| {
                if w.is_empty() {
                    vec![0.0f32; inner.param_len]
                } else {
                    w.clone()
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node_logic::neighborhood_average;

    fn project(net: &SimNet, id: usize, hood: &[usize]) -> ProjectionOutcome {
        net.try_project(id, hood, Duration::ZERO, &mut |rows, _aux| {
            (neighborhood_average(rows), Vec::new())
        })
    }

    #[test]
    fn lazy_params_and_projection_average() {
        let net = SimNet::new(4, 2, SimNetConfig::ideal(0.01));
        net.update_own(0, &mut |w| w.copy_from_slice(&[4.0, 0.0]));
        // Nodes 1, 2 untouched = implicit zeros.
        let out = project(&net, 1, &[0, 1, 2]);
        assert_eq!(out, ProjectionOutcome::Applied { participants: 3 });
        let snap = net.snapshot();
        for &j in &[0usize, 1, 2] {
            assert_eq!(snap[j], vec![4.0 / 3.0, 0.0]);
        }
        assert_eq!(snap[3], vec![0.0, 0.0]); // still implicit zero
        // Comm charge: collect + broadcast over 10 ms legs.
        assert!((net.take_last_comm() - 0.02).abs() < 1e-12);
        assert_eq!(net.net_stats().0, crate::node_logic::projection_messages(3));
    }

    #[test]
    fn tracker_matches_full_scan_after_updates() {
        let net = SimNet::new(5, 3, SimNetConfig::ideal(0.0));
        let mut rng = Xoshiro256pp::seeded(3);
        for step in 0..200 {
            let id = rng.index(5);
            if step % 3 == 0 {
                let _ = project(&net, id, &[0, 1, 2, 3, 4]);
            } else {
                let v = rng.gauss_f32(0.0, 1.0);
                net.update_own(id, &mut |w| w[0] += v);
            }
        }
        let (mean, residual) = net.mean_and_residual();
        let snap = net.snapshot();
        let full_mean = crate::coordinator::consensus::mean_param(&snap);
        for (a, b) in mean.iter().zip(&full_mean) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        // Residual matches the L2 form computed from the full scan.
        let expect: f64 = snap
            .iter()
            .map(|p| {
                p.iter()
                    .zip(&full_mean)
                    .map(|(&v, &m)| (v as f64 - m as f64).powi(2))
                    .sum::<f64>()
            })
            .sum::<f64>()
            .sqrt();
        assert!((residual - expect).abs() < 1e-6, "{residual} vs {expect}");
    }

    #[test]
    fn partition_blocks_cross_cut_edges() {
        let cfg = SimNetConfig {
            partitions: vec![PartitionWindow {
                start_secs: 10.0,
                end_secs: 20.0,
                boundary: 2,
            }],
            ..SimNetConfig::ideal(0.0)
        };
        let net = SimNet::new(4, 1, cfg);
        net.update_own(3, &mut |w| w[0] = 9.0);
        net.set_now(15.0); // inside the window: 1 cannot reach 2, 3
        let out = project(&net, 1, &[1, 2, 3]);
        assert_eq!(out, ProjectionOutcome::Isolated);
        net.set_now(25.0); // window over
        let out = project(&net, 1, &[1, 2, 3]);
        assert_eq!(out, ProjectionOutcome::Applied { participants: 3 });
        assert_eq!(net.snapshot()[1], vec![3.0]);
    }

    #[test]
    fn drops_shrink_participation() {
        let cfg = SimNetConfig {
            drop_prob: 1.0,
            ..SimNetConfig::ideal(0.0)
        };
        let net = SimNet::new(3, 1, cfg);
        // Every leg drops: the initiator is alone.
        assert_eq!(project(&net, 0, &[0, 1, 2]), ProjectionOutcome::Isolated);
        assert_eq!(net.net_stats().1, 2);
    }

    #[test]
    fn aux_blobs_gather_and_broadcast_with_params() {
        let net = SimNet::new(3, 1, SimNetConfig::ideal(0.0));
        net.update_own_with_aux(2, &mut |w, aux| {
            w[0] = 3.0;
            aux.push(4);
        });
        let out = net.try_project(0, &[0, 1, 2], Duration::ZERO, &mut |rows, aux_rows| {
            // Participant order: 0 and 1 unpublished (empty), 2's blob.
            assert_eq!(aux_rows, &[&[][..], &[][..], &[4u8][..]]);
            (neighborhood_average(rows), vec![6])
        });
        assert_eq!(out, ProjectionOutcome::Applied { participants: 3 });
        for id in 0..3 {
            net.update_own_with_aux(id, &mut |w, aux| {
                assert_eq!(w[0], 1.0);
                assert_eq!(aux, &vec![6]);
            });
        }
    }

    #[test]
    fn edge_latency_is_deterministic_and_bounded() {
        let lat = LatencyModel {
            min_secs: 0.001,
            max_secs: 0.010,
            jitter_secs: 0.0,
        };
        for (u, v) in [(0usize, 1usize), (5, 9), (100, 7)] {
            let a = lat.edge_base(u, v);
            assert_eq!(a, lat.edge_base(v, u), "symmetric");
            assert!((0.001..=0.010).contains(&a), "{a}");
        }
        // Distinct edges get distinct bases (hash spreads).
        assert_ne!(lat.edge_base(0, 1), lat.edge_base(0, 2));
    }
}
