//! Data substrate: datasets, the §V synthetic generator, the
//! notMNIST-like glyph corpus (offline substitute — see DESIGN.md §3),
//! the libsvm sparse-format loader for real corpora, and the streaming
//! row-block data plane (see docs/data.md).

mod dataset;
mod libsvm;
mod notmnist;
pub mod stream;
mod synthetic;

pub use dataset::{Dataset, Sample};
pub use libsvm::{load_libsvm, parse_libsvm, LibsvmOptions};
pub use notmnist::{ascii_art, render_glyph, GlyphStyle, NotMnistGen, GLYPH_CLASSES, GLYPH_DIM, GLYPH_SIDE};
pub use synthetic::SyntheticGen;
