//! Data substrate: datasets, the §V synthetic generator, and the
//! notMNIST-like glyph corpus (offline substitute — see DESIGN.md §3).

mod dataset;
mod notmnist;
mod synthetic;

pub use dataset::{Dataset, Sample};
pub use notmnist::{ascii_art, render_glyph, GlyphStyle, NotMnistGen, GLYPH_CLASSES, GLYPH_DIM, GLYPH_SIDE};
pub use synthetic::SyntheticGen;
