//! Streaming shard data plane: fixed-budget row blocks + a
//! memory-bounded staging buffer with backpressure credits.
//!
//! `dasgd launch` used to ship each node's shard as one logical
//! `PlanAssign` message, so a worker's whole assignment had to fit its
//! RAM (and the 1 GiB chunk-staging cap) before a single step could
//! run. This module is the alternative data plane:
//!
//! * [`RowBlock`] — a self-describing slice of one node's shard
//!   (`rows × dim` dense f32 rows + labels, an `encoding` byte, and a
//!   per-block FNV-1a checksum). [`RowBlock::carve`] splits a
//!   [`Dataset`] into blocks of at most `block_rows` rows; blocks ship
//!   as `ShardBlock` wire frames in `seq` order and a final
//!   `ShardComplete` carries the whole-shard checksum
//!   ([`fold_payloads`] over every block in order).
//! * [`BlockBuffer`] — the worker-side staging area, shared between the
//!   control-plane serve loop (producer) and the node threads
//!   (consumers). Total staged payload is bounded by a byte budget
//!   (`--staging-mb`); [`BlockBuffer::take_freed`] reports consumed
//!   bytes so the worker can return `ShardCredit` flow-control frames,
//!   and the launcher stops sending when its credit window closes.
//! * [`ShardReceiver`] — one node's view of the buffer: the streaming
//!   sampler handle [`NodeLogic`](crate::node_logic::NodeLogic) drains
//!   rows from, stepping as soon as the first block lands instead of
//!   waiting for the whole shard.
//!
//! See docs/data.md for the block format and the backpressure protocol.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::data::Dataset;
use crate::net::wire::{fnv1a64, Fnv64};

/// The only block encoding so far: dense row-major `f32` features with
/// one `u32` label per row. The byte exists so a sparse CSR encoding
/// can join without a wire version bump.
pub const ENCODING_DENSE_F32: u8 = 0;

/// Default rows per [`RowBlock`] (`--stream-block-rows`). At the
/// 50-feature synthetic world this is ~800 KiB of payload per block —
/// small enough that even a few-MiB staging budget holds several
/// blocks in flight.
pub const DEFAULT_BLOCK_ROWS: usize = 4096;

/// One self-describing slice of a node's shard.
#[derive(Clone, Debug, PartialEq)]
pub struct RowBlock {
    pub node: usize,
    /// 0-based position in the node's stream (in-order per node).
    pub seq: u32,
    pub encoding: u8,
    pub dim: usize,
    pub classes: usize,
    /// One label per row, each `< classes`.
    pub labels: Vec<u32>,
    /// Row-major `labels.len() × dim`.
    pub features: Vec<f32>,
    /// [`payload_checksum`] over this block's labels + features.
    pub checksum: u64,
}

impl RowBlock {
    /// Split `data` (node `node`'s shard) into blocks of at most
    /// `block_rows` rows, checksummed and numbered in order. An empty
    /// shard carves to no blocks.
    pub fn carve(node: usize, data: &Dataset, block_rows: usize) -> Vec<RowBlock> {
        assert!(block_rows > 0, "block_rows must be ≥ 1");
        let mut blocks = Vec::with_capacity(data.len().div_ceil(block_rows));
        for (seq, start) in (0..data.len()).step_by(block_rows).enumerate() {
            let end = (start + block_rows).min(data.len());
            let labels: Vec<u32> = data.labels()[start..end]
                .iter()
                .map(|&l| l as u32)
                .collect();
            let features = data.features_flat()[start * data.dim()..end * data.dim()].to_vec();
            let checksum = payload_checksum(&labels, &features);
            blocks.push(RowBlock {
                node,
                seq: seq as u32,
                encoding: ENCODING_DENSE_F32,
                dim: data.dim(),
                classes: data.classes(),
                labels,
                features,
                checksum,
            });
        }
        blocks
    }

    pub fn rows(&self) -> usize {
        self.labels.len()
    }

    /// Staged bytes this block accounts for (label + feature payload;
    /// the fixed header is noise next to it).
    pub fn payload_bytes(&self) -> u64 {
        (self.labels.len() * 4 + self.features.len() * 4) as u64
    }

    /// The block's payload as the canonical checksum byte stream
    /// (labels' LE bytes, then features' LE bit patterns).
    pub fn payload_le_bytes(&self) -> Vec<u8> {
        let mut bytes = Vec::with_capacity(self.payload_bytes() as usize);
        for &l in &self.labels {
            bytes.extend_from_slice(&l.to_le_bytes());
        }
        for &f in &self.features {
            bytes.extend_from_slice(&f.to_le_bytes());
        }
        bytes
    }

    /// Recompute and compare the per-block checksum, plus the shape
    /// invariants a hostile frame could violate. Returns a description
    /// of the first violation.
    pub fn validate(&self, dim: usize, classes: usize) -> Result<(), String> {
        if self.encoding != ENCODING_DENSE_F32 {
            return Err(format!("unknown block encoding {}", self.encoding));
        }
        if self.dim != dim || self.classes != classes {
            return Err(format!(
                "block shape {}×{} disagrees with the plan's {dim}×{classes}",
                self.dim, self.classes
            ));
        }
        if self.features.len() != self.labels.len() * dim {
            return Err(format!(
                "{} features for {} rows of dim {dim}",
                self.features.len(),
                self.labels.len()
            ));
        }
        if let Some(&bad) = self.labels.iter().find(|&&l| l as usize >= classes) {
            return Err(format!("label {bad} out of range for {classes} classes"));
        }
        let got = payload_checksum(&self.labels, &self.features);
        if got != self.checksum {
            return Err(format!(
                "block checksum mismatch (announced {:#x}, computed {got:#x})",
                self.checksum
            ));
        }
        Ok(())
    }

    /// Append this block's rows to a dataset of the same shape.
    pub fn append_to(&self, data: &mut Dataset) {
        for (i, &label) in self.labels.iter().enumerate() {
            data.push(
                &self.features[i * self.dim..(i + 1) * self.dim],
                label as usize,
            );
        }
    }
}

/// FNV-1a over a block payload: the labels' LE bytes followed by the
/// features' LE bit patterns (NaN-safe — bit patterns, not values).
pub fn payload_checksum(labels: &[u32], features: &[f32]) -> u64 {
    let mut h = Fnv64::new();
    fold_payload(&mut h, labels, features);
    h.finish()
}

fn fold_payload(h: &mut Fnv64, labels: &[u32], features: &[f32]) {
    for &l in labels {
        h.update(&l.to_le_bytes());
    }
    for &f in features {
        h.update(&f.to_le_bytes());
    }
}

/// The whole-shard checksum `ShardComplete` announces: one [`Fnv64`]
/// folded over every block's payload bytes in `seq` order. Equal to
/// [`fnv1a64`] of the concatenated payloads — which for a shard carved
/// by [`RowBlock::carve`] is exactly the shard's own rows, so the
/// receiver's fold certifies the reassembled shard bit-identical.
pub fn fold_payloads(blocks: &[RowBlock]) -> u64 {
    let mut h = Fnv64::new();
    for b in blocks {
        fold_payload(&mut h, &b.labels, &b.features);
    }
    h.finish()
}

/// Per-node reassembly progress a producer tracks while feeding blocks
/// in: next expected `seq`, the running payload fold, and the row
/// count. Compared against `ShardComplete` on arrival.
#[derive(Clone, Debug, Default)]
pub struct StreamProgress {
    pub next_seq: u32,
    pub rows: u64,
    hash: Option<Fnv64>,
}

impl StreamProgress {
    /// Fold one validated in-order block. Errors (without folding) on a
    /// sequence gap, duplicate, or reorder.
    pub fn fold(&mut self, block: &RowBlock) -> Result<(), String> {
        if block.seq != self.next_seq {
            return Err(format!(
                "block seq {} for node {} (expected {})",
                block.seq, block.node, self.next_seq
            ));
        }
        let mut h = self.hash.take().unwrap_or_default();
        fold_payload(&mut h, &block.labels, &block.features);
        self.hash = Some(h);
        self.next_seq += 1;
        self.rows += block.rows() as u64;
        Ok(())
    }

    /// The running whole-shard checksum ([`fnv1a64`]`(b"")` when no
    /// block has arrived — matching [`fold_payloads`] of `&[]`).
    pub fn checksum(&self) -> u64 {
        self.hash.unwrap_or_default().finish()
    }

    /// Check the stream's announced totals against what actually
    /// arrived.
    pub fn verify_complete(
        &self,
        block_count: u32,
        total_rows: u64,
        checksum: u64,
    ) -> Result<(), String> {
        if self.next_seq != block_count {
            return Err(format!(
                "stream announced {block_count} blocks, {} arrived",
                self.next_seq
            ));
        }
        if self.rows != total_rows {
            return Err(format!(
                "stream announced {total_rows} rows, {} arrived",
                self.rows
            ));
        }
        let got = self.checksum();
        if got != checksum {
            return Err(format!(
                "shard checksum mismatch (announced {checksum:#x}, computed {got:#x})"
            ));
        }
        Ok(())
    }
}

struct BufferInner {
    /// Per-node staged blocks, drained by that node's thread.
    queues: Vec<VecDeque<RowBlock>>,
    complete: Vec<bool>,
    staged: u64,
    /// High-water mark of `staged` over the buffer's lifetime.
    max_staged: u64,
    /// Bytes consumed since the last [`BlockBuffer::take_freed`] —
    /// the worker returns these as `ShardCredit`.
    freed: u64,
    stopped: bool,
}

/// Memory-bounded staging between the control-plane serve loop and the
/// node threads. One per worker; budget = `--staging-mb`.
pub struct BlockBuffer {
    inner: Mutex<BufferInner>,
    arrived: Condvar,
    budget: u64,
}

impl BlockBuffer {
    pub fn new(n_nodes: usize, budget_bytes: u64) -> Arc<Self> {
        Arc::new(Self {
            inner: Mutex::new(BufferInner {
                queues: (0..n_nodes).map(|_| VecDeque::new()).collect(),
                complete: vec![false; n_nodes],
                staged: 0,
                max_staged: 0,
                freed: 0,
                stopped: false,
            }),
            arrived: Condvar::new(),
            budget: budget_bytes,
        })
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget
    }

    /// Stage one block. Errors when the block would push staged payload
    /// past the budget — under a well-behaved sender the credit window
    /// prevents this, so an overrun means a flow-control violation, not
    /// a condition to wait out.
    pub fn push(&self, block: RowBlock) -> Result<(), String> {
        let bytes = block.payload_bytes();
        let mut inner = self.inner.lock().unwrap();
        if inner.staged + bytes > self.budget {
            return Err(format!(
                "staging {bytes} more bytes would exceed the {}-byte budget \
                 ({} already staged) — the sender ignored the credit window; \
                 raise --staging-mb or lower --stream-block-rows",
                self.budget, inner.staged
            ));
        }
        if block.node >= inner.queues.len() {
            return Err(format!("block for unknown node {}", block.node));
        }
        inner.staged += bytes;
        inner.max_staged = inner.max_staged.max(inner.staged);
        crate::obs::gauge_max(crate::obs::Gauge::StagingHighWater, inner.staged);
        let node = block.node;
        inner.queues[node].push_back(block);
        drop(inner);
        self.arrived.notify_all();
        Ok(())
    }

    /// Drain everything staged for `node` (non-blocking). Frees budget
    /// and accrues credit for the drained bytes.
    pub fn take(&self, node: usize) -> Vec<RowBlock> {
        let mut inner = self.inner.lock().unwrap();
        let blocks: Vec<RowBlock> = inner.queues[node].drain(..).collect();
        let bytes: u64 = blocks.iter().map(|b| b.payload_bytes()).sum();
        inner.staged -= bytes;
        inner.freed += bytes;
        blocks
    }

    /// Block (bounded by `timeout`) until `node` has a staged block,
    /// its stream completed, or the buffer stopped. Returns whether a
    /// block is available now.
    pub fn wait_for_block(&self, node: usize, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.queues[node].is_empty() {
                return true;
            }
            if inner.stopped || inner.complete[node] {
                return false;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return false;
            }
            let (guard, res) = self.arrived.wait_timeout(inner, left).unwrap();
            inner = guard;
            if res.timed_out() && inner.queues[node].is_empty() {
                return false;
            }
        }
    }

    /// Mark `node`'s stream complete (its `ShardComplete` validated).
    pub fn mark_complete(&self, node: usize) {
        self.inner.lock().unwrap().complete[node] = true;
        self.arrived.notify_all();
    }

    pub fn is_complete(&self, node: usize) -> bool {
        self.inner.lock().unwrap().complete[node]
    }

    /// Wake every waiter permanently (worker shutdown).
    pub fn stop(&self) {
        self.inner.lock().unwrap().stopped = true;
        self.arrived.notify_all();
    }

    /// Consume the credit accumulator: bytes drained since the last
    /// call, to be returned to the sender as `ShardCredit`.
    pub fn take_freed(&self) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        std::mem::take(&mut inner.freed)
    }

    pub fn staged_bytes(&self) -> u64 {
        self.inner.lock().unwrap().staged
    }

    /// Lifetime high-water mark of staged payload — what the acceptance
    /// test asserts stays within the budget.
    pub fn max_staged(&self) -> u64 {
        self.inner.lock().unwrap().max_staged
    }

    /// A per-node consumer handle over this buffer.
    pub fn receiver(self: &Arc<Self>, node: usize) -> ShardReceiver {
        ShardReceiver {
            buffer: Arc::clone(self),
            node,
        }
    }
}

/// One node's streaming sampler feed: drains that node's staged blocks
/// into its local [`Dataset`] as they land.
#[derive(Clone)]
pub struct ShardReceiver {
    buffer: Arc<BlockBuffer>,
    node: usize,
}

impl std::fmt::Debug for ShardReceiver {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardReceiver")
            .field("node", &self.node)
            .field("complete", &self.buffer.is_complete(self.node))
            .finish()
    }
}

impl ShardReceiver {
    pub fn node(&self) -> usize {
        self.node
    }

    /// Append every staged block's rows to `data` (non-blocking).
    /// Returns the number of rows appended.
    pub fn drain_into(&self, data: &mut Dataset) -> usize {
        let mut rows = 0;
        for block in self.buffer.take(self.node) {
            rows += block.rows();
            block.append_to(data);
        }
        rows
    }

    /// Bounded wait for the next block (false = nothing arrived and the
    /// stream is complete, stopped, or the timeout passed).
    pub fn wait_for_block(&self, timeout: Duration) -> bool {
        self.buffer.wait_for_block(self.node, timeout)
    }

    /// The stream delivered its final block and validated.
    pub fn is_complete(&self) -> bool {
        self.buffer.is_complete(self.node)
    }
}

/// Self-check: [`fold_payloads`] over a full carve equals [`fnv1a64`]
/// over the shard's own label+feature bytes — the identity the
/// end-to-end checksum certification rests on.
pub fn shard_checksum(data: &Dataset) -> u64 {
    let labels: Vec<u32> = data.labels().iter().map(|&l| l as u32).collect();
    let mut bytes = Vec::with_capacity(labels.len() * 4 + data.features_flat().len() * 4);
    for &l in &labels {
        bytes.extend_from_slice(&l.to_le_bytes());
    }
    for &f in data.features_flat() {
        bytes.extend_from_slice(&f.to_le_bytes());
    }
    fnv1a64(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(rows: usize, dim: usize, classes: usize, seed: u64) -> Dataset {
        let mut d = Dataset::with_capacity(dim, classes, rows);
        let mut x = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        for i in 0..rows {
            let feats: Vec<f32> = (0..dim)
                .map(|j| {
                    x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                    ((x >> 33) as f32 / (1u64 << 31) as f32) - 1.0 + j as f32 * 1e-3
                })
                .collect();
            d.push(&feats, i % classes);
        }
        d
    }

    #[test]
    fn carve_covers_every_row_in_order() {
        let d = shard(1000, 7, 3, 1);
        let blocks = RowBlock::carve(4, &d, 128);
        assert_eq!(blocks.len(), 8); // ceil(1000/128)
        let mut rebuilt = Dataset::new(7, 3);
        for (i, b) in blocks.iter().enumerate() {
            assert_eq!(b.seq as usize, i);
            assert_eq!(b.node, 4);
            b.validate(7, 3).unwrap();
            b.append_to(&mut rebuilt);
        }
        assert_eq!(rebuilt.labels(), d.labels());
        assert_eq!(rebuilt.features_flat(), d.features_flat());
        // Whole-shard fold equals the shard's own byte checksum.
        assert_eq!(fold_payloads(&blocks), shard_checksum(&d));
    }

    #[test]
    fn carve_of_empty_shard_is_empty() {
        let d = Dataset::new(5, 2);
        assert!(RowBlock::carve(0, &d, 64).is_empty());
        assert_eq!(fold_payloads(&[]), fnv1a64(b""));
        assert_eq!(shard_checksum(&d), fnv1a64(b""));
    }

    #[test]
    fn validate_catches_every_corruption() {
        let d = shard(50, 4, 2, 3);
        let b = &RowBlock::carve(0, &d, 64)[0];
        b.validate(4, 2).unwrap();
        // Wrong shape vs plan.
        assert!(b.validate(5, 2).is_err());
        assert!(b.validate(4, 3).is_err());
        // Flipped feature bit.
        let mut bad = b.clone();
        bad.features[7] += 1.0;
        assert!(bad.validate(4, 2).unwrap_err().contains("checksum"));
        // Corrupt label (out of range).
        let mut bad = b.clone();
        bad.labels[0] = 9;
        assert!(bad.validate(4, 2).unwrap_err().contains("label"));
        // Truncated features.
        let mut bad = b.clone();
        bad.features.pop();
        assert!(bad.validate(4, 2).is_err());
        // Unknown encoding.
        let mut bad = b.clone();
        bad.encoding = 7;
        assert!(bad.validate(4, 2).unwrap_err().contains("encoding"));
    }

    #[test]
    fn progress_rejects_gaps_duplicates_and_reorders() {
        let d = shard(300, 3, 2, 5);
        let blocks = RowBlock::carve(1, &d, 100);
        let mut p = StreamProgress::default();
        p.fold(&blocks[0]).unwrap();
        // Duplicate.
        assert!(p.fold(&blocks[0]).is_err());
        // Gap.
        assert!(p.fold(&blocks[2]).is_err());
        p.fold(&blocks[1]).unwrap();
        p.fold(&blocks[2]).unwrap();
        p.verify_complete(3, 300, fold_payloads(&blocks)).unwrap();
        // Lying totals are caught.
        assert!(p.verify_complete(2, 300, fold_payloads(&blocks)).is_err());
        assert!(p.verify_complete(3, 299, fold_payloads(&blocks)).is_err());
        assert!(p
            .verify_complete(3, 300, fold_payloads(&blocks) ^ 1)
            .is_err());
    }

    #[test]
    fn buffer_enforces_its_budget_and_credits_drains() {
        let d = shard(256, 4, 2, 7);
        let blocks = RowBlock::carve(0, &d, 64); // 4 blocks, 64·(4+16) B each
        let per_block = blocks[0].payload_bytes();
        let buf = BlockBuffer::new(1, per_block * 2);
        buf.push(blocks[0].clone()).unwrap();
        buf.push(blocks[1].clone()).unwrap();
        assert_eq!(buf.staged_bytes(), per_block * 2);
        // A third block overflows the budget and names the flag.
        let err = buf.push(blocks[2].clone()).unwrap_err();
        assert!(err.contains("--staging-mb"), "{err}");
        // Draining frees budget and accrues credit.
        let taken = buf.take(0);
        assert_eq!(taken.len(), 2);
        assert_eq!(buf.staged_bytes(), 0);
        assert_eq!(buf.take_freed(), per_block * 2);
        assert_eq!(buf.take_freed(), 0);
        buf.push(blocks[2].clone()).unwrap();
        buf.push(blocks[3].clone()).unwrap();
        assert_eq!(buf.max_staged(), per_block * 2);
    }

    #[test]
    fn receiver_drains_blocks_into_a_dataset_across_threads() {
        let d = shard(500, 6, 3, 11);
        let blocks = RowBlock::carve(0, &d, 50);
        let buf = BlockBuffer::new(1, u64::MAX);
        let recv = buf.receiver(0);
        let producer = {
            let buf = Arc::clone(&buf);
            let blocks = blocks.clone();
            std::thread::spawn(move || {
                for b in blocks {
                    buf.push(b).unwrap();
                    std::thread::sleep(Duration::from_millis(1));
                }
                buf.mark_complete(0);
            })
        };
        let mut got = Dataset::new(6, 3);
        while got.len() < 500 {
            if !recv.wait_for_block(Duration::from_secs(5)) && recv.is_complete() {
                recv.drain_into(&mut got);
                break;
            }
            recv.drain_into(&mut got);
        }
        producer.join().unwrap();
        recv.drain_into(&mut got);
        assert_eq!(got.labels(), d.labels());
        assert_eq!(got.features_flat(), d.features_flat());
        assert!(recv.is_complete());
    }

    #[test]
    fn stop_wakes_waiters() {
        let buf = BlockBuffer::new(2, 1 << 20);
        let waiter = {
            let buf = Arc::clone(&buf);
            std::thread::spawn(move || buf.wait_for_block(1, Duration::from_secs(30)))
        };
        std::thread::sleep(Duration::from_millis(20));
        buf.stop();
        assert!(!waiter.join().unwrap(), "stop must wake the waiter");
    }
}
