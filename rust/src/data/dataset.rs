//! Dataset container: flat row-major feature storage + labels.

/// A borrowed view of one sample.
#[derive(Clone, Copy, Debug)]
pub struct Sample<'a> {
    pub features: &'a [f32],
    pub label: usize,
}

/// In-memory classification dataset, row-major features.
#[derive(Clone, Debug)]
pub struct Dataset {
    dim: usize,
    classes: usize,
    features: Vec<f32>,
    labels: Vec<usize>,
}

impl Dataset {
    pub fn new(dim: usize, classes: usize) -> Self {
        Self {
            dim,
            classes,
            features: Vec::new(),
            labels: Vec::new(),
        }
    }

    pub fn with_capacity(dim: usize, classes: usize, n: usize) -> Self {
        Self {
            dim,
            classes,
            features: Vec::with_capacity(n * dim),
            labels: Vec::with_capacity(n),
        }
    }

    pub fn push(&mut self, features: &[f32], label: usize) {
        assert_eq!(features.len(), self.dim, "feature dim mismatch");
        assert!(label < self.classes, "label out of range");
        self.features.extend_from_slice(features);
        self.labels.push(label);
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    pub fn sample(&self, i: usize) -> Sample<'_> {
        Sample {
            features: &self.features[i * self.dim..(i + 1) * self.dim],
            label: self.labels[i],
        }
    }

    pub fn features_flat(&self) -> &[f32] {
        &self.features
    }

    pub fn labels(&self) -> &[usize] {
        &self.labels
    }

    /// One-hot encode labels into a flat row-major (n × classes) buffer.
    pub fn one_hot_labels(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len() * self.classes];
        for (i, &l) in self.labels.iter().enumerate() {
            out[i * self.classes + l] = 1.0;
        }
        out
    }

    /// Copy rows `idx` into a new dataset (sharding / subsampling).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut out = Dataset::with_capacity(self.dim, self.classes, idx.len());
        for &i in idx {
            let s = self.sample(i);
            out.push(s.features, s.label);
        }
        out
    }

    /// Append all samples of `other`.
    pub fn extend(&mut self, other: &Dataset) {
        assert_eq!(self.dim, other.dim);
        assert_eq!(self.classes, other.classes);
        self.features.extend_from_slice(&other.features);
        self.labels.extend_from_slice(&other.labels);
    }

    /// Per-class counts (distribution diagnostics).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.classes];
        for &l in &self.labels {
            counts[l] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let mut d = Dataset::new(2, 3);
        d.push(&[1.0, 2.0], 0);
        d.push(&[3.0, 4.0], 2);
        d.push(&[5.0, 6.0], 1);
        d
    }

    #[test]
    fn push_and_view() {
        let d = tiny();
        assert_eq!(d.len(), 3);
        assert_eq!(d.sample(1).features, &[3.0, 4.0]);
        assert_eq!(d.sample(1).label, 2);
        assert_eq!(d.class_counts(), vec![1, 1, 1]);
    }

    #[test]
    fn one_hot() {
        let d = tiny();
        let oh = d.one_hot_labels();
        assert_eq!(
            oh,
            vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 1.0, 0.0]
        );
    }

    #[test]
    fn subset_and_extend() {
        let d = tiny();
        let s = d.subset(&[2, 0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.sample(0).label, 1);
        let mut e = d.clone();
        e.extend(&s);
        assert_eq!(e.len(), 5);
    }

    #[test]
    #[should_panic(expected = "label out of range")]
    fn rejects_bad_label() {
        let mut d = Dataset::new(2, 3);
        d.push(&[0.0, 0.0], 3);
    }
}
