//! notMNIST-like glyph corpus (offline substitute for the 12 GB dataset).
//!
//! The paper's §V-E uses notMNIST: images of the letters A–J in many
//! fonts, 10 classes, 256 features (16×16). That download is unavailable
//! offline, so this module synthesizes an equivalent corpus: each letter
//! is a stroke skeleton (line segments in the unit square) rasterized at
//! 16×16 with anti-aliasing, under per-sample affine jitter (rotation,
//! scale, translation, slant), per-node style parameters (stroke width,
//! slant bias — playing the role of "fonts" concentrated on nodes so
//! node distributions differ), and pixel noise. The result exercises the
//! identical code path (D=256, C=10 multinomial logistic regression) with
//! comparable class overlap; see DESIGN.md §3.

use super::Dataset;
use crate::util::rng::Xoshiro256pp;

pub const GLYPH_SIDE: usize = 16;
pub const GLYPH_DIM: usize = GLYPH_SIDE * GLYPH_SIDE; // 256, as in §V-E
pub const GLYPH_CLASSES: usize = 10; // letters A..J

type Seg = ((f32, f32), (f32, f32));

/// Stroke skeletons for A–J in the unit square, y growing downwards.
fn skeleton(class: usize) -> Vec<Seg> {
    match class {
        // A
        0 => vec![
            ((0.5, 0.05), (0.1, 0.95)),
            ((0.5, 0.05), (0.9, 0.95)),
            ((0.25, 0.6), (0.75, 0.6)),
        ],
        // B
        1 => vec![
            ((0.2, 0.05), (0.2, 0.95)),
            ((0.2, 0.05), (0.7, 0.15)),
            ((0.7, 0.15), (0.7, 0.4)),
            ((0.7, 0.4), (0.2, 0.5)),
            ((0.2, 0.5), (0.75, 0.6)),
            ((0.75, 0.6), (0.75, 0.85)),
            ((0.75, 0.85), (0.2, 0.95)),
        ],
        // C
        2 => vec![
            ((0.85, 0.2), (0.5, 0.05)),
            ((0.5, 0.05), (0.15, 0.3)),
            ((0.15, 0.3), (0.15, 0.7)),
            ((0.15, 0.7), (0.5, 0.95)),
            ((0.5, 0.95), (0.85, 0.8)),
        ],
        // D
        3 => vec![
            ((0.2, 0.05), (0.2, 0.95)),
            ((0.2, 0.05), (0.65, 0.15)),
            ((0.65, 0.15), (0.85, 0.5)),
            ((0.85, 0.5), (0.65, 0.85)),
            ((0.65, 0.85), (0.2, 0.95)),
        ],
        // E
        4 => vec![
            ((0.2, 0.05), (0.2, 0.95)),
            ((0.2, 0.05), (0.85, 0.05)),
            ((0.2, 0.5), (0.7, 0.5)),
            ((0.2, 0.95), (0.85, 0.95)),
        ],
        // F
        5 => vec![
            ((0.2, 0.05), (0.2, 0.95)),
            ((0.2, 0.05), (0.85, 0.05)),
            ((0.2, 0.5), (0.7, 0.5)),
        ],
        // G
        6 => vec![
            ((0.85, 0.2), (0.5, 0.05)),
            ((0.5, 0.05), (0.15, 0.3)),
            ((0.15, 0.3), (0.15, 0.7)),
            ((0.15, 0.7), (0.5, 0.95)),
            ((0.5, 0.95), (0.85, 0.8)),
            ((0.85, 0.8), (0.85, 0.55)),
            ((0.85, 0.55), (0.55, 0.55)),
        ],
        // H
        7 => vec![
            ((0.2, 0.05), (0.2, 0.95)),
            ((0.8, 0.05), (0.8, 0.95)),
            ((0.2, 0.5), (0.8, 0.5)),
        ],
        // I
        8 => vec![
            ((0.5, 0.05), (0.5, 0.95)),
            ((0.3, 0.05), (0.7, 0.05)),
            ((0.3, 0.95), (0.7, 0.95)),
        ],
        // J
        9 => vec![
            ((0.65, 0.05), (0.65, 0.75)),
            ((0.65, 0.75), (0.45, 0.95)),
            ((0.45, 0.95), (0.2, 0.8)),
            ((0.4, 0.05), (0.9, 0.05)),
        ],
        _ => panic!("glyph class out of range"),
    }
}

fn dist_to_seg(px: f32, py: f32, seg: &Seg) -> f32 {
    let ((x1, y1), (x2, y2)) = *seg;
    let (dx, dy) = (x2 - x1, y2 - y1);
    let len_sq = dx * dx + dy * dy;
    let t = if len_sq <= 1e-12 {
        0.0
    } else {
        (((px - x1) * dx + (py - y1) * dy) / len_sq).clamp(0.0, 1.0)
    };
    let (cx, cy) = (x1 + t * dx, y1 + t * dy);
    ((px - cx) * (px - cx) + (py - cy) * (py - cy)).sqrt()
}

/// Affine jitter parameters for one sample.
#[derive(Clone, Copy, Debug)]
pub struct GlyphStyle {
    pub rotation: f32,
    pub scale: f32,
    pub slant: f32,
    pub dx: f32,
    pub dy: f32,
    pub thickness: f32,
    pub noise_std: f32,
}

impl Default for GlyphStyle {
    fn default() -> Self {
        Self {
            rotation: 0.0,
            scale: 1.0,
            slant: 0.0,
            dx: 0.0,
            dy: 0.0,
            thickness: 0.055,
            noise_std: 0.0,
        }
    }
}

/// Rasterize one letter (class 0..=9) with the given style into a
/// GLYPH_DIM-length pixel vector in [0, 1] (plus optional noise).
pub fn render_glyph(class: usize, style: &GlyphStyle, rng: &mut Xoshiro256pp) -> Vec<f32> {
    let segs = skeleton(class);
    let (sin, cos) = style.rotation.sin_cos();
    let mut out = vec![0.0f32; GLYPH_DIM];
    for row in 0..GLYPH_SIDE {
        for col in 0..GLYPH_SIDE {
            // Pixel center in the unit square, inverse-transformed into
            // glyph coordinates.
            let px = (col as f32 + 0.5) / GLYPH_SIDE as f32;
            let py = (row as f32 + 0.5) / GLYPH_SIDE as f32;
            // Undo translation, then rotation/scale/slant about center.
            let (ux, uy) = (px - 0.5 - style.dx, py - 0.5 - style.dy);
            let (rx, ry) = (ux * cos + uy * sin, -ux * sin + uy * cos);
            let gx = rx / style.scale - style.slant * ry + 0.5;
            let gy = ry / style.scale + 0.5;
            let d = segs
                .iter()
                .map(|s| dist_to_seg(gx, gy, s))
                .fold(f32::INFINITY, f32::min);
            // Smooth ink falloff around the stroke (anti-aliasing).
            let ink = 1.0 - ((d - style.thickness) / 0.03).clamp(0.0, 1.0);
            let noise = if style.noise_std > 0.0 {
                rng.gauss_f32(0.0, style.noise_std)
            } else {
                0.0
            };
            out[row * GLYPH_SIDE + col] = (ink + noise).clamp(0.0, 1.0);
        }
    }
    out
}

/// Per-node notMNIST-like generator. Each node gets "font" biases
/// (thickness, slant, rotation bias) and skewed class priors, so — as in
/// §V-A — node distributions differ.
#[derive(Clone, Debug)]
pub struct NotMnistGen {
    nodes: usize,
    node_thickness: Vec<f32>,
    node_slant: Vec<f32>,
    node_rot_bias: Vec<f32>,
    priors: Vec<f64>,
    noise_std: f32,
}

impl NotMnistGen {
    pub fn new(nodes: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::seeded(seed);
        let node_thickness = (0..nodes)
            .map(|_| 0.045 + rng.next_f32() * 0.035)
            .collect();
        let node_slant = (0..nodes).map(|_| rng.gauss_f32(0.0, 0.18)).collect();
        let node_rot_bias = (0..nodes).map(|_| rng.gauss_f32(0.0, 0.08)).collect();
        let mut priors = Vec::with_capacity(nodes * GLYPH_CLASSES);
        for _ in 0..nodes {
            let mut p: Vec<f64> = (0..GLYPH_CLASSES).map(|_| 0.3 + rng.next_f64()).collect();
            for _ in 0..2 {
                let c = rng.index(GLYPH_CLASSES);
                p[c] *= 2.5;
            }
            let total: f64 = p.iter().sum();
            priors.extend(p.into_iter().map(|x| x / total));
        }
        Self {
            nodes,
            node_thickness,
            node_slant,
            node_rot_bias,
            priors,
            noise_std: 0.12,
        }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Draw one (image, label) from node `i`'s distribution.
    pub fn draw(&self, node: usize, rng: &mut Xoshiro256pp) -> (Vec<f32>, usize) {
        assert!(node < self.nodes);
        let priors = &self.priors[node * GLYPH_CLASSES..(node + 1) * GLYPH_CLASSES];
        let class = rng.weighted_index(priors);
        let style = GlyphStyle {
            rotation: self.node_rot_bias[node] + rng.gauss_f32(0.0, 0.08),
            scale: 0.82 + rng.next_f32() * 0.3,
            slant: self.node_slant[node] + rng.gauss_f32(0.0, 0.06),
            dx: rng.gauss_f32(0.0, 0.04),
            dy: rng.gauss_f32(0.0, 0.04),
            thickness: self.node_thickness[node] + rng.gauss_f32(0.0, 0.006),
            noise_std: self.noise_std,
        };
        (render_glyph(class, &style, rng), class)
    }

    pub fn node_dataset(&self, node: usize, n: usize, rng: &mut Xoshiro256pp) -> Dataset {
        let mut d = Dataset::with_capacity(GLYPH_DIM, GLYPH_CLASSES, n);
        for _ in 0..n {
            let (x, y) = self.draw(node, rng);
            d.push(&x, y);
        }
        d
    }

    /// Global mixture test set (node chosen uniformly per sample).
    pub fn global_test_set(&self, n: usize, rng: &mut Xoshiro256pp) -> Dataset {
        let mut d = Dataset::with_capacity(GLYPH_DIM, GLYPH_CLASSES, n);
        for _ in 0..n {
            let node = rng.index(self.nodes);
            let (x, y) = self.draw(node, rng);
            d.push(&x, y);
        }
        d
    }
}

/// ASCII-art dump of one glyph (Fig. 5 stand-in, CLI `dasgd glyphs`).
pub fn ascii_art(pixels: &[f32]) -> String {
    assert_eq!(pixels.len(), GLYPH_DIM);
    let ramp: &[u8] = b" .:-=+*#%@";
    let mut out = String::with_capacity(GLYPH_DIM + GLYPH_SIDE);
    for row in 0..GLYPH_SIDE {
        for col in 0..GLYPH_SIDE {
            let v = pixels[row * GLYPH_SIDE + col].clamp(0.0, 1.0);
            let idx = ((v * (ramp.len() - 1) as f32).round() as usize).min(ramp.len() - 1);
            out.push(ramp[idx] as char);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_produces_ink_in_bounds() {
        let mut rng = Xoshiro256pp::seeded(1);
        for class in 0..GLYPH_CLASSES {
            let img = render_glyph(class, &GlyphStyle::default(), &mut rng);
            assert_eq!(img.len(), GLYPH_DIM);
            assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)));
            let ink: f32 = img.iter().sum();
            assert!(ink > 5.0, "class {class} nearly blank: ink={ink}");
            assert!(ink < GLYPH_DIM as f32 * 0.7, "class {class} all ink");
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // Clean renders of different letters must differ substantially.
        let mut rng = Xoshiro256pp::seeded(2);
        let imgs: Vec<Vec<f32>> = (0..GLYPH_CLASSES)
            .map(|c| render_glyph(c, &GlyphStyle::default(), &mut rng))
            .collect();
        for a in 0..GLYPH_CLASSES {
            for b in (a + 1)..GLYPH_CLASSES {
                let d = crate::linalg::dist2_sq(&imgs[a], &imgs[b]).sqrt();
                assert!(d > 1.0, "classes {a} and {b} too similar: {d}");
            }
        }
    }

    #[test]
    fn generator_is_deterministic() {
        let gen = NotMnistGen::new(4, 9);
        let mut r1 = Xoshiro256pp::seeded(5);
        let mut r2 = Xoshiro256pp::seeded(5);
        assert_eq!(gen.draw(1, &mut r1), gen.draw(1, &mut r2));
    }

    #[test]
    fn node_styles_differ() {
        let gen = NotMnistGen::new(8, 11);
        let t: Vec<f32> = gen.node_thickness.clone();
        assert!(t.iter().any(|&x| (x - t[0]).abs() > 1e-3));
    }

    #[test]
    fn datasets_have_declared_shape() {
        let gen = NotMnistGen::new(3, 13);
        let mut rng = Xoshiro256pp::seeded(1);
        let d = gen.node_dataset(0, 40, &mut rng);
        assert_eq!(d.dim(), 256);
        assert_eq!(d.classes(), 10);
        assert_eq!(d.len(), 40);
        let t = gen.global_test_set(64, &mut rng);
        assert_eq!(t.len(), 64);
    }

    #[test]
    fn ascii_art_shape() {
        let mut rng = Xoshiro256pp::seeded(3);
        let img = render_glyph(0, &GlyphStyle::default(), &mut rng);
        let art = ascii_art(&img);
        assert_eq!(art.lines().count(), GLYPH_SIDE);
        assert!(art.lines().all(|l| l.chars().count() == GLYPH_SIDE));
    }
}
