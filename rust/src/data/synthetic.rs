//! §V-A synthetic generator: 10-class / 50-feature classification where
//! **every node has its own distribution** — node-specific class means and
//! skewed class priors — so "training with only one or several nodes will
//! deviate from the global optimality" (paper §V-A), plus additive noise
//! on generated samples (§V-C).

use super::Dataset;
use crate::util::rng::Xoshiro256pp;

/// Generator of per-node data distributions.
#[derive(Clone, Debug)]
pub struct SyntheticGen {
    dim: usize,
    classes: usize,
    nodes: usize,
    /// Global class means, row-major (classes × dim).
    global_means: Vec<f32>,
    /// Per-node mean offsets, row-major (nodes × classes × dim).
    node_offsets: Vec<f32>,
    /// Per-node class priors, row-major (nodes × classes).
    priors: Vec<f64>,
    noise_std: f32,
}

impl SyntheticGen {
    /// The paper's setting: `classes = 10`, `dim = 50`, with enough
    /// class overlap + per-node skew + sample noise that the error curve
    /// decays gradually over tens of thousands of iterations (§V-C adds
    /// noise to the generated samples; a perfectly separable mixture
    /// would hit zero error immediately and show none of the paper's
    /// dynamics).
    pub fn paper_default(nodes: usize, seed: u64) -> Self {
        Self::new(nodes, 50, 10, 0.5, 0.7, 1.0, seed)
    }

    /// * `sep` — spread of the global class means (separability).
    /// * `node_skew` — magnitude of node-specific mean offsets.
    /// * `noise_std` — additive sample noise.
    pub fn new(
        nodes: usize,
        dim: usize,
        classes: usize,
        sep: f32,
        node_skew: f32,
        noise_std: f32,
        seed: u64,
    ) -> Self {
        let mut rng = Xoshiro256pp::seeded(seed);
        let global_means: Vec<f32> = (0..classes * dim)
            .map(|_| rng.gauss_f32(0.0, sep))
            .collect();
        let node_offsets: Vec<f32> = (0..nodes * classes * dim)
            .map(|_| rng.gauss_f32(0.0, node_skew))
            .collect();
        // Skewed priors: each node prefers a random subset of classes.
        let mut priors = Vec::with_capacity(nodes * classes);
        for _ in 0..nodes {
            let mut p: Vec<f64> = (0..classes).map(|_| 0.2 + rng.next_f64()).collect();
            // Boost 3 favored classes by 3x.
            for _ in 0..3 {
                let c = rng.index(classes);
                p[c] *= 3.0;
            }
            let total: f64 = p.iter().sum();
            priors.extend(p.into_iter().map(|x| x / total));
        }
        Self {
            dim,
            classes,
            nodes,
            global_means,
            node_offsets,
            priors,
            noise_std,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    fn mean_of(&self, node: usize, class: usize) -> Vec<f32> {
        let g = &self.global_means[class * self.dim..(class + 1) * self.dim];
        let off_base = (node * self.classes + class) * self.dim;
        let o = &self.node_offsets[off_base..off_base + self.dim];
        g.iter().zip(o).map(|(a, b)| a + b).collect()
    }

    /// Draw one sample from node `i`'s distribution V_i.
    pub fn draw(&self, node: usize, rng: &mut Xoshiro256pp) -> (Vec<f32>, usize) {
        assert!(node < self.nodes);
        let priors = &self.priors[node * self.classes..(node + 1) * self.classes];
        let class = rng.weighted_index(priors);
        let mean = self.mean_of(node, class);
        let x = mean
            .iter()
            .map(|m| m + rng.gauss_f32(0.0, self.noise_std))
            .collect();
        (x, class)
    }

    /// Generate a node-local dataset of `n` samples.
    pub fn node_dataset(&self, node: usize, n: usize, rng: &mut Xoshiro256pp) -> Dataset {
        let mut d = Dataset::with_capacity(self.dim, self.classes, n);
        for _ in 0..n {
            let (x, y) = self.draw(node, rng);
            d.push(&x, y);
        }
        d
    }

    /// Global test set: the mixture (1/N) Σ_i V_i of Problem (2) — node
    /// chosen uniformly per sample.
    pub fn global_test_set(&self, n: usize, rng: &mut Xoshiro256pp) -> Dataset {
        let mut d = Dataset::with_capacity(self.dim, self.classes, n);
        for _ in 0..n {
            let node = rng.index(self.nodes);
            let (x, y) = self.draw(node, rng);
            d.push(&x, y);
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_shapes() {
        let gen = SyntheticGen::paper_default(30, 7);
        assert_eq!(gen.dim(), 50);
        assert_eq!(gen.classes(), 10);
        let mut rng = Xoshiro256pp::seeded(1);
        let d = gen.node_dataset(3, 100, &mut rng);
        assert_eq!(d.len(), 100);
        assert_eq!(d.dim(), 50);
    }

    #[test]
    fn deterministic_given_seeds() {
        let gen = SyntheticGen::paper_default(5, 7);
        let mut r1 = Xoshiro256pp::seeded(3);
        let mut r2 = Xoshiro256pp::seeded(3);
        let (x1, y1) = gen.draw(2, &mut r1);
        let (x2, y2) = gen.draw(2, &mut r2);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn nodes_have_different_distributions() {
        let gen = SyntheticGen::paper_default(10, 11);
        // Node-conditional class means differ across nodes.
        let m0 = gen.mean_of(0, 0);
        let m1 = gen.mean_of(1, 0);
        let dist = crate::linalg::dist2_sq(&m0, &m1).sqrt();
        assert!(dist > 0.5, "node means too close: {dist}");
        // Priors are skewed: some class ≥ 2x another, and all sum to 1.
        let mut rng = Xoshiro256pp::seeded(5);
        let d = gen.node_dataset(0, 2000, &mut rng);
        let counts = d.class_counts();
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) > 1.5, "priors not skewed: {counts:?}");
    }

    #[test]
    fn global_test_set_mixes_nodes() {
        let gen = SyntheticGen::paper_default(10, 13);
        let mut rng = Xoshiro256pp::seeded(17);
        let t = gen.global_test_set(1000, &mut rng);
        assert_eq!(t.len(), 1000);
        // All classes appear in the global mixture.
        assert!(t.class_counts().iter().all(|&c| c > 0));
    }

    #[test]
    fn noise_is_applied() {
        let gen = SyntheticGen::new(2, 8, 2, 2.0, 0.0, 0.5, 1);
        let mut rng = Xoshiro256pp::seeded(2);
        // Two draws of the same class differ (noise), but correlate with
        // the class mean.
        let mut xs = Vec::new();
        for _ in 0..50 {
            let (x, y) = gen.draw(0, &mut rng);
            if y == 0 {
                xs.push(x);
            }
        }
        assert!(xs.len() > 5);
        assert_ne!(xs[0], xs[1]);
    }
}
