//! `libsvm`-format sparse dataset loader (the RCV1 / covtype / news20
//! family): one sample per line,
//!
//! ```text
//! <label> <index>:<value> <index>:<value> ...
//! ```
//!
//! with 1-based, strictly ascending indices. Parsing is total — a
//! malformed, truncated, NaN, or duplicate-index line returns a
//! line-numbered error, never a panic — and the loader validates
//! optional expected row/dim counts so a truncated download fails
//! loudly instead of training on a partial corpus. Rows densify into
//! the repo-wide [`Dataset`] (row-major f32), and an on-disk cache
//! (`<path>.cache`, checksummed against the source bytes) skips the
//! text parse on reload. Labels are remapped to `0..classes` by sorted
//! distinct value, so `-1/+1` SVM files and `1..k` multiclass files
//! both load unchanged.

use std::path::{Path, PathBuf};

use crate::data::Dataset;
use crate::net::wire::fnv1a64;

/// Loader knobs for [`load_libsvm`].
#[derive(Clone, Copy, Debug, Default)]
pub struct LibsvmOptions {
    /// Require the corpus to have exactly this feature dimension
    /// (errors otherwise); `None` infers the max seen index.
    pub expect_dim: Option<usize>,
    /// Require exactly this many data rows (truncation guard).
    pub expect_rows: Option<usize>,
    /// Write/reuse the `<path>.cache` binary next to the source file.
    pub cache: bool,
}

/// Parse libsvm-format text into a dense [`Dataset`]. Total: every
/// malformed input returns a line-numbered error. Blank lines and
/// `#` comment lines are skipped.
pub fn parse_libsvm(text: &str, expect_dim: Option<usize>) -> Result<Dataset, String> {
    let mut raw_labels: Vec<i64> = Vec::new();
    let mut rows: Vec<Vec<(usize, f32)>> = Vec::new();
    let mut max_index = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let lineno = lineno + 1;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut tokens = line.split_whitespace();
        let label_tok = tokens.next().expect("non-empty line has a first token");
        let label: f64 = label_tok
            .parse()
            .map_err(|e| format!("line {lineno}: label {label_tok:?}: {e}"))?;
        if !label.is_finite() || label.fract() != 0.0 || label.abs() > 1e15 {
            return Err(format!(
                "line {lineno}: label {label_tok:?} is not an integral class value"
            ));
        }
        let mut pairs: Vec<(usize, f32)> = Vec::new();
        let mut last_index = 0usize;
        for tok in tokens {
            let Some((idx_s, val_s)) = tok.split_once(':') else {
                return Err(format!(
                    "line {lineno}: feature {tok:?} is not <index>:<value>"
                ));
            };
            let idx: usize = idx_s
                .parse()
                .map_err(|e| format!("line {lineno}: index {idx_s:?}: {e}"))?;
            if idx == 0 {
                return Err(format!(
                    "line {lineno}: index 0 (libsvm indices are 1-based)"
                ));
            }
            if idx <= last_index {
                return Err(format!(
                    "line {lineno}: index {idx} after {last_index} — indices must be \
                     strictly ascending (duplicate or out-of-order feature)"
                ));
            }
            let val: f32 = val_s
                .parse()
                .map_err(|e| format!("line {lineno}: value {val_s:?}: {e}"))?;
            if !val.is_finite() {
                return Err(format!("line {lineno}: value {val_s:?} is not finite"));
            }
            last_index = idx;
            pairs.push((idx, val));
        }
        max_index = max_index.max(last_index);
        raw_labels.push(label as i64);
        rows.push(pairs);
    }
    if rows.is_empty() {
        return Err("no data rows (only blanks/comments)".to_string());
    }
    let dim = match expect_dim {
        Some(d) if max_index > d => {
            return Err(format!(
                "feature index {max_index} exceeds the expected dimension {d}"
            ));
        }
        Some(d) => d,
        None => max_index,
    };
    if dim == 0 {
        return Err("every row is empty — the corpus has no features".to_string());
    }
    // Remap labels to 0..classes by sorted distinct value (-1/+1 → 0/1).
    let mut distinct: Vec<i64> = raw_labels.clone();
    distinct.sort_unstable();
    distinct.dedup();
    let classes = distinct.len().max(2);
    let mut data = Dataset::with_capacity(dim, classes, rows.len());
    let mut dense = vec![0.0f32; dim];
    for (pairs, raw) in rows.iter().zip(&raw_labels) {
        dense.iter_mut().for_each(|v| *v = 0.0);
        for &(idx, val) in pairs {
            dense[idx - 1] = val;
        }
        let label = distinct.binary_search(raw).expect("label seen in pass 1");
        data.push(&dense, label);
    }
    Ok(data)
}

const CACHE_MAGIC: &[u8; 8] = b"DSLSVC01";

fn cache_path(path: &Path) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".cache");
    PathBuf::from(s)
}

fn encode_cache(source_sum: u64, data: &Dataset) -> Vec<u8> {
    let mut buf = Vec::with_capacity(
        8 + 8 + 24 + data.labels().len() * 4 + data.features_flat().len() * 4 + 8,
    );
    buf.extend_from_slice(CACHE_MAGIC);
    buf.extend_from_slice(&source_sum.to_le_bytes());
    buf.extend_from_slice(&(data.dim() as u64).to_le_bytes());
    buf.extend_from_slice(&(data.classes() as u64).to_le_bytes());
    buf.extend_from_slice(&(data.len() as u64).to_le_bytes());
    for &l in data.labels() {
        buf.extend_from_slice(&(l as u32).to_le_bytes());
    }
    for &f in data.features_flat() {
        buf.extend_from_slice(&f.to_le_bytes());
    }
    let payload_sum = fnv1a64(&buf[16..]);
    buf.extend_from_slice(&payload_sum.to_le_bytes());
    buf
}

fn decode_cache(bytes: &[u8], source_sum: u64) -> Option<Dataset> {
    if bytes.len() < 48 || &bytes[..8] != CACHE_MAGIC {
        return None;
    }
    let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
    if u64_at(8) != source_sum {
        return None; // stale: the source file changed
    }
    let payload = &bytes[16..bytes.len() - 8];
    if u64_at(bytes.len() - 8) != fnv1a64(payload) {
        return None;
    }
    let dim = u64_at(16) as usize;
    let classes = u64_at(24) as usize;
    let rows = u64_at(32) as usize;
    let need = rows
        .checked_mul(4)
        .and_then(|l| rows.checked_mul(dim)?.checked_mul(4)?.checked_add(l))
        .and_then(|p| p.checked_add(48));
    if dim == 0 || classes == 0 || need != Some(bytes.len()) {
        return None;
    }
    let mut data = Dataset::with_capacity(dim, classes, rows);
    let labels = &bytes[40..40 + rows * 4];
    let feats = &bytes[40 + rows * 4..bytes.len() - 8];
    for i in 0..rows {
        let label =
            u32::from_le_bytes(labels[i * 4..i * 4 + 4].try_into().unwrap()) as usize;
        if label >= classes {
            return None;
        }
        let row: Vec<f32> = feats[i * dim * 4..(i + 1) * dim * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        data.push(&row, label);
    }
    Some(data)
}

/// Load a libsvm-format file from disk. A missing file errors with the
/// resolved path and the dataset name (not a bare io error); a parse
/// error carries its line number; `expect_rows`/`expect_dim` mismatches
/// refuse the corpus. With `opts.cache`, a validated `<path>.cache`
/// skips the text parse (cache write failures are ignored — the parse
/// already succeeded).
pub fn load_libsvm(path: impl AsRef<Path>, opts: LibsvmOptions) -> crate::Result<Dataset> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).map_err(|e| {
        let resolved = std::fs::canonicalize(path).unwrap_or_else(|_| {
            std::env::current_dir()
                .map(|d| d.join(path))
                .unwrap_or_else(|_| path.to_path_buf())
        });
        anyhow::anyhow!(
            "libsvm dataset {:?}: cannot read {} — {e}",
            path.display().to_string(),
            resolved.display()
        )
    })?;
    let source_sum = fnv1a64(&bytes);
    let cache = cache_path(path);
    let data = if opts.cache {
        std::fs::read(&cache)
            .ok()
            .and_then(|c| decode_cache(&c, source_sum))
    } else {
        None
    };
    let (data, from_cache) = match data {
        Some(d) => (d, true),
        None => {
            let text = String::from_utf8_lossy(&bytes);
            let d = parse_libsvm(&text, opts.expect_dim)
                .map_err(|e| anyhow::anyhow!("libsvm dataset {}: {e}", path.display()))?;
            (d, false)
        }
    };
    if let Some(want) = opts.expect_rows {
        if data.len() != want {
            anyhow::bail!(
                "libsvm dataset {}: expected {want} rows, found {} — truncated or \
                 wrong file?",
                path.display(),
                data.len()
            );
        }
    }
    if let Some(want) = opts.expect_dim {
        if data.dim() != want {
            anyhow::bail!(
                "libsvm dataset {}: expected dimension {want}, found {}",
                path.display(),
                data.dim()
            );
        }
    }
    if opts.cache && !from_cache {
        // Best-effort: a read-only directory must not fail the load.
        let _ = std::fs::write(&cache, encode_cache(source_sum, &data));
    }
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = "\
# a comment
+1 1:0.5 3:1.5
-1 2:-2.0

+1 1:1.0 2:1.0 3:1.0
";

    #[test]
    fn parses_svm_style_labels_and_sparse_rows() {
        let d = parse_libsvm(TINY, None).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.dim(), 3);
        assert_eq!(d.classes(), 2);
        // -1 sorts before +1 → -1 is class 0.
        assert_eq!(d.labels(), &[1, 0, 1]);
        assert_eq!(d.sample(0).features, &[0.5, 0.0, 1.5]);
        assert_eq!(d.sample(1).features, &[0.0, -2.0, 0.0]);
    }

    #[test]
    fn multiclass_labels_remap_dense() {
        let d = parse_libsvm("3 1:1\n7 1:2\n3 1:3\n1 1:4\n", None).unwrap();
        assert_eq!(d.classes(), 3);
        assert_eq!(d.labels(), &[1, 2, 1, 0]);
    }

    #[test]
    fn single_class_corpus_still_has_two_classes() {
        // A degenerate one-label file must not produce classes=1 (the
        // objective layer assumes ≥ 2).
        let d = parse_libsvm("1 1:0.5\n1 2:0.5\n", None).unwrap();
        assert_eq!(d.classes(), 2);
        assert_eq!(d.labels(), &[0, 0]);
    }

    #[test]
    fn malformed_lines_error_with_line_numbers() {
        for (bad, needle) in [
            ("1 2:abc\n", "line 1"),
            ("1 1:0.5\nx 1:0.5\n", "line 2"),
            ("1.5 1:0.5\n", "integral"),
            ("nan 1:0.5\n", "label"),
            ("1 1:NaN\n", "finite"),
            ("1 1:inf\n", "finite"),
            ("1 0:0.5\n", "1-based"),
            ("1 2:0.5 2:0.7\n", "ascending"),
            ("1 3:0.5 2:0.7\n", "ascending"),
            ("1 nodim\n", "<index>:<value>"),
            ("", "no data rows"),
            ("# only a comment\n", "no data rows"),
        ] {
            let err = parse_libsvm(bad, None).unwrap_err();
            assert!(err.contains(needle), "{bad:?} → {err}");
        }
    }

    #[test]
    fn expected_dim_pads_and_bounds() {
        let d = parse_libsvm("1 1:1\n2 1:2\n", Some(5)).unwrap();
        assert_eq!(d.dim(), 5);
        assert_eq!(d.sample(0).features, &[1.0, 0.0, 0.0, 0.0, 0.0]);
        assert!(parse_libsvm("1 9:1\n", Some(5)).unwrap_err().contains("exceeds"));
    }

    #[test]
    fn load_round_trips_through_the_cache() {
        let dir = std::env::temp_dir().join(format!("dasgd-libsvm-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.svm");
        std::fs::write(&path, TINY).unwrap();
        let opts = LibsvmOptions {
            cache: true,
            ..Default::default()
        };
        let fresh = load_libsvm(&path, opts).unwrap();
        assert!(cache_path(&path).exists(), "cache file written");
        let cached = load_libsvm(&path, opts).unwrap();
        assert_eq!(fresh.labels(), cached.labels());
        assert_eq!(fresh.features_flat(), cached.features_flat());
        // A changed source invalidates the cache (no stale reuse).
        std::fs::write(&path, "1 1:9\n2 1:8\n").unwrap();
        let reparsed = load_libsvm(&path, opts).unwrap();
        assert_eq!(reparsed.len(), 2);
        assert_eq!(reparsed.sample(0).features, &[9.0]);
        // A corrupt cache is ignored, not trusted.
        std::fs::write(cache_path(&path), b"garbage").unwrap();
        let survived = load_libsvm(&path, opts).unwrap();
        assert_eq!(survived.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_file_names_the_path_and_dataset() {
        let err = load_libsvm("/definitely/not/here.svm", LibsvmOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("libsvm dataset"), "{err}");
        assert!(err.contains("/definitely/not/here.svm"), "{err}");
    }

    #[test]
    fn row_count_guard_catches_truncation() {
        let dir = std::env::temp_dir().join(format!("dasgd-libsvm-rows-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.svm");
        std::fs::write(&path, TINY).unwrap();
        let err = load_libsvm(
            &path,
            LibsvmOptions {
                expect_rows: Some(10),
                ..Default::default()
            },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("expected 10 rows"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
