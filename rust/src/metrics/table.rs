//! Fixed-width table printer for experiment/bench output.

/// A simple aligned-text table.
#[derive(Clone, Debug)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[f64], precision: usize) {
        self.row(
            &cells
                .iter()
                .map(|v| format!("{v:.precision$}"))
                .collect::<Vec<_>>(),
        );
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..cols {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>width$}", cells[i], width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (cols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["k", "value"]);
        t.row(&["10".into(), "1.5".into()]);
        t.rowf(&[20000.0, 0.125], 3);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("value"));
        assert!(lines[3].contains("20000.000"));
        // All rows same width.
        assert_eq!(lines[0].len(), lines[2].len().max(lines[0].len()));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
