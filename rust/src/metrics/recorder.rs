//! Training time-series recorder.

use std::path::Path;

use crate::util::csv::Schema;

/// One evaluation snapshot of a training run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Record {
    /// Paper iteration counter k (applied updates).
    pub k: u64,
    /// Wall-clock (or virtual, for the simulator) seconds since start.
    pub time_secs: f64,
    /// Consensus distance: the paper's d^k = Σ‖β_i − β̄‖ (§V-B) for
    /// engines that scan all parameters; simulations above
    /// [`crate::sim::EXACT_SCAN_MAX`] nodes record the incremental L2
    /// residual `sqrt(Σ‖β_i − β̄‖²)` instead (zero exactly at
    /// consensus; see `node_logic::ConsensusTracker`).
    pub consensus: f64,
    /// Held-out mean CE loss at β̄.
    pub test_loss: f64,
    /// Held-out prediction error at β̄ (§V-C).
    pub test_err: f64,
    /// Cumulative gradient steps / projection steps / messages / conflicts.
    pub grad_steps: u64,
    pub proj_steps: u64,
    pub messages: u64,
    pub conflicts: u64,
    /// Gradient-staleness quantiles in applied-update ticks, from the
    /// cluster-wide [`crate::obs`] aggregation (0 for engines that do
    /// not report them — the columns are append-only).
    pub staleness_p50: f64,
    pub staleness_p99: f64,
    /// Streaming staging high-water in bytes at snapshot time.
    pub staging_bytes: u64,
}

impl Record {
    /// Column names of [`Record::values`], in order — the append-only
    /// base of every run CSV (extensions like the compare dump's
    /// trailing `strategy` column go through [`run_schema`]`.with(..)`).
    pub const COLUMNS: [&'static str; 12] = [
        "k",
        "time_secs",
        "consensus",
        "test_loss",
        "test_err",
        "grad_steps",
        "proj_steps",
        "messages",
        "conflicts",
        "staleness_p50",
        "staleness_p99",
        "staging_bytes",
    ];

    /// The row values matching [`Record::COLUMNS`] position for position.
    pub fn values(&self) -> [f64; 12] {
        [
            self.k as f64,
            self.time_secs,
            self.consensus,
            self.test_loss,
            self.test_err,
            self.grad_steps as f64,
            self.proj_steps as f64,
            self.messages as f64,
            self.conflicts as f64,
            self.staleness_p50,
            self.staleness_p99,
            self.staging_bytes as f64,
        ]
    }
}

/// The canonical run time-series schema ([`Record::COLUMNS`]).
pub fn run_schema() -> Schema {
    Schema::new(&Record::COLUMNS)
}

/// A named series of [`Record`]s.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    pub name: String,
    pub records: Vec<Record>,
}

impl Recorder {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            records: Vec::new(),
        }
    }

    pub fn push(&mut self, r: Record) {
        self.records.push(r);
    }

    pub fn last(&self) -> Option<&Record> {
        self.records.last()
    }

    /// Final prediction error (Fig. 4's metric).
    pub fn final_err(&self) -> f64 {
        self.last().map(|r| r.test_err).unwrap_or(f64::NAN)
    }

    /// First k at which consensus dropped below `threshold` (Fig. 2's
    /// "below 10 after 10k updates" reading).
    pub fn k_to_consensus_below(&self, threshold: f64) -> Option<u64> {
        self.records
            .iter()
            .find(|r| r.consensus < threshold)
            .map(|r| r.k)
    }

    /// Dump as CSV (the canonical [`run_schema`]).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut w = run_schema().create(path)?;
        for r in &self.records {
            w.row(&r.values())?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(k: u64, consensus: f64, err: f64) -> Record {
        Record {
            k,
            consensus,
            test_err: err,
            ..Default::default()
        }
    }

    #[test]
    fn thresholds_and_final() {
        let mut r = Recorder::new("t");
        r.push(rec(0, 100.0, 0.9));
        r.push(rec(1000, 8.0, 0.5));
        r.push(rec(2000, 1.0, 0.3));
        assert_eq!(r.k_to_consensus_below(10.0), Some(1000));
        assert_eq!(r.k_to_consensus_below(0.5), None);
        assert!((r.final_err() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn csv_roundtrip_row_count() {
        let mut r = Recorder::new("t");
        r.push(rec(0, 1.0, 0.9));
        r.push(rec(1, 0.5, 0.8));
        let path = std::env::temp_dir().join("dasgd_rec_test.csv");
        r.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3); // header + 2 rows
        std::fs::remove_file(path).ok();
    }
}
