//! Metrics: training time-series recording, CSV export, table printing.

mod recorder;
mod table;

pub use recorder::{run_schema, Record, Recorder};
pub use table::Table;
