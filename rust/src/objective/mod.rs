//! First-class training objectives — the §II loss families as one
//! pluggable abstraction.
//!
//! The paper poses a *general* data-fitting problem over a networked
//! system; §II instantiates it with three convex loss families:
//! multinomial logistic regression, the binary SVM (hinge loss), and the
//! Lasso. [`Objective`] owns everything that differs between them —
//! parameter shape, label encoding, gradient-step semantics, evaluation
//! metrics, stable stepsizes, and PJRT artifact names — so the trainer,
//! the async runtime, the simulator, and every baseline run the *same*
//! select→step/project loop for all three (no per-objective forks).
//!
//! Classification datasets ([`crate::data::Dataset`]) carry integer class
//! labels; each objective defines its own reduction:
//!
//! * **LogReg** — labels used as-is (multi-class).
//! * **Hinge** — binary one-vs-rest split down the middle of the class
//!   range: `y = +1` if `label < classes/2`, else `−1` (balanced on the
//!   paper's 10-class synthetic mixture).
//! * **Lasso** — regression on the centered class index:
//!   `y = label − (classes−1)/2`.
//!
//! Adding a loss = adding a variant here plus a Pallas kernel under
//! `python/compile/kernels/` (see `docs/objectives.md`).

use crate::model::{hinge_step_native, lasso_step_native, LogReg};

/// Default regularization strength for the regularized families.
pub const DEFAULT_LAM: f32 = 1e-3;

/// One of the paper's §II loss families.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Objective {
    /// Multinomial logistic regression: W is row-major (dim × classes),
    /// mean cross-entropy loss, error = misclassification rate.
    LogReg,
    /// Binary SVM: `f(w) = (1/K)Σ max(0, 1 − y w·x) + λ‖w‖²`, w is
    /// (dim), error = sign-misclassification rate.
    Hinge {
        /// L2 regularization strength λ.
        lam: f32,
    },
    /// Lasso: `f(w) = (1/2K)Σ (w·x − y)² + λ‖w‖₁`, w is (dim),
    /// "error" column = RMSE of the prediction.
    Lasso {
        /// L1 regularization strength λ.
        lam: f32,
    },
}

impl Default for Objective {
    fn default() -> Self {
        Objective::LogReg
    }
}

impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl Objective {
    /// Hinge SVM with the default λ.
    pub fn hinge() -> Self {
        Objective::Hinge { lam: DEFAULT_LAM }
    }

    /// Lasso with the default λ.
    pub fn lasso() -> Self {
        Objective::Lasso { lam: DEFAULT_LAM }
    }

    /// All CLI-selectable names (used for usage strings / did-you-mean).
    pub const NAMES: [&'static str; 3] = ["logreg", "hinge", "lasso"];

    /// Parse a CLI name (`logreg`, `hinge`/`svm`, `lasso`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "logreg" => Some(Objective::LogReg),
            "hinge" | "svm" => Some(Objective::hinge()),
            "lasso" => Some(Objective::lasso()),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::LogReg => "logreg",
            Objective::Hinge { .. } => "hinge",
            Objective::Lasso { .. } => "lasso",
        }
    }

    /// Regularization strength, for the families that carry one (the
    /// PJRT step artifacts for those take λ as a trailing input).
    pub fn lam(&self) -> Option<f32> {
        match *self {
            Objective::LogReg => None,
            Objective::Hinge { lam } | Objective::Lasso { lam } => Some(lam),
        }
    }

    /// Length of the flat per-node parameter vector β_i.
    pub fn param_len(&self, dim: usize, classes: usize) -> usize {
        match self {
            Objective::LogReg => dim * classes,
            Objective::Hinge { .. } | Objective::Lasso { .. } => dim,
        }
    }

    /// Scalar target for one class label (hinge: ±1, lasso: centered
    /// class index). LogReg consumes labels directly and never calls this
    /// on its hot path; it returns the raw label for completeness.
    pub fn encode_label(&self, label: usize, classes: usize) -> f32 {
        match self {
            Objective::LogReg => label as f32,
            Objective::Hinge { .. } => {
                if 2 * label < classes {
                    1.0
                } else {
                    -1.0
                }
            }
            Objective::Lasso { .. } => label as f32 - (classes as f32 - 1.0) / 2.0,
        }
    }

    /// Encode a label slice into per-sample scalar targets.
    pub fn encode_targets(&self, labels: &[usize], classes: usize) -> Vec<f32> {
        labels
            .iter()
            .map(|&l| self.encode_label(l, classes))
            .collect()
    }

    /// The `y` input of the PJRT step artifact for a single sample:
    /// one-hot row for logreg, a 1-element encoded target otherwise.
    pub fn step_target(&self, label: usize, classes: usize) -> Vec<f32> {
        match self {
            Objective::LogReg => {
                let mut y = vec![0.0f32; classes];
                y[label] = 1.0;
                y
            }
            _ => vec![self.encode_label(label, classes)],
        }
    }

    /// Stage the non-tensor inputs of a batch-1 PJRT step call for one
    /// sample. The artifact input protocol — `[w, x, y, lr, scale]` plus
    /// a trailing `lam` for the regularized families — lives here so the
    /// trainer backend and the async runtime cannot drift apart.
    pub fn step_inputs(&self, label: usize, classes: usize, lr: f32, scale: f32) -> StepInputs {
        StepInputs {
            y: self.step_target(label, classes),
            lr: [lr],
            scale: [scale],
            lam: self.lam().map(|l| [l]),
        }
    }

    /// Batch-N sibling of [`Objective::step_inputs`]: stack the step
    /// targets of `labels` and scale the learning rate by the batch
    /// size. The batched artifacts take one mean-gradient step, so
    /// `B·lr` over a `B`-row minibatch matches `B` sequential steps at
    /// `lr` to first order in `lr` (the linear-scaling rule) — this is
    /// what the executor scheduler uses to collapse a backlogged node's
    /// owed gradient firings into one compiled call.
    pub fn step_inputs_batch(
        &self,
        labels: &[usize],
        classes: usize,
        lr: f32,
        scale: f32,
    ) -> StepInputs {
        let mut y = Vec::with_capacity(labels.len() * classes);
        for &label in labels {
            y.extend(self.step_target(label, classes));
        }
        StepInputs {
            y,
            lr: [lr * labels.len() as f32],
            scale: [scale],
            lam: self.lam().map(|l| [l]),
        }
    }

    /// One SGD/subgradient step on a flat row-major microbatch:
    /// `w ← w − lr·scale·∇f` in-place; returns the minibatch mean loss
    /// (regularized for hinge/lasso). Mirrors the Pallas step kernels
    /// exactly — the golden-vector suite pins this equivalence.
    #[allow(clippy::too_many_arguments)]
    pub fn native_step(
        &self,
        w: &mut Vec<f32>,
        xs: &[f32],
        labels: &[usize],
        dim: usize,
        classes: usize,
        lr: f32,
        scale: f32,
    ) -> f32 {
        let b = labels.len();
        assert_eq!(xs.len(), b * dim, "flat batch shape mismatch");
        assert_eq!(
            w.len(),
            self.param_len(dim, classes),
            "parameter length mismatch for {}",
            self.name()
        );
        let rows: Vec<&[f32]> = (0..b).map(|i| &xs[i * dim..(i + 1) * dim]).collect();
        match *self {
            Objective::LogReg => {
                let mut model = LogReg::from_weights(dim, classes, std::mem::take(w));
                let loss = model.sgd_step(&rows, labels, lr, scale);
                *w = model.w;
                loss
            }
            Objective::Hinge { lam } => {
                let ys = self.encode_targets(labels, classes);
                hinge_step_native(w, &rows, &ys, lr, scale, lam)
            }
            Objective::Lasso { lam } => {
                let ys = self.encode_targets(labels, classes);
                lasso_step_native(w, &rows, &ys, lr, scale, lam)
            }
        }
    }

    /// Evaluate `w` on a held-out flat batch: returns `(loss, err)`.
    ///
    /// `loss` is the objective's mean (regularized) loss; `err` is the
    /// objective's headline metric — misclassification rate for logreg
    /// and hinge, prediction RMSE for lasso. `targets` must hold the
    /// [`Objective::encode_targets`] encoding for hinge/lasso and may be
    /// empty for logreg.
    pub fn native_eval(
        &self,
        w: &[f32],
        dim: usize,
        classes: usize,
        features: &[f32],
        labels: &[usize],
        targets: &[f32],
    ) -> (f32, f32) {
        let n = labels.len();
        assert!(n > 0, "empty eval batch");
        assert_eq!(features.len(), n * dim);
        match *self {
            Objective::LogReg => {
                let model = LogReg::from_weights(dim, classes, w.to_vec());
                let e = model.evaluate(features, labels);
                (e.mean_loss(), e.error_rate())
            }
            Objective::Hinge { lam } => {
                assert_eq!(targets.len(), n, "hinge eval needs encoded targets");
                let mut loss = 0.0f32;
                let mut errs = 0usize;
                for (i, &y) in targets.iter().enumerate() {
                    let x = &features[i * dim..(i + 1) * dim];
                    let pred = crate::linalg::dot(w, x);
                    loss += (1.0 - y * pred).max(0.0);
                    if (pred > 0.0) != (y > 0.0) {
                        errs += 1;
                    }
                }
                loss = loss / n as f32 + lam * crate::linalg::dot(w, w);
                (loss, errs as f32 / n as f32)
            }
            Objective::Lasso { lam } => {
                assert_eq!(targets.len(), n, "lasso eval needs encoded targets");
                let mut sq = 0.0f32;
                for (i, &y) in targets.iter().enumerate() {
                    let x = &features[i * dim..(i + 1) * dim];
                    let r = crate::linalg::dot(w, x) - y;
                    sq += r * r;
                }
                let mse = sq / n as f32;
                let l1: f32 = w.iter().map(|v| v.abs()).sum();
                (0.5 * mse + lam * l1, mse.sqrt())
            }
        }
    }

    /// A stable diminishing stepsize for an N-node system.
    ///
    /// The kernel applies `lr·scale` with `scale = 1/N` (Eq. 6), so `a`
    /// folds N in to give an O(1) effective initial step. Hinge
    /// subgradients are bounded (‖g‖ ≲ ‖x‖), logreg's are softmax-bounded;
    /// the Lasso data term is quadratic with curvature λ_max(E[xxᵀ]) ≈
    /// Σ_d E[x_d²] (≈ 90 on the 50-feature synthetic world), so its
    /// stable effective step must sit well below 2/λ_max.
    pub fn default_stepsize(&self, n_nodes: usize) -> crate::coordinator::StepSize {
        use crate::coordinator::StepSize;
        let n = n_nodes as f32;
        match self {
            Objective::LogReg => StepSize::Poly {
                a: 1.2 * n,
                tau: 4000.0,
                pow: 0.75,
            },
            Objective::Hinge { .. } => StepSize::Poly {
                a: 0.4 * n,
                tau: 2000.0,
                pow: 0.75,
            },
            Objective::Lasso { .. } => StepSize::Poly {
                a: 0.02 * n,
                tau: 2000.0,
                pow: 0.75,
            },
        }
    }

    /// Name of the batch-1 PJRT step artifact for this objective.
    ///
    /// `family` is the artifact shape family tag (`"synth"` for 50
    /// features, `"notmnist"` for 256). The hinge/lasso kernels are
    /// compiled for the 50-feature synthetic shape only.
    pub fn pjrt_step_artifact(&self, family: &str) -> String {
        match self {
            Objective::LogReg => format!("logreg_step_{family}_b1"),
            Objective::Hinge { .. } => "hinge_step_b1".to_string(),
            Objective::Lasso { .. } => "lasso_step_b1".to_string(),
        }
    }

    /// Name of the batch-8 PJRT step artifact — the batched sibling of
    /// [`Objective::pjrt_step_artifact`] (same shape family, 8 feature
    /// rows per call; see `python/compile/aot.py`).
    pub fn pjrt_step_artifact_b8(&self, family: &str) -> String {
        match self {
            Objective::LogReg => format!("logreg_step_{family}_b8"),
            Objective::Hinge { .. } => "hinge_step_b8".to_string(),
            Objective::Lasso { .. } => "lasso_step_b8".to_string(),
        }
    }

    /// Name of the fixed-shape PJRT eval artifact. Every family has one:
    /// logreg per shape family, hinge/lasso in their single compiled
    /// shape (256 rows × 50 features, parameters (1, 50)).
    pub fn pjrt_eval_artifact(&self, family: &str) -> Option<String> {
        match self {
            Objective::LogReg => Some(format!("logreg_eval_{family}")),
            Objective::Hinge { .. } => Some("hinge_eval".to_string()),
            Objective::Lasso { .. } => Some("lasso_eval".to_string()),
        }
    }

    /// Name of the stacked-parameter gossip artifact matching this
    /// objective's parameter length: (16, dim·classes) stacks for
    /// logreg, the (16, 50) stack for the (dim)-shaped hinge/lasso
    /// parameters.
    pub fn pjrt_gossip_artifact(&self, family: &str) -> Option<String> {
        match self {
            Objective::LogReg => Some(format!("gossip_avg_{family}")),
            Objective::Hinge { .. } | Objective::Lasso { .. } => {
                Some("gossip_avg_dim50".to_string())
            }
        }
    }

    /// Turn the two scalar outputs of this objective's eval artifact
    /// into the `(loss, err)` pair [`Objective::native_eval`] reports.
    ///
    /// Every eval artifact returns `(loss_sum, err_sum)` over its fixed
    /// `n` rows; the error reduction is objective-defined — a count of
    /// misclassifications for logreg/hinge (mean = error rate), a sum
    /// of squared residuals for lasso (mean → RMSE).
    pub fn pjrt_eval_outputs(&self, loss_sum: f32, err_sum: f32, n: usize) -> (f32, f32) {
        let n = n as f32;
        match self {
            Objective::LogReg | Objective::Hinge { .. } => (loss_sum / n, err_sum / n),
            Objective::Lasso { .. } => (loss_sum / n, (err_sum / n).sqrt()),
        }
    }
}

/// Staged scalar/target inputs for a batch-1 PJRT step call (see
/// [`Objective::step_inputs`]). Owns the buffers so the borrow of the
/// parameter/feature slices stays with the caller.
pub struct StepInputs {
    y: Vec<f32>,
    lr: [f32; 1],
    scale: [f32; 1],
    lam: Option<[f32; 1]>,
}

impl StepInputs {
    /// Assemble the full artifact input list around `w` and `x`.
    pub fn buffers<'a>(&'a self, w: &'a [f32], x: &'a [f32]) -> Vec<&'a [f32]> {
        let mut inputs: Vec<&[f32]> = vec![w, x, &self.y, &self.lr, &self.scale];
        if let Some(lam) = &self.lam {
            inputs.push(lam);
        }
        inputs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_inputs_protocol() {
        // LogReg: 5 inputs, one-hot y, no lam.
        let s = Objective::LogReg.step_inputs(2, 4, 0.1, 0.5);
        let w = [0.0f32; 8];
        let x = [0.0f32; 2];
        let bufs = s.buffers(&w, &x);
        assert_eq!(bufs.len(), 5);
        assert_eq!(bufs[2], &[0.0, 0.0, 1.0, 0.0]);
        assert_eq!(bufs[3], &[0.1]);
        // Regularized families: 6 inputs with trailing lam.
        let s = Objective::hinge().step_inputs(3, 4, 0.1, 0.5);
        let bufs = s.buffers(&w, &x);
        assert_eq!(bufs.len(), 6);
        assert_eq!(bufs[2], &[-1.0]);
        assert_eq!(bufs[5], &[DEFAULT_LAM]);
    }

    #[test]
    fn parse_and_names() {
        assert_eq!(Objective::parse("logreg"), Some(Objective::LogReg));
        assert_eq!(Objective::parse("svm"), Some(Objective::hinge()));
        assert_eq!(Objective::parse("lasso"), Some(Objective::lasso()));
        assert_eq!(Objective::parse("ridge"), None);
        for name in Objective::NAMES {
            assert_eq!(Objective::parse(name).unwrap().name(), name);
        }
    }

    #[test]
    fn param_shapes() {
        assert_eq!(Objective::LogReg.param_len(50, 10), 500);
        assert_eq!(Objective::hinge().param_len(50, 10), 50);
        assert_eq!(Objective::lasso().param_len(50, 10), 50);
    }

    #[test]
    fn label_encodings() {
        let h = Objective::hinge();
        // 10 classes: 0..4 → +1, 5..9 → −1 (balanced one-vs-rest split).
        assert_eq!(h.encode_label(0, 10), 1.0);
        assert_eq!(h.encode_label(4, 10), 1.0);
        assert_eq!(h.encode_label(5, 10), -1.0);
        assert_eq!(h.encode_label(9, 10), -1.0);
        let l = Objective::lasso();
        // Centered class index: mean-zero targets.
        assert_eq!(l.encode_label(0, 10), -4.5);
        assert_eq!(l.encode_label(9, 10), 4.5);
        let sum: f32 = (0..10).map(|c| l.encode_label(c, 10)).sum();
        assert!(sum.abs() < 1e-6);
        // One-hot step target for logreg.
        assert_eq!(Objective::LogReg.step_target(2, 4), vec![0.0, 0.0, 1.0, 0.0]);
        assert_eq!(h.step_target(7, 10), vec![-1.0]);
    }

    #[test]
    fn native_step_matches_raw_functions() {
        let dim = 6;
        let xs: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.37).sin()).collect();
        let labels = [1usize];
        for (obj, classes) in [(Objective::hinge(), 2), (Objective::lasso(), 4)] {
            let mut w_obj = vec![0.1f32; dim];
            let mut w_raw = w_obj.clone();
            let loss_obj = obj.native_step(&mut w_obj, &xs, &labels, dim, classes, 0.3, 0.5);
            let y = obj.encode_label(labels[0], classes);
            let loss_raw = match obj {
                Objective::Hinge { lam } => {
                    hinge_step_native(&mut w_raw, &[&xs], &[y], 0.3, 0.5, lam)
                }
                Objective::Lasso { lam } => {
                    lasso_step_native(&mut w_raw, &[&xs], &[y], 0.3, 0.5, lam)
                }
                Objective::LogReg => unreachable!(),
            };
            assert_eq!(w_obj, w_raw, "{obj}");
            assert_eq!(loss_obj, loss_raw, "{obj}");
        }
    }

    #[test]
    fn native_eval_zero_weights() {
        // w = 0: hinge loss = 1 (margin 0), lasso RMSE = rms(targets).
        let dim = 3;
        let features = vec![1.0f32; 2 * dim];
        let labels = [0usize, 1];
        let h = Objective::hinge();
        let ht = h.encode_targets(&labels, 2);
        let (hl, he) = h.native_eval(&[0.0; 3], dim, 2, &features, &labels, &ht);
        assert!((hl - 1.0).abs() < 1e-6);
        // pred = 0 → predicted −1 → the +1 sample is wrong, the −1 right.
        assert!((he - 0.5).abs() < 1e-6);
        let l = Objective::lasso();
        let lt = l.encode_targets(&labels, 2); // [−0.5, +0.5]
        let (_, rmse) = l.native_eval(&[0.0; 3], dim, 2, &features, &labels, &lt);
        assert!((rmse - 0.5).abs() < 1e-6);
    }

    #[test]
    fn stepsizes_decrease_and_scale_with_n() {
        for obj in [Objective::LogReg, Objective::hinge(), Objective::lasso()] {
            let s = obj.default_stepsize(30);
            assert!(s.at(10_000) < s.at(0), "{obj}");
            let s1 = obj.default_stepsize(1);
            // a folds N: 30-node initial step is 30x the 1-node one.
            assert!((s.at(0) / s1.at(0) - 30.0).abs() < 1e-3);
        }
        // Lasso's effective step respects the curvature bound.
        let lasso = Objective::lasso().default_stepsize(30);
        assert!(lasso.at(0) / 30.0 < 0.03);
    }

    #[test]
    fn artifact_names() {
        assert_eq!(
            Objective::LogReg.pjrt_step_artifact("synth"),
            "logreg_step_synth_b1"
        );
        assert_eq!(Objective::hinge().pjrt_step_artifact("synth"), "hinge_step_b1");
        assert_eq!(
            Objective::LogReg.pjrt_eval_artifact("notmnist").as_deref(),
            Some("logreg_eval_notmnist")
        );
        assert_eq!(
            Objective::lasso().pjrt_eval_artifact("synth").as_deref(),
            Some("lasso_eval")
        );
        assert_eq!(
            Objective::hinge().pjrt_eval_artifact("synth").as_deref(),
            Some("hinge_eval")
        );
        assert_eq!(
            Objective::lasso().pjrt_gossip_artifact("synth").as_deref(),
            Some("gossip_avg_dim50")
        );
    }

    #[test]
    fn pjrt_eval_output_reduction() {
        // logreg/hinge: (mean loss, error rate); lasso: (mean loss, RMSE).
        let (l, e) = Objective::hinge().pjrt_eval_outputs(128.0, 64.0, 256);
        assert!((l - 0.5).abs() < 1e-6);
        assert!((e - 0.25).abs() < 1e-6);
        let (_, rmse) = Objective::lasso().pjrt_eval_outputs(10.0, 4.0, 4);
        assert!((rmse - 1.0).abs() < 1e-6);
    }
}
