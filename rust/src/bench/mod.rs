//! benchlib: a minimal criterion replacement (warmup + adaptive
//! iteration count + summary statistics), since `criterion` does not
//! resolve offline. Used by every `cargo bench` target.

use crate::util::stats;
use crate::util::Stopwatch;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_secs: f64,
    pub median_secs: f64,
    pub p99_secs: f64,
    pub std_secs: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_secs
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  median {:>12}  p99 {:>12}  ±{:>10}",
            self.name,
            self.iters,
            fmt_secs(self.mean_secs),
            fmt_secs(self.median_secs),
            fmt_secs(self.p99_secs),
            fmt_secs(self.std_secs),
        )
    }
}

/// Pretty-print a duration in adaptive units.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Benchmark `f`, auto-scaling the iteration count to fill
/// `target_secs` of measurement after `warmup_secs` of warmup.
pub fn bench(name: &str, warmup_secs: f64, target_secs: f64, mut f: impl FnMut()) -> BenchResult {
    // Warmup + rate estimation.
    let sw = Stopwatch::new();
    let mut warm_iters = 0u64;
    while sw.elapsed_secs() < warmup_secs || warm_iters == 0 {
        f();
        warm_iters += 1;
    }
    let per_iter = sw.elapsed_secs() / warm_iters as f64;
    // Sample in batches so timer overhead stays negligible for fast fns.
    let samples_target = 50usize;
    let batch = ((target_secs / samples_target as f64) / per_iter).ceil().max(1.0) as u64;
    let mut samples = Vec::with_capacity(samples_target);
    let total = Stopwatch::new();
    let mut iters = 0u64;
    while total.elapsed_secs() < target_secs || samples.len() < 5 {
        let sw = Stopwatch::new();
        for _ in 0..batch {
            f();
        }
        samples.push(sw.elapsed_secs() / batch as f64);
        iters += batch;
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_secs: stats::mean(&samples),
        median_secs: stats::percentile(&samples, 50.0),
        p99_secs: stats::percentile(&samples, 99.0),
        std_secs: stats::std_dev(&samples),
    }
}

/// Bench-target harness: prints a header and runs the cases.
pub struct Harness {
    title: String,
    results: Vec<BenchResult>,
}

impl Harness {
    pub fn new(title: &str) -> Self {
        println!("\n=== {title} ===");
        Self {
            title: title.to_string(),
            results: Vec::new(),
        }
    }

    pub fn case(&mut self, name: &str, f: impl FnMut()) -> &BenchResult {
        let r = bench(name, 0.2, 1.0, f);
        println!("{}", r.report_line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn quick_case(&mut self, name: &str, f: impl FnMut()) -> &BenchResult {
        let r = bench(name, 0.05, 0.3, f);
        println!("{}", r.report_line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn title(&self) -> &str {
        &self.title
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleeps_roughly() {
        let r = bench("sleep", 0.01, 0.15, || {
            std::thread::sleep(std::time::Duration::from_micros(300));
        });
        assert!(
            r.mean_secs > 200e-6 && r.mean_secs < 3e-3,
            "mean={}",
            r.mean_secs
        );
        assert!(r.iters >= 5);
        assert!(r.median_secs > 0.0 && r.p99_secs >= r.median_secs);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
