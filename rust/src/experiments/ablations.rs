//! §IV ablations:
//!
//! * **Communication overhead (§IV-B)** — sweep the gradient-step
//!   probability p_grad: fewer projections = fewer messages but slower
//!   consensus. The paper states the trade-off; we measure it.
//! * **Update conflicts (§IV-C)** — distributed geometric selection at
//!   increasing firing rates: conflict frequency, and lock-up vs
//!   ignore-conflicts accuracy.
//! * **Topology families** (extension) — consensus speed across ring /
//!   random-regular / two-cluster / complete at 30 nodes.

use anyhow::Result;

use crate::coordinator::{
    ConflictPolicy, NativeBackend, SelectionMode, TrainConfig, Trainer,
};
use crate::graph::{self, Graph};
use crate::metrics::Table;

use super::{make_regular, scaled, synth_world};

// ---------------------------------------------------------------------------
// §IV-B: communication vs consensus
// ---------------------------------------------------------------------------

pub struct CommRow {
    pub p_grad: f64,
    pub messages: u64,
    pub final_consensus: f64,
    pub final_err: f64,
}

pub fn comm_overhead(scale: f64, seed: u64) -> Result<Vec<CommRow>> {
    let n = 30;
    let iters = scaled(10_000, scale, 500);
    let mut rows = Vec::new();
    for &p in &[0.1, 0.3, 0.5, 0.7, 0.9] {
        let (shards, test) = synth_world(n, 200, 256, seed);
        let cfg = TrainConfig::paper_default(n)
            .with_p_grad(p)
            .with_init_scale(0.5)
            .with_seed(seed ^ (p * 100.0) as u64);
        let mut t = Trainer::new(cfg, make_regular(n, 4), shards, NativeBackend::new(50, 10));
        let rec = t.run(iters, iters, &test, "comm")?;
        rows.push(CommRow {
            p_grad: p,
            messages: t.counters.messages,
            final_consensus: rec.last().unwrap().consensus,
            final_err: rec.final_err(),
        });
    }
    Ok(rows)
}

pub fn comm_table(rows: &[CommRow]) -> Table {
    let mut t = Table::new(&["p_grad", "messages", "final d^k", "final err"]);
    for r in rows {
        t.row(&[
            format!("{:.1}", r.p_grad),
            format!("{}", r.messages),
            format!("{:.3}", r.final_consensus),
            format!("{:.3}", r.final_err),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// §IV-C: conflicts
// ---------------------------------------------------------------------------

pub struct ConflictRow {
    pub rate: f64,
    pub policy: &'static str,
    pub conflicts: u64,
    pub aborted: u64,
    pub messages: u64,
    pub final_err: f64,
}

pub fn conflicts(scale: f64, seed: u64) -> Result<Vec<ConflictRow>> {
    let n = 20;
    let iters = scaled(6_000, scale, 400);
    let mut rows = Vec::new();
    for &rate in &[0.02, 0.1, 0.3] {
        for (policy, name) in [
            (ConflictPolicy::LockUp, "lock-up"),
            (ConflictPolicy::Ignore, "ignore"),
        ] {
            let (shards, test) = synth_world(n, 200, 256, seed);
            let cfg = TrainConfig {
                selection: SelectionMode::DistributedGeometric { p: rate },
                conflicts: policy,
                ..TrainConfig::paper_default(n)
            }
            .with_seed(seed ^ (rate * 1000.0) as u64);
            let mut t =
                Trainer::new(cfg, make_regular(n, 4), shards, NativeBackend::new(50, 10));
            let rec = t.run(iters, iters, &test, "conflict")?;
            rows.push(ConflictRow {
                rate,
                policy: name,
                conflicts: t.counters.conflicts,
                aborted: t.counters.aborted,
                messages: t.counters.messages,
                final_err: rec.final_err(),
            });
        }
    }
    Ok(rows)
}

pub fn conflict_table(rows: &[ConflictRow]) -> Table {
    let mut t = Table::new(&[
        "fire rate",
        "policy",
        "conflicts",
        "aborted",
        "messages",
        "final err",
    ]);
    for r in rows {
        t.row(&[
            format!("{:.2}", r.rate),
            r.policy.into(),
            format!("{}", r.conflicts),
            format!("{}", r.aborted),
            format!("{}", r.messages),
            format!("{:.3}", r.final_err),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Topology families (extension)
// ---------------------------------------------------------------------------

pub struct TopologyRow {
    pub name: String,
    pub edges: usize,
    pub diameter: usize,
    pub final_consensus: f64,
    pub final_err: f64,
}

pub fn topologies(scale: f64, seed: u64) -> Result<Vec<TopologyRow>> {
    let n = 30;
    let iters = scaled(10_000, scale, 500);
    let mut rng = crate::util::rng::Xoshiro256pp::seeded(seed);
    let families: Vec<(String, Graph)> = vec![
        ("ring (2-regular)".into(), graph::ring(n)),
        ("4-regular circulant".into(), make_regular(n, 4)),
        (
            "4-regular random".into(),
            graph::random_regular(n, 4, &mut rng),
        ),
        ("two clusters + bridge".into(), graph::two_clusters(n / 2)),
        ("complete".into(), graph::complete(n)),
    ];
    let mut rows = Vec::new();
    for (name, g) in families {
        let (shards, test) = synth_world(n, 200, 256, seed);
        let cfg = TrainConfig::paper_default(n)
            .with_init_scale(0.5)
            .with_seed(seed ^ name.len() as u64);
        let edges = g.edge_count();
        let diameter = g.diameter().unwrap_or(0);
        let mut t = Trainer::new(cfg, g, shards, NativeBackend::new(50, 10));
        let rec = t.run(iters, iters, &test, &name)?;
        rows.push(TopologyRow {
            name,
            edges,
            diameter,
            final_consensus: rec.last().unwrap().consensus,
            final_err: rec.final_err(),
        });
    }
    Ok(rows)
}

pub fn topology_table(rows: &[TopologyRow]) -> Table {
    let mut t = Table::new(&["topology", "edges", "diameter", "final d^k", "final err"]);
    for r in rows {
        t.row(&[
            r.name.clone(),
            format!("{}", r.edges),
            format!("{}", r.diameter),
            format!("{:.3}", r.final_consensus),
            format!("{:.3}", r.final_err),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_overhead_tradeoff() {
        let rows = comm_overhead(0.08, 3).unwrap();
        assert_eq!(rows.len(), 5);
        // More gradient steps (higher p_grad) ⇒ fewer messages.
        assert!(rows.first().unwrap().messages > rows.last().unwrap().messages);
    }

    #[test]
    fn conflict_rates_grow_with_fire_rate() {
        let rows = conflicts(0.1, 5).unwrap();
        let lockup: Vec<&ConflictRow> =
            rows.iter().filter(|r| r.policy == "lock-up").collect();
        assert!(lockup.last().unwrap().conflicts >= lockup.first().unwrap().conflicts);
        // Ignore policy never aborts.
        assert!(rows
            .iter()
            .filter(|r| r.policy == "ignore")
            .all(|r| r.aborted == 0));
    }

    #[test]
    fn topology_families_run() {
        let rows = topologies(0.05, 7).unwrap();
        assert_eq!(rows.len(), 5);
        // Complete graph has diameter 1 and the tightest consensus.
        let complete = rows.iter().find(|r| r.name == "complete").unwrap();
        assert_eq!(complete.diameter, 1);
    }
}
