//! Straggler extension — the intro's motivating claim, quantified: under
//! heterogeneous node speeds, barrier-based schemes (sync DSGD, the
//! server–worker structure) pay the slowest node every round, while
//! Alg. 2's asynchronous updates only slow the straggler itself.
//!
//! All three run in *virtual time* (see [`crate::sim`]) at equal
//! horizons; we report final error and effective update counts.

use anyhow::Result;

use crate::baselines::{server_worker, sync_dsgd, ServerWorkerConfig, SyncDsgdConfig};
use crate::coordinator::StepSize;
use crate::metrics::Table;
use crate::objective::Objective;
use crate::sim::{sync_round_time, virtual_async_run, SpeedModel, VirtualAsyncConfig};
use crate::util::rng::Xoshiro256pp;

use super::{make_regular, synth_world};

pub struct StragglerRow {
    pub straggle_factor: f64,
    pub scheme: &'static str,
    pub updates: u64,
    pub final_err: f64,
}

/// Compare the three schemes at increasing straggler severity.
pub fn run(scale: f64, seed: u64) -> Result<Vec<StragglerRow>> {
    let n = 10;
    let horizon = 400.0 * scale.max(0.05);
    let g = make_regular(n, 4);
    let mut rows = Vec::new();
    for &factor in &[1.0, 5.0, 20.0] {
        let speeds = SpeedModel::with_stragglers(n, 1.0, 1, factor);
        let (shards, test) = synth_world(n, 200, 300, seed);

        // Asynchronous Alg. 2 (virtual clock).
        let cfg = VirtualAsyncConfig {
            p_grad: 0.5,
            stepsize: StepSize::Poly {
                a: 1.2 * n as f32,
                tau: 4000.0,
                pow: 0.75,
            },
            objective: Objective::LogReg,
            horizon,
            eval_every: horizon / 4.0,
            comm_latency: 0.05,
            seed,
        };
        let rep = virtual_async_run(&g, &shards, &test, &speeds, &cfg);
        rows.push(StragglerRow {
            straggle_factor: factor,
            scheme: "async (Alg. 2)",
            updates: rep.updates,
            final_err: rep.recorder.last().unwrap().test_err,
        });

        // Sync DSGD: rounds until the virtual clock hits the horizon.
        let mut rng = Xoshiro256pp::seeded(seed ^ 0x55);
        let mut vt = 0.0;
        let mut rounds = 0u64;
        while vt < horizon {
            vt += sync_round_time(&speeds.sample_all(&mut rng), 0.05);
            rounds += 1;
        }
        let cfg = SyncDsgdConfig {
            stepsize: StepSize::Poly {
                a: 8.0,
                tau: 3000.0,
                pow: 0.75,
            },
            objective: Objective::LogReg,
            rounds,
            eval_every: rounds.max(1),
            seed,
        };
        let rep = sync_dsgd(&g, &shards, &test, &cfg);
        rows.push(StragglerRow {
            straggle_factor: factor,
            scheme: "sync DSGD",
            updates: rep.grad_steps,
            final_err: rep.recorder.last().unwrap().test_err,
        });

        // Server–worker, dropping 10% slowest per round.
        let mut rng = Xoshiro256pp::seeded(seed ^ 0x77);
        let worker_speed: Vec<f64> = (0..n).map(|i| speeds.mean(i)).collect();
        // Round time estimation for the same horizon (kept workers only).
        let keep = ((n as f64) * 0.9).ceil() as usize;
        let mut vt = 0.0;
        let mut rounds = 0u64;
        while vt < horizon {
            let mut times: Vec<f64> = (0..n)
                .map(|i| worker_speed[i] * rng.exponential(1.0))
                .collect();
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vt += times[keep - 1] + 0.05;
            rounds += 1;
        }
        let cfg = ServerWorkerConfig {
            stepsize: StepSize::Poly {
                a: 1.0,
                tau: 3000.0,
                pow: 0.75,
            },
            objective: Objective::LogReg,
            rounds,
            eval_every: rounds.max(1),
            drop_frac: 0.1,
            worker_speed,
            seed,
        };
        let rep = server_worker(&shards, &test, &cfg);
        rows.push(StragglerRow {
            straggle_factor: factor,
            scheme: "server-worker (drop 10%)",
            updates: rounds * keep as u64,
            final_err: rep.recorder.last().unwrap().test_err,
        });
    }
    Ok(rows)
}

pub fn table(rows: &[StragglerRow]) -> Table {
    let mut t = Table::new(&["straggle x", "scheme", "updates", "final err"]);
    for r in rows {
        t.row(&[
            format!("{:.0}", r.straggle_factor),
            r.scheme.into(),
            format!("{}", r.updates),
            format!("{:.3}", r.final_err),
        ]);
    }
    t
}

/// Shape check: as stragglers worsen, async update throughput degrades
/// less than sync DSGD's.
pub fn check_shape(rows: &[StragglerRow]) -> Vec<String> {
    let mut notes = Vec::new();
    let updates = |scheme: &str, factor: f64| {
        rows.iter()
            .find(|r| r.scheme.starts_with(scheme) && r.straggle_factor == factor)
            .map(|r| r.updates as f64)
            .unwrap_or(f64::NAN)
    };
    let async_drop = updates("async", 20.0) / updates("async", 1.0);
    let sync_drop = updates("sync", 20.0) / updates("sync", 1.0);
    notes.push(format!(
        "throughput retained at 20x straggler: async {:.0}%, sync {:.0}%",
        async_drop * 100.0,
        sync_drop * 100.0
    ));
    if async_drop > sync_drop {
        notes.push("OK: async retains more throughput under stragglers".into());
    } else {
        notes.push("MISMATCH: async should degrade less than sync".into());
    }
    notes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straggler_comparison_favors_async() {
        let rows = run(0.25, 3).unwrap();
        assert_eq!(rows.len(), 9);
        let notes = check_shape(&rows);
        assert!(
            notes.iter().all(|n| !n.starts_with("MISMATCH")),
            "{notes:?}"
        );
    }
}
