//! Heterogeneity sweep: consensus and prediction error as per-node
//! data skew rises (label-skew Dirichlet α falling from near-IID to
//! pathological), plus a mixed hinge/Lasso cohort — the workload class
//! the paper's "very large and heterogeneous system" framing promises.
//!
//! Every run is the same Alg. 2 event-driven simulation on the same
//! topology and virtual-time budget; only the [`WorkloadPlan`] changes.
//! Falling α concentrates each class on fewer nodes, so local gradients
//! point in increasingly different directions and the projection steps
//! have to carry more of the work: consensus error at a fixed budget
//! degrades gracefully rather than collapsing, which is the claim worth
//! quantifying.

use crate::experiments::make_regular;
use crate::metrics::Table;
use crate::objective::Objective;
use crate::sim::{simnet_run_plan, SimConfig, SpeedModel};
use crate::transport::SimNetConfig;
use crate::workload::PlanSpec;

/// One sweep point.
#[derive(Clone, Debug)]
pub struct HetRow {
    /// Human label for the plan ("dirichlet α=0.1", "mixed α=0.1", …).
    pub label: String,
    pub updates: u64,
    pub proj_steps: u64,
    /// Final d^k consensus distance.
    pub consensus: f64,
    /// Final headline metric of the mean parameter (mixed cohorts use
    /// the weighted per-family convention).
    pub test_err: f64,
}

/// Run the sweep. `scale` shrinks the virtual-time budget; seeds are
/// shared across points so only the workload differs.
pub fn run(scale: f64, seed: u64) -> crate::Result<Vec<HetRow>> {
    let n = 24;
    let degree = 4;
    let horizon = (120.0 * scale).max(20.0);
    let specs: Vec<(String, PlanSpec)> = vec![
        ("near-iid (α=100)".into(), PlanSpec::Dirichlet { alpha: 100.0 }),
        ("dirichlet α=1".into(), PlanSpec::Dirichlet { alpha: 1.0 }),
        ("dirichlet α=0.1".into(), PlanSpec::Dirichlet { alpha: 0.1 }),
        ("dirichlet α=0.01".into(), PlanSpec::Dirichlet { alpha: 0.01 }),
        ("quantity α=0.3".into(), PlanSpec::Quantity { alpha: 0.3 }),
        ("feature-shift σ=1".into(), PlanSpec::FeatureShift { sigma: 1.0 }),
        ("mixed hinge+lasso α=0.1".into(), PlanSpec::Mixed { alpha: 0.1 }),
    ];
    let g = make_regular(n, degree);
    let speeds = SpeedModel::homogeneous(n, 1.0);
    let mut rows = Vec::with_capacity(specs.len());
    for (label, spec) in specs {
        let (plan, test) = spec.build(Objective::LogReg, n, 40, 512, seed);
        let cfg = SimConfig {
            p_grad: 0.5,
            stepsize: Objective::LogReg.default_stepsize(n),
            objective: Objective::LogReg,
            horizon,
            eval_every: horizon / 4.0,
            net: SimNetConfig::ideal(0.002),
            seed,
        };
        let rep = simnet_run_plan(&g, &plan, &test, &speeds, &cfg);
        let last = rep.recorder.last().expect("simulation recorded snapshots");
        rows.push(HetRow {
            label,
            updates: rep.updates,
            proj_steps: rep.proj_steps,
            consensus: last.consensus,
            test_err: last.test_err,
        });
    }
    Ok(rows)
}

/// Render the sweep as a table.
pub fn table(rows: &[HetRow]) -> Table {
    let mut t = Table::new(&["plan", "updates", "proj", "d^k", "test err"]);
    for r in rows {
        t.row(&[
            r.label.clone(),
            format!("{}", r.updates),
            format!("{}", r.proj_steps),
            format!("{:.3}", r.consensus),
            format!("{:.3}", r.test_err),
        ]);
    }
    t
}

/// Dump the sweep as CSV. The column order is an append-only
/// [`Schema`](crate::util::csv::Schema) — extensions go at the end,
/// exactly like the run time-series and the compare dump.
pub fn write_csv(rows: &[HetRow], path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
    let schema = crate::util::csv::Schema::new(&[
        "plan",
        "updates",
        "proj_steps",
        "consensus",
        "test_err",
    ]);
    let mut w = schema.create(path)?;
    for r in rows {
        w.row_str(&[
            r.label.clone(),
            format!("{}", r.updates),
            format!("{}", r.proj_steps),
            format!("{}", r.consensus),
            format!("{}", r.test_err),
        ])?;
    }
    w.flush()
}

/// Shape notes: rising skew should not stall the run, and the near-IID
/// point should be at least as easy as the pathological one.
pub fn check_shape(rows: &[HetRow]) -> Vec<String> {
    let mut notes = Vec::new();
    if rows.iter().any(|r| r.proj_steps == 0) {
        notes.push("MISMATCH: some plan completed no projections".into());
    }
    if let (Some(iid), Some(worst)) = (rows.first(), rows.iter().find(|r| r.label.contains("0.01")))
    {
        if iid.test_err <= worst.test_err + 0.15 {
            notes.push(format!(
                "near-iid err {:.3} ≤ extreme-skew err {:.3} (+0.15 slack) — expected ordering",
                iid.test_err, worst.test_err
            ));
        } else {
            notes.push(format!(
                "MISMATCH: near-iid err {:.3} much worse than extreme skew {:.3}",
                iid.test_err, worst.test_err
            ));
        }
    }
    notes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_runs_at_tiny_scale() {
        let rows = run(0.05, 3).unwrap();
        assert_eq!(rows.len(), 7);
        for r in &rows {
            assert!(r.updates > 0, "{}: no updates", r.label);
            assert!(r.consensus.is_finite() && r.test_err.is_finite(), "{}", r.label);
        }
        // Table renders without panicking.
        let _ = table(&rows).render();
        let _ = check_shape(&rows);
    }
}
