//! One module per paper figure plus the §IV ablations and the straggler
//! extension. Each experiment exposes a `run(scale)` entry returning a
//! printable result, shared by the `cargo bench` targets and the CLI
//! (`dasgd fig2`, …). `scale` shrinks iteration counts for quick runs
//! (scale = 1.0 reproduces the paper's budgets).

pub mod ablations;
pub mod compare;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig6;
pub mod heterogeneity;
pub mod lemma1;
pub mod losses;
pub mod straggler;

use crate::coordinator::{
    Backend, EvalBatch, NativeBackend, PjrtBackend, StepBackend, TrainConfig, Trainer,
};
use crate::data::{Dataset, SyntheticGen};
use crate::graph::{regular_circulant, Graph};
use crate::metrics::Recorder;
use crate::util::rng::Xoshiro256pp;

/// Iteration budget helper: paper budget × scale, at least `min`.
pub fn scaled(paper: u64, scale: f64, min: u64) -> u64 {
    ((paper as f64 * scale) as u64).max(min)
}

/// A k-regular (or nearest feasible) graph on n nodes.
///
/// The circulant construction needs even n for odd k; when (n odd, k odd)
/// we use k−1 — the nearest feasible regular degree — and note it.
pub fn make_regular(n: usize, k: usize) -> Graph {
    let k = k.min(n - 1);
    let k = if k % 2 == 1 && n % 2 == 1 { k - 1 } else { k };
    let k = k.max(2).min(n - 1);
    regular_circulant(n, k)
}

/// Build the §V-A synthetic world: per-node shards + global test set.
pub fn synth_world(
    n: usize,
    samples_per_node: usize,
    test_n: usize,
    seed: u64,
) -> (Vec<Dataset>, Dataset) {
    let gen = SyntheticGen::paper_default(n, seed);
    let mut rng = Xoshiro256pp::seeded(seed ^ 0xDA7A);
    let shards = (0..n)
        .map(|i| gen.node_dataset(i, samples_per_node, &mut rng))
        .collect();
    let test = gen.global_test_set(test_n, &mut rng);
    (shards, test)
}

/// Which compute path an experiment runs on (native is the default for
/// the figure sweeps; PJRT is the production path exercised by
/// examples + benches).
pub fn backend_from_env() -> Backend {
    match std::env::var("DASGD_BACKEND").as_deref() {
        Ok("pjrt") => Backend::Pjrt,
        _ => Backend::Native,
    }
}

/// Run Alg. 2 on a prepared world with either backend, optimizing
/// `cfg.objective` (the backend is constructed for that loss family —
/// the trainer code path is identical for all of them).
pub fn run_alg2(
    cfg: &TrainConfig,
    graph: Graph,
    shards: Vec<Dataset>,
    test: &Dataset,
    iters: u64,
    eval_every: u64,
    name: &str,
) -> anyhow::Result<Recorder> {
    let dim = shards[0].dim();
    let classes = shards[0].classes();
    match cfg.backend {
        Backend::Native => {
            let mut t = Trainer::new(
                cfg.clone(),
                graph,
                shards,
                NativeBackend::for_objective(cfg.objective, dim, classes),
            );
            t.run(iters, eval_every, test, name)
        }
        Backend::Pjrt => {
            let family = if dim == 50 { "synth" } else { "notmnist" };
            let arts =
                crate::coordinator::PjrtArtifacts::for_objective(cfg.objective, family);
            let engine = crate::runtime::Engine::load_default()?;
            let backend = PjrtBackend::new(engine, arts, dim, classes)?;
            let mut t = Trainer::new(cfg.clone(), graph, shards, backend);
            t.run(iters, eval_every, test, name)
        }
    }
}

/// Cross-check helper used by tests: run the same seeded experiment on
/// both backends and return the two recorders.
pub fn run_both_backends(
    n: usize,
    k: usize,
    iters: u64,
    seed: u64,
) -> anyhow::Result<(Recorder, Recorder)> {
    let (shards, test) = synth_world(n, 60, 256, seed);
    let base = TrainConfig::paper_default(n).with_seed(seed);
    let native = run_alg2(
        &base.clone().with_backend(Backend::Native),
        make_regular(n, k),
        shards.clone(),
        &test,
        iters,
        iters,
        "native",
    )?;
    let pjrt = run_alg2(
        &base.with_backend(Backend::Pjrt),
        make_regular(n, k),
        shards,
        &test,
        iters,
        iters,
        "pjrt",
    )?;
    Ok((native, pjrt))
}

/// Evaluate a mean parameter vector on a test set with the native math
/// of `obj` (metric helper shared by experiments and examples).
pub fn native_eval(obj: crate::objective::Objective, w: &[f32], test: &Dataset) -> (f32, f32) {
    let batch = EvalBatch::for_objective(obj, test, None);
    let mut nb = NativeBackend::for_objective(obj, test.dim(), test.classes());
    nb.evaluate(w, &batch)
        .expect("native evaluation is infallible")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn make_regular_feasible_everywhere() {
        for n in 10..=31 {
            for k in [2, 4, 9, 10, 15] {
                if k < n {
                    let g = make_regular(n, k);
                    assert!(g.is_connected(), "n={n} k={k}");
                    assert!(g.is_regular().is_some(), "n={n} k={k}");
                }
            }
        }
    }

    #[test]
    fn scaled_budgets() {
        assert_eq!(scaled(10_000, 1.0, 100), 10_000);
        assert_eq!(scaled(10_000, 0.01, 500), 500);
    }

    #[test]
    fn synth_world_shapes() {
        let (shards, test) = synth_world(5, 20, 100, 3);
        assert_eq!(shards.len(), 5);
        assert_eq!(shards[0].dim(), 50);
        assert_eq!(test.len(), 100);
    }
}
