//! Fig. 4 — "With more nodes joining": final prediction error vs network
//! size N ∈ {10..30}, for per-node degree 4 and 10, 500 samples/node.
//!
//! Paper reading: error trends down as N grows (more data in the
//! system), with noise from the stochastic algorithm, and the advantage
//! of better connectivity grows with N.

use anyhow::Result;

use crate::coordinator::TrainConfig;
use crate::metrics::Table;

use super::{make_regular, run_alg2, scaled, synth_world};

pub struct Fig4Point {
    pub n: usize,
    pub degree: usize,
    pub final_err: f64,
}

pub struct Fig4Result {
    pub points: Vec<Fig4Point>,
    pub iters: u64,
}

impl Fig4Result {
    pub fn table(&self) -> Table {
        let mut t = Table::new(&["N", "deg 4 err", "deg 10 err"]);
        let ns: Vec<usize> = {
            let mut v: Vec<usize> = self.points.iter().map(|p| p.n).collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        for n in ns {
            let get = |d: usize| {
                self.points
                    .iter()
                    .find(|p| p.n == n && p.degree == d)
                    .map(|p| format!("{:.3}", p.final_err))
                    .unwrap_or_else(|| "-".into())
            };
            t.row(&[format!("{n}"), get(4), get(10)]);
        }
        t
    }

    fn errs_for(&self, degree: usize) -> Vec<(usize, f64)> {
        let mut v: Vec<(usize, f64)> = self
            .points
            .iter()
            .filter(|p| p.degree == degree)
            .map(|p| (p.n, p.final_err))
            .collect();
        v.sort_unstable_by_key(|&(n, _)| n);
        v
    }
}

/// Run Fig. 4. scale = 1.0 → 20k iterations at N = 10, growing
/// proportionally with N (each node gets the same expected number of
/// updates — the paper's asymptotic regime where more nodes means more
/// total data actually consumed, not the same budget spread thinner).
///
/// The GLOBAL task is held fixed: the world always has 30 node
/// distributions and the test set is their full mixture; a system of N
/// nodes covers the first N distributions. "More nodes joining" then
/// genuinely adds information about the same objective — the paper's
/// question — rather than changing the test difficulty with N.
/// Per-node data is kept small (150 samples) so the error is
/// data-limited and the trend measurable.
pub fn run(scale: f64, seed: u64) -> Result<Fig4Result> {
    let base_iters = scaled(20_000, scale, 600);
    const WORLD: usize = 30;
    let mut points = Vec::new();
    for &n in &[10usize, 15, 20, 25, 30] {
        let iters = base_iters * n as u64 / 10;
        let eval_every = iters; // only the final error matters
        for &deg in &[4usize, 10] {
            let (all_shards, test) = synth_world(WORLD, 150, 512, seed);
            let shards: Vec<_> = all_shards.into_iter().take(n).collect();
            let cfg = TrainConfig::paper_default(n)
                .with_seed(seed ^ ((n * 31 + deg) as u64))
                .with_backend(super::backend_from_env());
            let rec = run_alg2(
                &cfg,
                make_regular(n, deg),
                shards,
                &test,
                iters,
                eval_every,
                &format!("n{n}d{deg}"),
            )?;
            points.push(Fig4Point {
                n,
                degree: deg,
                final_err: rec.final_err(),
            });
        }
    }
    Ok(Fig4Result {
        points,
        iters: base_iters,
    })
}

/// Paper-shape checks: decreasing trend with N (allowing noise), denser
/// graph no worse on average.
pub fn check_shape(r: &Fig4Result) -> Vec<String> {
    let mut notes = Vec::new();
    for deg in [4usize, 10] {
        let errs = r.errs_for(deg);
        let first = errs.first().unwrap().1;
        let last = errs.last().unwrap().1;
        notes.push(format!(
            "deg {deg}: err N={} → {first:.3}, N={} → {last:.3}",
            errs.first().unwrap().0,
            errs.last().unwrap().0
        ));
        if last <= first + 0.05 {
            notes.push(format!("OK: deg-{deg} error non-increasing with N (±noise)"));
        } else {
            notes.push(format!("MISMATCH: deg-{deg} error grew with N"));
        }
    }
    let mean = |deg: usize| {
        let errs = r.errs_for(deg);
        errs.iter().map(|&(_, e)| e).sum::<f64>() / errs.len() as f64
    };
    if mean(10) <= mean(4) + 0.02 {
        notes.push("OK: better-connected systems do no worse on average".into());
    } else {
        notes.push(format!(
            "MISMATCH: mean err deg10 {:.3} > deg4 {:.3}",
            mean(10),
            mean(4)
        ));
    }
    notes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_points_cover_grid() {
        let r = run(0.05, 3).unwrap();
        assert_eq!(r.points.len(), 10);
        assert!(r.points.iter().all(|p| (0.0..=1.0).contains(&p.final_err)));
        let t = r.table().render();
        assert!(t.contains("deg 10"));
    }
}
