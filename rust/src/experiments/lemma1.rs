//! Lemma 1 — η ≥ (1 − σ₂²)(k+1)/N for k-regular graphs, and the induced
//! Theorem-2 contraction factor C = η/N.
//!
//! We compute the spectral bound for a degree sweep on N = 30 and
//! measure the *empirical* per-projection contraction of DF(β) from
//! consensus-only runs (p_grad = 0, random init). The paper's claim to
//! validate: the bound (and hence convergence speed) increases with
//! degree, and the measured contraction rate follows the same ordering.

use anyhow::Result;

use crate::coordinator::{consensus, NativeBackend, TrainConfig, Trainer};
use crate::graph::spectral;
use crate::metrics::Table;

use super::{make_regular, scaled, synth_world};

pub struct Lemma1Row {
    pub degree: usize,
    pub sigma2: f64,
    pub eta_bound: f64,
    pub c_bound: f64,
    /// Measured mean DF(β^{k+1})/DF(β^k) over projection steps.
    pub measured_contraction: f64,
    /// Projections needed to shrink d^k by 10x.
    pub proj_per_decade: f64,
}

pub struct Lemma1Result {
    pub n: usize,
    pub rows: Vec<Lemma1Row>,
}

impl Lemma1Result {
    pub fn table(&self) -> Table {
        let mut t = Table::new(&[
            "degree k",
            "sigma2(A)",
            "eta bound",
            "C = eta/N",
            "measured DF ratio",
            "proj/decade",
        ]);
        for r in &self.rows {
            t.row(&[
                format!("{}", r.degree),
                format!("{:.4}", r.sigma2),
                format!("{:.5}", r.eta_bound),
                format!("{:.6}", r.c_bound),
                format!("{:.4}", r.measured_contraction),
                format!("{:.1}", r.proj_per_decade),
            ]);
        }
        t
    }
}

/// Measure the consensus-only contraction rate on one topology.
fn measure_contraction(n: usize, degree: usize, iters: u64, seed: u64) -> (f64, f64) {
    let g = make_regular(n, degree);
    let (shards, _test) = synth_world(n, 10, 64, seed);
    let cfg = TrainConfig::paper_default(n)
        .with_p_grad(0.0) // projections only: pure consensus dynamics
        .with_init_scale(1.0)
        .with_seed(seed);
    let mut t = Trainer::new(cfg, g.clone(), shards, NativeBackend::new(50, 10));
    let mut ratios = Vec::new();
    let mut df_prev = consensus::feasibility(&t.params(), &t.graph).df_sq;
    let d0 = t.consensus_distance();
    let mut k_decade = None;
    let mut slot_rng = crate::util::rng::Xoshiro256pp::seeded(seed ^ 0xFACE);
    for k in 0..iters {
        // Drive one projection via the public trainer API surface: a
        // single-slot run would re-evaluate; instead use the internal
        // selection by running one iteration.
        let m = slot_rng.index(n);
        let hood = t.graph.closed_neighborhood(m);
        let rows: Vec<Vec<f32>> = hood.iter().map(|&i| t.nodes[i].w.clone()).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let avg = crate::linalg::mean_of(&refs);
        for &i in &hood {
            t.nodes[i].w.copy_from_slice(&avg);
        }
        let df = consensus::feasibility(&t.params(), &t.graph).df_sq;
        if df_prev > 1e-12 {
            ratios.push(df / df_prev);
        }
        df_prev = df;
        if k_decade.is_none() && t.consensus_distance() < d0 / 10.0 {
            k_decade = Some(k + 1);
        }
        if df < 1e-16 {
            break;
        }
    }
    let mean_ratio = crate::util::stats::mean(&ratios);
    (mean_ratio, k_decade.map(|k| k as f64).unwrap_or(f64::NAN))
}

/// Run the Lemma 1 sweep. scale controls the measurement length.
pub fn run(scale: f64, seed: u64) -> Result<Lemma1Result> {
    let n = 30;
    let iters = scaled(2_000, scale, 150);
    let mut rows = Vec::new();
    for &degree in &[2usize, 4, 8, 14, 29] {
        let g = make_regular(n, degree);
        let s2 = spectral::sigma2(&g, 300);
        let eta = spectral::lemma1_eta_lower_bound(&g);
        let c = spectral::theorem2_c_bound(&g);
        let (measured, per_decade) = measure_contraction(n, degree, iters, seed);
        rows.push(Lemma1Row {
            degree,
            sigma2: s2,
            eta_bound: eta,
            c_bound: c,
            measured_contraction: measured,
            proj_per_decade: per_decade,
        });
    }
    Ok(Lemma1Result { n, rows })
}

/// Shape checks: bound increases with degree; measured contraction
/// improves (ratio decreases) with degree.
pub fn check_shape(r: &Lemma1Result) -> Vec<String> {
    let mut notes = Vec::new();
    let etas: Vec<f64> = r.rows.iter().map(|x| x.eta_bound).collect();
    let increasing = etas.windows(2).all(|w| w[1] >= w[0] - 1e-9);
    if increasing {
        notes.push("OK: Lemma-1 η bound increases with degree".into());
    } else {
        notes.push(format!("MISMATCH: η bound not monotone: {etas:?}"));
    }
    let first = r.rows.first().unwrap().measured_contraction;
    let last = r.rows.last().unwrap().measured_contraction;
    if last <= first {
        notes.push(format!(
            "OK: measured DF contraction improves with degree ({first:.3} → {last:.3})"
        ));
    } else {
        notes.push(format!(
            "MISMATCH: contraction worsened with degree ({first:.3} → {last:.3})"
        ));
    }
    // The complete graph must contract hardest (σ₂ = 0).
    let complete = r.rows.last().unwrap();
    if complete.sigma2 < 0.05 {
        notes.push("OK: complete graph σ₂ ≈ 0".into());
    } else {
        notes.push(format!("MISMATCH: complete-graph σ₂ = {}", complete.sigma2));
    }
    notes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lemma1_orderings_hold() {
        let r = run(0.2, 9).unwrap();
        let notes = check_shape(&r);
        assert!(
            notes.iter().all(|n| !n.starts_with("MISMATCH")),
            "{notes:?}"
        );
        // η bound within (0, 1].
        assert!(r.rows.iter().all(|x| x.eta_bound > 0.0 && x.eta_bound <= 1.0));
    }
}
