//! Head-to-head strategy comparison: every requested update strategy
//! runs the *same* event-driven SimNet schedule — identical seed,
//! shards, topology, speeds, latency/drop/partition model, stepsize and
//! evaluation cadence — so the consensus and accuracy curves differ
//! only by the update rule. The dump is one CSV holding every
//! strategy's full time series, tagged by a trailing `strategy` column
//! appended to the canonical run schema (append-only, so the shared
//! columns line up with every other run CSV).
//!
//! With the baseline included (`dasgd`), its curve is bit-identical to
//! a plain `dasgd sim` run of the same schedule: the strategy layer
//! adds no RNG draws and the baseline's math is byte-for-byte Eq.
//! (6)/(7).

use std::path::Path;

use crate::experiments::make_regular;
use crate::metrics::{run_schema, Recorder, Table};
use crate::node_logic::StrategyKind;
use crate::objective::Objective;
use crate::sim::{simnet_run_plan, SimConfig, SpeedModel};
use crate::transport::SimNetConfig;
use crate::workload::WorkloadPlan;

/// One fixed schedule shared by every strategy in the comparison.
#[derive(Clone, Debug)]
pub struct CompareConfig {
    /// The strategies to race (deduplicated order is the caller's).
    pub strategies: Vec<StrategyKind>,
    pub n: usize,
    pub degree: usize,
    /// The §II loss family every node optimizes.
    pub objective: Objective,
    pub p_grad: f64,
    /// Virtual seconds to simulate.
    pub horizon: f64,
    pub eval_every: f64,
    /// The network model (latency / drops / partitions).
    pub net: SimNetConfig,
    pub seed: u64,
    pub samples_per_node: usize,
    pub test_n: usize,
}

impl CompareConfig {
    /// All four strategies on a small lossy schedule (CI-sized).
    pub fn quick(seed: u64) -> Self {
        Self {
            strategies: StrategyKind::ALL.to_vec(),
            n: 12,
            degree: 4,
            objective: Objective::LogReg,
            p_grad: 0.5,
            horizon: 40.0,
            eval_every: 10.0,
            net: SimNetConfig::ideal(0.002),
            seed,
            samples_per_node: 40,
            test_n: 256,
        }
    }
}

/// One strategy's full curve plus its headline numbers.
#[derive(Debug)]
pub struct CompareCurve {
    pub strategy: StrategyKind,
    pub recorder: Recorder,
    pub updates: u64,
    pub grad_steps: u64,
    pub proj_steps: u64,
    /// Final d^k consensus distance.
    pub consensus: f64,
    /// Final prediction error at β̄.
    pub test_err: f64,
}

/// Run every strategy over the shared schedule.
pub fn run(cfg: &CompareConfig) -> crate::Result<Vec<CompareCurve>> {
    anyhow::ensure!(!cfg.strategies.is_empty(), "no strategies to compare");
    let g = make_regular(cfg.n, cfg.degree);
    let speeds = SpeedModel::homogeneous(cfg.n, 1.0);
    // One world for everyone: the plan is rebuilt per strategy but the
    // shards, test set, and every seed below are identical.
    let gen = crate::data::SyntheticGen::paper_default(cfg.n, cfg.seed);
    let mut rng = crate::util::rng::Xoshiro256pp::seeded(cfg.seed ^ 0xDA7A);
    let shards: Vec<crate::data::Dataset> = (0..cfg.n)
        .map(|i| gen.node_dataset(i, cfg.samples_per_node, &mut rng))
        .collect();
    let test = gen.global_test_set(cfg.test_n, &mut rng);
    let sim = SimConfig {
        p_grad: cfg.p_grad,
        stepsize: cfg.objective.default_stepsize(cfg.n),
        objective: cfg.objective,
        horizon: cfg.horizon,
        eval_every: cfg.eval_every,
        net: cfg.net.clone(),
        seed: cfg.seed,
    };
    let mut curves = Vec::with_capacity(cfg.strategies.len());
    for &kind in &cfg.strategies {
        let plan = WorkloadPlan::homogeneous(cfg.objective, shards.clone())
            .with_uniform_strategy(kind);
        let rep = simnet_run_plan(&g, &plan, &test, &speeds, &sim);
        let last = *rep.recorder.last().expect("simulation recorded snapshots");
        curves.push(CompareCurve {
            strategy: kind,
            recorder: rep.recorder,
            updates: rep.updates,
            grad_steps: rep.grad_steps,
            proj_steps: rep.proj_steps,
            consensus: last.consensus,
            test_err: last.test_err,
        });
    }
    Ok(curves)
}

/// Dump every curve into one CSV: the canonical run schema plus a
/// trailing `strategy` tag (append-only, never reordered).
pub fn write_csv(curves: &[CompareCurve], path: impl AsRef<Path>) -> std::io::Result<()> {
    let schema = run_schema().with("strategy");
    let mut w = schema.create(path)?;
    for c in curves {
        for r in &c.recorder.records {
            let mut vals: Vec<String> = r.values().iter().map(|v| format!("{v}")).collect();
            vals.push(c.strategy.name().to_string());
            w.row_str(&vals)?;
        }
    }
    w.flush()
}

/// Render the headline numbers as a table.
pub fn table(curves: &[CompareCurve]) -> Table {
    let mut t = Table::new(&["strategy", "updates", "grad", "proj", "d^k", "test err"]);
    for c in curves {
        t.row(&[
            c.strategy.name().to_string(),
            format!("{}", c.updates),
            format!("{}", c.grad_steps),
            format!("{}", c.proj_steps),
            format!("{:.3}", c.consensus),
            format!("{:.3}", c.test_err),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_strategies_race_on_one_schedule() {
        let cfg = CompareConfig::quick(7);
        let curves = run(&cfg).unwrap();
        assert_eq!(curves.len(), StrategyKind::ALL.len());
        for c in &curves {
            assert!(c.updates > 0, "{}: no updates", c.strategy);
            assert!(
                c.consensus.is_finite() && c.test_err.is_finite(),
                "{}: non-finite outcome",
                c.strategy
            );
        }
        let _ = table(&curves).render();
    }

    #[test]
    fn dasgd_curve_matches_a_plain_sim_of_the_same_schedule() {
        // The baseline raced through the strategy layer must be
        // bit-identical to the pre-refactor single-run path.
        let cfg = CompareConfig {
            strategies: vec![StrategyKind::Dasgd],
            ..CompareConfig::quick(11)
        };
        let curves = run(&cfg).unwrap();
        let g = make_regular(cfg.n, cfg.degree);
        let speeds = SpeedModel::homogeneous(cfg.n, 1.0);
        let gen = crate::data::SyntheticGen::paper_default(cfg.n, cfg.seed);
        let mut rng = crate::util::rng::Xoshiro256pp::seeded(cfg.seed ^ 0xDA7A);
        let shards: Vec<crate::data::Dataset> = (0..cfg.n)
            .map(|i| gen.node_dataset(i, cfg.samples_per_node, &mut rng))
            .collect();
        let test = gen.global_test_set(cfg.test_n, &mut rng);
        let sim = SimConfig {
            p_grad: cfg.p_grad,
            stepsize: cfg.objective.default_stepsize(cfg.n),
            objective: cfg.objective,
            horizon: cfg.horizon,
            eval_every: cfg.eval_every,
            net: cfg.net.clone(),
            seed: cfg.seed,
        };
        let rep = crate::sim::simnet_run(&g, &shards, &test, &speeds, &sim);
        assert_eq!(curves[0].updates, rep.updates);
        assert_eq!(
            curves[0].recorder.records.len(),
            rep.recorder.records.len()
        );
        for (a, b) in curves[0].recorder.records.iter().zip(&rep.recorder.records) {
            assert_eq!(a, b, "baseline curve diverged through the strategy layer");
        }
    }

    #[test]
    fn csv_has_one_block_per_strategy_with_the_trailing_tag() {
        let cfg = CompareConfig {
            strategies: vec![StrategyKind::Dasgd, StrategyKind::Rfast],
            horizon: 10.0,
            eval_every: 5.0,
            ..CompareConfig::quick(3)
        };
        let curves = run(&cfg).unwrap();
        let path = std::env::temp_dir().join("dasgd_compare_test.csv");
        write_csv(&curves, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let header = lines.next().unwrap();
        assert!(
            header.ends_with(",strategy"),
            "strategy must be the appended last column: {header}"
        );
        let rows: Vec<&str> = lines.collect();
        assert!(rows.iter().any(|l| l.ends_with(",dasgd")));
        assert!(rows.iter().any(|l| l.ends_with(",rfast")));
        let expect: usize = curves.iter().map(|c| c.recorder.records.len()).sum();
        assert_eq!(rows.len(), expect);
        std::fs::remove_file(path).ok();
    }
}
