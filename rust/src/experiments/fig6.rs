//! Fig. 6 — prediction error on the notMNIST-like corpus (256 features,
//! 10 letter classes), two 30-node systems (4-regular vs 15-regular),
//! with the centralized-SGD reference of §V-E.
//!
//! Paper reading: error converges to < 0.1 — "almost the same result of
//! a centralized version of SGD" — and both connectivities converge to
//! the *same* value (topology affects speed, not the limit).

use anyhow::Result;

use crate::baselines::CentralizedSgd;
use crate::coordinator::{StepSize, TrainConfig};
use crate::data::{Dataset, NotMnistGen};
use crate::metrics::{Recorder, Table};
use crate::util::rng::Xoshiro256pp;

use super::{make_regular, run_alg2, scaled};

pub struct Fig6Result {
    pub series: Vec<(String, Recorder)>,
    pub centralized: Recorder,
    pub iters: u64,
}

impl Fig6Result {
    pub fn table(&self) -> Table {
        let mut header = vec!["k".to_string()];
        for (n, _) in &self.series {
            header.push(format!("err ({n})"));
        }
        header.push("err (centralized)".into());
        let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
        let mut t = Table::new(&hdr);
        let rows = self.series[0].1.records.len();
        for r in 0..rows {
            let mut cells = vec![format!("{}", self.series[0].1.records[r].k)];
            for (_, rec) in &self.series {
                cells.push(format!("{:.3}", rec.records[r].test_err));
            }
            let c = self
                .centralized
                .records
                .get(r)
                .map(|x| format!("{:.3}", x.test_err))
                .unwrap_or_else(|| "-".into());
            cells.push(c);
            t.row(&cells);
        }
        t
    }
}

/// Build the notMNIST-like world: per-node shards + global test set.
pub fn notmnist_world(
    n: usize,
    samples_per_node: usize,
    test_n: usize,
    seed: u64,
) -> (Vec<Dataset>, Dataset) {
    let gen = NotMnistGen::new(n, seed);
    let mut rng = Xoshiro256pp::seeded(seed ^ 0x9071);
    let shards = (0..n)
        .map(|i| gen.node_dataset(i, samples_per_node, &mut rng))
        .collect();
    let test = gen.global_test_set(test_n, &mut rng);
    (shards, test)
}

/// Run Fig. 6. scale = 1.0 → 40k iterations.
pub fn run(scale: f64, seed: u64) -> Result<Fig6Result> {
    let n = 30;
    let iters = scaled(40_000, scale, 800);
    let eval_every = (iters / 16).max(1);
    let mut series = Vec::new();
    for k in [4usize, 15] {
        let (shards, test) = notmnist_world(n, 400, 512, seed);
        let cfg = TrainConfig {
            stepsize: StepSize::Poly {
                // Images are in [0,1] with ~40 active pixels: larger
                // effective step than the gaussian synthetic world.
                a: 3.0 * n as f32,
                tau: 8000.0,
                pow: 0.75,
            },
            ..TrainConfig::paper_default(n)
        }
        .with_seed(seed ^ (k as u64) << 4)
        .with_backend(super::backend_from_env());
        let rec = run_alg2(
            &cfg,
            make_regular(n, k),
            shards,
            &test,
            iters,
            eval_every,
            &format!("{k}-regular"),
        )?;
        series.push((format!("{k}-regular"), rec));
    }

    // Centralized reference on the pooled data.
    let (shards, test) = notmnist_world(n, 400, 512, seed);
    let mut pool = Dataset::new(256, 10);
    for s in &shards {
        pool.extend(s);
    }
    let mut sgd = CentralizedSgd::new(
        256,
        10,
        StepSize::Poly {
            a: 3.0,
            tau: 8000.0,
            pow: 0.75,
        },
        seed ^ 0xCE17,
    );
    let centralized = sgd.run(&pool, &test, iters, (iters / 16).max(1));

    Ok(Fig6Result {
        series,
        centralized,
        iters,
    })
}

/// Paper-shape checks.
pub fn check_shape(r: &Fig6Result) -> Vec<String> {
    let mut notes = Vec::new();
    let e_sparse = r.series[0].1.final_err();
    let e_dense = r.series[1].1.final_err();
    let e_central = r.centralized.final_err();
    notes.push(format!(
        "final err: 4-regular {e_sparse:.3}, 15-regular {e_dense:.3}, centralized {e_central:.3}"
    ));
    if (e_sparse - e_dense).abs() < 0.08 {
        notes.push("OK: both connectivities converge to ~the same error".into());
    } else {
        notes.push("MISMATCH: connectivities diverge in final error".into());
    }
    if e_sparse <= e_central + 0.08 && e_dense <= e_central + 0.08 {
        notes.push("OK: decentralized ≈ centralized final error (§V-E)".into());
    } else {
        notes.push(format!(
            "MISMATCH: decentralized ({:.3}/{:.3}) worse than centralized ({:.3})",
            e_sparse, e_dense, e_central
        ));
    }
    notes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_small_scale_learns_glyphs() {
        let r = run(0.06, 5).unwrap();
        let first = r.series[0].1.records.first().unwrap().test_err;
        let last = r.series[0].1.final_err();
        assert!(last < first, "err {first} -> {last}");
        // Centralized learns too.
        assert!(r.centralized.final_err() < first);
        let t = r.table().render();
        assert!(t.contains("centralized"));
    }
}
