//! Fig. 2 — "Distance to global consensus": d^k vs updates (log-y) for
//! two 30-node systems, 4-regular vs 15-regular.
//!
//! Paper reading: d^k falls fast (below 10 within 10k updates, with 50
//! features × 30 nodes) and the 15-regular graph converges faster —
//! consistent with Lemma 1.

use anyhow::Result;

use crate::coordinator::TrainConfig;
use crate::metrics::{Recorder, Table};

use super::{make_regular, run_alg2, scaled, synth_world};

pub struct Fig2Result {
    pub series: Vec<(String, Recorder)>,
    pub iters: u64,
}

impl Fig2Result {
    pub fn table(&self) -> Table {
        let mut header = vec!["k".to_string()];
        header.extend(self.series.iter().map(|(n, _)| format!("d^k ({n})")));
        let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
        let mut t = Table::new(&hdr);
        let rows = self.series[0].1.records.len();
        for r in 0..rows {
            let mut cells = vec![format!("{}", self.series[0].1.records[r].k)];
            for (_, rec) in &self.series {
                cells.push(format!("{:.3}", rec.records[r].consensus));
            }
            t.row(&cells);
        }
        t
    }
}

/// Run the Fig. 2 experiment. `scale` = 1.0 reproduces the paper's 20k
/// updates on 30 nodes; smaller scales shrink for benches/tests.
pub fn run(scale: f64, seed: u64) -> Result<Fig2Result> {
    let n = 30;
    let iters = scaled(20_000, scale, 400);
    let eval_every = (iters / 20).max(1);
    let mut series = Vec::new();
    for k in [4usize, 15] {
        let (shards, test) = synth_world(n, 200, 256, seed);
        let cfg = TrainConfig::paper_default(n)
            .with_seed(seed ^ k as u64)
            .with_init_scale(1.0) // start from disagreement, as plotted
            .with_backend(super::backend_from_env());
        let rec = run_alg2(
            &cfg,
            make_regular(n, k),
            shards,
            &test,
            iters,
            eval_every,
            &format!("{k}-regular"),
        )?;
        series.push((format!("{k}-regular"), rec));
    }
    Ok(Fig2Result { series, iters })
}

/// Paper-shape checks used by the bench harness and tests.
pub fn check_shape(r: &Fig2Result) -> Vec<String> {
    let mut notes = Vec::new();
    let (sparse, dense) = (&r.series[0].1, &r.series[1].1);
    let d0 = sparse.records.first().unwrap().consensus;
    let d_end_sparse = sparse.last().unwrap().consensus;
    let d_end_dense = dense.last().unwrap().consensus;
    notes.push(format!(
        "d^0 = {d0:.1}; final: 4-regular {d_end_sparse:.3}, 15-regular {d_end_dense:.3}"
    ));
    // "Faster" = reaches d0/20 at an earlier k. When both are already
    // below the threshold at the first post-init eval the run has
    // converged beyond the comparison's resolution — count that as OK.
    let threshold = d0 / 20.0;
    let k_sparse = sparse.k_to_consensus_below(threshold);
    let k_dense = dense.k_to_consensus_below(threshold);
    match (k_dense, k_sparse) {
        (Some(kd), Some(ks)) if kd <= ks => notes.push(format!(
            "OK: denser graph faster to d^0/20 (k {kd} ≤ {ks}; paper: 15-regular faster)"
        )),
        (Some(kd), Some(ks)) if kd <= ks + (r.iters / 10).max(1) => notes.push(format!(
            "OK (within noise): dense k {kd} vs sparse k {ks} to d^0/20"
        )),
        (Some(kd), Some(ks)) => notes.push(format!(
            "MISMATCH: denser graph should converge faster (k {kd} > {ks})"
        )),
        (Some(_), None) => {
            notes.push("OK: only the denser graph reached d^0/20".into())
        }
        (None, _) => notes.push("MISMATCH: dense graph never reached d^0/20".into()),
    }
    if d_end_sparse < d0 {
        notes.push("OK: d^k decreased (Theorem 1 feasibility)".into());
    } else {
        notes.push("MISMATCH: d^k did not decrease".into());
    }
    notes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_small_scale_shape() {
        let r = run(0.1, 7).unwrap();
        // 2k iterations: consensus must clearly contract from random init.
        let notes = check_shape(&r);
        assert!(
            notes.iter().all(|n| !n.starts_with("MISMATCH")),
            "{notes:?}"
        );
        let t = r.table().render();
        assert!(t.contains("15-regular"));
    }
}
