//! §II loss families beyond logistic regression: decentralized SVM
//! (hinge) and Lasso under the same Alg. 2 dynamics — gradient step on
//! the selected node w.p. p_grad, closed-neighborhood average otherwise.
//!
//! The parameter is a single (1, 50) row vector, so this exercises the
//! `hinge_step_b1` / `lasso_step_b1` artifacts (or their native mirrors)
//! inside the identical select→step/project loop, demonstrating that the
//! coordinator is loss-agnostic.

use anyhow::Result;

use crate::coordinator::{StepSize, TrainConfig};
use crate::graph::Graph;
use crate::metrics::Table;
use crate::model::{hinge_step_native, lasso_step_native};
use crate::runtime::Engine;
use crate::util::rng::Xoshiro256pp;

use super::{make_regular, scaled};

const DIM: usize = 50;

/// Which §II loss family to run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Loss {
    Hinge,
    Lasso,
}

/// One node's local world for the scalar-output families.
struct LossNode {
    w: Vec<f32>,
    xs: Vec<f32>,   // (n, DIM) flat
    ys: Vec<f32>,   // labels (±1) or regression targets
    rng: Xoshiro256pp,
}

pub struct LossRow {
    pub loss: &'static str,
    pub backend: &'static str,
    pub final_consensus: f64,
    pub initial_metric: f64,
    pub final_metric: f64,
}

/// Generate a binary-SVM or Lasso world with node-specific skew.
fn build_nodes(loss: Loss, n: usize, samples: usize, seed: u64) -> (Vec<LossNode>, Vec<f32>) {
    let mut root = Xoshiro256pp::seeded(seed);
    let true_w: Vec<f32> = (0..DIM).map(|_| root.gauss_f32(0.0, 1.0)).collect();
    let mut nodes = Vec::with_capacity(n);
    for i in 0..n {
        let mut rng = root.split(i as u64);
        // Node-specific input covariance skew (heterogeneous V_i).
        let scale_vec: Vec<f32> = (0..DIM).map(|_| 0.6 + rng.next_f32()).collect();
        let mut xs = Vec::with_capacity(samples * DIM);
        let mut ys = Vec::with_capacity(samples);
        for _ in 0..samples {
            let x: Vec<f32> = scale_vec
                .iter()
                .map(|s| s * rng.gauss_f32(0.0, 1.0))
                .collect();
            let dot = crate::linalg::dot(&true_w, &x);
            match loss {
                Loss::Hinge => ys.push(if dot + rng.gauss_f32(0.0, 0.5) > 0.0 {
                    1.0
                } else {
                    -1.0
                }),
                Loss::Lasso => ys.push(dot + rng.gauss_f32(0.0, 0.3)),
            }
            xs.extend(x);
        }
        nodes.push(LossNode {
            w: vec![0.0; DIM],
            xs,
            ys,
            rng,
        });
    }
    (nodes, true_w)
}

/// Global metric at the node-average w̄: hinge → misclassification rate
/// on a held-out set; lasso → RMSE against the generating model.
fn metric(loss: Loss, w: &[f32], true_w: &[f32], seed: u64) -> f64 {
    let mut rng = Xoshiro256pp::seeded(seed ^ 0x7E57);
    let trials = 2000;
    let mut acc = 0.0f64;
    for _ in 0..trials {
        let x: Vec<f32> = (0..DIM).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let truth = crate::linalg::dot(true_w, &x);
        let pred = crate::linalg::dot(w, &x);
        match loss {
            Loss::Hinge => {
                let y = if truth > 0.0 { 1.0 } else { -1.0 };
                if pred * y <= 0.0 {
                    acc += 1.0;
                }
            }
            Loss::Lasso => acc += ((pred - truth) as f64).powi(2),
        }
    }
    match loss {
        Loss::Hinge => acc / trials as f64,
        Loss::Lasso => (acc / trials as f64).sqrt(),
    }
}

fn consensus_of(nodes: &[LossNode]) -> f64 {
    let params: Vec<Vec<f32>> = nodes.iter().map(|n| n.w.clone()).collect();
    crate::coordinator::consensus::consensus_distance(&params)
}

fn mean_w(nodes: &[LossNode]) -> Vec<f32> {
    let rows: Vec<&[f32]> = nodes.iter().map(|n| n.w.as_slice()).collect();
    crate::linalg::mean_of(&rows)
}

/// Run one decentralized loss-family experiment.
#[allow(clippy::too_many_arguments)]
fn run_family(
    loss: Loss,
    engine: Option<&mut Engine>,
    graph: &Graph,
    iters: u64,
    cfg: &TrainConfig,
    lam: f32,
    seed: u64,
) -> Result<LossRow> {
    let n = graph.len();
    let (mut nodes, true_w) = build_nodes(loss, n, 120, seed);
    let initial_metric = metric(loss, &mean_w(&nodes), &true_w, seed);
    let mut rng = Xoshiro256pp::seeded(seed ^ 0xAB);
    let artifact = match loss {
        Loss::Hinge => "hinge_step_b1",
        Loss::Lasso => "lasso_step_b1",
    };
    let mut engine = engine;
    for k in 0..iters {
        let m = rng.index(n);
        if rng.next_f64() < cfg.p_grad {
            let lr = cfg.stepsize.at(k);
            let scale = 1.0 / n as f32;
            let node = &mut nodes[m];
            let idx = node.rng.index(node.ys.len());
            let x = node.xs[idx * DIM..(idx + 1) * DIM].to_vec();
            let y = node.ys[idx];
            match engine.as_deref_mut() {
                Some(e) => {
                    let outs = e.execute_f32(
                        artifact,
                        &[&node.w, &x, &[y], &[lr], &[scale], &[lam]],
                    )?;
                    node.w = outs.into_iter().next().unwrap();
                }
                None => {
                    match loss {
                        Loss::Hinge => {
                            hinge_step_native(&mut node.w, &[&x], &[y], lr, scale, lam);
                        }
                        Loss::Lasso => {
                            lasso_step_native(&mut node.w, &[&x], &[y], lr, scale, lam);
                        }
                    };
                }
            }
        } else {
            let hood = graph.closed_neighborhood(m);
            let rows: Vec<&[f32]> = hood.iter().map(|&i| nodes[i].w.as_slice()).collect();
            let avg = crate::linalg::mean_of(&rows);
            for &i in &hood {
                nodes[i].w.copy_from_slice(&avg);
            }
        }
    }
    Ok(LossRow {
        loss: match loss {
            Loss::Hinge => "SVM (hinge)",
            Loss::Lasso => "Lasso",
        },
        backend: if engine.is_some() { "pjrt" } else { "native" },
        final_consensus: consensus_of(&nodes),
        initial_metric,
        final_metric: metric(loss, &mean_w(&nodes), &true_w, seed),
    })
}

/// Run both §II families on both backends (PJRT skipped if artifacts
/// are missing).
pub fn run(scale: f64, seed: u64) -> Result<Vec<LossRow>> {
    let n = 12;
    let iters = scaled(8_000, scale, 500);
    let graph = make_regular(n, 4);
    // Hinge subgradients are bounded (‖g‖ ≤ ‖x‖), so an O(1) effective
    // step is fine; the Lasso data term is quadratic with curvature
    // λ_max(E[xxᵀ]) ≈ Σ E[x_d²] ≈ 60 here, so its stable step must sit
    // below 2/λ_max ≈ 0.03.
    let cfg_for = |loss: Loss| TrainConfig {
        stepsize: StepSize::Poly {
            a: match loss {
                Loss::Hinge => 0.4 * n as f32,
                Loss::Lasso => 0.02 * n as f32,
            },
            tau: 2000.0,
            pow: 0.75,
        },
        ..TrainConfig::paper_default(n)
    };
    let mut rows = Vec::new();
    for loss in [Loss::Hinge, Loss::Lasso] {
        rows.push(run_family(
            loss,
            None,
            &graph,
            iters,
            &cfg_for(loss),
            0.001,
            seed,
        )?);
    }
    if let Ok(mut engine) = Engine::load_default() {
        for loss in [Loss::Hinge, Loss::Lasso] {
            rows.push(run_family(
                loss,
                Some(&mut engine),
                &graph,
                iters,
                &cfg_for(loss),
                0.001,
                seed,
            )?);
        }
    }
    Ok(rows)
}

pub fn table(rows: &[LossRow]) -> Table {
    let mut t = Table::new(&[
        "loss family",
        "backend",
        "metric start",
        "metric final",
        "final d^k",
    ]);
    for r in rows {
        t.row(&[
            r.loss.into(),
            r.backend.into(),
            format!("{:.3}", r.initial_metric),
            format!("{:.3}", r.final_metric),
            format!("{:.3}", r.final_consensus),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hinge_and_lasso_learn_decentralized_native() {
        let rows = run(0.25, 5).unwrap();
        let native: Vec<&LossRow> =
            rows.iter().filter(|r| r.backend == "native").collect();
        assert_eq!(native.len(), 2);
        for r in native {
            assert!(
                r.final_metric < r.initial_metric * 0.6,
                "{}: {} -> {}",
                r.loss,
                r.initial_metric,
                r.final_metric
            );
            // Steps are still sizable at this short horizon, so only
            // require bounded disagreement (it tightens as α_k decays).
            assert!(r.final_consensus < 10.0, "{}: d={}", r.loss, r.final_consensus);
        }
    }
}
