//! §II loss families beyond logistic regression: decentralized SVM
//! (hinge) and Lasso under the same Alg. 2 dynamics.
//!
//! Since the objective redesign this experiment is a thin wrapper over
//! [`run_alg2`]: the *identical* `Trainer`/`StepBackend` code path that
//! reproduces the logreg figures runs hinge and lasso too — the only
//! input that changes is `TrainConfig::objective`. The PJRT rows execute
//! the compiled `hinge_step_b1` / `lasso_step_b1` Pallas artifacts;
//! native rows use the mirrored rust math. That the coordinator is
//! loss-agnostic is now a property of the API, not of a bespoke loop.

use anyhow::Result;

use crate::coordinator::{Backend, TrainConfig};
use crate::metrics::Table;
use crate::objective::Objective;
use crate::runtime::Manifest;

use super::{make_regular, run_alg2, scaled, synth_world};

pub struct LossRow {
    pub loss: &'static str,
    pub backend: &'static str,
    pub final_consensus: f64,
    /// Objective metric at k = 0 (hinge: misclassification rate of the
    /// binary reduction; lasso: prediction RMSE).
    pub initial_metric: f64,
    pub final_metric: f64,
}

fn run_family(obj: Objective, backend: Backend, scale: f64, seed: u64) -> Result<LossRow> {
    let n = 12;
    let iters = scaled(8_000, scale, 500);
    let (shards, test) = synth_world(n, 120, 512, seed);
    let cfg = TrainConfig::objective_default(obj, n)
        .with_backend(backend)
        // Start from disagreement so the consensus column is meaningful.
        .with_init_scale(0.5)
        .with_seed(seed ^ obj.name().as_bytes()[0] as u64);
    let rec = run_alg2(
        &cfg,
        make_regular(n, 4),
        shards,
        &test,
        iters,
        iters,
        obj.name(),
    )?;
    Ok(LossRow {
        loss: match obj {
            Objective::Hinge { .. } => "SVM (hinge)",
            Objective::Lasso { .. } => "Lasso",
            Objective::LogReg => "LogReg",
        },
        backend: match backend {
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
        },
        final_consensus: rec.last().unwrap().consensus,
        initial_metric: rec.records.first().unwrap().test_err,
        final_metric: rec.last().unwrap().test_err,
    })
}

/// Run both §II families on both backends (PJRT skipped if this build
/// has no engine or the artifact set is missing).
pub fn run(scale: f64, seed: u64) -> Result<Vec<LossRow>> {
    let mut rows = Vec::new();
    for obj in [Objective::hinge(), Objective::lasso()] {
        rows.push(run_family(obj, Backend::Native, scale, seed)?);
    }
    // Manifest-only probe: a full `Engine::load` would compile every
    // artifact just to be thrown away (each PJRT run loads its own
    // engine — PJRT handles are single-owner). The probe also checks
    // that the set actually contains the hinge/lasso kernels, so a
    // stale artifact dir skips cleanly instead of failing mid-run.
    let pjrt_ready = cfg!(feature = "pjrt")
        && Manifest::load(crate::runtime::default_artifact_dir())
            .map(|m| {
                // The full hinge/lasso kernel set: steps plus the
                // (1, 50)-shape eval + gossip artifacts the backend
                // now requires (regenerate stale dirs with
                // `make artifacts`).
                ["hinge_step_b1", "lasso_step_b1", "hinge_eval", "lasso_eval", "gossip_avg_dim50"]
                    .iter()
                    .all(|a| m.get(a).is_ok())
            })
            .unwrap_or(false);
    if pjrt_ready {
        for obj in [Objective::hinge(), Objective::lasso()] {
            rows.push(run_family(obj, Backend::Pjrt, scale, seed)?);
        }
    }
    Ok(rows)
}

pub fn table(rows: &[LossRow]) -> Table {
    let mut t = Table::new(&[
        "loss family",
        "backend",
        "metric start",
        "metric final",
        "final d^k",
    ]);
    for r in rows {
        t.row(&[
            r.loss.into(),
            r.backend.into(),
            format!("{:.3}", r.initial_metric),
            format!("{:.3}", r.final_metric),
            format!("{:.3}", r.final_consensus),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hinge_and_lasso_learn_decentralized_native() {
        let rows = run(0.25, 5).unwrap();
        let native: Vec<&LossRow> =
            rows.iter().filter(|r| r.backend == "native").collect();
        assert_eq!(native.len(), 2);
        for r in native {
            assert!(
                r.final_metric < r.initial_metric * 0.8,
                "{}: {} -> {}",
                r.loss,
                r.initial_metric,
                r.final_metric
            );
            // Steps are still sizable at this short horizon, so only
            // require bounded disagreement (it tightens as α_k decays).
            assert!(r.final_consensus < 10.0, "{}: d={}", r.loss, r.final_consensus);
        }
    }
}
