//! Fig. 3 — "Prediction error" of β̄ vs iterations for two 30-node
//! systems, 2-regular vs 10-regular.
//!
//! Paper reading: error falls under 0.4 by 40k iterations (random guess
//! = 0.9 with 10 classes) and falls faster on the 10-regular graph.

use anyhow::Result;

use crate::coordinator::TrainConfig;
use crate::metrics::{Recorder, Table};

use super::{make_regular, run_alg2, scaled, synth_world};

pub struct Fig3Result {
    pub series: Vec<(String, Recorder)>,
    pub iters: u64,
}

impl Fig3Result {
    pub fn table(&self) -> Table {
        let mut header = vec!["k".to_string()];
        for (n, _) in &self.series {
            header.push(format!("err ({n})"));
        }
        let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
        let mut t = Table::new(&hdr);
        for r in 0..self.series[0].1.records.len() {
            let mut cells = vec![format!("{}", self.series[0].1.records[r].k)];
            for (_, rec) in &self.series {
                cells.push(format!("{:.3}", rec.records[r].test_err));
            }
            t.row(&cells);
        }
        t
    }
}

/// Run Fig. 3. scale = 1.0 → the paper's 40k iterations.
pub fn run(scale: f64, seed: u64) -> Result<Fig3Result> {
    let n = 30;
    let iters = scaled(40_000, scale, 800);
    let eval_every = (iters / 20).max(1);
    let mut series = Vec::new();
    for k in [2usize, 10] {
        let (shards, test) = synth_world(n, 500, 512, seed);
        let cfg = TrainConfig::paper_default(n)
            .with_seed(seed ^ (k as u64) << 8)
            .with_backend(super::backend_from_env());
        let rec = run_alg2(
            &cfg,
            make_regular(n, k),
            shards,
            &test,
            iters,
            eval_every,
            &format!("{k}-regular"),
        )?;
        series.push((format!("{k}-regular"), rec));
    }
    Ok(Fig3Result { series, iters })
}

/// Paper-shape checks.
pub fn check_shape(r: &Fig3Result) -> Vec<String> {
    let mut notes = Vec::new();
    let (sparse, dense) = (&r.series[0].1, &r.series[1].1);
    let e0 = sparse.records.first().unwrap().test_err;
    let e_sparse = sparse.final_err();
    let e_dense = dense.final_err();
    notes.push(format!(
        "err: start {e0:.3}, final 2-regular {e_sparse:.3}, 10-regular {e_dense:.3}"
    ));
    if e_sparse < e0 && e_dense < e0 {
        notes.push("OK: prediction error decreases with more iterations".into());
    } else {
        notes.push("MISMATCH: error did not decrease".into());
    }
    // Average the last third of eval points to de-noise the comparison.
    let tail = |rec: &Recorder| {
        let n = rec.records.len();
        let from = n - (n / 3).max(1);
        rec.records[from..]
            .iter()
            .map(|r| r.test_err)
            .sum::<f64>()
            / (n - from) as f64
    };
    if tail(dense) <= tail(sparse) + 0.02 {
        notes.push("OK: denser graph error ≤ sparser (paper: 10-regular faster)".into());
    } else {
        notes.push(format!(
            "MISMATCH: dense tail {:.3} > sparse tail {:.3}",
            tail(dense),
            tail(sparse)
        ));
    }
    notes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_small_scale_error_decreases() {
        let r = run(0.08, 11).unwrap();
        let notes = check_shape(&r);
        // At tiny scale only require the decrease property.
        assert!(
            notes.iter().any(|n| n.contains("error decreases")),
            "{notes:?}"
        );
    }
}
