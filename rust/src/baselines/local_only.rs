//! Local-only lower bound: every node trains on its own shard and never
//! communicates. Because node distributions differ (§V-A), the average
//! of purely-local models is biased — this quantifies the gap Alg. 2's
//! consensus closes. Objective-generic: the per-node loop runs any §II
//! loss family through the canonical
//! [`node_logic::sgd_step`](crate::node_logic::sgd_step).

use crate::coordinator::{consensus, StepSize};
use crate::data::Dataset;
use crate::node_logic::{self, Probe, Strategy};
use crate::objective::Objective;
use crate::util::rng::Xoshiro256pp;
use crate::workload::WorkloadPlan;

/// Train each node independently for `iters_per_node` steps of `obj`;
/// return (error metric of β̄ on the global test set, mean per-node
/// error metric on it). The metric is the objective's: misclassification
/// rate for logreg/hinge, RMSE for lasso. (A thin wrapper over
/// [`local_only_errors_plan`].)
pub fn local_only_errors_for(
    obj: Objective,
    shards: &[Dataset],
    test: &Dataset,
    stepsize: StepSize,
    iters_per_node: u64,
    seed: u64,
) -> (f64, f64) {
    let plan = WorkloadPlan::homogeneous(obj, shards.to_vec());
    local_only_errors_plan(&plan, test, stepsize, iters_per_node, seed)
}

/// Local-only lower bound with per-node construction from a
/// [`WorkloadPlan`]: each node trains *its own* objective on *its own*
/// shard. A node's error is measured under its own family; the mean
/// model's error follows the mixed-cohort convention
/// ([`Probe::mixed`]).
pub fn local_only_errors_plan(
    plan: &WorkloadPlan,
    test: &Dataset,
    stepsize: StepSize,
    iters_per_node: u64,
    seed: u64,
) -> (f64, f64) {
    let dim = plan.dim();
    let classes = plan.classes();
    let probe = Probe::mixed(&plan.objectives(), test);
    // One single-objective probe per distinct objective, λ included
    // (per-node metrics are measured under the node's own loss).
    let mut family_probes: Vec<(Objective, Probe)> = Vec::new();
    for o in plan.objectives() {
        if !family_probes.iter().any(|(e, _)| *e == o) {
            family_probes.push((o, Probe::new(o, test)));
        }
    }
    let mut root = Xoshiro256pp::seeded(seed);
    let mut params = Vec::with_capacity(plan.len());
    let mut per_node_err = 0.0f64;
    // Classic references run the canonical Eq. (6) rule through the
    // baseline strategy (the single entry point to it).
    let mut strategy = node_logic::StrategyKind::Dasgd.build(0.0);
    for i in 0..plan.len() {
        let obj = plan.objective(i);
        let mut rng = root.split(i as u64);
        let mut w = vec![0.0f32; plan.param_len()];
        for k in 0..iters_per_node {
            strategy.step_sample(
                obj,
                &mut w,
                plan.shard(i),
                &mut rng,
                dim,
                classes,
                stepsize.at(k),
                1.0,
            );
        }
        let fam = family_probes
            .iter()
            .find(|(o, _)| *o == obj)
            .expect("every node's objective has a probe");
        per_node_err += fam.1.eval(&w).1 as f64;
        params.push(w);
    }
    per_node_err /= plan.len() as f64;
    let mean = consensus::mean_param(&params);
    (probe.eval(&mean).1 as f64, per_node_err)
}

/// Logistic-regression shorthand (the paper's setting).
pub fn local_only_errors(
    shards: &[Dataset],
    test: &Dataset,
    stepsize: StepSize,
    iters_per_node: u64,
    seed: u64,
) -> (f64, f64) {
    local_only_errors_for(Objective::LogReg, shards, test, stepsize, iters_per_node, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGen;

    fn skewed_world(n: usize) -> (Vec<Dataset>, Dataset) {
        // Strong per-node skew: local training must underperform global.
        let gen = SyntheticGen::new(n, 10, 4, 2.0, 1.5, 0.3, 21);
        let mut rng = Xoshiro256pp::seeded(3);
        let shards = (0..n).map(|i| gen.node_dataset(i, 150, &mut rng)).collect();
        let test = gen.global_test_set(400, &mut rng);
        (shards, test)
    }

    #[test]
    fn local_models_are_biased_on_global_mixture() {
        let (shards, test) = skewed_world(8);
        let step = StepSize::Poly {
            a: 0.8,
            tau: 500.0,
            pow: 0.75,
        };
        let (avg_err, per_node_err) = local_only_errors(&shards, &test, step, 800, 5);
        // Each node fits its own skewed distribution: worse on the mixture
        // than random-ish improvement but clearly imperfect.
        assert!(per_node_err > 0.15, "per-node err {per_node_err}");
        // Errors are valid rates.
        assert!((0.0..=1.0).contains(&avg_err));
        assert!((0.0..=1.0).contains(&per_node_err));
    }

    #[test]
    fn objective_generic_local_runs() {
        let (shards, test) = skewed_world(4);
        for obj in [Objective::hinge(), Objective::lasso()] {
            let (avg, per_node) = local_only_errors_for(
                obj,
                &shards,
                &test,
                obj.default_stepsize(1),
                500,
                7,
            );
            assert!(avg.is_finite() && per_node.is_finite(), "{obj}");
            assert!(avg >= 0.0 && per_node >= 0.0, "{obj}");
        }
    }
}
