//! Local-only lower bound: every node trains on its own shard and never
//! communicates. Because node distributions differ (§V-A), the average
//! of purely-local models is biased — this quantifies the gap Alg. 2's
//! consensus closes.

use crate::coordinator::{consensus, StepSize};
use crate::data::Dataset;
use crate::model::LogReg;
use crate::util::rng::Xoshiro256pp;

/// Train each node independently for `iters_per_node` steps; return
/// (error of β̄ on the global test set, mean per-node error on it).
pub fn local_only_errors(
    shards: &[Dataset],
    test: &Dataset,
    stepsize: StepSize,
    iters_per_node: u64,
    seed: u64,
) -> (f64, f64) {
    let dim = shards[0].dim();
    let classes = shards[0].classes();
    let mut root = Xoshiro256pp::seeded(seed);
    let mut params = Vec::with_capacity(shards.len());
    let mut per_node_err = 0.0f64;
    let test_flat = test.features_flat();
    let test_labels = test.labels();
    for (i, shard) in shards.iter().enumerate() {
        let mut rng = root.split(i as u64);
        let mut model = LogReg::zeros(dim, classes);
        for k in 0..iters_per_node {
            let idx = rng.index(shard.len());
            let s = shard.sample(idx);
            model.sgd_step(&[s.features], &[s.label], stepsize.at(k), 1.0);
        }
        per_node_err += model.evaluate(test_flat, test_labels).error_rate() as f64;
        params.push(model.w);
    }
    per_node_err /= shards.len() as f64;
    let mean = consensus::mean_param(&params);
    let avg_model = LogReg::from_weights(dim, classes, mean);
    let avg_err = avg_model.evaluate(test_flat, test_labels).error_rate() as f64;
    (avg_err, per_node_err)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGen;

    #[test]
    fn local_models_are_biased_on_global_mixture() {
        let n = 8;
        // Strong per-node skew: local training must underperform global.
        let gen = SyntheticGen::new(n, 10, 4, 2.0, 1.5, 0.3, 21);
        let mut rng = Xoshiro256pp::seeded(3);
        let shards: Vec<Dataset> =
            (0..n).map(|i| gen.node_dataset(i, 150, &mut rng)).collect();
        let test = gen.global_test_set(400, &mut rng);
        let step = StepSize::Poly {
            a: 0.8,
            tau: 500.0,
            pow: 0.75,
        };
        let (avg_err, per_node_err) = local_only_errors(&shards, &test, step, 800, 5);
        // Each node fits its own skewed distribution: worse on the mixture
        // than random-ish improvement but clearly imperfect.
        assert!(per_node_err > 0.15, "per-node err {per_node_err}");
        // Errors are valid rates.
        assert!((0.0..=1.0).contains(&avg_err));
        assert!((0.0..=1.0).contains(&per_node_err));
    }
}
