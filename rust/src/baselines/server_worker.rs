//! The Fig. 1(a) server–worker (parameter-server) baseline with the
//! intro's straggler policy: each synchronized round, every worker
//! computes a gradient on the current global variable; the server waits
//! only for the fastest `1 − drop_frac` of workers ("the late workers
//! are simply ignored, which is equivalent to introducing noise"), then
//! averages their updates and broadcasts.
//!
//! Per-round virtual time (used by `crate::sim`) is the max compute time
//! among *surviving* workers — dropping stragglers trades gradient bias
//! for round latency, which is the paper's motivating tension.

use crate::coordinator::StepSize;
use crate::data::Dataset;
use crate::metrics::Recorder;
use crate::node_logic::{self, Counts, Probe, Strategy};
use crate::objective::Objective;
use crate::util::rng::Xoshiro256pp;
use crate::util::Stopwatch;
use crate::workload::WorkloadPlan;

#[derive(Clone, Debug)]
pub struct ServerWorkerConfig {
    pub stepsize: StepSize,
    /// The §II loss family the server optimizes.
    pub objective: Objective,
    pub rounds: u64,
    pub eval_every: u64,
    /// Fraction of slowest workers dropped each round (0 = fully sync).
    pub drop_frac: f64,
    /// Per-worker mean compute times (heterogeneity); empty = uniform.
    pub worker_speed: Vec<f64>,
    pub seed: u64,
}

#[derive(Debug)]
pub struct ServerWorkerReport {
    pub recorder: Recorder,
    /// Total virtual time accumulated over rounds (straggler model).
    pub virtual_time: f64,
    pub messages: u64,
}

/// Run the parameter-server baseline with one objective on every
/// worker (a thin wrapper over [`server_worker_plan`]).
pub fn server_worker(
    shards: &[Dataset],
    test: &Dataset,
    cfg: &ServerWorkerConfig,
) -> ServerWorkerReport {
    let plan = WorkloadPlan::homogeneous(cfg.objective, shards.to_vec());
    server_worker_plan(&plan, test, cfg)
}

/// Parameter-server baseline with per-worker construction from a
/// [`WorkloadPlan`]: each surviving worker computes the gradient of
/// *its own* loss family at the current global variable (families must
/// share the parameter space — the plan guarantees it). `cfg.objective`
/// is superseded by the plan.
pub fn server_worker_plan(
    plan: &WorkloadPlan,
    test: &Dataset,
    cfg: &ServerWorkerConfig,
) -> ServerWorkerReport {
    let n = plan.len();
    assert!(n > 0);
    let dim = plan.dim();
    let classes = plan.classes();
    let mut root = Xoshiro256pp::seeded(cfg.seed);
    let mut rngs: Vec<Xoshiro256pp> = (0..n).map(|i| root.split(i as u64)).collect();
    let mut straggler_rng = root.split(u64::MAX);
    let speeds: Vec<f64> = if cfg.worker_speed.is_empty() {
        vec![1.0; n]
    } else {
        assert_eq!(cfg.worker_speed.len(), n);
        cfg.worker_speed.clone()
    };

    let mut global = vec![0.0f32; plan.param_len()];
    let keep = ((n as f64) * (1.0 - cfg.drop_frac)).ceil().max(1.0) as usize;
    let probe = Probe::mixed(&plan.objectives(), test);

    // Every worker's step is the canonical Eq. (6) rule, entered
    // through the baseline strategy.
    let mut strategy = node_logic::StrategyKind::Dasgd.build(0.0);
    let mut rec = Recorder::new("server_worker");
    let sw = Stopwatch::new();
    let mut virtual_time = 0.0f64;
    let mut messages = 0u64;

    let snap = |round: u64, w: &[f32], messages: u64, rec: &mut Recorder, sw: &Stopwatch| {
        let counts = Counts {
            grad_steps: round * keep as u64,
            messages,
            ..Counts::default()
        };
        // Single global variable: consensus distance is identically 0.
        rec.push(probe.snapshot_at(round, sw.elapsed_secs(), w, 0.0, &counts));
    };

    snap(0, &global, 0, &mut rec, &sw);
    for round in 1..=cfg.rounds {
        let lr = cfg.stepsize.at(round * keep as u64);
        // Draw per-worker compute times; keep the fastest `keep`.
        let mut times: Vec<(f64, usize)> = (0..n)
            .map(|i| (speeds[i] * straggler_rng.exponential(1.0), i))
            .collect();
        times.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let survivors = &times[..keep];
        virtual_time += survivors.last().unwrap().0;

        // Each survivor computes a gradient at the current global W and
        // sends it up; the server averages and broadcasts. The step is
        // the canonical Eq. (6) update at scale 1 on a copy of W.
        let mut delta = vec![0.0f32; global.len()];
        for &(_, i) in survivors {
            let mut local = global.clone();
            strategy.step_sample(
                plan.objective(i),
                &mut local,
                plan.shard(i),
                &mut rngs[i],
                dim,
                classes,
                lr,
                1.0,
            );
            for (d, (lw, gw)) in delta.iter_mut().zip(local.iter().zip(&global)) {
                *d += lw - gw;
            }
            messages += 2; // gradient up + broadcast down
        }
        for (gw, d) in global.iter_mut().zip(&delta) {
            *gw += d / keep as f32;
        }
        if round % cfg.eval_every == 0 || round == cfg.rounds {
            snap(round, &global, messages, &mut rec, &sw);
        }
    }
    ServerWorkerReport {
        recorder: rec,
        virtual_time,
        messages,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGen;

    fn setup(n: usize) -> (Vec<Dataset>, Dataset) {
        let gen = SyntheticGen::new(n, 10, 4, 2.5, 0.4, 0.3, 9);
        let mut rng = Xoshiro256pp::seeded(4);
        let shards = (0..n).map(|i| gen.node_dataset(i, 80, &mut rng)).collect();
        let test = gen.global_test_set(300, &mut rng);
        (shards, test)
    }

    #[test]
    fn server_worker_learns() {
        let (shards, test) = setup(8);
        let cfg = ServerWorkerConfig {
            stepsize: StepSize::Poly {
                a: 1.0,
                tau: 2000.0,
                pow: 0.75,
            },
            objective: Objective::LogReg,
            rounds: 300,
            eval_every: 100,
            drop_frac: 0.0,
            worker_speed: vec![],
            seed: 1,
        };
        let rep = server_worker(&shards, &test, &cfg);
        assert!(rep.recorder.last().unwrap().test_err < 0.5);
        assert!(rep.virtual_time > 0.0);
    }

    #[test]
    fn dropping_stragglers_cuts_round_time() {
        let (shards, test) = setup(10);
        let mk = |drop| {
            let cfg = ServerWorkerConfig {
                stepsize: StepSize::Constant(0.3),
                objective: Objective::LogReg,
                rounds: 200,
                eval_every: 200,
                drop_frac: drop,
                // One pathological straggler, 20x slower.
                worker_speed: {
                    let mut v = vec![1.0; 10];
                    v[0] = 20.0;
                    v
                },
                seed: 2,
            };
            server_worker(&shards, &test, &cfg).virtual_time
        };
        let full = mk(0.0);
        let dropped = mk(0.2);
        assert!(
            dropped < full * 0.6,
            "drop should cut time: full={full} dropped={dropped}"
        );
    }
}
