//! Synchronous decentralized subgradient descent (Nedić–Ozdaglar [14]):
//! in every slot **all** nodes take a gradient step and then average
//! with their neighbors using the doubly-stochastic local-averaging
//! matrix. This is the [3]/[14]/[15] family the paper contrasts with —
//! it converges well but requires global slot synchronization, which is
//! exactly what Alg. 2 removes. The virtual-time straggler comparison
//! (`crate::sim`) charges each round the *slowest* node's compute time.

use crate::coordinator::StepSize;
use crate::data::Dataset;
use crate::graph::Graph;
use crate::metrics::Recorder;
use crate::node_logic::{self, Counts, Probe, Strategy};
use crate::objective::Objective;
use crate::util::rng::Xoshiro256pp;
use crate::util::Stopwatch;
use crate::workload::WorkloadPlan;

#[derive(Clone, Debug)]
pub struct SyncDsgdConfig {
    pub stepsize: StepSize,
    /// The §II loss family every node optimizes.
    pub objective: Objective,
    pub rounds: u64,
    pub eval_every: u64,
    pub seed: u64,
}

#[derive(Debug)]
pub struct SyncDsgdReport {
    pub recorder: Recorder,
    /// Messages exchanged: every round, every edge carries 2 messages.
    pub messages: u64,
    /// Gradient evaluations: N per round.
    pub grad_steps: u64,
}

/// Run synchronous DSGD with one objective on every node; returns the
/// time series at β̄ (a thin wrapper over [`sync_dsgd_plan`]).
pub fn sync_dsgd(
    g: &Graph,
    shards: &[Dataset],
    test: &Dataset,
    cfg: &SyncDsgdConfig,
) -> SyncDsgdReport {
    let plan = WorkloadPlan::homogeneous(cfg.objective, shards.to_vec());
    sync_dsgd_plan(g, &plan, test, cfg)
}

/// Synchronous DSGD with per-node construction from a [`WorkloadPlan`]
/// (heterogeneous objectives and/or non-IID shards; `cfg.objective` is
/// superseded by the plan).
pub fn sync_dsgd_plan(
    g: &Graph,
    plan: &WorkloadPlan,
    test: &Dataset,
    cfg: &SyncDsgdConfig,
) -> SyncDsgdReport {
    assert_eq!(g.len(), plan.len());
    let n = g.len();
    let dim = plan.dim();
    let classes = plan.classes();
    let mut root = Xoshiro256pp::seeded(cfg.seed);
    let mut rngs: Vec<Xoshiro256pp> = (0..n).map(|i| root.split(i as u64)).collect();
    let mut params: Vec<Vec<f32>> = vec![vec![0.0; plan.param_len()]; n];
    let probe = Probe::mixed(&plan.objectives(), test);

    // Both phases run the paper-baseline rules (Eq. (6) step, matrix-A
    // average), entered through the baseline strategy.
    let mut strategy = node_logic::StrategyKind::Dasgd.build(0.0);
    let mut rec = Recorder::new("sync_dsgd");
    let sw = Stopwatch::new();
    let mut counts = Counts::default();

    rec.push(probe.snapshot(0, sw.elapsed_secs(), &params, &counts));
    for round in 1..=cfg.rounds {
        let lr = cfg.stepsize.at(round * n as u64); // comparable per-sample decay
        // Phase 1 (synchronized): every node takes one local SGD step
        // of *its own* objective (the same canonical Eq. (6) step every
        // engine runs).
        for i in 0..n {
            let mut w = std::mem::take(&mut params[i]);
            strategy.step_sample(
                plan.objective(i),
                &mut w,
                plan.shard(i),
                &mut rngs[i],
                dim,
                classes,
                lr,
                1.0 / n as f32,
            );
            params[i] = w;
            counts.grad_steps += 1;
        }
        // Phase 2 (synchronized): consensus averaging with matrix A.
        let mut next: Vec<Vec<f32>> = Vec::with_capacity(n);
        for i in 0..n {
            let hood = g.closed_neighborhood(i);
            let rows: Vec<&[f32]> = hood.iter().map(|&j| params[j].as_slice()).collect();
            let aux_rows: Vec<&[u8]> = vec![&[]; rows.len()];
            next.push(strategy.mix(&rows, &aux_rows).0);
            counts.messages += g.degree(i) as u64; // receive one vector per neighbor
        }
        params = next;
        if round % cfg.eval_every == 0 || round == cfg.rounds {
            rec.push(probe.snapshot(round, sw.elapsed_secs(), &params, &counts));
        }
    }
    SyncDsgdReport {
        recorder: rec,
        messages: counts.messages,
        grad_steps: counts.grad_steps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGen;
    use crate::graph::regular_circulant;

    #[test]
    fn sync_dsgd_converges_and_reaches_consensus() {
        let n = 8;
        let gen = SyntheticGen::new(n, 10, 4, 2.5, 0.4, 0.3, 5);
        let mut rng = Xoshiro256pp::seeded(2);
        let shards: Vec<Dataset> =
            (0..n).map(|i| gen.node_dataset(i, 80, &mut rng)).collect();
        let test = gen.global_test_set(300, &mut rng);
        let g = regular_circulant(n, 4);
        let cfg = SyncDsgdConfig {
            stepsize: StepSize::Poly {
                a: 8.0,
                tau: 3000.0,
                pow: 0.75,
            },
            objective: Objective::LogReg,
            rounds: 400,
            eval_every: 100,
            seed: 3,
        };
        let rep = sync_dsgd(&g, &shards, &test, &cfg);
        let last = rep.recorder.last().unwrap();
        assert!(last.test_err < 0.5, "err={}", last.test_err);
        // Averaging every round keeps consensus tight.
        assert!(last.consensus < 5.0, "consensus={}", last.consensus);
        assert_eq!(rep.grad_steps, 400 * n as u64);
        assert!(rep.messages > 0);
    }

    #[test]
    fn sync_dsgd_runs_a_mixed_plan() {
        use crate::workload::PlanSpec;
        let (plan, test) =
            PlanSpec::Mixed { alpha: 0.5 }.build(Objective::LogReg, 6, 60, 200, 3);
        let g = regular_circulant(6, 2);
        let cfg = SyncDsgdConfig {
            stepsize: Objective::lasso().default_stepsize(1),
            objective: Objective::LogReg, // superseded by the plan
            rounds: 150,
            eval_every: 50,
            seed: 5,
        };
        let rep = sync_dsgd_plan(&g, &plan, &test, &cfg);
        let last = rep.recorder.last().unwrap();
        assert!(last.test_loss.is_finite() && last.test_err.is_finite());
        // Every-round averaging keeps the mixed cohort at consensus.
        assert!(last.consensus < 5.0, "consensus={}", last.consensus);
        assert_eq!(rep.grad_steps, 150 * 6);
    }
}
